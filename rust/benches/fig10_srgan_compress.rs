//! Bench: regenerate Fig 10 (SRGAN ± compressed data across GPU scales).

fn main() {
    let t0 = std::time::Instant::now();
    let rows = fanstore::experiments::compression::run_fig10();
    fanstore::experiments::compression::report_fig10(&rows);
    println!("[bench fig10 done in {:.2}s]", t0.elapsed().as_secs_f64());
}
