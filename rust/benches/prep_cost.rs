//! Bench: regenerate the §6.3 data-preparation cost table (real packing
//! of scaled Table 2 datasets, ± LZSS, with full-scale extrapolation).

fn main() {
    let files = std::env::var("FANSTORE_PREP_FILES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3000);
    let t0 = std::time::Instant::now();
    let rows = fanstore::experiments::prep::run(files, 16).expect("prep");
    fanstore::experiments::prep::report(&rows);
    println!("[bench prep_cost done in {:.2}s]", t0.elapsed().as_secs_f64());
}
