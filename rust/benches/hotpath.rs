//! Hot-path microbenchmarks (the §Perf driver in EXPERIMENTS.md).
//!
//! Measures, with wall-clock timing loops:
//!   * LZSS compress/decompress rates per level (compressible + random)
//!     — the decompress rate here calibrates `FanStoreSim::decompress_bw`;
//!   * metadata hashtable lookup/stat/readdir throughput;
//!   * refcount-cache acquire/release (single-shard and sharded);
//!   * partition pack/scan throughput;
//!   * transport round-trip latency (the in-proc "MPI" path);
//!   * end-to-end in-proc read_all on a 4-node cluster;
//!   * aggregate same-node cached-read throughput vs. trainer thread count
//!     (the lock-decomposition scaling check: a node-global lock pins this
//!     at ~1×; the sharded/zero-copy hot path must scale);
//!   * remote-read pipeline: sync-per-file vs batched `ReadFiles` vs
//!     batched+background-prefetch on the same shuffled workload (the
//!     §5.4 overlap claim, end to end);
//!   * spilled-partition reads: reopen vs pooled-pread vs mmap backing
//!     (the syscall-lean `DiskStore` file path);
//!   * wire send: per-frame vs coalesced small-request streams over a
//!     loopback socket (the `CoalescingWriter` syscall amortization);
//!   * serve path: mmap-spilled read → framed response, zero-copy payload
//!     handles vs the materialize-an-owned-buffer baseline, with the
//!     global payload-memcpy counter proving 0 copies on the former;
//!   * reply send: the worker's reply fan-in, one write per reply vs the
//!     bridge's coalescing reply writer;
//!   * failover wrapper: round trips on the bare transport vs through a
//!     zero-probability `FaultInjector` (the healthy-path overhead of the
//!     PR 7 robustness layer — CI holds it within 5% of baseline).
//!
//! Besides the human-readable log, emits `BENCH_hotpath.json`
//! (section → ops/s and bytes/s) so the perf trajectory is tracked across
//! PRs.  Pass `--smoke` (CI) for reduced sizes with the same sections.

use std::sync::Arc;
use std::time::Instant;

use fanstore::cache::{RefCountCache, ShardedCache};
use fanstore::compress::{lzss, Codec};
use fanstore::config::ClusterConfig;
use fanstore::coordinator::Cluster;
use fanstore::metadata::record::{FileLocation, FileMeta, FileStat};
use fanstore::metadata::table::MetaTable;
use fanstore::net::tcp::{TcpServer, TcpTransport};
use fanstore::net::transport::{InProcTransport, NodeEndpoint, Request, Response, Transport};
use fanstore::net::wire::{self, CoalescingWriter};
use fanstore::partition::builder::{build_partitions, InputFile};
use fanstore::storage::disk::{DiskStore, SpillReadMode};
use fanstore::storage::payload::{payload_copies, Payload};
use fanstore::util::human_rate;
use fanstore::util::prng::Prng;
use fanstore::vfs::{OpenFlags, Vfs};
use fanstore::workload::datasets::synth_content;

/// (section, ops/s, bytes/s) — 0.0 where a rate does not apply.
type Entries = Vec<(String, f64, f64)>;

fn time<F: FnMut()>(mut f: F, iters: u32) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn bench_lzss(out: &mut Entries, smoke: bool) {
    println!("== LZSS codec ==");
    let buf = if smoke { 1 << 20 } else { 4 << 20 };
    let mut rng = Prng::new(42);
    let srgan_like = synth_content(&mut rng, buf, 0.72);
    let mut random = vec![0u8; buf];
    rng.fill_bytes(&mut random);

    for level in [1u8, 3, 5, 9] {
        let secs = time(
            || {
                std::hint::black_box(lzss::compress(&srgan_like, level));
            },
            3,
        );
        let c = lzss::compress(&srgan_like, level);
        let rate = srgan_like.len() as f64 / secs;
        println!(
            "  compress  level {level}: {:>12}  ratio {:.2}x (srgan-like 4 MiB)",
            human_rate(rate),
            srgan_like.len() as f64 / c.len() as f64
        );
        out.push((format!("lzss/compress_l{level}"), 0.0, rate));
    }
    let c5 = lzss::compress(&srgan_like, 5);
    let secs = time(
        || {
            std::hint::black_box(lzss::decompress(&c5, srgan_like.len()).unwrap());
        },
        10,
    );
    let rate = srgan_like.len() as f64 / secs;
    println!(
        "  decompress        : {:>12}  (raw-output rate; calibrates FanStoreSim::decompress_bw)",
        human_rate(rate)
    );
    out.push(("lzss/decompress".into(), 0.0, rate));
    let secs = time(
        || {
            std::hint::black_box(lzss::compress(&random, 5));
        },
        3,
    );
    let rate = random.len() as f64 / secs;
    println!(
        "  compress  random  : {:>12}  (incompressible reject path)",
        human_rate(rate)
    );
    out.push(("lzss/compress_random".into(), 0.0, rate));
}

fn bench_metadata(out: &mut Entries, smoke: bool) {
    println!("== metadata table ==");
    let mut t = MetaTable::new();
    let n = if smoke { 50_000u64 } else { 200_000u64 };
    let t0 = Instant::now();
    for i in 0..n {
        t.insert(
            &format!("/data/d{:03}/f{i:07}", i % 500),
            FileMeta {
                stat: FileStat::regular(i, 1000),
                location: FileLocation {
                    node: 0,
                    partition: 0,
                    offset: 0,
                    stored_len: 1000,
                    codec: Codec::None,
                },
                generation: 0,
            },
        );
    }
    let rate = n as f64 / t0.elapsed().as_secs_f64();
    println!("  insert: {rate:.0} entries/s ({n} files)");
    out.push(("metadata/insert".into(), rate, 0.0));
    let t0 = Instant::now();
    let mut found = 0u64;
    for i in 0..n {
        if t.stat(&format!("/data/d{:03}/f{i:07}", i % 500)).is_ok() {
            found += 1;
        }
    }
    let rate = n as f64 / t0.elapsed().as_secs_f64();
    println!("  stat:   {rate:.0} ops/s (hit {found})");
    out.push(("metadata/stat".into(), rate, 0.0));
    let t0 = Instant::now();
    let mut listed = 0usize;
    for d in 0..500 {
        listed += t.readdir(&format!("/data/d{d:03}")).unwrap().len();
    }
    let rate = 500.0 / t0.elapsed().as_secs_f64();
    println!("  readdir: {rate:.0} dirs/s ({listed} entries total, cached)");
    out.push(("metadata/readdir".into(), rate, 0.0));
}

fn bench_cache(out: &mut Entries, smoke: bool) {
    println!("== refcount cache ==");
    let mut c = RefCountCache::new();
    let n = if smoke { 100_000u64 } else { 500_000u64 };
    let t0 = Instant::now();
    for i in 0..n {
        let path = format!("/f{}", i % 1000);
        let pin = match c.acquire(&path) {
            Some(d) => d,
            None => c.insert(path.as_str(), vec![0u8; 64].into()),
        };
        c.release(&path, &pin);
    }
    let rate = n as f64 / t0.elapsed().as_secs_f64();
    println!("  acquire+release: {rate:.0} ops/s");
    out.push(("cache/acquire_release".into(), rate, 0.0));

    // sharded cache, 8 concurrent threads (the node-wide configuration)
    let c = Arc::new(ShardedCache::new());
    const THREADS: u64 = 8;
    let per_thread = n / THREADS;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let c = Arc::clone(&c);
            std::thread::spawn(move || {
                for i in 0..per_thread {
                    let path = format!("/f{}", (t * 7 + i) % 1000);
                    let pin = match c.acquire(&path) {
                        Some(d) => d,
                        None => c.insert(path.as_str(), vec![0u8; 64].into()),
                    };
                    c.release(&path, &pin);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let rate = (THREADS * per_thread) as f64 / t0.elapsed().as_secs_f64();
    println!("  sharded acquire+release ({THREADS} threads): {rate:.0} ops/s");
    out.push(("cache/sharded_acquire_release_8t".into(), rate, 0.0));
}

fn bench_partition(out: &mut Entries, smoke: bool) {
    println!("== partition pack/scan ==");
    let mut rng = Prng::new(7);
    let files: Vec<InputFile> = (0..if smoke { 400 } else { 2000 })
        .map(|i| {
            let mut data = vec![0u8; 32 * 1024];
            rng.fill_bytes(&mut data);
            InputFile {
                path: format!("d/f{i}"),
                data,
            }
        })
        .collect();
    let total: usize = files.iter().map(|f| f.data.len()).sum();
    let t0 = Instant::now();
    let (blobs, _) = build_partitions(&files, 8, fanstore::compress::Codec::None).unwrap();
    let rate = total as f64 / t0.elapsed().as_secs_f64();
    println!("  pack: {:>12} ({} files)", human_rate(rate), files.len());
    out.push(("partition/pack".into(), 0.0, rate));
    let t0 = Instant::now();
    let mut n = 0;
    for b in &blobs {
        n += fanstore::partition::format::PartitionReader::new(b)
            .unwrap()
            .read_all()
            .unwrap()
            .len();
    }
    let rate = total as f64 / t0.elapsed().as_secs_f64();
    println!("  scan: {:>12} ({n} entries)", human_rate(rate));
    out.push(("partition/scan".into(), 0.0, rate));
}

/// Echo worker replying with one shared 128 KiB payload: the Arc moves (or
/// serializes straight from the buffer on TCP) — the bytes are never cloned
/// on the serving side.
fn spawn_payload_echo(ep: NodeEndpoint) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let payload: Payload = vec![0u8; 128 * 1024].into();
        while let Ok(msg) = ep.inbox.recv() {
            if matches!(msg.req, Request::Shutdown) {
                msg.reply.send(Response::Ok);
                break;
            }
            msg.reply.send(Response::FileData {
                stored: payload.clone(),
            });
        }
    })
}

fn time_roundtrips(tp: &dyn Transport, iters: u32) -> f64 {
    let t0 = Instant::now();
    for i in 0..iters {
        let r = tp
            .call(
                0,
                1,
                Request::ReadFile {
                    path: format!("/f{i}").into(),
                },
            )
            .unwrap();
        std::hint::black_box(r);
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn bench_transport(out: &mut Entries, smoke: bool) {
    println!("== transport round trip (inproc vs TCP loopback) ==");
    // in-proc (mpsc) fabric
    let (tp, eps) = InProcTransport::fully_connected(2);
    let mut eps = eps.into_iter();
    let _e0 = eps.next().unwrap();
    let e1 = eps.next().unwrap();
    let handle = spawn_payload_echo(e1);
    let iters = if smoke { 4_000 } else { 20_000 };
    let per = time_roundtrips(&tp, iters);
    println!(
        "  inproc round trip (128 KiB payload): {:.1} µs, {:.0} req/s",
        per * 1e6,
        1.0 / per
    );
    out.push(("transport/roundtrip_128k".into(), 1.0 / per, 128.0 * 1024.0 / per));
    tp.shutdown_all();
    handle.join().unwrap();

    // real-socket fabric: same protocol through the wire codec + demux
    let (srv, ep) = TcpServer::bind(1, "127.0.0.1:0").expect("bind loopback");
    let handle = spawn_payload_echo(ep);
    let addr = srv.local_addr();
    // peer 0 is never dialed (the bench only calls node 1)
    let tcp = TcpTransport::connect(&[addr, addr]).expect("connect loopback");
    let iters = if smoke { 1_000 } else { 5_000 };
    let per = time_roundtrips(&tcp, iters);
    println!(
        "  tcp    round trip (128 KiB payload): {:.1} µs, {:.0} req/s",
        per * 1e6,
        1.0 / per
    );
    out.push(("transport/tcp_roundtrip_128k".into(), 1.0 / per, 128.0 * 1024.0 / per));
    tcp.shutdown_all();
    handle.join().unwrap();
    drop(srv);
}

/// Healthy-path overhead of the PR 7 robustness layer: the same in-proc
/// round-trip storm on the bare transport vs wrapped in a zero-probability
/// [`FaultInjector`] (kills only, none scheduled — the chaos tests' no-op
/// configuration).  The wrapper adds one PRNG roll plus a kill-vector
/// check per send; CI asserts `failover/healthy_path` stays >= 0.95x
/// `failover/baseline` ops/s.
fn bench_failover_overhead(out: &mut Entries, smoke: bool) {
    use fanstore::net::fault::{FaultInjector, FaultPlan};
    println!("== failover wrapper: bare transport vs zero-plan FaultInjector ==");
    let iters = if smoke { 4_000 } else { 20_000 };

    let (tp, eps) = InProcTransport::fully_connected(2);
    let mut eps = eps.into_iter();
    let _e0 = eps.next().unwrap();
    let handle = spawn_payload_echo(eps.next().unwrap());
    let per = time_roundtrips(&tp, iters);
    let base = 1.0 / per;
    println!("  baseline    : {:.1} µs, {base:.0} req/s", per * 1e6);
    out.push(("failover/baseline".into(), base, 128.0 * 1024.0 / per));
    tp.shutdown_all();
    handle.join().unwrap();

    let (tp, eps) = InProcTransport::fully_connected(2);
    let mut eps = eps.into_iter();
    let _e0 = eps.next().unwrap();
    let handle = spawn_payload_echo(eps.next().unwrap());
    let tp: Arc<dyn Transport> = Arc::new(tp);
    let inj = FaultInjector::new(Arc::clone(&tp), FaultPlan::none(), 0x7E57);
    let per = time_roundtrips(&inj, iters);
    let hp = 1.0 / per;
    println!(
        "  healthy_path: {:.1} µs, {hp:.0} req/s ({:.3}x of baseline)",
        per * 1e6,
        hp / base.max(1e-9)
    );
    out.push(("failover/healthy_path".into(), hp, 128.0 * 1024.0 / per));
    inj.shutdown_all();
    handle.join().unwrap();
}

/// Healthy-cluster cost of the PR 9 recovery layer: the same end-to-end
/// read sweep with recovery off (`probe_interval_ms = 0`) vs a recovery
/// thread per node probing aggressively (every 2 ms).  With every peer Up
/// the ticks early-out (no Down holders, empty reseed queue, nothing
/// under-replicated), so the measured cost is the keepalive traffic
/// itself; CI asserts `recovery/steady_state` stays >= 0.95x
/// `recovery/baseline` ops/s.
fn bench_recovery_overhead(out: &mut Entries, smoke: bool) {
    println!("== recovery layer: reads with prober off vs probing every 2 ms ==");
    let (n_files, size) = if smoke { (96, 32 * 1024) } else { (384, 128 * 1024) };
    let mut rng = Prng::new(41);
    let files: Vec<InputFile> = (0..n_files)
        .map(|i| {
            let mut data = vec![0u8; size];
            rng.fill_bytes(&mut data);
            InputFile {
                path: format!("train/f{i:04}"),
                data,
            }
        })
        .collect();
    let mut run = |probe_ms: u64| -> (f64, f64) {
        let cluster = Cluster::launch(
            &files,
            ClusterConfig {
                nodes: 3,
                partitions: 6,
                replication: 2,
                probe_interval_ms: probe_ms,
                ..Default::default()
            },
        )
        .unwrap();
        let mut vfs = cluster.client(0);
        let t0 = Instant::now();
        let mut bytes = 0u64;
        for _ in 0..2 {
            for f in &files {
                bytes += vfs
                    .read_all(&format!("/fanstore/user/{}", f.path))
                    .unwrap()
                    .len() as u64;
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        drop(vfs);
        cluster.shutdown();
        (2.0 * files.len() as f64 / secs, bytes as f64 / secs)
    };
    let (base, base_bw) = run(0);
    println!("  baseline    : {base:.0} files/s");
    out.push(("recovery/baseline".into(), base, base_bw));
    let (ss, ss_bw) = run(2);
    println!(
        "  steady_state: {ss:.0} files/s ({:.3}x of baseline)",
        ss / base.max(1e-9)
    );
    out.push(("recovery/steady_state".into(), ss, ss_bw));
}

fn bench_read_path(out: &mut Entries, smoke: bool) {
    println!("== in-proc end-to-end read_all (4 nodes) ==");
    let (n_files, size) = if smoke { (128, 32 * 1024) } else { (512, 128 * 1024) };
    let mut rng = Prng::new(9);
    let files: Vec<InputFile> = (0..n_files)
        .map(|i| {
            let mut data = vec![0u8; size];
            rng.fill_bytes(&mut data);
            InputFile {
                path: format!("train/f{i:04}"),
                data,
            }
        })
        .collect();
    let cluster = Cluster::launch(
        &files,
        ClusterConfig {
            nodes: 4,
            partitions: 8,
            ..Default::default()
        },
    )
    .unwrap();
    let mut vfs = cluster.client(0);
    let t0 = Instant::now();
    let mut bytes = 0u64;
    for f in &files {
        bytes += vfs
            .read_all(&format!("/fanstore/user/{}", f.path))
            .unwrap()
            .len() as u64;
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "  single client: {:>12}, {:.0} files/s (75% remote)",
        human_rate(bytes as f64 / secs),
        files.len() as f64 / secs
    );
    out.push((
        "read_path/single_client_4nodes".into(),
        files.len() as f64 / secs,
        bytes as f64 / secs,
    ));
    cluster.shutdown();
}

/// Aggregate cached-read throughput on ONE node as trainer threads grow.
///
/// All files are pinned in the node cache by a "pinner" client holding
/// open descriptors, so every read is a cache hit: this isolates the
/// node-local synchronization (sharded cache + atomic stats + zero-copy
/// Arc hand-off).  Under the old `Arc<Mutex<NodeState>>` the aggregate is
/// flat (~1×) regardless of thread count; the decomposed hot path must
/// scale.
fn bench_multithread_reads(out: &mut Entries, smoke: bool) {
    println!("== same-node cached reads vs trainer threads (1 node) ==");
    const FILE_KB: usize = 128;
    const N_FILES: usize = 64;
    let reads_per_thread: usize = if smoke { 128 } else { 512 };
    let mut rng = Prng::new(11);
    let files: Vec<InputFile> = (0..N_FILES)
        .map(|i| {
            let mut data = vec![0u8; FILE_KB * 1024];
            rng.fill_bytes(&mut data);
            InputFile {
                path: format!("train/f{i:04}"),
                data,
            }
        })
        .collect();
    let cluster = Cluster::launch(
        &files,
        ClusterConfig {
            nodes: 1,
            partitions: 2,
            ..Default::default()
        },
    )
    .unwrap();
    let paths: Vec<String> = files
        .iter()
        .map(|f| format!("/fanstore/user/{}", f.path))
        .collect();

    // pin everything so the measured loop is pure cache-hit traffic
    let mut pinner = cluster.client(0);
    let pins: Vec<_> = paths
        .iter()
        .map(|p| pinner.open(p, OpenFlags::Read).unwrap())
        .collect();

    let mut base = 0.0f64;
    for k in [1usize, 2, 4, 8, 16] {
        let paths = Arc::new(paths.clone());
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for t in 0..k {
            let mut vfs = cluster.client(0);
            let paths = Arc::clone(&paths);
            handles.push(std::thread::spawn(move || {
                let mut bytes = 0u64;
                for i in 0..reads_per_thread {
                    let p = &paths[(t * 17 + i) % paths.len()];
                    bytes += vfs.read_all(p).unwrap().len() as u64;
                }
                bytes
            }));
        }
        let mut bytes = 0u64;
        for h in handles {
            bytes += h.join().unwrap();
        }
        let secs = t0.elapsed().as_secs_f64();
        let ops = (k * reads_per_thread) as f64 / secs;
        let rate = bytes as f64 / secs;
        if k == 1 {
            base = rate;
        }
        println!(
            "  {k:>2} threads: {:>12} aggregate, {ops:.0} reads/s ({:.2}x vs 1 thread)",
            human_rate(rate),
            rate / base
        );
        out.push((format!("mt_cached_read/{k}_threads"), ops, rate));
    }

    for fd in pins {
        pinner.close(fd).unwrap();
    }
    cluster.shutdown();
}

/// Remote-read pipeline on a real 4-node cluster: the same shuffled
/// full-dataset read from node 0 (75% remote) three ways.  This is the
/// acceptance gauge for the batched+prefetch read path: amortized round
/// trips plus fetch/compute overlap must beat one synchronous round trip
/// per file.
fn bench_remote_pipeline(out: &mut Entries, smoke: bool) {
    println!("== remote read pipeline: sync vs batched vs batched+prefetch (4 nodes) ==");
    let (n_files, size, batch) = if smoke {
        (128usize, 32 << 10, 16usize)
    } else {
        (512usize, 128 << 10, 16usize)
    };
    let rows = fanstore::experiments::scaling::run_inproc_pipeline(4, n_files, size, batch)
        .expect("pipeline bench");
    let mut base = 0.0f64;
    for r in &rows {
        let fps = r.files_per_sec();
        if r.key == "sync_per_file" {
            base = fps;
        }
        println!(
            "  {:>17}: {:>12}, {fps:.0} files/s ({:.2}x vs sync), {} transport requests",
            r.mode,
            human_rate(r.bytes_per_sec()),
            fps / base.max(1e-9),
            r.requests_served
        );
        out.push((format!("remote_read/{}", r.key), fps, r.bytes_per_sec()));
    }
}

/// Spilled-partition read path: the same dataset read back through each
/// [`SpillReadMode`].  Small files make the per-read syscall budget the
/// dominant cost, which is exactly what the pooled-fd/mmap backing cuts:
/// reopen pays open+seek+read+close, pread pays one positioned read, mmap
/// pays none.
fn bench_spill_read(out: &mut Entries, smoke: bool) {
    println!("== spilled-partition reads: reopen vs pread vs mmap ==");
    let (n_files, size, rounds) = if smoke {
        (256usize, 4 << 10, 4u32)
    } else {
        (1024usize, 8 << 10, 16u32)
    };
    let mut rng = Prng::new(31);
    let files: Vec<InputFile> = (0..n_files)
        .map(|i| {
            let mut data = vec![0u8; size];
            rng.fill_bytes(&mut data);
            InputFile {
                path: format!("d/f{i:05}"),
                data,
            }
        })
        .collect();
    let (blobs, _) = build_partitions(&files, 4, fanstore::compress::Codec::None).unwrap();
    let base = std::env::temp_dir().join(format!("fanstore_bench_spill_{}", std::process::id()));
    std::fs::remove_dir_all(&base).ok();
    let paths: Vec<String> = files.iter().map(|f| format!("/b/{}", f.path)).collect();
    let mut base_rate = 0.0f64;
    for mode in [SpillReadMode::Reopen, SpillReadMode::Pread, SpillReadMode::Mmap] {
        let dir = base.join(mode.name());
        let mut store = DiskStore::on_disk_with_mode(&dir, mode).unwrap();
        for (pid, blob) in blobs.iter().enumerate() {
            store.load_partition(pid as u32, blob.clone(), "/b").unwrap();
        }
        let t0 = Instant::now();
        let mut bytes = 0u64;
        for _ in 0..rounds {
            for p in &paths {
                let (data, _) = store.read_stored(p).unwrap();
                bytes += data.len() as u64;
                std::hint::black_box(&data);
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        let ops = (rounds as usize * paths.len()) as f64 / secs;
        if mode == SpillReadMode::Reopen {
            base_rate = ops;
        }
        println!(
            "  {:>6}: {:>12}, {ops:.0} reads/s ({:.2}x vs reopen)",
            mode.name(),
            human_rate(bytes as f64 / secs),
            ops / base_rate.max(1e-9)
        );
        out.push((
            format!("spill_read/{}", mode.name()),
            ops,
            bytes as f64 / secs,
        ));
    }
    std::fs::remove_dir_all(&base).ok();
}

/// Tiered reads under a zipfian access skew: the same spilled store read
/// with a static placement (everything stays on disk — the pre-PR 8
/// behavior) vs after the heat-based migrator promotes the hot partitions
/// into RAM under a byte budget.  85% of reads land on the files of two
/// hot partitions; the budget admits exactly those two, so the promoted
/// leg serves the skewed majority as zero-copy RAM views while the cold
/// tail still pays the positioned read.  CI asserts
/// `tiered_read/heat_promoted` beats `tiered_read/static_spill` by a
/// margin — the acceptance gauge for dynamic placement actually paying
/// off on the access pattern it targets.
fn bench_tiered_read(out: &mut Entries, smoke: bool) {
    use fanstore::storage::{FreqPlacement, PlacementPolicy};
    println!("== tiered reads: static spill vs heat-promoted RAM (zipfian skew) ==");
    let (n_files, size, seq_len, rounds) = if smoke {
        (256usize, 4 << 10, 1024usize, 4u32)
    } else {
        (1024usize, 8 << 10, 4096usize, 16u32)
    };
    let mut rng = Prng::new(61);
    let files: Vec<InputFile> = (0..n_files)
        .map(|i| {
            let mut data = vec![0u8; size];
            rng.fill_bytes(&mut data);
            InputFile {
                path: format!("z/f{i:05}"),
                data,
            }
        })
        .collect();
    let (blobs, _) = build_partitions(&files, 4, fanstore::compress::Codec::None).unwrap();
    let base = std::env::temp_dir().join(format!("fanstore_bench_tier_{}", std::process::id()));
    std::fs::remove_dir_all(&base).ok();

    let load = |dir: &std::path::Path| {
        let mut store = DiskStore::on_disk_with_mode(dir, SpillReadMode::Pread).unwrap();
        for (pid, blob) in blobs.iter().enumerate() {
            store.load_partition(pid as u32, blob.clone(), "/z").unwrap();
        }
        store
    };

    // hot set = the files of partitions 0 and 1; the budget admits exactly
    // those two partitions, so the policy can promote the skew target and
    // nothing else
    let probe = load(&base.join("probe"));
    let budget: u64 = probe
        .take_heat()
        .iter()
        .filter(|h| h.pid < 2)
        .map(|h| h.bytes)
        .sum();
    let all: Vec<String> = files.iter().map(|f| format!("/z/{}", f.path)).collect();
    let hot: Vec<String> = all
        .iter()
        .filter(|p| probe.locate(p).unwrap().partition < 2)
        .cloned()
        .collect();
    drop(probe);

    // one fixed zipfian-ish sequence, shared by both legs: 85% hot
    let mut rng = Prng::new(67);
    let seq: Vec<&String> = (0..seq_len)
        .map(|_| {
            if rng.index(100) < 85 {
                &hot[rng.index(hot.len())]
            } else {
                &all[rng.index(all.len())]
            }
        })
        .collect();

    let sweep = |store: &DiskStore| -> (f64, f64) {
        let t0 = Instant::now();
        let mut bytes = 0u64;
        for _ in 0..rounds {
            for p in &seq {
                let (data, _) = store.read_stored(p).unwrap();
                bytes += data.len() as u64;
                std::hint::black_box(&data);
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        (
            (rounds as usize * seq.len()) as f64 / secs,
            bytes as f64 / secs,
        )
    };

    // leg 1: static placement — every read is a positioned disk read
    let store = load(&base.join("static"));
    let (static_ops, static_rate) = sweep(&store);
    println!(
        "  static_spill : {:>12}, {static_ops:.0} reads/s",
        human_rate(static_rate)
    );
    out.push(("tiered_read/static_spill".into(), static_ops, static_rate));
    drop(store);

    // leg 2: heat-based placement — warm the heat with one skewed pass,
    // let the frequency policy converge under the budget, then measure
    let store = load(&base.join("tiered"));
    let mut policy = FreqPlacement::new();
    for p in &seq {
        store.read_stored(p).unwrap();
    }
    let plan = policy.plan(&store.take_heat(), budget);
    for pid in plan.demote {
        store.demote_partition(pid).unwrap();
    }
    for pid in plan.promote {
        store.promote_partition(pid).unwrap();
    }
    assert_eq!(
        (store.partition_resident(0), store.partition_resident(1)),
        (Some(true), Some(true)),
        "the skew target must be RAM-resident before the measured sweep"
    );
    let hot_before = store.tier_counts().3;
    let (tiered_ops, tiered_rate) = sweep(&store);
    let hot_frac =
        (store.tier_counts().3 - hot_before) as f64 / (rounds as usize * seq.len()) as f64;
    println!(
        "  heat_promoted: {:>12}, {tiered_ops:.0} reads/s ({:.2}x vs static, {:.0}% RAM-tier hits)",
        human_rate(tiered_rate),
        tiered_ops / static_ops.max(1e-9),
        hot_frac * 100.0
    );
    out.push(("tiered_read/heat_promoted".into(), tiered_ops, tiered_rate));
    // emitted for CI: the measured sweep really was skew-majority-hot
    out.push(("tiered_read/hot_hit_fraction".into(), hot_frac, 0.0));
    drop(store);
    std::fs::remove_dir_all(&base).ok();
}

/// Wire small-request streams over a real loopback socket: one vectored
/// write per frame vs the coalescing writer (flush-on-full / queue-drain
/// rules, as `TcpTransport` uses per pooled connection).
fn bench_wire_send(out: &mut Entries, smoke: bool) {
    println!("== wire send: per-frame vs coalesced (loopback, small requests) ==");
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap();
    let sink = std::thread::spawn(move || {
        let (s, _) = listener.accept().expect("accept");
        // buffered reads keep the sink off the critical path: the sender's
        // syscall budget is what this section measures
        let mut r = std::io::BufReader::with_capacity(256 << 10, s);
        let mut n = 0u64;
        while wire::read_frame(&mut r).is_ok() {
            n += 1;
        }
        n
    });
    let mut stream = std::net::TcpStream::connect(addr).expect("connect loopback");
    stream.set_nodelay(true).ok();
    // a representative metadata storm: small stat requests
    let frames: Vec<wire::Frame> = (0..256u64)
        .map(|i| {
            wire::encode_request(
                i,
                0,
                &Request::StatOutput {
                    path: format!("/ckpt/shard_{i:04}.bin").into(),
                },
            )
        })
        .collect();
    let iters = if smoke { 20u32 } else { 100 };
    let total = iters as u64 * frames.len() as u64;

    let t0 = Instant::now();
    for _ in 0..iters {
        for f in &frames {
            f.write_to(&mut stream).expect("per-frame write");
        }
    }
    let per_frame = total as f64 / t0.elapsed().as_secs_f64();
    println!("  per-frame: {per_frame:.0} frames/s (1 writev per frame)");
    out.push(("wire_send/per_frame".into(), per_frame, 0.0));

    let mut cw = CoalescingWriter::new(stream);
    let t0 = Instant::now();
    for _ in 0..iters {
        for (i, f) in frames.iter().enumerate() {
            // writers stay queued through the storm; the last one flushes
            cw.write_frame(f, i + 1 != frames.len()).expect("coalesced write");
        }
    }
    cw.flush().expect("final flush");
    let coalesced = total as f64 / t0.elapsed().as_secs_f64();
    let (sent, flushes) = cw.counts();
    println!(
        "  coalesced: {coalesced:.0} frames/s ({:.2}x, {sent} frames in {flushes} flushes)",
        coalesced / per_frame.max(1e-9)
    );
    out.push(("wire_send/coalesced".into(), coalesced, 0.0));
    drop(cw); // EOF for the sink
    let received = sink.join().expect("sink thread");
    assert_eq!(received, 2 * total, "every frame decoded at the sink");
}

/// The serve path end to end on an mmap-spilled store: read_stored →
/// encode_response → vectored frame write, two ways.
///
/// * `zero_copy` — the payload rides as a region view from the map all the
///   way into the `writev`: the global payload-memcpy counter must not
///   move (the acceptance proof for the zero-copy serve path).
/// * `copy` — the pre-handle baseline: materialize an owned buffer before
///   framing, exactly one counted memcpy per serve.
///
/// Besides the rates, the *total memcpy counts* are emitted as their own
/// `*_payload_memcpys` sections (a count, not a rate — CI asserts 0 vs ≥1).
fn bench_serve_path(out: &mut Entries, smoke: bool) {
    println!("== serve path: mmap read → framed response, zero-copy vs copy ==");
    let (n_files, size, rounds) = if smoke {
        (128usize, 32 << 10, 4u32)
    } else {
        (512usize, 64 << 10, 16u32)
    };
    let mut rng = Prng::new(47);
    let files: Vec<InputFile> = (0..n_files)
        .map(|i| {
            let mut data = vec![0u8; size];
            rng.fill_bytes(&mut data);
            InputFile {
                path: format!("s/f{i:05}"),
                data,
            }
        })
        .collect();
    let (blobs, _) = build_partitions(&files, 4, fanstore::compress::Codec::None).unwrap();
    let dir = std::env::temp_dir().join(format!("fanstore_bench_serve_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let mut store = DiskStore::on_disk_with_mode(&dir, SpillReadMode::Mmap).unwrap();
    for (pid, blob) in blobs.iter().enumerate() {
        store.load_partition(pid as u32, blob.clone(), "/s").unwrap();
    }
    let paths: Vec<String> = files.iter().map(|f| format!("/s/{}", f.path)).collect();
    let total_ops = (rounds as usize * paths.len()) as u64;
    // probe: did the maps actually come up?  (mmap silently degrades to
    // pooled pread on exotic filesystems — then the copy-count contrast
    // below is vacuous and its asserts are skipped)
    let _ = store.read_stored(&paths[0]).unwrap();
    let mapped = store.spill_read_counts().2 > 0;
    // emitted so CI can condition the copy-count contrast on the maps
    // actually existing (1.0 = mapped, 0.0 = degraded to pread)
    out.push((
        "serve_path/mmap_active".into(),
        if mapped { 1.0 } else { 0.0 },
        0.0,
    ));

    // zero-copy: the payload handle goes straight into the frame
    let mut sink = std::io::sink();
    let copies_before = payload_copies();
    let t0 = Instant::now();
    let mut bytes = 0u64;
    for _ in 0..rounds {
        for p in &paths {
            let (payload, _) = store.read_stored(p).unwrap();
            bytes += payload.len() as u64;
            let frame = wire::encode_response(1, &Response::FileData { stored: payload });
            frame.write_to(&mut sink).unwrap();
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let zero_copies = payload_copies() - copies_before;
    let zc_ops = total_ops as f64 / secs;
    println!(
        "  zero_copy: {:>12}, {zc_ops:.0} serves/s, {zero_copies} payload memcpys",
        human_rate(bytes as f64 / secs)
    );
    out.push(("serve_path/zero_copy".into(), zc_ops, bytes as f64 / secs));
    out.push((
        "serve_path/zero_copy_payload_memcpys".into(),
        zero_copies as f64,
        0.0,
    ));
    assert_eq!(
        zero_copies, 0,
        "the zero-copy serve path must not memcpy payload bytes"
    );

    // baseline: force the payload into an owned buffer first (the pre-
    // Payload behavior — one memcpy per serve)
    let copies_before = payload_copies();
    let t0 = Instant::now();
    let mut bytes = 0u64;
    for _ in 0..rounds {
        for p in &paths {
            let (payload, _) = store.read_stored(p).unwrap();
            bytes += payload.len() as u64;
            let owned: Payload = payload.into_arc().into(); // the counted copy
            let frame = wire::encode_response(1, &Response::FileData { stored: owned });
            frame.write_to(&mut sink).unwrap();
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let copy_copies = payload_copies() - copies_before;
    let cp_ops = total_ops as f64 / secs;
    println!(
        "  copy     : {:>12}, {cp_ops:.0} serves/s ({:.2}x slower), {copy_copies} payload memcpys",
        human_rate(bytes as f64 / secs),
        zc_ops / cp_ops.max(1e-9)
    );
    out.push(("serve_path/copy".into(), cp_ops, bytes as f64 / secs));
    out.push((
        "serve_path/copy_payload_memcpys".into(),
        copy_copies as f64,
        0.0,
    ));
    assert!(
        !mapped || copy_copies >= total_ops,
        "the baseline must memcpy at least once per serve: {copy_copies} < {total_ops}"
    );
    drop(store);
    std::fs::remove_dir_all(&dir).ok();
}

/// Compressed serve path on an mmap-spilled store holding SRGAN-like
/// `.npy` inputs (0.72 redundancy — the paper's compressible class).
/// Three legs over the same files and the same framing sink:
///
/// * `raw` — partitions packed with `Codec::None`: every serve frames the
///   full raw bytes (the no-compression baseline and the net-byte
///   denominator).
/// * `wire_compressed` — partitions packed with `Codec::Lzss(5)`: the
///   serve frames the *stored* (compressed) bytes and decode belongs to
///   the consuming node.  Still zero-copy — the payload-memcpy counter is
///   emitted (`compress_serve/wire_compressed_payload_memcpys`) and must
///   stay 0.
/// * `rest_compressed` — same compressed store, but the server decodes
///   before framing (`read_raw`): what a compressed-at-rest /
///   raw-over-wire design would pay per serve.
///
/// Besides the rates, `compress_serve/raw_net_bytes` and
/// `compress_serve/wire_net_bytes` record the total frame bytes (body +
/// 4-byte prefix) each leg would put on the network; CI asserts the
/// wire-compressed leg moves ≥2x fewer bytes on this workload.
fn bench_compress_serve(out: &mut Entries, smoke: bool) {
    println!("== compressed serve: raw vs wire-compressed vs rest-compressed (mmap spill) ==");
    let (n_files, size, rounds) = if smoke {
        (32usize, 64 << 10, 2u32)
    } else {
        (128usize, 64 << 10, 8u32)
    };
    let mut rng = Prng::new(53);
    let files: Vec<InputFile> = (0..n_files)
        .map(|i| InputFile {
            path: format!("t/f{i:05}.npy"),
            data: synth_content(&mut rng, size, 0.72),
        })
        .collect();
    let raw_total: u64 = files.iter().map(|f| f.data.len() as u64).sum();
    let base = std::env::temp_dir().join(format!("fanstore_bench_cserve_{}", std::process::id()));
    std::fs::remove_dir_all(&base).ok();
    let paths: Vec<String> = files.iter().map(|f| format!("/c/{}", f.path)).collect();
    let total_ops = (rounds as usize * paths.len()) as u64;
    let mut sink = std::io::sink();

    // leg 1: no compression anywhere — the baseline and the denominator
    let (blobs, _) = build_partitions(&files, 4, Codec::None).unwrap();
    let mut store = DiskStore::on_disk_with_mode(&base.join("raw"), SpillReadMode::Mmap).unwrap();
    for (pid, blob) in blobs.iter().enumerate() {
        store.load_partition(pid as u32, blob.clone(), "/c").unwrap();
    }
    let mut raw_net_bytes = 0u64;
    let t0 = Instant::now();
    for _ in 0..rounds {
        for p in &paths {
            let (payload, _) = store.read_stored(p).unwrap();
            let frame = wire::encode_response(1, &Response::FileData { stored: payload });
            raw_net_bytes += frame.body_len() as u64 + 4;
            frame.write_to(&mut sink).unwrap();
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "  raw            : {:>12}, {:.0} serves/s, {raw_net_bytes} net bytes",
        human_rate(raw_net_bytes as f64 / secs),
        total_ops as f64 / secs
    );
    out.push(("compress_serve/raw".into(), total_ops as f64 / secs, raw_net_bytes as f64 / secs));
    drop(store);

    // leg 2: compressed at rest, compressed over the wire — the stored
    // form goes straight from the map into the frame, uncopied
    let (blobs, bstats) = build_partitions(&files, 4, Codec::Lzss(5)).unwrap();
    let mut store = DiskStore::on_disk_with_mode(&base.join("wire"), SpillReadMode::Mmap).unwrap();
    for (pid, blob) in blobs.iter().enumerate() {
        store.load_partition(pid as u32, blob.clone(), "/c").unwrap();
    }
    let copies_before = payload_copies();
    let mut wire_net_bytes = 0u64;
    let t0 = Instant::now();
    for _ in 0..rounds {
        for p in &paths {
            let (payload, _) = store.read_stored(p).unwrap();
            let frame = wire::encode_response(1, &Response::FileData { stored: payload });
            wire_net_bytes += frame.body_len() as u64 + 4;
            frame.write_to(&mut sink).unwrap();
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let wire_copies = payload_copies() - copies_before;
    println!(
        "  wire_compressed: {:>12}, {:.0} serves/s, {wire_net_bytes} net bytes \
         ({:.2}x fewer, ratio {:.2}x, {wire_copies} payload memcpys)",
        human_rate(wire_net_bytes as f64 / secs),
        total_ops as f64 / secs,
        raw_net_bytes as f64 / wire_net_bytes.max(1) as f64,
        bstats.ratio()
    );
    out.push((
        "compress_serve/wire_compressed".into(),
        total_ops as f64 / secs,
        wire_net_bytes as f64 / secs,
    ));
    out.push(("compress_serve/wire_compressed_payload_memcpys".into(), wire_copies as f64, 0.0));
    assert_eq!(
        wire_copies, 0,
        "serving compressed stored bytes must not memcpy payloads"
    );
    assert!(
        wire_net_bytes * 2 <= raw_net_bytes,
        "wire-compressed serves must move >=2x fewer network bytes on \
         compressible data: {wire_net_bytes} vs {raw_net_bytes}"
    );

    // leg 3: compressed at rest but decoded server-side before framing —
    // every serve pays the decompress plus frames the full raw bytes
    let mut rest_net_bytes = 0u64;
    let t0 = Instant::now();
    for _ in 0..rounds {
        for p in &paths {
            let raw = store.read_raw(p).unwrap();
            let frame = wire::encode_response(1, &Response::FileData { stored: raw.into() });
            rest_net_bytes += frame.body_len() as u64 + 4;
            frame.write_to(&mut sink).unwrap();
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "  rest_compressed: {:>12}, {:.0} serves/s, {rest_net_bytes} net bytes \
         (server-side decode)",
        human_rate(rest_net_bytes as f64 / secs),
        total_ops as f64 / secs
    );
    out.push((
        "compress_serve/rest_compressed".into(),
        total_ops as f64 / secs,
        rest_net_bytes as f64 / secs,
    ));
    out.push(("compress_serve/raw_net_bytes".into(), raw_net_bytes as f64, 0.0));
    out.push(("compress_serve/wire_net_bytes".into(), wire_net_bytes as f64, 0.0));
    assert!(
        rest_net_bytes as f64 >= raw_total as f64 * rounds as f64,
        "server-side decode must frame the full raw bytes"
    );
    drop(store);
    std::fs::remove_dir_all(&base).ok();
}

/// The worker's reply fan-in over a real loopback socket: a storm of small
/// `Meta`/`Ok`/`NotFound` replies written one frame per write vs through
/// the bridge's coalescing reply writer (replies with other requests still
/// outstanding stay buffered; the last outstanding one flushes).
fn bench_reply_send(out: &mut Entries, smoke: bool) {
    println!("== reply send: per-frame vs coalesced (loopback, small replies) ==");
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap();
    let sink = std::thread::spawn(move || {
        let (s, _) = listener.accept().expect("accept");
        let mut r = std::io::BufReader::with_capacity(256 << 10, s);
        let mut n = 0u64;
        while wire::read_frame(&mut r).is_ok() {
            n += 1;
        }
        n
    });
    let stream = std::net::TcpStream::connect(addr).expect("connect loopback");
    stream.set_nodelay(true).ok();
    // a fan-in burst: the replies a batched-resume stat storm produces
    let stat = FileStat::regular(1, 4096);
    let frames: Vec<wire::Frame> = (0..256u64)
        .map(|i| {
            let resp = match i % 3 {
                0 => Response::Meta {
                    stat,
                    origin: (i % 7) as u32,
                    generation: i,
                },
                1 => Response::Ok,
                _ => Response::Err(format!("ENOENT /ckpt/shard_{i:04}.bin")),
            };
            wire::encode_response(i, &resp)
        })
        .collect();
    let iters = if smoke { 20u32 } else { 100 };
    let total = iters as u64 * frames.len() as u64;

    let mut stream = stream;
    let t0 = Instant::now();
    for _ in 0..iters {
        for f in &frames {
            f.write_to(&mut stream).expect("per-frame reply write");
        }
    }
    let per_frame = total as f64 / t0.elapsed().as_secs_f64();
    println!("  per_frame: {per_frame:.0} replies/s (1 writev per reply)");
    out.push(("reply_send/per_frame".into(), per_frame, 0.0));

    // coalesced: all but the last reply of each burst observe another
    // outstanding request behind them (the bridge's inflight counter)
    let mut cw = CoalescingWriter::new(stream);
    let t0 = Instant::now();
    for _ in 0..iters {
        for (i, f) in frames.iter().enumerate() {
            cw.write_frame(f, i + 1 != frames.len()).expect("coalesced reply");
        }
    }
    cw.flush().expect("final flush");
    let coalesced = total as f64 / t0.elapsed().as_secs_f64();
    let (sent, flushes) = cw.counts();
    println!(
        "  coalesced: {coalesced:.0} replies/s ({:.2}x, {sent} replies in {flushes} flushes)",
        coalesced / per_frame.max(1e-9)
    );
    out.push(("reply_send/coalesced".into(), coalesced, 0.0));
    drop(cw); // EOF for the sink
    let received = sink.join().expect("sink thread");
    assert_eq!(received, 2 * total, "every reply decoded at the sink");
}

/// Write `BENCH_hotpath.json`: {"section": {"ops_per_sec": x, "bytes_per_sec": y}, ...}
fn write_json(entries: &Entries) {
    let mut s = String::from("{\n");
    for (i, (name, ops, bytes)) in entries.iter().enumerate() {
        let comma = if i + 1 == entries.len() { "" } else { "," };
        s.push_str(&format!(
            "  \"{name}\": {{\"ops_per_sec\": {ops:.1}, \"bytes_per_sec\": {bytes:.1}}}{comma}\n"
        ));
    }
    s.push_str("}\n");
    match std::fs::write("BENCH_hotpath.json", &s) {
        Ok(()) => println!("wrote BENCH_hotpath.json ({} sections)", entries.len()),
        Err(e) => eprintln!("could not write BENCH_hotpath.json: {e}"),
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!(
        "FanStore hot-path microbenchmarks{}",
        if smoke { " (smoke mode: reduced sizes)" } else { "" }
    );
    let mut entries = Entries::new();
    bench_lzss(&mut entries, smoke);
    bench_metadata(&mut entries, smoke);
    bench_cache(&mut entries, smoke);
    bench_partition(&mut entries, smoke);
    bench_spill_read(&mut entries, smoke);
    bench_tiered_read(&mut entries, smoke);
    bench_serve_path(&mut entries, smoke);
    bench_compress_serve(&mut entries, smoke);
    bench_wire_send(&mut entries, smoke);
    bench_reply_send(&mut entries, smoke);
    bench_transport(&mut entries, smoke);
    bench_failover_overhead(&mut entries, smoke);
    bench_recovery_overhead(&mut entries, smoke);
    bench_read_path(&mut entries, smoke);
    bench_multithread_reads(&mut entries, smoke);
    bench_remote_pipeline(&mut entries, smoke);
    write_json(&entries);
}
