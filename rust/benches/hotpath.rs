//! Hot-path microbenchmarks (the §Perf driver in EXPERIMENTS.md).
//!
//! Measures, with wall-clock timing loops:
//!   * LZSS compress/decompress rates per level (compressible + random)
//!     — the decompress rate here calibrates `FanStoreSim::decompress_bw`;
//!   * metadata hashtable lookup/stat/readdir throughput;
//!   * refcount-cache acquire/release;
//!   * partition pack/scan throughput;
//!   * transport round-trip latency (the in-proc "MPI" path);
//!   * end-to-end in-proc read_all on a 4-node cluster.

use std::time::Instant;

use fanstore::cache::RefCountCache;
use fanstore::compress::lzss;
use fanstore::config::ClusterConfig;
use fanstore::coordinator::Cluster;
use fanstore::metadata::record::{FileLocation, FileMeta, FileStat};
use fanstore::metadata::table::MetaTable;
use fanstore::net::transport::{InProcTransport, Request};
use fanstore::partition::builder::{build_partitions, InputFile};
use fanstore::util::human_rate;
use fanstore::util::prng::Prng;
use fanstore::vfs::Vfs;
use fanstore::workload::datasets::synth_content;

fn time<F: FnMut()>(mut f: F, iters: u32) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn bench_lzss() {
    println!("== LZSS codec ==");
    let mut rng = Prng::new(42);
    let srgan_like = synth_content(&mut rng, 4 << 20, 0.72);
    let mut random = vec![0u8; 4 << 20];
    rng.fill_bytes(&mut random);

    for level in [1u8, 3, 5, 9] {
        let secs = time(
            || {
                std::hint::black_box(lzss::compress(&srgan_like, level));
            },
            3,
        );
        let c = lzss::compress(&srgan_like, level);
        println!(
            "  compress  level {level}: {:>12}  ratio {:.2}x (srgan-like 4 MiB)",
            human_rate(srgan_like.len() as f64 / secs),
            srgan_like.len() as f64 / c.len() as f64
        );
    }
    let c5 = lzss::compress(&srgan_like, 5);
    let secs = time(
        || {
            std::hint::black_box(lzss::decompress(&c5, srgan_like.len()).unwrap());
        },
        10,
    );
    println!(
        "  decompress        : {:>12}  (raw-output rate; calibrates FanStoreSim::decompress_bw)",
        human_rate(srgan_like.len() as f64 / secs)
    );
    let secs = time(
        || {
            std::hint::black_box(lzss::compress(&random, 5));
        },
        3,
    );
    println!(
        "  compress  random  : {:>12}  (incompressible reject path)",
        human_rate(random.len() as f64 / secs)
    );
}

fn bench_metadata() {
    println!("== metadata table ==");
    let mut t = MetaTable::new();
    let n = 200_000u64;
    let t0 = Instant::now();
    for i in 0..n {
        t.insert(
            &format!("/data/d{:03}/f{i:07}", i % 500),
            FileMeta {
                stat: FileStat::regular(i, 1000),
                location: FileLocation {
                    node: 0,
                    partition: 0,
                    offset: 0,
                    stored_len: 1000,
                    compressed: false,
                },
            },
        );
    }
    println!(
        "  insert: {:.0} entries/s ({n} files)",
        n as f64 / t0.elapsed().as_secs_f64()
    );
    let t0 = Instant::now();
    let mut found = 0u64;
    for i in 0..n {
        if t.stat(&format!("/data/d{:03}/f{i:07}", i % 500)).is_ok() {
            found += 1;
        }
    }
    println!(
        "  stat:   {:.0} ops/s (hit {found})",
        n as f64 / t0.elapsed().as_secs_f64()
    );
    let t0 = Instant::now();
    let mut listed = 0usize;
    for d in 0..500 {
        listed += t.readdir(&format!("/data/d{d:03}")).unwrap().len();
    }
    println!(
        "  readdir: {:.0} dirs/s ({listed} entries total, cached)",
        500.0 / t0.elapsed().as_secs_f64()
    );
}

fn bench_cache() {
    println!("== refcount cache ==");
    let mut c = RefCountCache::new();
    let n = 500_000u64;
    let t0 = Instant::now();
    for i in 0..n {
        let path = format!("/f{}", i % 1000);
        if c.acquire(&path).is_none() {
            c.insert(&path, vec![0u8; 64]);
        }
        c.release(&path);
    }
    println!(
        "  acquire+release: {:.0} ops/s",
        n as f64 / t0.elapsed().as_secs_f64()
    );
}

fn bench_partition() {
    println!("== partition pack/scan ==");
    let mut rng = Prng::new(7);
    let files: Vec<InputFile> = (0..2000)
        .map(|i| {
            let mut data = vec![0u8; 32 * 1024];
            rng.fill_bytes(&mut data);
            InputFile {
                path: format!("d/f{i}"),
                data,
            }
        })
        .collect();
    let total: usize = files.iter().map(|f| f.data.len()).sum();
    let t0 = Instant::now();
    let (blobs, _) = build_partitions(&files, 8, fanstore::compress::Codec::None).unwrap();
    println!(
        "  pack: {:>12} ({} files)",
        human_rate(total as f64 / t0.elapsed().as_secs_f64()),
        files.len()
    );
    let t0 = Instant::now();
    let mut n = 0;
    for b in &blobs {
        n += fanstore::partition::format::PartitionReader::new(b)
            .unwrap()
            .read_all()
            .unwrap()
            .len();
    }
    println!(
        "  scan: {:>12} ({n} entries)",
        human_rate(total as f64 / t0.elapsed().as_secs_f64())
    );
}

fn bench_transport() {
    println!("== transport round trip ==");
    let (tp, eps) = InProcTransport::fully_connected(2);
    let mut eps = eps.into_iter();
    let _e0 = eps.next().unwrap();
    let e1 = eps.next().unwrap();
    let handle = std::thread::spawn(move || {
        while let Ok(msg) = e1.inbox.recv() {
            if matches!(msg.req, Request::Shutdown) {
                let _ = msg.reply.send(fanstore::net::transport::Response::Ok);
                break;
            }
            let _ = msg
                .reply
                .send(fanstore::net::transport::Response::FileData {
                    stored: vec![0u8; 128 * 1024],
                    raw_len: 128 * 1024,
                    compressed: false,
                });
        }
    });
    let iters = 20_000;
    let t0 = Instant::now();
    for i in 0..iters {
        let r = tp
            .call(
                0,
                1,
                Request::ReadFile {
                    path: format!("/f{i}"),
                },
            )
            .unwrap();
        std::hint::black_box(r);
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!(
        "  round trip (128 KiB payload): {:.1} µs, {:.0} req/s",
        per * 1e6,
        1.0 / per
    );
    tp.shutdown_all();
    handle.join().unwrap();
}

fn bench_read_path() {
    println!("== in-proc end-to-end read_all (4 nodes) ==");
    let mut rng = Prng::new(9);
    let files: Vec<InputFile> = (0..512)
        .map(|i| {
            let mut data = vec![0u8; 128 * 1024];
            rng.fill_bytes(&mut data);
            InputFile {
                path: format!("train/f{i:04}"),
                data,
            }
        })
        .collect();
    let cluster = Cluster::launch(
        &files,
        ClusterConfig {
            nodes: 4,
            partitions: 8,
            ..Default::default()
        },
    )
    .unwrap();
    let mut vfs = cluster.client(0);
    let t0 = Instant::now();
    let mut bytes = 0u64;
    for f in &files {
        bytes += vfs
            .read_all(&format!("/fanstore/user/{}", f.path))
            .unwrap()
            .len() as u64;
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "  single client: {:>12}, {:.0} files/s (75% remote)",
        human_rate(bytes as f64 / secs),
        files.len() as f64 / secs
    );
    cluster.shutdown();
}

fn main() {
    println!("FanStore hot-path microbenchmarks");
    bench_lzss();
    bench_metadata();
    bench_cache();
    bench_partition();
    bench_transport();
    bench_read_path();
}
