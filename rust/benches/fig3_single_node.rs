//! Bench: regenerate Fig 3 (single-node bandwidth + throughput on four
//! storage backends).  `cargo bench --bench fig3_single_node`
//! Optionally FANSTORE_SCALE=N divides the paper's file counts (default 8).

fn main() {
    let scale = std::env::var("FANSTORE_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let t0 = std::time::Instant::now();
    let rows = fanstore::experiments::single_node::run(scale);
    fanstore::experiments::single_node::report(&rows);
    println!("[bench fig3 done in {:.2}s, count scale 1/{scale}]", t0.elapsed().as_secs_f64());
}
