//! Bench: regenerate Fig 5 (benchmark scaling on the GPU cluster).

fn main() {
    let scale = std::env::var("FANSTORE_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let t0 = std::time::Instant::now();
    let res = fanstore::experiments::scaling::run(
        fanstore::experiments::scaling::ClusterKind::Gpu,
        scale,
        1.0,
    );
    fanstore::experiments::scaling::report(&res);
    let ablation = fanstore::experiments::scaling::run_replication_ablation(
        fanstore::experiments::scaling::ClusterKind::Gpu,
        16,
        (128 << 10) / scale.max(1),
        128 << 10,
    );
    fanstore::experiments::scaling::report_replication_ablation(&ablation, 16);
    println!("[bench fig5 done in {:.2}s, count scale 1/{scale}]", t0.elapsed().as_secs_f64());
}

// appended: the replication-factor ablation (DESIGN.md §4) shares this
// bench since it runs on the same GPU-cluster model.
