//! Bench: regenerate Fig 1 (global vs partitioned dataset view — REAL
//! training through FanStore + PJRT).  Needs `make artifacts` first.

fn main() {
    let dir = std::env::var("FANSTORE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.txt").exists() {
        eprintln!("fig1_views: artifacts/ missing — run `make artifacts` first");
        return;
    }
    let t0 = std::time::Instant::now();
    let engine = fanstore::runtime::Engine::load_subset(&dir, &["cnn_train_step", "cnn_eval_step"])
        .expect("engine");
    let runs = fanstore::experiments::views::run(&engine, 4, 640, 160, 6, None).expect("fig1");
    fanstore::experiments::views::report(&runs);
    println!("[bench fig1 done in {:.2}s]", t0.elapsed().as_secs_f64());
}
