//! Bench: regenerate Fig 8 (SRGAN init + train weak scaling, GPU cluster).

fn main() {
    let t0 = std::time::Instant::now();
    let series = fanstore::experiments::apps_scaling::run_fig8();
    fanstore::experiments::apps_scaling::report_series("Fig 8 (SRGAN)", &series);
    println!("[bench fig8 done in {:.2}s]", t0.elapsed().as_secs_f64());
}
