//! Bench: regenerate Fig 9 (FRNN weak scaling with broadcast replication).

fn main() {
    let t0 = std::time::Instant::now();
    let series = fanstore::experiments::apps_scaling::run_fig9();
    fanstore::experiments::apps_scaling::report_series("Fig 9 (FRNN)", &series);
    println!("[bench fig9 done in {:.2}s]", t0.elapsed().as_secs_f64());
}
