//! Bench: regenerate Fig 4 (application throughput on four backends).

fn main() {
    let t0 = std::time::Instant::now();
    let rows = fanstore::experiments::apps::run();
    fanstore::experiments::apps::report(&rows);
    println!("[bench fig4 done in {:.2}s]", t0.elapsed().as_secs_f64());
}
