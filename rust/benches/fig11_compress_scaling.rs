//! Bench: regenerate Fig 11 (relative compressed/uncompressed bandwidth
//! across CPU-cluster scales).

fn main() {
    let scale = std::env::var("FANSTORE_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);
    let t0 = std::time::Instant::now();
    let res = fanstore::experiments::compression::run_fig11(scale);
    fanstore::experiments::compression::report_fig11(&res);
    println!("[bench fig11 done in {:.2}s, count scale 1/{scale}]", t0.elapsed().as_secs_f64());
}
