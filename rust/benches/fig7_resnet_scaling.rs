//! Bench: regenerate Fig 7 (ResNet-50 weak scaling, GPU + CPU clusters,
//! with the SFS reference points).

fn main() {
    let t0 = std::time::Instant::now();
    let series = fanstore::experiments::apps_scaling::run_fig7();
    fanstore::experiments::apps_scaling::report_series("Fig 7 (ResNet-50)", &series);
    fanstore::experiments::apps_scaling::shape_checks_fig7(&series);
    println!("[bench fig7 done in {:.2}s]", t0.elapsed().as_secs_f64());
}
