//! Bench: regenerate Fig 6 (benchmark scaling on the 512-node CPU cluster).

fn main() {
    let scale = std::env::var("FANSTORE_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let t0 = std::time::Instant::now();
    let res = fanstore::experiments::scaling::run(
        fanstore::experiments::scaling::ClusterKind::Cpu,
        scale,
        1.0,
    );
    fanstore::experiments::scaling::report(&res);
    println!("[bench fig6 done in {:.2}s, count scale 1/{scale}]", t0.elapsed().as_secs_f64());
}
