//! Integration: the full three-layer path — FanStore reads feeding
//! AOT-compiled JAX/Pallas train steps via PJRT.  Skips cleanly when
//! `make artifacts` has not been run.

use fanstore::config::ClusterConfig;
use fanstore::coordinator::Cluster;
use fanstore::runtime::Engine;
use fanstore::trainer::data::gen_classification_dataset;
use fanstore::trainer::{train_cnn, DatasetView, TrainConfig};
use fanstore::vfs::Vfs;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.txt").exists()
}

fn launch(train: usize, test: usize, nodes: u32) -> (Cluster, Vec<String>, Vec<String>) {
    let mut files = gen_classification_dataset(train, "train", 31);
    files.extend(gen_classification_dataset(test, "test", 41));
    let cfg = ClusterConfig {
        nodes,
        partitions: nodes * 2,
        replicate_dirs: vec!["test".into()],
        ..Default::default()
    };
    let mount = cfg.mount.clone();
    let cluster = Cluster::launch(&files, cfg).unwrap();
    let train_paths = files
        .iter()
        .filter(|f| f.path.starts_with("train"))
        .map(|f| format!("{mount}/{}", f.path))
        .collect();
    let test_paths = files
        .iter()
        .filter(|f| f.path.starts_with("test"))
        .map(|f| format!("{mount}/{}", f.path))
        .collect();
    (cluster, train_paths, test_paths)
}

#[test]
fn train_through_fanstore_reduces_loss_and_checkpoints() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine =
        Engine::load_subset(artifacts_dir(), &["cnn_train_step", "cnn_eval_step"]).unwrap();
    let (cluster, train_paths, test_paths) = launch(320, 96, 2);
    let tc = TrainConfig {
        epochs: 2,
        ..Default::default()
    };
    let log = train_cnn(&cluster, &engine, &train_paths, &test_paths, &tc).unwrap();
    assert_eq!(log.epochs.len(), 2);
    let first = log.step_losses.first().copied().unwrap();
    let last = log.step_losses.last().copied().unwrap();
    assert!(last < first, "loss must drop: {first} -> {last}");
    assert!(log.final_test_acc() > 0.3, "acc {}", log.final_test_acc());

    // the checkpoints are real output files in the global namespace
    let mut vfs = cluster.client(1);
    let names = vfs.readdir("/ckpt").unwrap();
    assert_eq!(names.len(), 2, "one checkpoint per epoch: {names:?}");
    let blob = vfs.read_all(&format!("/ckpt/{}", names[0])).unwrap();
    // CNN surrogate has 277,802 f32 params = 1,111,208 bytes
    assert_eq!(blob.len() % 4, 0);
    assert!(blob.len() > 1_000_000);
    cluster.shutdown();
}

#[test]
fn global_view_no_worse_than_partitioned_per_epoch() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine =
        Engine::load_subset(artifacts_dir(), &["cnn_train_step", "cnn_eval_step"]).unwrap();
    let mut accs = Vec::new();
    for view in [DatasetView::Global, DatasetView::Partitioned] {
        let (cluster, train_paths, test_paths) = launch(320, 96, 4);
        let tc = TrainConfig {
            epochs: 2,
            view,
            checkpoint: false,
            ..Default::default()
        };
        let log = train_cnn(&cluster, &engine, &train_paths, &test_paths, &tc).unwrap();
        accs.push(
            log.epochs.iter().map(|e| e.test_acc).sum::<f32>() / log.epochs.len() as f32,
        );
        cluster.shutdown();
    }
    // Fig 1 shape: the global view converges at least as fast (mean
    // per-epoch test accuracy over the run).
    assert!(
        accs[0] >= accs[1] - 0.05,
        "global {} vs partitioned {}",
        accs[0],
        accs[1]
    );
}

#[test]
fn preprocess_artifact_matches_manifest_contract() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine = Engine::load_subset(artifacts_dir(), &["preprocess_batch"]).unwrap();
    let spec = engine.spec("preprocess_batch").unwrap().clone();
    use fanstore::runtime::tensor::{DType, Tensor};
    let imgs = Tensor::from_u8(
        &spec.inputs[0].dims,
        vec![200u8; spec.inputs[0].element_count()],
    );
    let flip = Tensor::zeros(DType::I32, &spec.inputs[1].dims);
    let out = engine.execute("preprocess_batch", &[imgs, flip]).unwrap();
    assert_eq!(out[0].dims, spec.outputs[0].dims);
    let vals = out[0].as_f32().unwrap();
    // all channels normalized: (200 - mean)/std stays within (0, 2.2)
    assert!(vals.iter().all(|v| *v > 0.0 && *v < 2.2));
}
