//! Migration under fire: the heat-based tier migrator (PR 8) must never
//! perturb the bytes a reader sees.  8 reader threads hammer a tiered
//! cluster with a zipfian-skewed, phase-shifting access pattern while a
//! churn thread drives `migrate_tick` and force-demotes partitions out
//! from under them — across every `SpillReadMode` and both fabrics — and
//! every read must come back byte-identical.  Afterwards the tier
//! counters must balance exactly: partitions start spilled, so
//! `promotions - demotions == RAM-resident partitions`, and
//! `migrated_bytes` is nonzero iff any migration ran.  A convergence test
//! proves the frequency policy pulls the hot partition into RAM (and
//! leaves untouched ones spilled), a decode-sharing test pins the
//! decoded side cache's once-per-generation guarantee under concurrent
//! opens, and a background-thread test proves the migrator promotes with
//! no manual ticks.

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use fanstore::compress::Codec;
use fanstore::config::{ClusterConfig, TransportKind};
use fanstore::coordinator::Cluster;
use fanstore::partition::builder::InputFile;
use fanstore::storage::disk::SpillReadMode;
use fanstore::storage::PlacementKind;
use fanstore::util::prng::Prng;
use fanstore::vfs::Vfs;

/// Unique scratch dir, removed on drop (hygiene: concurrent tests in one
/// process must not collide, leftovers must not poison reruns).
struct TempDir(std::path::PathBuf);

impl TempDir {
    fn new(tag: &str) -> TempDir {
        static SEQ: AtomicU32 = AtomicU32::new(0);
        let dir = std::env::temp_dir().join(format!(
            "fanstore_tier_{tag}_{}_{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::remove_dir_all(&dir).ok();
        TempDir(dir)
    }

    fn path_string(&self) -> String {
        self.0.to_string_lossy().into_owned()
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.0).ok();
    }
}

/// Mixed compressible / incompressible files so both stored shapes cross
/// the migration paths (promoted bytes are the *stored* bytes).
fn dataset(n: usize) -> Vec<InputFile> {
    let mut rng = Prng::new(0x7E1A);
    (0..n)
        .map(|i| {
            let mut data = vec![0u8; 300 + rng.index(2048)];
            if i % 2 == 0 {
                rng.fill_bytes(&mut data);
            } else {
                data.fill((i % 251) as u8);
            }
            InputFile {
                path: format!("train/c{}/f{i:04}.raw", i % 3),
                data,
            }
        })
        .collect()
}

const MODES: [SpillReadMode; 3] = [
    SpillReadMode::Reopen,
    SpillReadMode::Pread,
    SpillReadMode::Mmap,
];

/// Zipfian-ish pick: 70% of reads land in an 8-file hot window whose
/// position depends on `phase`, the rest are uniform over the dataset.
fn skewed_pick(rng: &mut Prng, phase: usize, n: usize) -> usize {
    if rng.index(10) < 7 {
        (phase * 24 + rng.index(8)) % n
    } else {
        rng.index(n)
    }
}

fn migration_under_fire(transport: TransportKind) {
    const NODES: u32 = 2;
    const PARTITIONS: u32 = 4;
    const THREADS: usize = 8;
    const ROUNDS: usize = 40;

    let files = dataset(48);
    let total: u64 = files.iter().map(|f| f.data.len() as u64).sum();
    let expect: Arc<Vec<(String, Vec<u8>)>> = Arc::new(
        files
            .iter()
            .map(|f| (format!("/fanstore/user/{}", f.path), f.data.clone()))
            .collect(),
    );

    for mode in MODES {
        let dir = TempDir::new(&format!("fire_{}_{}", transport.name(), mode.name()));
        let cluster = Cluster::launch(
            &files,
            ClusterConfig {
                nodes: NODES,
                partitions: PARTITIONS,
                codec: Codec::Lzss(3),
                spill_dir: Some(dir.path_string()),
                spill_read_mode: mode,
                // comfortably fits the hottest partition per node, tight
                // enough that cold ones have no business being resident
                ram_budget_bytes: total / 2,
                tier_policy: PlacementKind::Freq,
                // no background thread: the churn thread below owns the
                // migration schedule, so every run sees real churn
                migrate_interval_ms: 0,
                transport,
                ..Default::default()
            },
        )
        .unwrap();
        let states: Vec<_> = (0..NODES).map(|n| cluster.node_state(n)).collect();

        // churn thread: tick the policy AND force-demote partitions out
        // from under the readers, so both migration directions run while
        // reads are in flight
        let done = Arc::new(AtomicBool::new(false));
        let churn = {
            let states = states.clone();
            let done = Arc::clone(&done);
            std::thread::spawn(move || {
                // keep churning a few rounds past the readers so each
                // node's resident set provably gets force-demoted and
                // re-promoted at least once, however fast the reads ran
                let mut iter = 0u32;
                while !done.load(Ordering::Relaxed) || iter < 24 {
                    for s in &states {
                        s.migrate_tick();
                        // non-local pids error; already-spilled return Ok(0)
                        s.store.demote_partition(iter % PARTITIONS).ok();
                    }
                    iter += 1;
                    std::thread::sleep(Duration::from_millis(1));
                }
            })
        };

        let mut readers = Vec::new();
        for t in 0..THREADS {
            let mut vfs = cluster.client(t as u32 % NODES);
            let expect = Arc::clone(&expect);
            let name = mode.name();
            readers.push(std::thread::spawn(move || {
                let mut rng = Prng::new(0xF1E + t as u64);
                for phase in 0..2 {
                    for _ in 0..ROUNDS {
                        let k = skewed_pick(&mut rng, phase, expect.len());
                        let (path, want) = &expect[k];
                        assert_eq!(
                            &vfs.read_all(path).unwrap(),
                            want,
                            "{name}: bytes diverged under migration on {path}"
                        );
                    }
                }
            }));
        }
        for r in readers {
            r.join().expect("no reader observed torn bytes");
        }
        done.store(true, Ordering::Relaxed);
        churn.join().unwrap();

        // settle: one quiet tick per node, then a full sweep so promoted
        // partitions provably serve from the RAM tier
        for s in &states {
            s.migrate_tick();
        }
        for n in 0..NODES {
            let mut vfs = cluster.client(n);
            for (path, want) in expect.iter() {
                assert_eq!(&vfs.read_all(path).unwrap(), want, "settle sweep {path}");
            }
        }

        // exact counter algebra: every partition starts spilled, every
        // swap is counted once, so the tier ledger must reconcile with
        // live residency — no lost or phantom migrations under fire
        for (n, s) in states.iter().enumerate() {
            let (promos, demos, moved, _) = s.store.tier_counts();
            let resident = (0..PARTITIONS)
                .filter(|&pid| s.store.partition_resident(pid) == Some(true))
                .count() as u64;
            assert!(
                promos >= demos,
                "{}: node {n} demoted more than it ever promoted ({promos} vs {demos})",
                mode.name()
            );
            assert_eq!(
                promos - demos,
                resident,
                "{}: node {n} tier ledger does not reconcile with residency",
                mode.name()
            );
            assert_eq!(
                moved > 0,
                promos + demos > 0,
                "{}: node {n} migrated_bytes must move iff a migration ran",
                mode.name()
            );
            assert!(
                s.store.ram_resident_bytes() <= total / 2,
                "{}: node {n} RAM tier exceeds its budget",
                mode.name()
            );
        }

        let report = cluster.shutdown();
        let (promos, demos, hot): (u64, u64, u64) =
            report.per_node.iter().fold((0, 0, 0), |acc, s| {
                (
                    acc.0 + s.promotions,
                    acc.1 + s.demotions,
                    acc.2 + s.tier_hot_hits,
                )
            });
        assert!(promos > 0, "{}: churn must promote", mode.name());
        assert!(demos > 0, "{}: churn must demote", mode.name());
        assert!(
            hot > 0,
            "{}: promoted partitions must serve RAM-tier hits",
            mode.name()
        );
        // the spilled reads that did happen landed on the configured mode
        let spills: (u64, u64, u64) = report.per_node.iter().fold((0, 0, 0), |acc, s| {
            (
                acc.0 + s.spill_reads_reopen,
                acc.1 + s.spill_reads_pread,
                acc.2 + s.spill_reads_mmap,
            )
        });
        match mode {
            SpillReadMode::Reopen => assert_eq!((spills.1, spills.2), (0, 0)),
            SpillReadMode::Pread => assert_eq!((spills.0, spills.2), (0, 0)),
            SpillReadMode::Mmap => assert_eq!(spills.0, 0),
        }
    }
}

#[test]
fn migration_under_fire_inproc() {
    migration_under_fire(TransportKind::InProc);
}

#[test]
fn migration_under_fire_tcp() {
    migration_under_fire(TransportKind::TcpLoopback);
}

/// The frequency policy must converge the hot set into RAM: after skewed
/// reads and one tick, exactly the partition holding the hot files is
/// resident — untouched partitions (EWMA score zero) stay spilled no
/// matter how much budget is free — and subsequent hot reads are counted
/// as RAM-tier hits, one per read.
#[test]
fn freq_policy_converges_hot_partition_into_ram() {
    let files = dataset(32);
    let total: u64 = files.iter().map(|f| f.data.len() as u64).sum();
    let dir = TempDir::new("converge");
    let cluster = Cluster::launch(
        &files,
        ClusterConfig {
            nodes: 1,
            partitions: 4,
            codec: Codec::Lzss(3),
            spill_dir: Some(dir.path_string()),
            spill_read_mode: SpillReadMode::Pread,
            ram_budget_bytes: total, // budget is not the constraint here
            tier_policy: PlacementKind::Freq,
            migrate_interval_ms: 0,
            ..Default::default()
        },
    )
    .unwrap();
    let state = cluster.node_state(0);

    // group the store's paths by partition; heat only partition 0's files
    let mut by_pid: Vec<Vec<String>> = vec![Vec::new(); 4];
    for p in state.store.paths() {
        let at = state.store.locate(p).expect("indexed path locates");
        by_pid[at.partition as usize].push(p.clone());
    }
    assert!(by_pid.iter().all(|v| !v.is_empty()), "4 non-empty partitions");

    let mut vfs = cluster.client(0);
    for _ in 0..5 {
        for p in &by_pid[0] {
            vfs.read_all(p).unwrap();
        }
    }
    let (promoted, demoted) = state.migrate_tick();
    assert_eq!((promoted, demoted), (1, 0), "one hot partition, one move");
    assert_eq!(state.store.partition_resident(0), Some(true));
    for pid in 1..4 {
        assert_eq!(
            state.store.partition_resident(pid),
            Some(false),
            "partition {pid} was never read; score 0 must not promote"
        );
    }

    // every post-promotion hot read is a RAM-tier hit, exactly one each
    let (.., hot_before) = state.store.tier_counts();
    for p in &by_pid[0] {
        vfs.read_all(p).unwrap();
    }
    let (.., hot_after) = state.store.tier_counts();
    assert_eq!(
        hot_after - hot_before,
        by_pid[0].len() as u64,
        "each hot read serves from the RAM tier"
    );
    drop(vfs);
    cluster.shutdown();
}

/// The background migrator promotes on its own: with a live interval and
/// no manual ticks, skewed reads alone must pull a partition into RAM.
#[test]
fn background_migrator_promotes_without_manual_ticks() {
    let files = dataset(24);
    let total: u64 = files.iter().map(|f| f.data.len() as u64).sum();
    let dir = TempDir::new("bg");
    let cluster = Cluster::launch(
        &files,
        ClusterConfig {
            nodes: 1,
            partitions: 3,
            codec: Codec::Lzss(3),
            spill_dir: Some(dir.path_string()),
            spill_read_mode: SpillReadMode::Pread,
            ram_budget_bytes: total,
            tier_policy: PlacementKind::Freq,
            migrate_interval_ms: 2,
            ..Default::default()
        },
    )
    .unwrap();
    let state = cluster.node_state(0);
    let paths: Vec<String> = files
        .iter()
        .map(|f| format!("/fanstore/user/{}", f.path))
        .collect();

    let mut vfs = cluster.client(0);
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        for p in &paths {
            vfs.read_all(p).unwrap();
        }
        if state.store.tier_counts().0 > 0 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "background migrator never promoted despite sustained heat"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    drop(vfs);
    let report = cluster.shutdown(); // joins the migrator before snapshot
    assert!(report.per_node[0].promotions > 0);
}

/// Decoded side cache (PR 8 satellite): N concurrent opens of one hot
/// compressed file must share a single decompression.  The file is warmed
/// once (the only decode), then 8 threads open/read it simultaneously —
/// `decompressions` stays exactly 1 and every threaded open counts a
/// decoded-cache hit.  With no tiering configured, the tier ledger stays
/// all-zero.
#[test]
fn concurrent_opens_share_one_decompression() {
    const THREADS: usize = 8;
    let files = vec![InputFile {
        path: "train/c0/hot.raw".into(),
        data: vec![42u8; 16384], // highly compressible: stored Lzss-tagged
    }];
    let cluster = Arc::new(
        Cluster::launch(
            &files,
            ClusterConfig {
                nodes: 1,
                partitions: 1,
                codec: Codec::Lzss(5),
                ..Default::default()
            },
        )
        .unwrap(),
    );
    let path = "/fanstore/user/train/c0/hot.raw".to_string();

    // warm: the one and only decompression for this generation
    let mut vfs = cluster.client(0);
    assert_eq!(vfs.read_all(&path).unwrap(), files[0].data);
    drop(vfs);

    let barrier = Arc::new(Barrier::new(THREADS));
    let mut handles = Vec::new();
    for _ in 0..THREADS {
        let mut vfs = cluster.client(0);
        let barrier = Arc::clone(&barrier);
        let path = path.clone();
        let want = files[0].data.clone();
        handles.push(std::thread::spawn(move || {
            barrier.wait();
            assert_eq!(vfs.read_all(&path).unwrap(), want);
        }));
    }
    for h in handles {
        h.join().expect("no concurrent opener failed");
    }

    let cluster = Arc::try_unwrap(cluster).ok().expect("all clones dropped");
    let report = cluster.shutdown();
    let s = &report.per_node[0];
    assert_eq!(
        s.decompressions, 1,
        "N concurrent opens must share the warm decode"
    );
    assert_eq!(
        s.decoded_cache_hits, THREADS as u64,
        "every threaded open hits the decoded side cache"
    );
    // no spill tier, no policy: nothing can migrate (RAM-tier hits still
    // count — every read of an in-memory store is a hot hit by definition)
    assert_eq!(
        (s.promotions, s.demotions, s.migrated_bytes),
        (0, 0, 0),
        "tiering off: the migration ledger must stay zero"
    );
}
