//! Integration coverage for the batched remote-read protocol and the
//! asynchronous prefetch pipeline: per-file results inside one batch
//! (data / ENOENT / I/O fault), the VFS mini-batch hint, the background
//! pipeline's exact counter algebra under concurrent trainer threads, and
//! the unlink GC + output-metadata-cache satellites.

use std::sync::Arc;

use fanstore::compress::Codec;
use fanstore::config::ClusterConfig;
use fanstore::coordinator::Cluster;
use fanstore::net::transport::{FileFetch, Request, Response, Transport};
use fanstore::partition::builder::InputFile;
use fanstore::storage::disk::SpillReadMode;
use fanstore::util::prng::Prng;
use fanstore::vfs::Vfs;

fn inputs(n: usize, seed: u64) -> Vec<InputFile> {
    let mut rng = Prng::new(seed);
    (0..n)
        .map(|i| {
            let mut data = vec![0u8; 200 + 13 * i];
            rng.fill_bytes(&mut data);
            InputFile {
                path: format!("train/class{}/img{i:03}.raw", i % 4),
                data,
            }
        })
        .collect()
}

/// Deterministically find an output path whose consistent-hash home is
/// `home` under this cluster's placement.
fn path_with_home(cluster: &Cluster, prefix: &str, home: u32) -> String {
    for i in 0..10_000 {
        let p = format!("{prefix}{i}.bin");
        if cluster.placement.output_home(&p) == home {
            return p;
        }
    }
    panic!("no candidate path hashes to node {home}");
}

// ---------------------------------------------------------------------------
// Batched protocol edges
// ---------------------------------------------------------------------------

#[test]
fn readfiles_mixed_hit_enoent_and_duplicates_in_one_batch() {
    // nodes=2, partitions=2: file i -> partition i%2 -> node i%2
    let files = inputs(8, 1);
    let cluster = Cluster::launch(
        &files,
        ClusterConfig {
            nodes: 2,
            partitions: 2,
            ..Default::default()
        },
    )
    .unwrap();
    let resp = cluster
        .transport
        .call(
            0,
            1,
            Request::ReadFiles {
                paths: vec![
                    "/fanstore/user/train/class1/img001.raw".into(),
                    "/fanstore/user/train/ghost.raw".into(),
                    "/fanstore/user/train/class1/img001.raw".into(), // duplicate
                    "/fanstore/user/train/class3/img003.raw".into(),
                ],
            },
        )
        .unwrap();
    let got = resp.into_files_data().unwrap();
    assert_eq!(got.len(), 4, "one result per requested path, in order");
    for (slot, want_idx) in [(0usize, 1usize), (2, 1), (3, 3)] {
        match &got[slot].1 {
            FileFetch::Data { stored, .. } => {
                assert_eq!(&stored[..], &files[want_idx].data[..], "slot {slot}");
            }
            other => panic!("slot {slot}: unexpected {other:?}"),
        }
    }
    assert!(
        matches!(got[1].1, FileFetch::NotFound),
        "missing file is per-file ENOENT, not a batch failure: {:?}",
        got[1].1
    );
    // empty batch is a valid request
    match cluster
        .transport
        .call(0, 1, Request::ReadFiles { paths: vec![] })
        .unwrap()
    {
        Response::FilesData(v) => assert!(v.is_empty()),
        other => panic!("unexpected {other:?}"),
    }
    cluster.shutdown();
}

#[test]
fn readfiles_io_fault_is_not_enoent() {
    // spill-to-disk cluster; deleting the spilled partition files turns
    // node 1's reads into real I/O faults, which must surface per file as
    // Fault — never as NotFound.  Reopen mode is the one backing where a
    // deleted file is visible per read (pooled pread fds and mmap regions
    // deliberately keep the unlinked inode readable — the payload-handle
    // lifetime tests prove that side).
    let files = inputs(8, 2);
    let spill = std::env::temp_dir().join(format!("fanstore_bp_{}", std::process::id()));
    let cluster = Cluster::launch(
        &files,
        ClusterConfig {
            nodes: 2,
            partitions: 2,
            spill_dir: Some(spill.to_string_lossy().into_owned()),
            spill_read_mode: SpillReadMode::Reopen,
            ..Default::default()
        },
    )
    .unwrap();
    for entry in std::fs::read_dir(spill.join("node001")).unwrap() {
        std::fs::remove_file(entry.unwrap().path()).unwrap();
    }
    let resp = cluster
        .transport
        .call(
            0,
            1,
            Request::ReadFiles {
                paths: vec![
                    "/fanstore/user/train/class1/img001.raw".into(), // indexed, file gone
                    "/fanstore/user/train/ghost.raw".into(),         // never existed
                ],
            },
        )
        .unwrap();
    let got = resp.into_files_data().unwrap();
    assert!(
        matches!(got[0].1, FileFetch::Fault(_)),
        "deleted backing file must be an I/O fault: {:?}",
        got[0].1
    );
    assert!(
        matches!(got[1].1, FileFetch::NotFound),
        "unknown path stays ENOENT: {:?}",
        got[1].1
    );
    cluster.shutdown();
    std::fs::remove_dir_all(&spill).ok();
}

// ---------------------------------------------------------------------------
// VFS mini-batch hint (one ReadFiles per owner node)
// ---------------------------------------------------------------------------

#[test]
fn vfs_prefetch_hint_batches_and_opens_consume_it() {
    let files = inputs(32, 3);
    let cluster = Cluster::launch(
        &files,
        ClusterConfig {
            nodes: 4,
            partitions: 8,
            codec: Codec::Lzss(3), // exercise reader-side decode in the batch path
            ..Default::default()
        },
    )
    .unwrap();
    let paths: Vec<String> = files
        .iter()
        .map(|f| format!("/fanstore/user/{}", f.path))
        .collect();
    let mut vfs = cluster.client(0);
    for chunk in paths.chunks(8) {
        let mut hint: Vec<String> = chunk.to_vec();
        hint.push("/fanstore/user/train/ghost.raw".into()); // hint ignores bad paths
        hint.push(chunk[1].clone()); // duplicated remote path must not leak a pin
        vfs.prefetch(&hint).unwrap();
        for p in chunk {
            let want = &files[paths.iter().position(|q| q == p).unwrap()].data;
            assert_eq!(&vfs.read_all(p).unwrap(), want, "{p}");
        }
    }
    // the bogus path still fails with ENOENT at open time
    assert!(vfs.read_all("/fanstore/user/train/ghost.raw").is_err());
    drop(vfs);
    let st = cluster.node_state(0);
    assert_eq!(st.cache.resident_files(), 0, "all hint pins consumed/released");
    drop(st);
    let report = cluster.shutdown();
    let batched: u64 = report.per_node.iter().map(|s| s.batched_reads_served).sum();
    assert!(batched > 0, "mini-batch hints must use ReadFiles");
    // batching amortizes: way fewer requests than the 24 remote files
    assert!(
        report.requests_served < 24,
        "expected batched round trips, served {}",
        report.requests_served
    );
}

// ---------------------------------------------------------------------------
// Background pipeline: byte-exact under concurrency + exact counter algebra
// ---------------------------------------------------------------------------

#[test]
fn prefetch_pipeline_stress_exact_algebra() {
    const NODES: u32 = 3;
    const THREADS: usize = 4;
    const N_FILES: usize = 48;
    let files = inputs(N_FILES, 4);
    let cluster = Arc::new(
        Cluster::launch(
            &files,
            ClusterConfig {
                nodes: NODES,
                partitions: 6,
                prefetch_window: 8,
                prefetch_fetchers: 2,
                ..Default::default()
            },
        )
        .unwrap(),
    );
    let expect: Arc<Vec<(String, Vec<u8>)>> = Arc::new(
        files
            .iter()
            .map(|f| (format!("/fanstore/user/{}", f.path), f.data.clone()))
            .collect(),
    );

    // every node schedules the full sequence once, shuffled per node
    let mut orders = Vec::new();
    for node in 0..NODES {
        let mut order: Vec<usize> = (0..N_FILES).collect();
        Prng::new(100 + node as u64).shuffle(&mut order);
        cluster
            .prefetch_handle(node)
            .schedule(order.iter().map(|&i| expect[i].0.clone()));
        orders.push(order);
    }

    // K trainer threads per node split each node's sequence round-robin
    let mut handles = Vec::new();
    for node in 0..NODES {
        for t in 0..THREADS {
            let cluster = Arc::clone(&cluster);
            let expect = Arc::clone(&expect);
            let order = orders[node as usize].clone();
            handles.push(std::thread::spawn(move || {
                let mut vfs = cluster.prefetching_client(node);
                let mut reads = 0u64;
                for (k, &i) in order.iter().enumerate() {
                    if k % THREADS != t {
                        continue;
                    }
                    let (path, want) = &expect[i];
                    assert_eq!(&vfs.read_all(path).unwrap(), want, "{path}");
                    reads += 1;
                }
                reads
            }));
        }
    }
    let total_reads: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total_reads, NODES as u64 * N_FILES as u64);

    // snapshot the engine stats before stopping (stats go away with them)
    let pf_stats: Vec<_> = (0..NODES).map(|n| cluster.prefetch_stats(n)).collect();
    for node in 0..NODES {
        let pf = &pf_stats[node as usize];
        assert_eq!(pf.scheduled, N_FILES as u64, "node {node}: {pf:?}");
        assert_eq!(pf.failed, 0, "node {node}: no faults in this workload");
        assert_eq!(
            pf.claimed + pf.stolen,
            N_FILES as u64,
            "node {node}: every read claims or steals its path: {pf:?}"
        );
        assert_eq!(
            pf.picked + pf.stolen + pf.coalesced,
            N_FILES as u64,
            "node {node}: every scheduled path is picked, stolen, or coalesced: {pf:?}"
        );
    }
    cluster.stop_prefetchers();

    for node in 0..NODES {
        let pf = &pf_stats[node as usize];
        let st = cluster.node_state(node);
        let cs = st.cache.stats();
        let ns = st.stats.snapshot();
        assert_eq!(
            st.cache.resident_files(),
            0,
            "node {node}: descriptors closed + engines stopped -> empty cache"
        );
        // every picked path is exactly one cache acquire; every read that
        // didn't claim is exactly one acquire
        assert_eq!(
            cs.hits + cs.misses,
            N_FILES as u64 - pf.claimed + pf.picked,
            "node {node}: acquire algebra: cache {cs:?}, pf {pf:?}"
        );
        // every miss (reader's or fetcher's) is exactly one fetch
        assert_eq!(
            ns.local_reads + ns.remote_reads_issued,
            cs.misses,
            "node {node}: fetch algebra: {ns:?} vs {cs:?}"
        );
        // fetch breakdown matches the engine's own accounting
        assert_eq!(
            pf.picked,
            pf.prehits + pf.fetched_local + pf.fetched_remote,
            "node {node}: {pf:?}"
        );
        drop(st);
    }
    Arc::try_unwrap(cluster)
        .ok()
        .expect("all thread handles joined")
        .shutdown();
}

// ---------------------------------------------------------------------------
// Satellites: unlink GC at the origin + output metadata caching
// ---------------------------------------------------------------------------

#[test]
fn remote_unlink_gcs_origin_and_stale_meta_self_corrects() {
    let files = inputs(8, 5);
    let cluster = Cluster::launch(
        &files,
        ClusterConfig {
            nodes: 4,
            partitions: 4,
            ..Default::default()
        },
    )
    .unwrap();
    // home at node 0; writer (origin) node 1; unlinker node 2; reader node 3
    let path = path_with_home(&cluster, "/gc/a", 0);
    let v1 = vec![0xA1u8; 100];
    cluster.client(1).write_file(&path, &v1).unwrap();

    let mut reader = cluster.client(3);
    assert_eq!(reader.read_all(&path).unwrap(), v1);
    assert_eq!(reader.read_all(&path).unwrap(), v1);
    assert_eq!(
        cluster.node_state(3).stats.snapshot().output_meta_hits,
        1,
        "second open must use the cached metadata, not a StatOutput RPC"
    );

    // remote unlink (node 2 is neither home nor origin): previously
    // rejected; now removes home metadata AND GCs the origin buffer
    cluster.client(2).unlink(&path).unwrap();
    assert!(
        !cluster
            .node_state(1)
            .output_data
            .read()
            .unwrap()
            .contains_key(&path),
        "origin buffer must be dropped, not leaked until shutdown"
    );
    assert!(cluster.client(2).stat(&path).is_err(), "name is gone");
    assert!(
        matches!(cluster.client(2).unlink(&path), Err(fanstore::FanError::NotFound(_))),
        "double unlink is ENOENT"
    );

    // same name, new generation, different origin (node 2) and size
    let v2 = vec![0xB2u8; 37];
    cluster.client(2).write_file(&path, &v2).unwrap();
    // node 3 still holds the stale cached metadata (old origin/size); the
    // ENOENT from the dead origin must trigger a fresh stat + refetch
    assert_eq!(
        reader.read_all(&path).unwrap(),
        v2,
        "stale output metadata must self-correct on read"
    );

    // local unlink at the home node also GCs a remote origin's buffer
    let path2 = path_with_home(&cluster, "/gc/b", 0);
    cluster.client(1).write_file(&path2, &[7u8; 64]).unwrap();
    cluster.client(0).unlink(&path2).unwrap();
    assert!(
        !cluster
            .node_state(1)
            .output_data
            .read()
            .unwrap()
            .contains_key(&path2),
        "home-side unlink must GC the remote origin too"
    );
    cluster.shutdown();
}

#[test]
fn same_origin_same_size_rewrite_invalidates_resident_output() {
    // The window the generation stamp closes: node 3 holds the OLD bytes
    // resident in its cache, the rewrite lands on the SAME origin with the
    // SAME size, so neither the size check nor the origin's ENOENT can
    // catch it — only the commit generation can.
    let files = inputs(8, 6);
    let cluster = Cluster::launch(
        &files,
        ClusterConfig {
            nodes: 4,
            partitions: 4,
            ..Default::default()
        },
    )
    .unwrap();
    let path = path_with_home(&cluster, "/gen/a", 0);
    let v1 = vec![0xA1u8; 64];
    let v2 = vec![0xB2u8; 64]; // same size, different bytes
    cluster.client(1).write_file(&path, &v1).unwrap();

    // reader on node 3 keeps a descriptor open so the bytes STAY resident
    // across the rewrite (refcount > 0 pins them in the cache)
    let mut reader = cluster.client(3);
    let fd = reader
        .open(&path, fanstore::vfs::OpenFlags::Read)
        .unwrap();
    assert_eq!(reader.read_all(&path).unwrap(), v1);

    // unlink + rewrite from the SAME origin node with the SAME size
    cluster.client(1).unlink(&path).unwrap();
    cluster.client(1).write_file(&path, &v2).unwrap();

    assert_eq!(
        reader.read_all(&path).unwrap(),
        v2,
        "resident same-origin same-size rewrite must not serve stale bytes"
    );
    reader.close(fd).unwrap();
    cluster.shutdown();
}

#[test]
fn stat_many_batches_by_home_and_warms_the_meta_cache() {
    let files = inputs(8, 7);
    let cluster = Cluster::launch(
        &files,
        ClusterConfig {
            nodes: 4,
            partitions: 4,
            ..Default::default()
        },
    )
    .unwrap();
    // outputs homed on three different remote nodes (from reader node 0's
    // perspective) plus one local home and one missing path
    let mut paths = Vec::new();
    for (i, home) in [(0u32, 1u32), (1, 2), (2, 3), (3, 2), (4, 0)] {
        let p = path_with_home(&cluster, &format!("/shards/s{i}_"), home);
        cluster
            .client((i + 1) % 4)
            .write_file(&p, &vec![i as u8; 50 + i as usize])
            .unwrap();
        paths.push(p);
    }
    paths.push("/shards/ghost.bin".into());
    // an input path mixes in fine (answered from the replicated table)
    paths.push(format!("/fanstore/user/{}", files[0].path));
    // duplicate of a remote-homed path: must resolve, not report ENOENT
    paths.push(paths[1].clone());

    let mut reader = cluster.client(0);
    let results = reader.stat_many(&paths);
    assert_eq!(results.len(), 8);
    for i in 0..5 {
        assert_eq!(
            results[i].as_ref().unwrap().size,
            50 + i as u64,
            "{}",
            paths[i]
        );
    }
    assert!(
        matches!(&results[5], Err(fanstore::FanError::NotFound(_))),
        "missing path fails in place without poisoning the batch"
    );
    assert_eq!(
        results[6].as_ref().unwrap().size as usize,
        files[0].data.len()
    );
    assert_eq!(
        results[7].as_ref().unwrap().size,
        51,
        "duplicated path resolves like its first occurrence"
    );

    // the remote-home metadata is now cached: per-path stats are all hits
    for p in &paths[..4] {
        reader.stat(p).unwrap();
    }
    let hits = cluster.node_state(0).stats.snapshot().output_meta_hits;
    assert_eq!(
        hits, 4,
        "stat_many must warm the output metadata cache for remote homes"
    );
    let report = cluster.shutdown();
    let served = report.requests_served;
    // 5 writes (2 land at remote homes) + ≤13 awaited listing-invalidation
    // broadcasts (N-1 per commit, the already-invalidated home skipped) +
    // 3 StatOutputs gathers (homes 1,2,3) + nothing else remote: still
    // well under one stat round trip per path on the resume path itself
    assert!(
        served <= 12 + 13,
        "stat_many must gather per home, not per path: {served} requests"
    );
}
