//! Chaos integration (PR 7): node failure mid-epoch on both fabrics.
//!
//! The acceptance contract: with a surviving replica, reads stay
//! byte-identical to a healthy run while `failovers` fires; with every
//! holder dead, reads degrade to a real errno in bounded time; and the
//! fault injector replays the exact same schedule from the same seed over
//! real sockets.  Every test doubles as a no-hung-threads check — a
//! parked waiter or an unbounded wait deadlocks the cluster join and the
//! test itself.

use std::sync::Arc;
use std::time::{Duration, Instant};

use fanstore::config::{ClusterConfig, TransportKind};
use fanstore::coordinator::Cluster;
use fanstore::error::{errno, FanError};
use fanstore::experiments::failover::run_failover;
use fanstore::net::fault::{FaultInjector, FaultPlan};
use fanstore::net::transport::{Request, Transport};
use fanstore::partition::builder::InputFile;
use fanstore::util::prng::Prng;
use fanstore::vfs::Vfs;

fn inputs(n: usize, seed: u64) -> Vec<InputFile> {
    let mut rng = Prng::new(seed);
    (0..n)
        .map(|i| {
            let mut data = vec![0u8; 300 + 17 * i];
            rng.fill_bytes(&mut data);
            InputFile {
                path: format!("train/class{}/img{i:03}.raw", i % 4),
                data,
            }
        })
        .collect()
}

#[test]
fn kill_a_node_mid_epoch_reads_stay_byte_identical_on_both_fabrics() {
    // 3 nodes, replication 2: node 1 is the preferred holder of the one
    // partition node 0 must fetch remotely — the kill lands on the hot
    // remote path, and the surviving replica (node 2) must cover it
    let runs = run_failover(
        &[TransportKind::InProc, TransportKind::TcpLoopback],
        48,
        2048,
    )
    .unwrap();
    assert_eq!(runs.len(), 2);
    for r in &runs {
        assert_eq!(
            r.chaos_digest,
            r.healthy_digest,
            "{}: chaos sweep must read the exact same bytes",
            r.kind.name()
        );
        assert!(
            r.chaos_stats.failovers > 0,
            "{}: the kill must force at least one re-routed read: {:?}",
            r.kind.name(),
            r.chaos_stats
        );
        assert!(
            r.chaos_stats.peers_marked_down >= 1,
            "{}: the dead holder must be marked Down: {:?}",
            r.kind.name(),
            r.chaos_stats
        );
        assert_eq!(
            r.chaos_stats.degraded_reads, 0,
            "{}: a surviving replica means nothing degrades: {:?}",
            r.kind.name(),
            r.chaos_stats
        );
    }
    // identical dataset + identical sweep order on both fabrics: the
    // fabric must not change a single byte
    assert_eq!(
        runs[0].healthy_digest, runs[1].healthy_digest,
        "fabrics must agree on the healthy bytes"
    );
}

#[test]
fn all_holders_down_reads_degrade_with_an_errno_not_a_hang() {
    // 2 nodes, replication 1: partition 1 lives only on node 1.  Killing
    // it leaves its files with zero live holders — those reads must fail
    // fast with EIO while node 0's local files keep serving.
    let files = inputs(16, 42);
    let mut cluster = Cluster::launch(
        &files,
        ClusterConfig {
            nodes: 2,
            partitions: 2,
            replication: 1,
            transport: TransportKind::TcpLoopback,
            ..Default::default()
        },
    )
    .unwrap();
    let mut vfs = cluster.client(0);
    cluster.kill_node(1);

    let t0 = Instant::now();
    let mut ok = 0u32;
    let mut degraded = 0u32;
    for f in &files {
        match vfs.read_all(&format!("/fanstore/user/{}", f.path)) {
            Ok(data) => {
                assert_eq!(data, f.data);
                ok += 1;
            }
            Err(e) => {
                assert!(
                    matches!(e, FanError::Transport(_)),
                    "dead-holder read must be a transport error, got {e}"
                );
                assert_eq!(e.errno(), errno::EIO, "degraded read must map to EIO");
                degraded += 1;
            }
        }
    }
    let elapsed = t0.elapsed();
    assert!(ok > 0, "local partition must keep serving");
    assert!(degraded > 0, "dead partition must surface errors");
    assert!(
        elapsed < Duration::from_secs(30),
        "degraded reads must be bounded, took {elapsed:?} for {} reads",
        files.len()
    );
    let stats = cluster.node_state(0).stats.snapshot();
    assert_eq!(
        stats.degraded_reads, degraded as u64,
        "every failed read is accounted: {stats:?}"
    );
    assert!(
        stats.peers_marked_down >= 1,
        "node 1 must have been marked Down: {stats:?}"
    );
    drop(vfs);
    cluster.shutdown();
}

#[test]
fn fault_injector_replays_the_same_schedule_over_real_sockets() {
    let plan = FaultPlan {
        drop_p: 0.25,
        reset_p: 0.15,
        delay_p: 0.25,
        max_delay_ms: 2,
    };
    let mut schedules = Vec::new();
    for _ in 0..2 {
        let files = inputs(12, 77);
        let cluster = Cluster::launch(
            &files,
            ClusterConfig {
                nodes: 2,
                partitions: 2,
                transport: TransportKind::TcpLoopback,
                ..Default::default()
            },
        )
        .unwrap();
        let inj = FaultInjector::new(Arc::clone(&cluster.transport), plan, 0xD57);
        for i in 0..30 {
            let _ = inj.call(
                0,
                1,
                Request::ListOutputs {
                    dir: format!("/d{i}").into(),
                },
            );
        }
        schedules.push(inj.events());
        cluster.shutdown();
    }
    assert!(
        !schedules[0].is_empty(),
        "0.65 fault mass must fire within 30 sends"
    );
    assert_eq!(
        schedules[0], schedules[1],
        "same seed, same message sequence => same injected schedule"
    );
}
