//! Regression-corpus replay + bounded fuzz smokes (PR 10).
//!
//! `rust/tests/corpus/` holds hand-written hostile wire inputs — huge batch
//! counts backed by empty tails, overlong varints, unknown tags/kinds/codec
//! ids, truncated structures, and MAX_FRAME-adjacent length prefixes.  Every
//! file is replayed through the same oracles the live fuzzer uses (panic
//! containment, allocation-amplification bounds, torn-frame detection), so a
//! decode regression fails `cargo test` long before a fuzz campaign runs.
//!
//! This binary registers [`CountingAlloc`] as its global allocator — unlike
//! the library's own unit-test binary — so the allocation oracle here is
//! *live*, not a no-op: the test asserts it.

use fanstore::compress::Codec;
use fanstore::fuzz::alloc_guard::{self, CountingAlloc};
use fanstore::fuzz::wire::{replay_body, replay_stream};
use fanstore::fuzz::{run_store_fuzz, run_wire_fuzz};
use fanstore::metadata::record::{FileLocation, FileMeta, FileStat};
use fanstore::net::transport::{FileFetch, MetaFetch, Request, Response};
use fanstore::net::wire::{encode_request, encode_response};
use fanstore::storage::payload::Payload;
use std::sync::Arc;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn corpus(name: &str) -> Vec<u8> {
    let path = format!("{}/rust/tests/corpus/{name}", env!("CARGO_MANIFEST_DIR"));
    std::fs::read(&path).unwrap_or_else(|e| panic!("read corpus file {path}: {e}"))
}

#[test]
fn the_allocation_oracle_is_live_in_this_binary() {
    assert!(
        alloc_guard::installed(),
        "fuzz_corpus must run under the counting allocator"
    );
}

#[test]
fn hostile_corpus_bodies_are_rejected_within_bounds() {
    // every file here must be rejected by BOTH decoders — cheaply (the
    // allocation oracle is live in this binary) and without panicking
    let reject = [
        "req_huge_count_read_files.bin",
        "req_huge_count_stat_outputs.bin",
        "resp_huge_count_names.bin",
        "req_bad_tag.bin",
        "body_bad_kind.bin",
        "req_truncated_commit.bin",
        "resp_fetch_bad_codec.bin",
        "body_overlong_varint.bin",
    ];
    for name in reject {
        let accepted =
            replay_body(&corpus(name)).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(!accepted, "{name}: hostile body must not decode");
    }
}

#[test]
fn degenerate_but_legal_bodies_decode_within_bounds() {
    // 64 empty names: 75 input bytes materializing 64 `String`s — legal,
    // and the worst case for the per-item allocation allowance
    let accepted = replay_body(&corpus("body_empty_names_64.bin"))
        .expect("empty-names body violated an oracle");
    assert!(accepted, "empty-names body is canonical and must decode");
}

#[test]
fn hostile_corpus_streams_fail_cheaply() {
    // a MAX_FRAME length claim backed by 64 delivered bytes, and a length
    // above MAX_FRAME: neither may panic, allocate past the streaming
    // bound, or hand back a torn frame
    for name in ["stream_frame_len_max.bin", "stream_frame_len_over.bin"] {
        let produced =
            replay_stream(&corpus(name)).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(!produced, "{name}: must not produce a frame");
    }
    // the accept path still works: a complete 5-byte frame
    let mut ok = vec![5u8, 0, 0, 0];
    ok.extend([1, 2, 3, 4, 5]);
    assert!(replay_stream(&ok).expect("tiny valid frame"), "frame lost");
}

#[test]
fn every_message_variant_replays_under_the_allocation_oracle() {
    let path = || -> Arc<str> { Arc::from("out/ckpt/model_0007.bin") };
    let stat = FileStat::regular(42, 4096);
    let meta = FileMeta {
        stat,
        location: FileLocation {
            node: 3,
            partition: 7,
            offset: 8192,
            stored_len: 2048,
            codec: Codec::Lzss(5),
        },
        generation: 9,
    };
    let data = Payload::from(vec![0xA5u8; 1024]);
    let requests = [
        Request::ReadFile { path: path() },
        Request::ReadFiles { paths: vec![path(), Arc::from("a"), Arc::from("")] },
        Request::StatOutput { path: path() },
        Request::StatOutputs { paths: vec![path()] },
        Request::CommitOutput {
            path: path(),
            meta: meta.clone(),
            data: data.clone(),
            stamped: true,
        },
        Request::ListOutputs { dir: Arc::from("out") },
        Request::UnlinkOutput { path: path() },
        Request::DropOutput { path: path() },
        Request::InvalidateListings { path: Arc::from("out") },
        Request::Ping { epoch: 77 },
        Request::FetchPartition { pid: 5 },
        Request::InstallPartition { pid: 5, blob: data.clone() },
        Request::Shutdown,
    ];
    for (i, req) in requests.iter().enumerate() {
        let body = encode_request(i as u64, 2, req).to_body_bytes();
        let accepted = replay_body(&body)
            .unwrap_or_else(|e| panic!("request variant {i} ({req:?}): {e}"));
        assert!(accepted, "request variant {i} must decode");
    }
    let responses = [
        Response::FileData { stored: data.clone() },
        Response::FilesData(vec![
            (path(), FileFetch::Data { stored: data.clone() }),
            (Arc::from("b"), FileFetch::NotFound),
            (Arc::from("c"), FileFetch::Fault("disk on fire".into())),
        ]),
        Response::Meta { stat, origin: 1, generation: 4 },
        Response::Metas(vec![
            (path(), MetaFetch::Meta { stat, origin: 1, generation: 4 }),
            (Arc::from("d"), MetaFetch::NotFound),
        ]),
        Response::Names(vec![String::new(), "model_0007.bin".into()]),
        Response::Pong { epoch: 77 },
        Response::PartitionData { blob: data },
        Response::Ok,
        Response::Err("no".into()),
    ];
    for (i, resp) in responses.iter().enumerate() {
        let body = encode_response(i as u64, resp).to_body_bytes();
        let accepted = replay_body(&body)
            .unwrap_or_else(|e| panic!("response variant {i} ({resp:?}): {e}"));
        assert!(accepted, "response variant {i} must decode");
    }
}

#[test]
fn bounded_wire_fuzz_smoke() {
    let report = run_wire_fuzz(0xC0FF_EE00, 3_000).expect("wire fuzz diverged");
    assert!(report.alloc_guarded, "oracle must be live here");
    assert!(report.accepted > 0, "generator coverage: some inputs decode");
    assert!(report.rejected > 0, "mutation coverage: some inputs rejected");
    assert!(report.max_alloc > 0, "allocation counter never moved");
}

#[test]
fn bounded_store_fuzz_smoke() {
    let report = run_store_fuzz(0xFA57_F00D, 150).expect("store fuzz diverged");
    assert!(report.ops >= 150);
    assert!(report.rounds >= 2);
}
