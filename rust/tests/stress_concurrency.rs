//! Concurrency stress: many trainer threads per node hammering one cluster
//! with mixed open/read/close/stat/readdir/write traffic.
//!
//! The decomposed `NodeShared` has no node-global lock, so this exercises
//! the sharded cache, the sealed metadata/store, the output `RwLock`s and
//! the atomic stats all at once.  Asserts:
//!
//! * no deadlock (the test completes and the cluster shuts down),
//! * byte-exact contents for every read under concurrency,
//! * the per-node atomic counters sum to exactly the totals the threads
//!   report: every read-open is one cache acquire (hit or miss), every
//!   cache miss is exactly one fetch (local or remote), every write is one
//!   committed output.

use fanstore::config::ClusterConfig;
use fanstore::coordinator::Cluster;
use fanstore::partition::builder::InputFile;
use fanstore::util::prng::Prng;
use fanstore::vfs::{OpenFlags, Vfs};

const NODES: u32 = 3;
const THREADS_PER_NODE: u32 = 6;
const ITERS: usize = 60;

fn inputs(n: usize) -> Vec<InputFile> {
    (0..n)
        .map(|i| InputFile {
            path: format!("train/class{}/img{i:03}.raw", i % 4),
            data: vec![(i % 251) as u8; 300 + 7 * i],
        })
        .collect()
}

/// What one trainer thread did, for the global accounting.
#[derive(Default)]
struct ThreadTally {
    read_opens: u64,
    writes: u64,
    bytes_written: u64,
}

#[test]
fn stress_mixed_ops_many_threads_per_node() {
    let files = inputs(36);
    let cluster = Cluster::launch(
        &files,
        ClusterConfig {
            nodes: NODES,
            partitions: 6,
            ..Default::default()
        },
    )
    .unwrap();

    let paths: Vec<(String, Vec<u8>)> = files
        .iter()
        .map(|f| (format!("/fanstore/user/{}", f.path), f.data.clone()))
        .collect();

    let mut handles = Vec::new();
    for node in 0..NODES {
        for t in 0..THREADS_PER_NODE {
            let gtid = node * THREADS_PER_NODE + t;
            let mut vfs = cluster.client(node);
            let paths = paths.clone();
            handles.push(std::thread::spawn(move || -> ThreadTally {
                let mut rng = Prng::new(0x57E55 + gtid as u64);
                let mut tally = ThreadTally::default();
                let mut last_output: Option<(String, Vec<u8>)> = None;
                for i in 0..ITERS {
                    // whole-file read of a random input, byte-exact
                    let (p, want) = &paths[rng.index(paths.len())];
                    let got = vfs.read_all(p).expect("input read");
                    assert_eq!(&got, want, "{p}");
                    tally.read_opens += 1;

                    // stat a random input (metadata only, no cache traffic)
                    let (p, want) = &paths[rng.index(paths.len())];
                    assert_eq!(vfs.stat(p).expect("stat").size as usize, want.len());

                    // partial read through the descriptor API
                    if i % 5 == 0 {
                        let (p, want) = &paths[rng.index(paths.len())];
                        let fd = vfs.open(p, OpenFlags::Read).expect("open");
                        tally.read_opens += 1;
                        let mut buf = vec![0u8; 17];
                        let n = vfs.read(fd, &mut buf).expect("read");
                        assert!(n > 0);
                        assert_eq!(&buf[..n], &want[..n]);
                        vfs.close(fd).expect("close");
                    }

                    // directory listings under churn
                    if i % 7 == 0 {
                        let names = vfs.readdir("/fanstore/user/train").expect("readdir");
                        assert_eq!(names.len(), 4, "class0..class3");
                        // output dir listing may be empty early on; must
                        // never error once outputs exist, and stays sorted
                        if let Ok(outs) = vfs.readdir("/stress/out") {
                            assert!(outs.windows(2).all(|w| w[0] < w[1]), "sorted, deduped");
                        }
                    }

                    // write a unique output file (checkpoint pattern)
                    if i % 8 == 0 {
                        let path = format!("/stress/out/t{gtid:02}_{i:03}.bin");
                        let data = vec![(gtid % 251) as u8; 64 + (i % 128)];
                        vfs.write_file(&path, &data).expect("write output");
                        tally.writes += 1;
                        tally.bytes_written += data.len() as u64;
                        last_output = Some((path, data));
                    }

                    // resume-read our own latest checkpoint
                    if i % 8 == 4 {
                        if let Some((p, want)) = &last_output {
                            let got = vfs.read_all(p).expect("output read");
                            assert_eq!(&got, want, "{p}");
                            tally.read_opens += 1;
                        }
                    }
                }
                tally
            }));
        }
    }

    let mut total = ThreadTally::default();
    for h in handles {
        let t = h.join().expect("no thread panicked/deadlocked");
        total.read_opens += t.read_opens;
        total.writes += t.writes;
        total.bytes_written += t.bytes_written;
    }

    // full output listing visible from any node
    let mut vfs = cluster.client(0);
    let outs = vfs.readdir("/stress/out").unwrap();
    assert_eq!(outs.len() as u64, total.writes, "every commit listed");

    // cache + stats algebra across all nodes
    let mut hits = 0u64;
    let mut misses = 0u64;
    for node in 0..NODES {
        let st = cluster.node_state(node);
        let cs = st.cache.stats();
        hits += cs.hits;
        misses += cs.misses;
        assert_eq!(
            st.cache.resident_files(),
            0,
            "all descriptors closed -> empty cache on node {node}"
        );
    }
    assert_eq!(
        hits + misses,
        total.read_opens,
        "one cache acquire per read-open"
    );

    let report = cluster.shutdown();
    let fetches: u64 = report
        .per_node
        .iter()
        .map(|s| s.local_reads + s.remote_reads_issued)
        .sum();
    assert_eq!(fetches, misses, "every cache miss is exactly one fetch");
    let committed: u64 = report.per_node.iter().map(|s| s.outputs_committed).sum();
    let out_bytes: u64 = report.per_node.iter().map(|s| s.output_bytes).sum();
    assert_eq!(committed, total.writes);
    assert_eq!(out_bytes, total.bytes_written);
}
