//! Chaos recovery integration (PR 9): kill → detect → re-replicate →
//! survive the next kill, on both fabrics.
//!
//! The acceptance contract: a mid-sweep kill leaves reads byte-identical
//! (PR 7 failover) and the survivors, driven through deterministic
//! probe/repair ticks, re-converge to full replication with exact counter
//! algebra (`repairs_started == repairs_completed`, `repaired_bytes` is
//! the sum of the adopted partition blobs).  After re-convergence a
//! *second* kill of a different node must not degrade a single read.  And
//! a committed output stays readable — and gets re-replicated — after the
//! death of its own origin home.

use std::sync::Arc;

use fanstore::config::{ClusterConfig, TransportKind};
use fanstore::coordinator::Cluster;
use fanstore::net::health::PeerState;
use fanstore::node::RepairReport;
use fanstore::partition::builder::InputFile;
use fanstore::util::prng::Prng;
use fanstore::vfs::Vfs;

fn inputs(n: usize, seed: u64) -> Vec<InputFile> {
    let mut rng = Prng::new(seed);
    (0..n)
        .map(|i| {
            let mut data = vec![0u8; 300 + 17 * i];
            rng.fill_bytes(&mut data);
            InputFile {
                path: format!("train/class{}/img{i:03}.raw", i % 4),
                data,
            }
        })
        .collect()
}

fn mount_path(f: &InputFile) -> String {
    format!("/fanstore/user/{}", f.path)
}

#[test]
fn mid_sweep_kill_repairs_to_full_replication_then_survives_a_second_kill() {
    for kind in [TransportKind::InProc, TransportKind::TcpLoopback] {
        // 3 nodes, 6 partitions, replication 2: holders(p) = {p%3, (p+1)%3}.
        // Node 1 holds partitions {0, 1, 3, 4}; after it dies, deterministic
        // adoption gives partitions 1 and 4 to node 0 and 0 and 3 to node 2.
        let files = inputs(48, 0xBEEF);
        let mut cluster = Cluster::launch(
            &files,
            ClusterConfig {
                nodes: 3,
                partitions: 6,
                replication: 2,
                transport: kind,
                ..Default::default()
            },
        )
        .unwrap();
        let mut vfs = cluster.client(0);

        // -- mid-sweep kill: reads stay byte-identical ------------------
        for f in files.iter().take(24) {
            assert_eq!(vfs.read_all(&mount_path(f)).unwrap(), f.data, "{}", kind.name());
        }
        cluster.kill_node(1);
        for f in files.iter().skip(24) {
            assert_eq!(
                vfs.read_all(&mount_path(f)).unwrap(),
                f.data,
                "{}: chaos sweep must read the exact same bytes",
                kind.name()
            );
        }
        let st0 = cluster.node_state(0).stats.snapshot();
        assert!(st0.failovers > 0, "{}: kill must force re-routes: {st0:?}", kind.name());
        assert_eq!(st0.degraded_reads, 0, "{}: replica covers everything", kind.name());

        // -- detection: survivors walk the corpse to Down ----------------
        let tp = Arc::clone(&cluster.transport);
        for s in [0u32, 2] {
            let n = cluster.node_state(s);
            n.probe_tick(&*tp);
            n.probe_tick(&*tp);
            assert_eq!(n.health.state(1), PeerState::Down, "{}: node {s}", kind.name());
        }

        // -- repair: one tick per survivor restores full replication -----
        let node0 = cluster.node_state(0);
        let node2 = cluster.node_state(2);
        assert_eq!(node0.repair_tick(&*tp), RepairReport { started: 2, completed: 2 });
        assert_eq!(node2.repair_tick(&*tp), RepairReport { started: 2, completed: 2 });
        assert!(node0.holds_partition(1) && node0.holds_partition(4));
        assert!(node2.holds_partition(0) && node2.holds_partition(3));

        // exact counter algebra: every started repair completed, and the
        // repaired bytes are precisely the adopted partition blobs
        let blob = |n: &Arc<fanstore::node::NodeShared>, pid: u32| {
            n.partition_blob(pid).unwrap().len() as u64
        };
        let st0 = node0.stats.snapshot();
        assert_eq!((st0.repairs_started, st0.repairs_completed), (2, 2));
        assert_eq!(st0.repaired_bytes, blob(&node2, 1) + blob(&node2, 4), "{}", kind.name());
        let st2 = node2.stats.snapshot();
        assert_eq!((st2.repairs_started, st2.repairs_completed), (2, 2));
        assert_eq!(st2.repaired_bytes, blob(&node0, 0) + blob(&node0, 3), "{}", kind.name());

        // the tick is convergent: the need re-derives to nothing
        assert_eq!(node0.repair_tick(&*tp), RepairReport::default());
        assert_eq!(node2.repair_tick(&*tp), RepairReport::default());

        // -- a second kill now costs nothing: every partition has a live
        //    copy again, and node 0 holds all six locally ----------------
        cluster.kill_node(2);
        let mut vfs = cluster.client(0);
        for f in &files {
            assert_eq!(
                vfs.read_all(&mount_path(f)).unwrap(),
                f.data,
                "{}: post-repair sweep must be byte-identical",
                kind.name()
            );
        }
        let st0 = cluster.node_state(0).stats.snapshot();
        assert_eq!(
            st0.degraded_reads, 0,
            "{}: re-replication means the second kill degrades nothing: {st0:?}",
            kind.name()
        );
        drop(vfs);
        cluster.shutdown();
    }
}

#[test]
fn committed_output_survives_death_of_its_origin_home() {
    for kind in [TransportKind::InProc, TransportKind::TcpLoopback] {
        let files = inputs(12, 0x51ED);
        let mut cluster = Cluster::launch(
            &files,
            ClusterConfig {
                nodes: 3,
                partitions: 3,
                replication: 2,
                transport: kind,
                ..Default::default()
            },
        )
        .unwrap();

        let path = "/ckpt/model_final.bin";
        let homes = cluster.placement.output_homes(path);
        assert_eq!(homes.len(), 2, "replication-2 outputs get two homes");
        let origin = homes[0];
        let survivor_home = homes[1];
        let bystander = (0..3u32).find(|n| !homes.contains(n)).unwrap();

        // the checkpoint is written *by the node that is its own primary
        // home* — killing that node takes down the origin buffer and the
        // stamping home at once, the worst case for the old design
        let mut data = vec![0u8; 4096];
        Prng::new(0xC4E).fill_bytes(&mut data);
        let mut writer = cluster.client(origin);
        writer.write_file(path, &data).unwrap();
        drop(writer);
        cluster.kill_node(origin);

        // a node holding no copy reads through the surviving home
        let mut reader = cluster.client(bystander);
        assert_eq!(
            reader.read_all(path).unwrap(),
            data,
            "{}: output must survive its origin home",
            kind.name()
        );
        assert_eq!(reader.stat(path).unwrap().size, data.len() as u64);

        // detection + repair: the surviving home re-commits the output to
        // the deterministic adoptee (the bystander), restoring 2 copies
        let tp = Arc::clone(&cluster.transport);
        for s in [survivor_home, bystander] {
            let n = cluster.node_state(s);
            n.probe_tick(&*tp);
            n.probe_tick(&*tp);
            assert_eq!(n.health.state(origin), PeerState::Down, "{}", kind.name());
        }
        // input repairs share the per-tick budget with the output push, so
        // tick until quiescent (bounded: the predicates strictly shrink)
        for _ in 0..8 {
            let mut progress = 0;
            for s in [survivor_home, bystander] {
                progress += cluster.node_state(s).repair_tick(&*tp).started;
            }
            if progress == 0 {
                break;
            }
        }
        let adoptee = cluster.node_state(bystander);
        assert!(
            adoptee.output_data.read().unwrap().contains_key(path),
            "{}: adoptee must hold the re-replicated bytes",
            kind.name()
        );
        assert!(
            adoptee.output_meta.read().unwrap().get(path).is_some(),
            "{}: adoptee must hold the re-replicated metadata",
            kind.name()
        );
        let sth = cluster.node_state(survivor_home).stats.snapshot();
        assert!(
            sth.repairs_completed >= 1,
            "{}: the surviving home drives the output push: {sth:?}",
            kind.name()
        );

        // the re-replicated copy serves locally on the adoptee
        let mut local = cluster.client(bystander);
        assert_eq!(local.read_all(path).unwrap(), data, "{}", kind.name());
        drop(local);
        drop(reader);
        cluster.shutdown();
    }
}

/// Double failure (PR 10, the ROADMAP's carried window): after BOTH output
/// homes of a path die, the adopted copy — installed by the PR 9 repair
/// tick — must answer `stat` metadata too, not just reads.  Before the
/// fix, `stat` only consulted the homes and degraded to EIO even though a
/// live node provably held bytes + stamped metadata.
#[test]
fn output_stat_survives_death_of_every_home_via_the_adoptee() {
    for kind in [TransportKind::InProc, TransportKind::TcpLoopback] {
        // 4 nodes, replication 2: homes(path) = {h, h+2}, so the adoptee
        // arithmetic — first non-home live node from (homes[0]+1) — always
        // lands on the bystander h+1
        let files = inputs(8, 0xD0B1);
        let mut cluster = Cluster::launch(
            &files,
            ClusterConfig {
                nodes: 4,
                partitions: 4,
                replication: 2,
                transport: kind,
                ..Default::default()
            },
        )
        .unwrap();

        let path = "/ckpt/double_fail.bin";
        let homes = cluster.placement.output_homes(path);
        assert_eq!(homes.len(), 2);
        let adoptee_id = (homes[0] + 1) % 4;
        assert!(!homes.contains(&adoptee_id), "stride-2 homes skip h+1");
        let other = (0..4u32)
            .find(|n| !homes.contains(n) && *n != adoptee_id)
            .unwrap();

        // worst case: the writer IS the primary home, so the first kill
        // takes the origin buffer and the stamping home down together
        let mut data = vec![0u8; 4096];
        Prng::new(0xDF01).fill_bytes(&mut data);
        let mut writer = cluster.client(homes[0]);
        writer.write_file(path, &data).unwrap();
        drop(writer);

        // first kill + detection + repair: the surviving home re-commits
        // the output (bytes + stamped metadata) to the adoptee.  No client
        // reads in between — they would warm per-node meta caches and mask
        // the stat path this test exists to pin down.
        cluster.kill_node(homes[0]);
        let tp = Arc::clone(&cluster.transport);
        for s in [homes[1], adoptee_id, other] {
            let n = cluster.node_state(s);
            n.probe_tick(&*tp);
            n.probe_tick(&*tp);
            assert_eq!(n.health.state(homes[0]), PeerState::Down, "{}", kind.name());
        }
        for _ in 0..8 {
            let mut progress = 0;
            for s in [homes[1], adoptee_id, other] {
                progress += cluster.node_state(s).repair_tick(&*tp).started;
            }
            if progress == 0 {
                break;
            }
        }
        let adoptee = cluster.node_state(adoptee_id);
        assert!(
            adoptee.output_meta.read().unwrap().get(path).is_some(),
            "{}: repair must install stamped metadata at the adoptee",
            kind.name()
        );

        // second kill: now EVERY home of the path is down
        cluster.kill_node(homes[1]);
        for s in [adoptee_id, other] {
            let n = cluster.node_state(s);
            n.probe_tick(&*tp);
            n.probe_tick(&*tp);
            assert_eq!(n.health.state(homes[1]), PeerState::Down, "{}", kind.name());
        }

        // a cold bystander stats and reads through the adopted copy
        let mut reader = cluster.client(other);
        assert_eq!(
            reader.stat(path).unwrap().size,
            data.len() as u64,
            "{}: stat must consult the adopted copy when every home is down",
            kind.name()
        );
        assert_eq!(reader.read_all(path).unwrap(), data, "{}", kind.name());

        // the adoptee itself stats through its own adopted home table
        let mut local = cluster.client(adoptee_id);
        assert_eq!(
            local.stat(path).unwrap().size,
            data.len() as u64,
            "{}: the adoptee answers from its local adopted record",
            kind.name()
        );
        assert_eq!(local.read_all(path).unwrap(), data, "{}", kind.name());
        drop(local);
        drop(reader);
        cluster.shutdown();
    }
}
