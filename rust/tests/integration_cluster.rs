//! Integration tests over the full in-process FanStore stack: prep →
//! cluster launch → concurrent multi-node I/O → consistency → shutdown.

use fanstore::compress::Codec;
use fanstore::config::ClusterConfig;
use fanstore::coordinator::Cluster;
use fanstore::error::FanError;
use fanstore::partition::builder::InputFile;
use fanstore::util::prng::Prng;
use fanstore::vfs::{OpenFlags, Vfs};
use fanstore::workload::datasets::DatasetSpec;

fn dataset(n: usize, seed: u64) -> Vec<InputFile> {
    DatasetSpec::imagenet().generate(n, 256, seed)
}

#[test]
fn concurrent_readers_across_nodes_see_identical_bytes() {
    let files = dataset(60, 1);
    let cluster = Cluster::launch(
        &files,
        ClusterConfig {
            nodes: 4,
            partitions: 8,
            ..Default::default()
        },
    )
    .unwrap();
    let mut handles = Vec::new();
    for node in 0..4u32 {
        for reader in 0..3u32 {
            let mut vfs = cluster.client(node);
            let files: Vec<(String, Vec<u8>)> = files
                .iter()
                .map(|f| (format!("/fanstore/user/{}", f.path), f.data.clone()))
                .collect();
            handles.push(std::thread::spawn(move || {
                let mut rng = Prng::new((node * 10 + reader) as u64 + 5);
                for _ in 0..120 {
                    let (path, want) = &files[rng.index(files.len())];
                    let got = vfs.read_all(path).expect("read");
                    assert_eq!(&got, want, "{path}");
                }
            }));
        }
    }
    for h in handles {
        h.join().unwrap();
    }
    cluster.shutdown();
}

#[test]
fn sequential_read_api_with_small_buffers() {
    let files = dataset(6, 2);
    let cluster = Cluster::launch(&files, ClusterConfig::default()).unwrap();
    let mut vfs = cluster.client(1);
    let path = format!("/fanstore/user/{}", files[0].path);
    let fd = vfs.open(&path, OpenFlags::Read).unwrap();
    let mut out = Vec::new();
    let mut buf = [0u8; 977]; // deliberately odd size
    loop {
        let n = vfs.read(fd, &mut buf).unwrap();
        if n == 0 {
            break;
        }
        out.extend_from_slice(&buf[..n]);
    }
    vfs.close(fd).unwrap();
    assert_eq!(out, files[0].data);
    // double close is EBADF
    assert!(matches!(vfs.close(fd), Err(FanError::BadFd(_))));
    cluster.shutdown();
}

#[test]
fn consistency_multi_read_single_write() {
    let files = dataset(10, 3);
    let cluster = Cluster::launch(
        &files,
        ClusterConfig {
            nodes: 3,
            partitions: 3,
            ..Default::default()
        },
    )
    .unwrap();
    let mut a = cluster.client(0);
    let mut b = cluster.client(2);
    let input = format!("/fanstore/user/{}", files[0].path);

    // inputs are immutable
    assert!(matches!(
        a.open(&input, OpenFlags::Write),
        Err(FanError::Consistency(_))
    ));
    assert!(matches!(a.unlink(&input), Err(FanError::Consistency(_))));

    // output invisible until close (visible-until-finish, §5.4)
    let fd = a.open("/out/gen_0001.png", OpenFlags::Write).unwrap();
    a.write(fd, b"partial").unwrap();
    assert!(b.stat("/out/gen_0001.png").is_err(), "must be invisible before close");
    a.write(fd, b" data").unwrap();
    a.close(fd).unwrap();
    assert_eq!(b.stat("/out/gen_0001.png").unwrap().size, 12);
    assert_eq!(b.read_all("/out/gen_0001.png").unwrap(), b"partial data");

    // single-write: a second writer of the same path is rejected
    assert!(matches!(
        b.open("/out/gen_0001.png", OpenFlags::Write),
        Err(FanError::Consistency(_))
    ));
    // reading through a write fd and vice versa is rejected
    let fd2 = a.open("/out/gen_0002.png", OpenFlags::Write).unwrap();
    let mut buf = [0u8; 4];
    assert!(a.read(fd2, &mut buf).is_err());
    a.close(fd2).unwrap();
    let fd3 = b.open(&input, OpenFlags::Read).unwrap();
    assert!(b.write(fd3, b"x").is_err());
    b.close(fd3).unwrap();
    cluster.shutdown();
}

#[test]
fn readdir_gathers_outputs_from_all_homes() {
    let files = dataset(8, 4);
    let cluster = Cluster::launch(
        &files,
        ClusterConfig {
            nodes: 4,
            partitions: 4,
            ..Default::default()
        },
    )
    .unwrap();
    // write outputs from different nodes into one directory
    for node in 0..4u32 {
        let mut vfs = cluster.client(node);
        vfs.write_file(&format!("/ckpt/model_n{node}.bin"), &[node as u8; 64])
            .unwrap();
    }
    let mut vfs = cluster.client(0);
    let names = vfs.readdir("/ckpt").unwrap();
    assert_eq!(
        names,
        vec![
            "model_n0.bin",
            "model_n1.bin",
            "model_n2.bin",
            "model_n3.bin"
        ]
    );
    // and each is readable from any node
    for n in &names {
        assert_eq!(vfs.read_all(&format!("/ckpt/{n}")).unwrap().len(), 64);
    }
    cluster.shutdown();
}

#[test]
fn readdir_listing_cache_hits_and_cluster_wide_invalidation() {
    let files = dataset(9, 14);
    let cluster = Cluster::launch(
        &files,
        ClusterConfig {
            nodes: 3,
            partitions: 3,
            ..Default::default()
        },
    )
    .unwrap();
    let hits = |node: u32| cluster.node_state(node).stats.snapshot().readdir_cache_hits;

    let mut reader = cluster.client(0);
    let mut writer = cluster.client(2);
    writer.write_file("/ckpt/a.bin", b"aa").unwrap();

    // first listing gathers and caches; the repeat is a local lookup
    assert_eq!(reader.readdir("/ckpt").unwrap(), vec!["a.bin"]);
    let h0 = hits(0);
    assert_eq!(reader.readdir("/ckpt").unwrap(), vec!["a.bin"]);
    assert_eq!(hits(0), h0 + 1, "repeat readdir must hit the cache");

    // a commit from ANY node invalidates the cached listing everywhere
    writer.write_file("/ckpt/b.bin", b"bb").unwrap();
    assert_eq!(reader.readdir("/ckpt").unwrap(), vec!["a.bin", "b.bin"]);
    // a second client on a third node shares the per-node cache
    let mut sibling = cluster.client(1);
    assert_eq!(sibling.readdir("/ckpt").unwrap(), vec!["a.bin", "b.bin"]);
    let h1 = hits(1);
    assert_eq!(sibling.readdir("/ckpt").unwrap(), vec!["a.bin", "b.bin"]);
    assert_eq!(hits(1), h1 + 1);

    // unlink from any node invalidates too
    sibling.unlink("/ckpt/a.bin").unwrap();
    assert_eq!(reader.readdir("/ckpt").unwrap(), vec!["b.bin"]);
    assert_eq!(sibling.readdir("/ckpt").unwrap(), vec!["b.bin"]);

    // input listings are cacheable as well
    let inputs = reader.readdir("/fanstore/user/imagenet-1k").unwrap();
    assert!(!inputs.is_empty());
    let h2 = hits(0);
    assert_eq!(reader.readdir("/fanstore/user/imagenet-1k").unwrap(), inputs);
    assert_eq!(hits(0), h2 + 1);
    cluster.shutdown();
}

#[test]
fn compressed_cluster_with_spill_to_disk() {
    let spec = DatasetSpec::srgan();
    let files = spec.generate(24, 512, 5);
    let spill = std::env::temp_dir().join(format!("fanstore_it_{}", std::process::id()));
    let cluster = Cluster::launch(
        &files,
        ClusterConfig {
            nodes: 2,
            partitions: 4,
            codec: Codec::Lzss(5),
            spill_dir: Some(spill.to_string_lossy().into_owned()),
            ..Default::default()
        },
    )
    .unwrap();
    assert!(cluster.prep_stats.ratio() > 1.5, "srgan-like must compress");
    let mut vfs = cluster.client(0);
    for f in &files {
        assert_eq!(
            vfs.read_all(&format!("/fanstore/user/{}", f.path)).unwrap(),
            f.data
        );
    }
    // partitions actually hit the disk
    let blobs: Vec<_> = std::fs::read_dir(spill.join("node000"))
        .unwrap()
        .collect();
    assert!(!blobs.is_empty());
    cluster.shutdown();
    std::fs::remove_dir_all(&spill).ok();
}

#[test]
fn stats_reflect_locality() {
    let files = dataset(40, 6);
    // broadcast: replication == nodes
    let cluster = Cluster::launch(
        &files,
        ClusterConfig {
            nodes: 4,
            partitions: 8,
            replication: 4,
            ..Default::default()
        },
    )
    .unwrap();
    for node in 0..4 {
        let mut vfs = cluster.client(node);
        for f in &files {
            vfs.read_all(&format!("/fanstore/user/{}", f.path)).unwrap();
        }
    }
    let report = cluster.shutdown();
    let local: u64 = report.per_node.iter().map(|s| s.local_reads).sum();
    let remote: u64 = report.per_node.iter().map(|s| s.remote_reads_issued).sum();
    assert_eq!(local, 160);
    assert_eq!(remote, 0);
}

#[test]
fn cache_is_shared_between_clients_on_a_node() {
    let files = dataset(5, 7);
    let cluster = Cluster::launch(&files, ClusterConfig::default()).unwrap();
    let path = format!("/fanstore/user/{}", files[0].path);
    let mut a = cluster.client(0);
    let mut b = cluster.client(0); // second "process" on the same node
    let fd_a = a.open(&path, OpenFlags::Read).unwrap();
    let fd_b = b.open(&path, OpenFlags::Read).unwrap();
    let st = cluster.node_state(0);
    assert_eq!(st.cache.refcount(&path), 2, "both fds pin one entry");
    a.close(fd_a).unwrap();
    assert_eq!(st.cache.refcount(&path), 1, "entry survives first close");
    b.close(fd_b).unwrap();
    assert_eq!(st.cache.refcount(&path), 0, "evicted at zero (§5.4)");
    assert_eq!(st.cache.resident_files(), 0);
    drop(st);
    cluster.shutdown();
}

#[test]
fn committed_output_reads_are_cached_per_node() {
    let files = dataset(8, 11);
    let cluster = Cluster::launch(
        &files,
        ClusterConfig {
            nodes: 2,
            partitions: 4,
            ..Default::default()
        },
    )
    .unwrap();
    // checkpoint written on node 1, resumed from node 0 by two "processes"
    let mut w = cluster.client(1);
    let ckpt = vec![7u8; 4096];
    w.write_file("/ckpt/big.bin", &ckpt).unwrap();
    let mut a = cluster.client(0);
    let mut b = cluster.client(0);
    let fd_a = a.open("/ckpt/big.bin", OpenFlags::Read).unwrap();
    let fd_b = b.open("/ckpt/big.bin", OpenFlags::Read).unwrap();
    let st = cluster.node_state(0);
    assert_eq!(
        st.cache.refcount("/ckpt/big.bin"),
        2,
        "output content pinned in the node cache like inputs"
    );
    let mut out = vec![0u8; 4096];
    let mut got = 0;
    while got < out.len() {
        let n = a.read(fd_a, &mut out[got..]).unwrap();
        assert!(n > 0);
        got += n;
    }
    assert_eq!(out, ckpt);
    a.close(fd_a).unwrap();
    b.close(fd_b).unwrap();
    drop(st);
    let report = cluster.shutdown();
    assert_eq!(
        report.per_node[0].remote_reads_issued, 1,
        "second same-node open must hit the cache, not re-fetch the origin"
    );
    assert_eq!(report.per_node[0].bytes_fetched_remote, 4096);
}

#[test]
fn property_any_cluster_shape_serves_all_files() {
    fanstore::util::proptest_lite::check("cluster serves all", 0x10AD, 8, |rng| {
        let nodes = (rng.index(4) + 1) as u32;
        let parts = (rng.index(8) + 1) as u32 * nodes;
        let repl = (rng.index(nodes as usize) + 1) as u32;
        let n = rng.index(30) + 5;
        let files = dataset(n, rng.next_u64());
        let cluster = Cluster::launch(
            &files,
            ClusterConfig {
                nodes,
                partitions: parts,
                replication: repl,
                codec: if rng.chance(0.5) {
                    Codec::Lzss(3)
                } else {
                    Codec::None
                },
                ..Default::default()
            },
        )
        .map_err(|e| e.to_string())?;
        let reader = rng.index(nodes as usize) as u32;
        let mut vfs = cluster.client(reader);
        for f in &files {
            let got = vfs
                .read_all(&format!("/fanstore/user/{}", f.path))
                .map_err(|e| e.to_string())?;
            fanstore::prop_assert!(got == f.data, "mismatch {}", f.path);
        }
        cluster.shutdown();
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Failure injection
// ---------------------------------------------------------------------------

#[test]
fn dead_peer_surfaces_transport_error_not_hang() {
    use fanstore::net::transport::{InProcTransport, Request, Response};
    let (tp, eps) = InProcTransport::fully_connected(2);
    // node 1's worker dies immediately (crash injection)
    drop(eps);
    let err = tp
        .call(0, 1, Request::ReadFile { path: "/x".into() })
        .unwrap_err();
    assert!(matches!(err, fanstore::FanError::Transport(_)), "{err}");
    // a well-behaved peer still errors cleanly rather than panicking
    let (tp2, mut eps2) = InProcTransport::fully_connected(2);
    let ep1 = eps2.pop().unwrap();
    let handle = std::thread::spawn(move || {
        // worker replies Err then exits mid-conversation
        if let Ok(msg) = ep1.inbox.recv() {
            msg.reply.send(Response::Err("injected".into()));
        }
    });
    let resp = tp2
        .call(0, 1, Request::ReadFile { path: "/y".into() })
        .unwrap();
    assert!(resp.into_file_data().is_err());
    handle.join().unwrap();
}

#[test]
fn corrupted_partition_rejected_at_load() {
    let files = dataset(6, 9);
    let (blobs, _) = fanstore::partition::builder::build_partitions(
        &files,
        1,
        Codec::None,
    )
    .unwrap();
    let mut blob = blobs.into_iter().next().unwrap();
    blob.truncate(blob.len() - 10); // torn write
    let mut store = fanstore::storage::disk::DiskStore::in_memory();
    assert!(store.load_partition(0, blob, "/m").is_err());
    assert_eq!(store.file_count(), 0, "no partial index on failure");
}

#[test]
fn corrupted_compressed_stream_fails_read_not_panics() {
    let files: Vec<InputFile> = vec![InputFile {
        path: "a/x".into(),
        data: vec![3u8; 4096],
    }];
    let (blobs, _) =
        fanstore::partition::builder::build_partitions(&files, 1, Codec::Lzss(5)).unwrap();
    let mut blob = blobs.into_iter().next().unwrap();
    // flip bytes inside the compressed payload (after the 412-byte header)
    let n = blob.len();
    for b in blob[420..n.min(440)].iter_mut() {
        *b ^= 0xFF;
    }
    let mut store = fanstore::storage::disk::DiskStore::in_memory();
    // loading may or may not notice (sizes can still parse); the read must
    // surface a codec error rather than corrupt data or panic
    if store.load_partition(0, blob, "/m").is_ok() {
        match store.read_raw("/m/a/x") {
            Err(fanstore::FanError::Codec(_)) | Err(fanstore::FanError::Format(_)) => {}
            Ok(data) => assert_ne!(data, vec![3u8; 4096], "silent corruption"),
            Err(other) => panic!("unexpected error class: {other}"),
        }
    }
}

#[test]
fn cluster_survives_client_drop_mid_read() {
    let files = dataset(20, 10);
    let cluster = Cluster::launch(
        &files,
        ClusterConfig {
            nodes: 2,
            partitions: 4,
            ..Default::default()
        },
    )
    .unwrap();
    {
        let mut vfs = cluster.client(0);
        let path = format!("/fanstore/user/{}", files[0].path);
        let _fd = vfs.open(&path, OpenFlags::Read).unwrap();
        // client dropped with the fd still open (process crash analogue)
    }
    // the cluster still serves other clients
    let mut vfs2 = cluster.client(1);
    for f in &files {
        vfs2.read_all(&format!("/fanstore/user/{}", f.path)).unwrap();
    }
    cluster.shutdown();
}
