//! Loopback-TCP integration: the same cluster logic that runs over mpsc
//! channels runs over real sockets with byte-identical results and the
//! exact same stats/cache counter algebra (the acceptance gauge for the
//! pluggable transport), including the prefetch pipeline stress and the
//! output commit/stat/unlink lifecycle.

use std::sync::Arc;

use fanstore::config::{ClusterConfig, TransportKind};
use fanstore::coordinator::Cluster;
use fanstore::experiments::scaling::{run_transport_equivalence, transport_runs_equivalent};
use fanstore::partition::builder::InputFile;
use fanstore::util::prng::Prng;
use fanstore::vfs::Vfs;

fn inputs(n: usize, seed: u64) -> Vec<InputFile> {
    let mut rng = Prng::new(seed);
    (0..n)
        .map(|i| {
            let mut data = vec![0u8; 200 + 13 * i];
            rng.fill_bytes(&mut data);
            InputFile {
                path: format!("train/class{}/img{i:03}.raw", i % 4),
                data,
            }
        })
        .collect()
}

/// Deterministically find an output path whose consistent-hash home is
/// `home` under this cluster's placement.
fn path_with_home(cluster: &Cluster, prefix: &str, home: u32) -> String {
    for i in 0..10_000 {
        let p = format!("{prefix}{i}.bin");
        if cluster.placement.output_home(&p) == home {
            return p;
        }
    }
    panic!("no candidate path hashes to node {home}");
}

#[test]
fn three_node_tcp_run_matches_inproc_exactly() {
    // every node reads the whole dataset in its own shuffled order, hinted
    // in mini-batches — once over mpsc, once over real loopback sockets
    let runs = run_transport_equivalence(
        &[TransportKind::InProc, TransportKind::TcpLoopback],
        3,
        36,
        2048,
        8,
    )
    .unwrap();
    assert_eq!(runs.len(), 2);
    let (inproc, tcp) = (&runs[0], &runs[1]);
    assert_eq!(inproc.digest, tcp.digest, "byte-identical reads");
    assert_eq!(inproc.bytes_read, tcp.bytes_read);
    assert_eq!(
        inproc.per_node, tcp.per_node,
        "node stats algebra must match exactly:\n inproc {:?}\n tcp {:?}",
        inproc.per_node, tcp.per_node
    );
    assert_eq!(inproc.cache, tcp.cache, "cache hit/miss algebra must match");
    assert_eq!(
        inproc.requests_served, tcp.requests_served,
        "same protocol, same round-trip count"
    );
    assert!(transport_runs_equivalent(inproc, tcp));
    // sanity: the workload actually exercised the fabric
    let remote: u64 = tcp.per_node.iter().map(|s| s.remote_reads_issued).sum();
    assert!(remote > 0, "3-node single-copy placement must read remotely");
}

#[test]
fn tcp_prefetch_pipeline_stress_exact_algebra() {
    // the batch_prefetch stress assertions, over real sockets
    const NODES: u32 = 3;
    const THREADS: usize = 4;
    const N_FILES: usize = 48;
    let files = inputs(N_FILES, 4);
    let cluster = Arc::new(
        Cluster::launch(
            &files,
            ClusterConfig {
                nodes: NODES,
                partitions: 6,
                prefetch_window: 8,
                prefetch_fetchers: 2,
                transport: TransportKind::TcpLoopback,
                ..Default::default()
            },
        )
        .unwrap(),
    );
    let expect: Arc<Vec<(String, Vec<u8>)>> = Arc::new(
        files
            .iter()
            .map(|f| (format!("/fanstore/user/{}", f.path), f.data.clone()))
            .collect(),
    );

    // every node schedules the full sequence once, shuffled per node
    let mut orders = Vec::new();
    for node in 0..NODES {
        let mut order: Vec<usize> = (0..N_FILES).collect();
        Prng::new(100 + node as u64).shuffle(&mut order);
        cluster
            .prefetch_handle(node)
            .schedule(order.iter().map(|&i| expect[i].0.clone()));
        orders.push(order);
    }

    // K trainer threads per node split each node's sequence round-robin
    let mut handles = Vec::new();
    for node in 0..NODES {
        for t in 0..THREADS {
            let cluster = Arc::clone(&cluster);
            let expect = Arc::clone(&expect);
            let order = orders[node as usize].clone();
            handles.push(std::thread::spawn(move || {
                let mut vfs = cluster.prefetching_client(node);
                let mut reads = 0u64;
                for (k, &i) in order.iter().enumerate() {
                    if k % THREADS != t {
                        continue;
                    }
                    let (path, want) = &expect[i];
                    assert_eq!(&vfs.read_all(path).unwrap(), want, "{path}");
                    reads += 1;
                }
                reads
            }));
        }
    }
    let total_reads: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total_reads, NODES as u64 * N_FILES as u64);

    let pf_stats: Vec<_> = (0..NODES).map(|n| cluster.prefetch_stats(n)).collect();
    for node in 0..NODES {
        let pf = &pf_stats[node as usize];
        assert_eq!(pf.scheduled, N_FILES as u64, "node {node}: {pf:?}");
        assert_eq!(pf.failed, 0, "node {node}: no faults over loopback TCP");
        assert_eq!(
            pf.claimed + pf.stolen,
            N_FILES as u64,
            "node {node}: every read claims or steals its path: {pf:?}"
        );
        assert_eq!(
            pf.picked + pf.stolen + pf.coalesced,
            N_FILES as u64,
            "node {node}: every scheduled path is picked, stolen, or coalesced: {pf:?}"
        );
    }
    cluster.stop_prefetchers();

    for node in 0..NODES {
        let pf = &pf_stats[node as usize];
        let st = cluster.node_state(node);
        let cs = st.cache.stats();
        let ns = st.stats.snapshot();
        assert_eq!(
            st.cache.resident_files(),
            0,
            "node {node}: descriptors closed + engines stopped -> empty cache"
        );
        assert_eq!(
            cs.hits + cs.misses,
            N_FILES as u64 - pf.claimed + pf.picked,
            "node {node}: acquire algebra: cache {cs:?}, pf {pf:?}"
        );
        assert_eq!(
            ns.local_reads + ns.remote_reads_issued,
            cs.misses,
            "node {node}: fetch algebra: {ns:?} vs {cs:?}"
        );
        assert_eq!(
            pf.picked,
            pf.prehits + pf.fetched_local + pf.fetched_remote,
            "node {node}: {pf:?}"
        );
        drop(st);
    }
    Arc::try_unwrap(cluster)
        .ok()
        .expect("all thread handles joined")
        .shutdown();
}

#[test]
fn tcp_output_lifecycle_commit_stat_read_unlink() {
    let files = inputs(8, 9);
    let cluster = Cluster::launch(
        &files,
        ClusterConfig {
            nodes: 3,
            partitions: 3,
            transport: TransportKind::TcpLoopback,
            ..Default::default()
        },
    )
    .unwrap();
    // writer on node 1, home forced to node 0, readers everywhere
    let path = path_with_home(&cluster, "/ckpt/tcp_a", 0);
    let ckpt = vec![0x5Au8; 4096];
    cluster.client(1).write_file(&path, &ckpt).unwrap();
    for node in 0..3 {
        let mut v = cluster.client(node);
        assert_eq!(v.stat(&path).unwrap().size, 4096, "visible on node {node}");
        assert_eq!(v.read_all(&path).unwrap(), ckpt, "readable on node {node}");
    }
    // readdir gathers homes over the sockets
    let names = cluster.client(2).readdir("/ckpt").unwrap();
    assert_eq!(names.len(), 1);
    // unlink from a node that is neither home nor origin; the origin
    // buffer must be GC'd through the socket path too
    cluster.client(2).unlink(&path).unwrap();
    assert!(
        !cluster
            .node_state(1)
            .output_data
            .read()
            .unwrap()
            .contains_key(&path),
        "origin buffer dropped over TCP"
    );
    assert!(cluster.client(0).stat(&path).is_err(), "name gone everywhere");
    cluster.shutdown();
}

#[test]
fn tcp_batched_stat_many_resumes_in_one_round_trip_per_home() {
    let files = inputs(6, 10);
    let cluster = Cluster::launch(
        &files,
        ClusterConfig {
            nodes: 3,
            partitions: 3,
            transport: TransportKind::TcpLoopback,
            ..Default::default()
        },
    )
    .unwrap();
    // multi-shard checkpoint: shards homed across the cluster
    let mut shard_paths = Vec::new();
    for (i, home) in [(0u32, 0u32), (1, 1), (2, 2), (3, 1)] {
        let p = path_with_home(&cluster, &format!("/resume/shard{i}_"), home);
        cluster
            .client(i % 3)
            .write_file(&p, &vec![i as u8; 100 + i as usize])
            .unwrap();
        shard_paths.push(p);
    }
    shard_paths.push("/resume/missing.bin".into());
    let mut reader = cluster.client(0);
    let stats = reader.stat_many(&shard_paths);
    assert_eq!(stats.len(), 5, "one result per path, in order");
    for (i, s) in stats.iter().take(4).enumerate() {
        assert_eq!(
            s.as_ref().unwrap().size,
            100 + i as u64,
            "{}",
            shard_paths[i]
        );
    }
    assert!(stats[4].is_err(), "missing shard reports ENOENT in place");
    // the batched stat warmed the meta cache: the subsequent shard opens
    // skip their StatOutput round trips (counted as output_meta_hits)
    for p in &shard_paths[..4] {
        reader.read_all(p).unwrap();
    }
    let hits = cluster.node_state(0).stats.snapshot().output_meta_hits;
    assert!(
        hits >= 2,
        "resume opens must reuse stat_many's cached metadata, hits={hits}"
    );
    cluster.shutdown();
}
