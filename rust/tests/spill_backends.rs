//! Backend equivalence for the syscall-lean data plane: every spilled-read
//! mode (`Reopen`/`Pread`/`Mmap`) must return byte-identical
//! `read_stored`/`read_raw` results — equal to the RAM backing — under
//! 8-thread concurrent reads, and the per-mode counters must tally every
//! read under the configured mode.  A cluster-level spin proves the
//! `ClusterConfig::spill_read_mode` knob reaches the node stores and that
//! the end-to-end read path is unchanged by the backing.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use fanstore::compress::Codec;
use fanstore::config::ClusterConfig;
use fanstore::coordinator::Cluster;
use fanstore::partition::builder::{build_partitions, InputFile};
use fanstore::storage::disk::{DiskStore, SpillReadMode};
use fanstore::util::prng::Prng;
use fanstore::vfs::Vfs;

/// Unique scratch dir, removed on drop (hygiene: concurrent tests in one
/// process must not collide, leftovers must not poison reruns).
struct TempDir(std::path::PathBuf);

impl TempDir {
    fn new(tag: &str) -> TempDir {
        static SEQ: AtomicU32 = AtomicU32::new(0);
        let dir = std::env::temp_dir().join(format!(
            "fanstore_spill_{tag}_{}_{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::remove_dir_all(&dir).ok();
        TempDir(dir)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.0).ok();
    }
}

/// Mixed compressible / incompressible files so both stored-bytes shapes
/// (compressed and raw) cross every backend.
fn dataset(n: usize) -> Vec<InputFile> {
    let mut rng = Prng::new(0x5B1A);
    (0..n)
        .map(|i| {
            let mut data = vec![0u8; 300 + rng.index(2048)];
            if i % 2 == 0 {
                rng.fill_bytes(&mut data);
            } else {
                data.fill((i % 251) as u8);
            }
            InputFile {
                path: format!("train/c{}/f{i:04}.raw", i % 3),
                data,
            }
        })
        .collect()
}

const MODES: [SpillReadMode; 3] = [
    SpillReadMode::Reopen,
    SpillReadMode::Pread,
    SpillReadMode::Mmap,
];

#[test]
fn spill_backends_byte_identical_under_concurrent_reads() {
    let files = dataset(48);
    let (blobs, _) = build_partitions(&files, 4, Codec::Lzss(3)).unwrap();

    // reference: the RAM backing
    let mut ram = DiskStore::in_memory();
    for (pid, b) in blobs.iter().enumerate() {
        ram.load_partition(pid as u32, b.clone(), "/m").unwrap();
    }
    let paths: Arc<Vec<String>> =
        Arc::new(files.iter().map(|f| format!("/m/{}", f.path)).collect());
    let expect_stored: Arc<Vec<Vec<u8>>> = Arc::new(
        paths
            .iter()
            .map(|p| ram.read_stored(p).unwrap().0.to_vec())
            .collect(),
    );
    let expect_raw: Arc<Vec<Vec<u8>>> = Arc::new(files.iter().map(|f| f.data.clone()).collect());

    for mode in MODES {
        let dir = TempDir::new(mode.name());
        let mut store = DiskStore::on_disk_with_mode(&dir.0, mode).unwrap();
        for (pid, b) in blobs.iter().enumerate() {
            store.load_partition(pid as u32, b.clone(), "/m").unwrap();
        }
        let store = Arc::new(store);
        let mut handles = Vec::new();
        for t in 0..8usize {
            let store = Arc::clone(&store);
            let paths = Arc::clone(&paths);
            let expect_stored = Arc::clone(&expect_stored);
            let expect_raw = Arc::clone(&expect_raw);
            handles.push(std::thread::spawn(move || {
                for i in 0..paths.len() * 4 {
                    let k = (t * 11 + i) % paths.len();
                    let (stored, at) = store.read_stored(&paths[k]).expect("read_stored");
                    assert_eq!(
                        &stored[..],
                        &expect_stored[k][..],
                        "{} stored bytes diverge on {}",
                        mode.name(),
                        paths[k]
                    );
                    assert_eq!(at.raw_len as usize, expect_raw[k].len());
                    assert_eq!(
                        store.read_raw(&paths[k]).expect("read_raw"),
                        expect_raw[k],
                        "{} raw bytes diverge on {}",
                        mode.name(),
                        paths[k]
                    );
                }
            }));
        }
        for h in handles {
            h.join().expect("no reader thread panicked");
        }
        // every spilled read tallied under the configured mode: 8 threads
        // × 4 rounds × paths, twice per iteration (read_stored + read_raw)
        let (reopen, pread, mmap) = store.spill_read_counts();
        let expected = 8 * 4 * paths.len() as u64 * 2;
        assert_eq!(reopen + pread + mmap, expected, "{}", mode.name());
        match mode {
            SpillReadMode::Reopen => assert_eq!((pread, mmap), (0, 0)),
            SpillReadMode::Pread => assert_eq!((reopen, mmap), (0, 0)),
            // mmap may fall back to pread if mapping is unavailable, but
            // must never reopen per read
            SpillReadMode::Mmap => {
                assert_eq!(reopen, 0);
                assert!(mmap > 0 || pread > 0);
            }
        }
    }
}

/// Payload-handle lifetime: a `Payload` returned by `read_stored` must
/// stay byte-valid after the backing file is unlinked AND after the store
/// itself is dropped — the `Arc` inside the handle is what keeps the RAM
/// blob alive / the mmap region mapped.  Concurrent readers hammering the
/// held handles while the store goes away must never observe freed bytes.
#[test]
fn payload_handles_survive_unlink_and_store_drop() {
    let files = dataset(32);
    let (blobs, _) = build_partitions(&files, 4, Codec::Lzss(3)).unwrap();
    let mut ram = DiskStore::in_memory();
    for (pid, b) in blobs.iter().enumerate() {
        ram.load_partition(pid as u32, b.clone(), "/m").unwrap();
    }
    let paths: Vec<String> = files.iter().map(|f| format!("/m/{}", f.path)).collect();
    let expect: Arc<Vec<Vec<u8>>> = Arc::new(
        paths
            .iter()
            .map(|p| ram.read_stored(p).unwrap().0.to_vec())
            .collect(),
    );

    // RAM backing participates too: its payloads are views into the Arc'd
    // partition blob, which the handles must keep alive past store drop
    let ram_payloads: Vec<_> = paths.iter().map(|p| ram.read_stored(p).unwrap().0).collect();
    drop(ram);
    for (p, want) in ram_payloads.iter().zip(expect.iter()) {
        assert_eq!(&p[..], &want[..], "RAM view outlives its store");
    }

    for mode in MODES {
        let dir = TempDir::new(&format!("lifetime_{}", mode.name()));
        let mut store = DiskStore::on_disk_with_mode(&dir.0, mode).unwrap();
        for (pid, b) in blobs.iter().enumerate() {
            store.load_partition(pid as u32, b.clone(), "/m").unwrap();
        }
        let payloads: Arc<Vec<_>> =
            Arc::new(paths.iter().map(|p| store.read_stored(p).unwrap().0).collect());
        // race 1: unlink the spilled partition files under the held maps
        // (mapped pages stay valid after unlink; pooled fds keep the inode)
        for entry in std::fs::read_dir(&dir.0).unwrap() {
            std::fs::remove_file(entry.unwrap().path()).ok();
        }
        // race 2: drop the store itself while 8 threads verify the handles
        let mut handles = Vec::new();
        for t in 0..8usize {
            let payloads = Arc::clone(&payloads);
            let expect = Arc::clone(&expect);
            let name = mode.name();
            handles.push(std::thread::spawn(move || {
                for round in 0..6 {
                    for i in 0..payloads.len() {
                        let k = (t * 13 + i) % payloads.len();
                        assert_eq!(
                            &payloads[k][..],
                            &expect[k][..],
                            "{name} round {round}: handle bytes diverged"
                        );
                    }
                }
            }));
        }
        drop(store); // SpillFiles + maps' own Arcs go away mid-verification
        for h in handles {
            h.join().expect("no reader observed freed bytes");
        }
        // the handles are the last owners now; still byte-identical
        for (p, want) in payloads.iter().zip(expect.iter()) {
            assert_eq!(&p[..], &want[..], "{} post-drop bytes", mode.name());
        }
    }
}

/// Spill-mode churn: stores over the same dataset are built and torn down
/// in every mode, back to back, while payload handles from each dead
/// incarnation are retained — all of them must stay byte-identical to the
/// reference regardless of which backing produced them.
#[test]
fn payload_handles_byte_identical_across_mode_churn() {
    let files = dataset(16);
    let (blobs, _) = build_partitions(&files, 2, Codec::Lzss(3)).unwrap();
    let mut ram = DiskStore::in_memory();
    for (pid, b) in blobs.iter().enumerate() {
        ram.load_partition(pid as u32, b.clone(), "/m").unwrap();
    }
    let paths: Vec<String> = files.iter().map(|f| format!("/m/{}", f.path)).collect();
    let expect: Vec<Vec<u8>> = paths
        .iter()
        .map(|p| ram.read_stored(p).unwrap().0.to_vec())
        .collect();

    let mut retained = Vec::new();
    for round in 0..3 {
        for mode in MODES {
            let dir = TempDir::new(&format!("churn_{round}_{}", mode.name()));
            let mut store = DiskStore::on_disk_with_mode(&dir.0, mode).unwrap();
            for (pid, b) in blobs.iter().enumerate() {
                store.load_partition(pid as u32, b.clone(), "/m").unwrap();
            }
            for (i, p) in paths.iter().enumerate() {
                let (payload, at) = store.read_stored(p).unwrap();
                assert_eq!(payload.len() as u64, at.stored_len);
                retained.push((i, mode.name(), payload));
            }
            // store (and its TempDir) die here; the handles live on
        }
    }
    assert_eq!(retained.len(), 3 * MODES.len() * paths.len());
    for (i, mode, payload) in &retained {
        assert_eq!(
            &payload[..],
            &expect[*i][..],
            "{mode}: retained handle diverged after churn"
        );
    }
}

/// Every LZSS level × every backing (RAM + the three spilled modes) must
/// round-trip byte-identically, with the stored codec visible on the
/// payload handle: the compressible half of the dataset comes back
/// `Codec::Lzss(l)`-tagged and smaller than raw, while the incompressible
/// half rides the reject path and is stored verbatim (`Codec::None`).
/// Returns (compressed, verbatim) file counts so the caller can prove
/// both shapes were exercised.
fn check_roundtrip(
    store: &DiskStore,
    files: &[InputFile],
    codec: Codec,
    tag: &str,
) -> (usize, usize) {
    let mut compressed = 0;
    let mut verbatim = 0;
    for f in files {
        let p = format!("/m/{}", f.path);
        let (stored, at) = store.read_stored(&p).unwrap();
        assert_eq!(at.raw_len as usize, f.data.len(), "{tag} {p} raw_len");
        assert_eq!(stored.codec(), at.codec, "{tag} {p} codec tag");
        match stored.codec() {
            Codec::None => {
                verbatim += 1;
                assert_eq!(&stored[..], &f.data[..], "{tag} {p} verbatim bytes");
            }
            c => {
                compressed += 1;
                assert_eq!(c, codec, "{tag} {p} stored under the wrong codec");
                assert!(stored.len() < f.data.len(), "{tag} {p} did not shrink");
                assert_eq!(
                    c.decompress(&stored, f.data.len()).unwrap(),
                    f.data,
                    "{tag} {p} decode mismatch"
                );
            }
        }
        assert_eq!(store.read_raw(&p).unwrap(), f.data, "{tag} {p} read_raw");
    }
    (compressed, verbatim)
}

#[test]
fn lzss_all_levels_roundtrip_across_all_spill_modes() {
    let files = dataset(8);
    for level in 1..=9u8 {
        let codec = Codec::Lzss(level);
        let (blobs, _) = build_partitions(&files, 2, codec).unwrap();

        let mut ram = DiskStore::in_memory();
        for (pid, b) in blobs.iter().enumerate() {
            ram.load_partition(pid as u32, b.clone(), "/m").unwrap();
        }
        let shapes = check_roundtrip(&ram, &files, codec, &format!("ram l{level}"));
        assert!(
            shapes.0 > 0 && shapes.1 > 0,
            "level {level}: the dataset must exercise both stored shapes, got {shapes:?}"
        );

        for mode in MODES {
            let dir = TempDir::new(&format!("lvl{level}_{}", mode.name()));
            let mut store = DiskStore::on_disk_with_mode(&dir.0, mode).unwrap();
            for (pid, b) in blobs.iter().enumerate() {
                store.load_partition(pid as u32, b.clone(), "/m").unwrap();
            }
            let tag = format!("{} l{level}", mode.name());
            assert_eq!(
                check_roundtrip(&store, &files, codec, &tag),
                shapes,
                "{tag}: stored shapes diverge from the RAM backing"
            );
        }
    }
}

#[test]
fn cluster_reads_identical_across_spill_modes() {
    let files = dataset(24);
    let mut digests: Vec<(String, Vec<Vec<u8>>)> = Vec::new();
    for mode in MODES {
        let dir = TempDir::new(&format!("cluster_{}", mode.name()));
        let cluster = Cluster::launch(
            &files,
            ClusterConfig {
                nodes: 3,
                partitions: 6,
                codec: Codec::Lzss(3),
                spill_dir: Some(dir.0.to_string_lossy().into_owned()),
                spill_read_mode: mode,
                ..Default::default()
            },
        )
        .unwrap();
        let mut vfs = cluster.client(0);
        let contents: Vec<Vec<u8>> = files
            .iter()
            .map(|f| vfs.read_all(&format!("/fanstore/user/{}", f.path)).unwrap())
            .collect();
        drop(vfs);
        let report = cluster.shutdown();
        // the knob reached the stores: reads landed on the right counter
        let spills: (u64, u64, u64) = report.per_node.iter().fold((0, 0, 0), |acc, s| {
            (
                acc.0 + s.spill_reads_reopen,
                acc.1 + s.spill_reads_pread,
                acc.2 + s.spill_reads_mmap,
            )
        });
        let total = spills.0 + spills.1 + spills.2;
        assert!(total > 0, "{}: spilled reads must be counted", mode.name());
        match mode {
            SpillReadMode::Reopen => assert_eq!((spills.1, spills.2), (0, 0)),
            SpillReadMode::Pread => assert_eq!((spills.0, spills.2), (0, 0)),
            SpillReadMode::Mmap => assert_eq!(spills.0, 0),
        }
        digests.push((mode.name().to_string(), contents));
    }
    for (f, want) in files.iter().zip(&digests[0].1) {
        assert_eq!(&f.data, want, "{}", f.path);
    }
    for pair in digests.windows(2) {
        assert_eq!(
            pair[0].1, pair[1].1,
            "{} and {} reads diverge",
            pair[0].0, pair[1].0
        );
    }
}
