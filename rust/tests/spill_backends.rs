//! Backend equivalence for the syscall-lean data plane: every spilled-read
//! mode (`Reopen`/`Pread`/`Mmap`) must return byte-identical
//! `read_stored`/`read_raw` results — equal to the RAM backing — under
//! 8-thread concurrent reads, and the per-mode counters must tally every
//! read under the configured mode.  A cluster-level spin proves the
//! `ClusterConfig::spill_read_mode` knob reaches the node stores and that
//! the end-to-end read path is unchanged by the backing.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use fanstore::compress::Codec;
use fanstore::config::ClusterConfig;
use fanstore::coordinator::Cluster;
use fanstore::partition::builder::{build_partitions, InputFile};
use fanstore::storage::disk::{DiskStore, SpillReadMode};
use fanstore::util::prng::Prng;
use fanstore::vfs::Vfs;

/// Unique scratch dir, removed on drop (hygiene: concurrent tests in one
/// process must not collide, leftovers must not poison reruns).
struct TempDir(std::path::PathBuf);

impl TempDir {
    fn new(tag: &str) -> TempDir {
        static SEQ: AtomicU32 = AtomicU32::new(0);
        let dir = std::env::temp_dir().join(format!(
            "fanstore_spill_{tag}_{}_{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::remove_dir_all(&dir).ok();
        TempDir(dir)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.0).ok();
    }
}

/// Mixed compressible / incompressible files so both stored-bytes shapes
/// (compressed and raw) cross every backend.
fn dataset(n: usize) -> Vec<InputFile> {
    let mut rng = Prng::new(0x5B1A);
    (0..n)
        .map(|i| {
            let mut data = vec![0u8; 300 + rng.index(2048)];
            if i % 2 == 0 {
                rng.fill_bytes(&mut data);
            } else {
                data.fill((i % 251) as u8);
            }
            InputFile {
                path: format!("train/c{}/f{i:04}.raw", i % 3),
                data,
            }
        })
        .collect()
}

const MODES: [SpillReadMode; 3] = [
    SpillReadMode::Reopen,
    SpillReadMode::Pread,
    SpillReadMode::Mmap,
];

#[test]
fn spill_backends_byte_identical_under_concurrent_reads() {
    let files = dataset(48);
    let (blobs, _) = build_partitions(&files, 4, Codec::Lzss(3)).unwrap();

    // reference: the RAM backing
    let mut ram = DiskStore::in_memory();
    for (pid, b) in blobs.iter().enumerate() {
        ram.load_partition(pid as u32, b.clone(), "/m").unwrap();
    }
    let paths: Arc<Vec<String>> =
        Arc::new(files.iter().map(|f| format!("/m/{}", f.path)).collect());
    let expect_stored: Arc<Vec<Vec<u8>>> = Arc::new(
        paths
            .iter()
            .map(|p| ram.read_stored(p).unwrap().0.to_vec())
            .collect(),
    );
    let expect_raw: Arc<Vec<Vec<u8>>> = Arc::new(files.iter().map(|f| f.data.clone()).collect());

    for mode in MODES {
        let dir = TempDir::new(mode.name());
        let mut store = DiskStore::on_disk_with_mode(&dir.0, mode).unwrap();
        for (pid, b) in blobs.iter().enumerate() {
            store.load_partition(pid as u32, b.clone(), "/m").unwrap();
        }
        let store = Arc::new(store);
        let mut handles = Vec::new();
        for t in 0..8usize {
            let store = Arc::clone(&store);
            let paths = Arc::clone(&paths);
            let expect_stored = Arc::clone(&expect_stored);
            let expect_raw = Arc::clone(&expect_raw);
            handles.push(std::thread::spawn(move || {
                for i in 0..paths.len() * 4 {
                    let k = (t * 11 + i) % paths.len();
                    let (stored, at) = store.read_stored(&paths[k]).expect("read_stored");
                    assert_eq!(
                        &stored[..],
                        &expect_stored[k][..],
                        "{} stored bytes diverge on {}",
                        mode.name(),
                        paths[k]
                    );
                    assert_eq!(at.raw_len as usize, expect_raw[k].len());
                    assert_eq!(
                        store.read_raw(&paths[k]).expect("read_raw"),
                        expect_raw[k],
                        "{} raw bytes diverge on {}",
                        mode.name(),
                        paths[k]
                    );
                }
            }));
        }
        for h in handles {
            h.join().expect("no reader thread panicked");
        }
        // every spilled read tallied under the configured mode: 8 threads
        // × 4 rounds × paths, twice per iteration (read_stored + read_raw)
        let (reopen, pread, mmap) = store.spill_read_counts();
        let expected = 8 * 4 * paths.len() as u64 * 2;
        assert_eq!(reopen + pread + mmap, expected, "{}", mode.name());
        match mode {
            SpillReadMode::Reopen => assert_eq!((pread, mmap), (0, 0)),
            SpillReadMode::Pread => assert_eq!((reopen, mmap), (0, 0)),
            // mmap may fall back to pread if mapping is unavailable, but
            // must never reopen per read
            SpillReadMode::Mmap => {
                assert_eq!(reopen, 0);
                assert!(mmap > 0 || pread > 0);
            }
        }
    }
}

#[test]
fn cluster_reads_identical_across_spill_modes() {
    let files = dataset(24);
    let mut digests: Vec<(String, Vec<Vec<u8>>)> = Vec::new();
    for mode in MODES {
        let dir = TempDir::new(&format!("cluster_{}", mode.name()));
        let cluster = Cluster::launch(
            &files,
            ClusterConfig {
                nodes: 3,
                partitions: 6,
                codec: Codec::Lzss(3),
                spill_dir: Some(dir.0.to_string_lossy().into_owned()),
                spill_read_mode: mode,
                ..Default::default()
            },
        )
        .unwrap();
        let mut vfs = cluster.client(0);
        let contents: Vec<Vec<u8>> = files
            .iter()
            .map(|f| vfs.read_all(&format!("/fanstore/user/{}", f.path)).unwrap())
            .collect();
        drop(vfs);
        let report = cluster.shutdown();
        // the knob reached the stores: reads landed on the right counter
        let spills: (u64, u64, u64) = report.per_node.iter().fold((0, 0, 0), |acc, s| {
            (
                acc.0 + s.spill_reads_reopen,
                acc.1 + s.spill_reads_pread,
                acc.2 + s.spill_reads_mmap,
            )
        });
        let total = spills.0 + spills.1 + spills.2;
        assert!(total > 0, "{}: spilled reads must be counted", mode.name());
        match mode {
            SpillReadMode::Reopen => assert_eq!((spills.1, spills.2), (0, 0)),
            SpillReadMode::Pread => assert_eq!((spills.0, spills.2), (0, 0)),
            SpillReadMode::Mmap => assert_eq!(spills.0, 0),
        }
        digests.push((mode.name().to_string(), contents));
    }
    for (f, want) in files.iter().zip(&digests[0].1) {
        assert_eq!(&f.data, want, "{}", f.path);
    }
    for pair in digests.windows(2) {
        assert_eq!(
            pair[0].1, pair[1].1,
            "{} and {} reads diverge",
            pair[0].0, pair[1].0
        );
    }
}
