//! Classification dataset for the end-to-end training path.
//!
//! Each dataset file is one raw 32×32×3 u8 image (3072 bytes) whose class
//! is encoded in its directory name (`train/class07/img123.raw`), mirroring
//! the ImageNet directory-per-class layout of §2.  Images are Gaussian
//! noise plus a class-dependent bright vertical band — learnable by the CNN
//! surrogate, and class-separable enough that the Fig 1 global-vs-
//! partitioned gap reproduces.

use crate::error::{FanError, Result};
use crate::partition::builder::InputFile;
use crate::runtime::tensor::Tensor;
use crate::util::prng::Prng;
use crate::vfs::Vfs;

pub const IMG_HW: usize = 32;
pub const IMG_BYTES: usize = IMG_HW * IMG_HW * 3;
pub const CLASSES: usize = 10;

/// Generate `n` labelled image files (`prefix/classCC/imgNNNN.raw`).
///
/// Files are emitted in *class-directory order* (all of class 0, then all
/// of class 1, …), matching how a real dataset traversal enumerates
/// ImageNet's per-class directories.  On top of the class band, every image
/// carries an exposure (brightness) factor.
///
/// * `ordered_exposure = true` (training data): exposure drifts with file
///   order — the acquisition-drift artifact real instrument datasets have.
///   Combined with class-directory order this is what makes the Fig 1
///   partitioned view lose accuracy: an exclusive contiguous shard sees
///   each class under a *narrow* exposure range, so the averaged model has
///   never seen e.g. class 0 at high exposure.
/// * `ordered_exposure = false` (test data): exposure is i.i.d.
pub fn gen_classification_dataset_ex(
    n: usize,
    prefix: &str,
    seed: u64,
    ordered_exposure: bool,
) -> Vec<InputFile> {
    let mut rng = Prng::new(seed ^ 0xC1A55);
    (0..n)
        .map(|i| {
            let label = i * CLASSES / n.max(1);
            // exposure factor in [0.45, 1.40]
            let u = if ordered_exposure {
                // drift across the *within-class* file order so every class
                // spans the full exposure range across the dataset
                (i % (n / CLASSES).max(1)) as f64 / ((n / CLASSES).max(1) as f64)
            } else {
                rng.f64()
            };
            let m = 0.45 + 0.95 * u;
            let px = |base: u32, rng: &mut Prng, spread: u64| -> u8 {
                ((base + rng.below(spread) as u32) as f64 * m).min(255.0) as u8
            };
            let mut img = vec![0u8; IMG_BYTES];
            for b in img.iter_mut() {
                *b = px(20, &mut rng, 40); // dim noise
            }
            // bright band for class k at columns [k*3, k*3+3)
            let band = IMG_HW / CLASSES;
            for y in 0..IMG_HW {
                for x in (label * band)..((label + 1) * band) {
                    for c in 0..3 {
                        img[(y * IMG_HW + x) * 3 + c] = px(170, &mut rng, 55);
                    }
                }
            }
            InputFile {
                path: format!("{prefix}/class{label:02}/img{i:05}.raw"),
                data: img,
            }
        })
        .collect()
}

/// Training-data defaults: class-directory order + exposure drift.
pub fn gen_classification_dataset(n: usize, prefix: &str, seed: u64) -> Vec<InputFile> {
    gen_classification_dataset_ex(n, prefix, seed, true)
}

/// Parse the label out of a dataset path.
pub fn label_of(path: &str) -> Result<i32> {
    path.split('/')
        .find_map(|c| c.strip_prefix("class"))
        .and_then(|s| s.parse::<i32>().ok())
        .ok_or_else(|| FanError::Config(format!("no class label in path {path}")))
}

/// Read a mini-batch through the VFS into (images u8 [B,32,32,3], labels).
/// Short batches are padded by replicating the last sample (the runtime's
/// shapes are static).
pub fn read_batch(
    vfs: &mut dyn Vfs,
    paths: &[String],
    idx: &[u32],
    batch: usize,
) -> Result<(Tensor, Vec<i32>)> {
    assert!(!idx.is_empty());
    // batched read-ahead hint: FanStore turns this into one ReadFiles
    // round trip per owner node (or a claim from the prefetch pipeline)
    // instead of a synchronous round trip per file
    let batch_paths: Vec<String> = idx.iter().map(|&i| paths[i as usize].clone()).collect();
    vfs.prefetch(&batch_paths)?;
    let mut data = Vec::with_capacity(batch * IMG_BYTES);
    let mut labels = Vec::with_capacity(batch);
    for k in 0..batch {
        let i = idx[k.min(idx.len() - 1)] as usize; // pad by repeating last
        let path = &paths[i];
        let bytes = vfs.read_all(path)?;
        if bytes.len() != IMG_BYTES {
            return Err(FanError::Format(format!(
                "{path}: expected {IMG_BYTES} bytes, got {}",
                bytes.len()
            )));
        }
        data.extend_from_slice(&bytes);
        labels.push(label_of(path)?);
    }
    Ok((
        Tensor::from_u8(&[batch, IMG_HW, IMG_HW, 3], data),
        labels,
    ))
}

/// Serialize parameters for checkpointing (raw LE f32 concat, as the AOT
/// params.bin format).
pub fn serialize_params(params: &[Tensor]) -> Vec<u8> {
    let mut out = Vec::new();
    for p in params {
        out.extend_from_slice(&p.data);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_shape_and_labels() {
        let files = gen_classification_dataset(25, "train", 1);
        assert_eq!(files.len(), 25);
        for (i, f) in files.iter().enumerate() {
            assert_eq!(f.data.len(), IMG_BYTES);
            assert_eq!(label_of(&f.path).unwrap(), (i * CLASSES / 25) as i32);
        }
        // class-directory order: labels are non-decreasing and cover 0..9
        let labels: Vec<i32> = files.iter().map(|f| label_of(&f.path).unwrap()).collect();
        assert!(labels.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(*labels.last().unwrap(), 9);
    }

    #[test]
    fn band_brighter_than_noise() {
        // exposure varies per file, so assert *contrast*, not absolutes
        let files = gen_classification_dataset(10, "t", 2);
        let f = &files[3]; // 10 files -> file 3 is class 3: columns 9..12 bright
        let y = 16;
        let bright = f.data[(y * IMG_HW + 10) * 3] as u32;
        let dim = f.data[(y * IMG_HW + 20) * 3] as u32;
        assert!(bright > 2 * dim, "bright={bright} dim={dim}");
    }

    #[test]
    fn exposure_drifts_within_class_for_training_data() {
        let files = gen_classification_dataset_ex(100, "t", 3, true);
        // first and last file of class 0 differ in overall brightness
        let lum = |f: &InputFile| f.data.iter().map(|&b| b as u64).sum::<u64>();
        assert!(lum(&files[9]) > lum(&files[0]) * 3 / 2);
    }

    #[test]
    fn label_parse_failures() {
        assert!(label_of("/x/y/z.raw").is_err());
        assert_eq!(label_of("/m/train/class07/a.raw").unwrap(), 7);
    }

    #[test]
    fn serialize_concats() {
        let p = vec![
            Tensor::from_f32(&[1], &[1.0]),
            Tensor::from_f32(&[2], &[2.0, 3.0]),
        ];
        assert_eq!(serialize_params(&p).len(), 12);
    }
}
