//! Distributed training driver (paper §3.1's loop, implemented over the
//! FanStore VFS + the PJRT runtime).
//!
//! Data-parallel synchronous SGD: every node holds a replica of the
//! parameters, draws its own mini-batch *through the FanStore read path*
//! (open → cache → decompress → decode), executes the AOT train-step
//! (which embeds the Pallas preprocess kernel: decode+normalize+augment+
//! fwd+bwd+SGD in one PJRT call), then an Allreduce averages the updated
//! replicas — algebraically identical to gradient averaging for SGD:
//! `avg(p - lr·g_i) = p - lr·avg(g_i)`.
//!
//! Checkpoints are written back through the VFS (visible-until-close), and
//! validation sweeps the replicated test directory, exactly the I/O pattern
//! of §3.4.

pub mod data;

use crate::coordinator::Cluster;
use crate::error::{FanError, Result};
use crate::runtime::tensor::{DType, Tensor};
use crate::runtime::Engine;
use crate::util::prng::Prng;
use crate::vfs::Vfs;
use crate::workload::access::EpochSampler;

/// Global vs partitioned dataset view (the Fig 1 ablation, §3.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetView {
    Global,
    Partitioned,
}

/// Training options.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub epochs: u32,
    /// Steps per epoch cap (None = full epoch).
    pub max_steps_per_epoch: Option<u32>,
    pub lr: f32,
    pub view: DatasetView,
    pub seed: u64,
    /// Write a checkpoint at each epoch end (through the VFS).
    pub checkpoint: bool,
    /// Horizontal-flip augmentation probability.  Defaults to 0 because the
    /// synthetic classification set encodes the label in band *position*, so
    /// flipping destroys it; the flip path itself is covered by the Pallas
    /// kernel tests and the preprocess_batch artifact.
    pub flip_prob: f64,
    /// Overlap remote reads with compute via each node's background
    /// prefetch pipeline (the paper's §5.4 worker threads).  On by
    /// default; correctness is identical either way — claims fall back to
    /// the synchronous path whenever the pipeline doesn't hold a file.
    pub prefetch: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 3,
            max_steps_per_epoch: None,
            lr: 0.05,
            view: DatasetView::Global,
            seed: 7,
            checkpoint: true,
            flip_prob: 0.0,
            prefetch: true,
        }
    }
}

/// Per-epoch record.
#[derive(Clone, Copy, Debug)]
pub struct EpochLog {
    pub epoch: u32,
    pub mean_loss: f32,
    pub train_acc: f32,
    pub test_acc: f32,
    pub files_read: u64,
    pub seconds: f64,
}

/// Full run record.
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    pub epochs: Vec<EpochLog>,
    pub step_losses: Vec<f32>,
}

impl TrainLog {
    pub fn final_test_acc(&self) -> f32 {
        self.epochs.last().map(|e| e.test_acc).unwrap_or(0.0)
    }

    pub fn files_per_sec(&self) -> f64 {
        let files: u64 = self.epochs.iter().map(|e| e.files_read).sum();
        let secs: f64 = self.epochs.iter().map(|e| e.seconds).sum();
        if secs > 0.0 {
            files as f64 / secs
        } else {
            0.0
        }
    }
}

/// Per-node running normalization statistics (the BatchNorm-like state).
///
/// Framework BN keeps running mean/variance as *buffers*, not parameters:
/// Horovod allreduces gradients but NOT these, and the rank-0 copy is what
/// checkpoints/evaluation use.  That asymmetry is exactly what the Fig 1
/// partitioned view breaks — each node's statistics are estimated only from
/// the data its view lets it sample.
#[derive(Clone, Debug)]
pub struct NormStats {
    pub mean: [f32; 3],
    pub std: [f32; 3],
    batches: u32,
}

impl NormStats {
    /// Start from the conventional ImageNet priors (matches model.py).
    pub fn imagenet_prior() -> Self {
        NormStats {
            mean: [125.3, 123.0, 113.9],
            std: [63.0, 62.1, 66.7],
            batches: 0,
        }
    }

    /// Fold one u8 image batch into the running estimate (momentum 0.9,
    /// the framework default).
    pub fn update(&mut self, images: &Tensor) {
        debug_assert_eq!(images.dtype, DType::U8);
        let mut sum = [0f64; 3];
        let mut sum2 = [0f64; 3];
        let n = images.data.len() / 3;
        for px in images.data.chunks_exact(3) {
            for c in 0..3 {
                let v = px[c] as f64;
                sum[c] += v;
                sum2[c] += v * v;
            }
        }
        let momentum = 0.9f32;
        for c in 0..3 {
            let m = (sum[c] / n as f64) as f32;
            let var = (sum2[c] / n as f64 - (sum[c] / n as f64).powi(2)).max(1.0) as f32;
            let s = var.sqrt();
            if self.batches == 0 {
                self.mean[c] = m;
                self.std[c] = s;
            } else {
                self.mean[c] = momentum * self.mean[c] + (1.0 - momentum) * m;
                self.std[c] = momentum * self.std[c] + (1.0 - momentum) * s;
            }
        }
        self.batches += 1;
    }

    pub fn mean_tensor(&self) -> Tensor {
        Tensor::from_f32(&[3], &self.mean)
    }

    pub fn std_tensor(&self) -> Tensor {
        Tensor::from_f32(&[3], &self.std)
    }
}

/// Elementwise mean of per-node parameter replicas (the Allreduce).
pub fn allreduce_mean(replicas: &[Vec<Tensor>]) -> Result<Vec<Tensor>> {
    let n = replicas.len();
    if n == 0 {
        return Err(FanError::Runtime("allreduce over zero replicas".into()));
    }
    let width = replicas[0].len();
    let mut out = Vec::with_capacity(width);
    for t in 0..width {
        let mut acc = replicas[0][t].as_f32()?;
        for replica in &replicas[1..] {
            for (a, b) in acc.iter_mut().zip(replica[t].as_f32()?) {
                *a += b;
            }
        }
        let inv = 1.0 / n as f32;
        for a in acc.iter_mut() {
            *a *= inv;
        }
        out.push(Tensor::from_f32(&replicas[0][t].dims, &acc));
    }
    Ok(out)
}

/// Train the CNN surrogate on a classification dataset staged in `cluster`.
///
/// `train_paths`/`test_paths` are FanStore paths of the image files produced
/// by [`data::gen_classification_dataset`] (label encoded in the path).
pub fn train_cnn(
    cluster: &Cluster,
    engine: &Engine,
    train_paths: &[String],
    test_paths: &[String],
    cfg: &TrainConfig,
) -> Result<TrainLog> {
    let spec = engine.spec("cnn_train_step")?.clone();
    let n_params = spec.param_count();
    let batch_spec = &spec.inputs[n_params]; // images input
    let batch = batch_spec.dims[0];
    let mut params = spec.load_params()?;

    let nodes = cluster.node_count();
    let mut clients: Vec<_> = (0..nodes)
        .map(|n| {
            if cfg.prefetch {
                cluster.prefetching_client(n)
            } else {
                cluster.client(n)
            }
        })
        .collect();
    // per-node prefetch pipelines: each epoch's shuffled access sequence is
    // scheduled ahead of the cursor, so fetchers overlap the train steps
    let pf_handles: Vec<Option<crate::prefetch::PrefetchHandle>> = (0..nodes)
        .map(|n| cfg.prefetch.then(|| cluster.prefetch_handle(n)))
        .collect();
    // one interned path table for the whole run: per-epoch scheduling
    // pushes the sampler's u32 indices, never path strings
    let epoch_table = cfg.prefetch.then(|| {
        std::sync::Arc::new(crate::prefetch::EpochPathTable::from_paths(train_paths))
    });
    let mut samplers: Vec<EpochSampler> = (0..nodes)
        .map(|n| match cfg.view {
            DatasetView::Global => EpochSampler::new(train_paths.len(), cfg.seed + n as u64),
            DatasetView::Partitioned => {
                EpochSampler::partitioned(train_paths.len(), n, nodes, cfg.seed)
            }
        })
        .collect();
    let mut rng = Prng::new(cfg.seed ^ 0xF11F);
    let mut log = TrainLog::default();
    // per-node normalization state (BN-like buffers, never allreduced)
    let mut norm: Vec<NormStats> = (0..nodes).map(|_| NormStats::imagenet_prior()).collect();
    // cross-epoch prefetch bookkeeping: entries at the head of the next
    // epoch's draw order already scheduled while the previous epoch's tail
    // drained (the top-of-epoch schedule skips them)
    let mut scheduled_ahead: Vec<usize> = vec![0; nodes as usize];

    // steps per epoch: one epoch consumes the dataset once *across the
    // cluster* (Horovod semantics) — each node contributes 1/N of it,
    // whichever view it samples from.
    let pop = train_paths.len().div_ceil(nodes as usize);
    let full_steps = pop.div_ceil(batch) as u32;

    for epoch in 0..cfg.epochs {
        let t0 = std::time::Instant::now();
        let mut losses = Vec::new();
        let mut accs = Vec::new();
        let mut files_read = 0u64;
        let steps_this_epoch = cfg
            .max_steps_per_epoch
            .map(|c| c.min(full_steps))
            .unwrap_or(full_steps);
        // schedule exactly this epoch's consumption window; anything the
        // sampler draws beyond it (an epoch wrap mid-loop) just falls back
        // to the synchronous read path
        let horizon = steps_this_epoch as usize * batch;
        for (node, handle) in pf_handles.iter().enumerate() {
            if let (Some(h), Some(table)) = (handle, &epoch_table) {
                // sampler indices ARE table indices (the table was built
                // from `train_paths` in order).  `draw_window` resolves the
                // effective order — at an exact epoch boundary that is the
                // pre-committed next-epoch order the sampler adopts on its
                // first draw; without it `upcoming()` is empty there and
                // the whole epoch would read cold.  Skip whatever the
                // cross-epoch hook below already queued.
                let ahead = scheduled_ahead[node];
                let order = samplers[node].draw_window(ahead, horizon.saturating_sub(ahead));
                scheduled_ahead[node] = 0;
                h.schedule_table(table, order);
            }
        }
        for step in 0..steps_this_epoch {
            // each node draws + reads + steps; then allreduce
            let mut replicas = Vec::with_capacity(nodes as usize);
            for node in 0..nodes as usize {
                // When the sampler wraps (None -> adopt/reshuffle) MID-epoch
                // (partitioned views, capped epochs), pre-commit the next
                // order and warm its head through the pipeline BEFORE the
                // wrap adopts it — pre-committing draws the RNG identically,
                // so the sampled sequence is unchanged, but the post-wrap
                // stretch no longer reads cold.  The stretch is capped at
                // this epoch's remaining consumption, so everything queued
                // here is claimed before the next schedule point.
                let idx = match samplers[node].next_batch(batch) {
                    Some(idx) => idx,
                    None => {
                        if let (Some(h), Some(table)) = (&pf_handles[node], &epoch_table) {
                            samplers[node].precommit_next();
                            let left = (steps_this_epoch - step) as usize * batch;
                            let stretch = cluster.config.prefetch_window.min(left);
                            let ids = samplers[node].draw_window(0, stretch);
                            h.schedule_table(table, ids);
                        }
                        samplers[node]
                            .next_batch(batch)
                            .expect("reshuffled epoch is non-empty")
                    }
                };
                let (images, labels) =
                    data::read_batch(&mut clients[node], train_paths, &idx, batch)?;
                files_read += idx.len() as u64;
                norm[node].update(&images);
                let flip: Vec<i32> = (0..batch)
                    .map(|_| if rng.chance(cfg.flip_prob) { 1 } else { 0 })
                    .collect();
                let mut inputs = params.clone();
                inputs.push(images);
                inputs.push(Tensor::from_i32(&[batch], &labels));
                inputs.push(Tensor::from_i32(&[batch], &flip));
                inputs.push(norm[node].mean_tensor());
                inputs.push(norm[node].std_tensor());
                inputs.push(Tensor::scalar_f32(cfg.lr));
                let out = engine.execute("cnn_train_step", &inputs)?;
                losses.push(out[n_params].scalar_value()?);
                accs.push(out[n_params + 1].scalar_value()?);
                replicas.push(out[..n_params].to_vec());
            }
            params = allreduce_mean(&replicas)?;
            log.step_losses.push(*losses.last().unwrap());
        }

        // Cross-epoch prefetch: pre-commit epoch N+1's sampler order and
        // schedule its head NOW, so the fetchers warm it while validation
        // and checkpointing drain epoch N's tail — no per-epoch cold start.
        // The head is capped at one prefetch window (the engine cannot pin
        // more anyway); the top-of-epoch schedule skips these entries.
        if epoch + 1 < cfg.epochs {
            for (node, handle) in pf_handles.iter().enumerate() {
                if let (Some(h), Some(table)) = (handle, &epoch_table) {
                    let head = cluster.config.prefetch_window.min(horizon);
                    let ids = samplers[node].draw_window(0, head);
                    scheduled_ahead[node] = ids.len();
                    h.schedule_table(table, ids);
                }
            }
        }

        // validation: rank 0 sweeps the (replicated) test set using ITS
        // normalization buffers — exactly what a Horovod+BN checkpoint does.
        let test_acc = evaluate_cnn(&mut clients[0], engine, test_paths, &params, &norm[0])?;
        let mean_loss = losses.iter().sum::<f32>() / losses.len().max(1) as f32;
        let train_acc = accs.iter().sum::<f32>() / accs.len().max(1) as f32;

        if cfg.checkpoint {
            // rank-0 checkpoint, epoch-labelled file (§3.4 / note 2)
            let blob = data::serialize_params(&params);
            clients[0].write_file(
                &format!("/ckpt/model_epoch{epoch:03}_{:?}.bin", cfg.view),
                &blob,
            )?;
        }

        log.epochs.push(EpochLog {
            epoch,
            mean_loss,
            train_acc,
            test_acc,
            files_read,
            seconds: t0.elapsed().as_secs_f64(),
        });
    }
    Ok(log)
}

/// Accuracy of `params` over the test set, read through the VFS,
/// normalized with `norm` (the evaluating rank's buffers).
pub fn evaluate_cnn(
    vfs: &mut dyn Vfs,
    engine: &Engine,
    test_paths: &[String],
    params: &[Tensor],
    norm: &NormStats,
) -> Result<f32> {
    let spec = engine.spec("cnn_eval_step")?.clone();
    let img_input = &spec.inputs[params.len()];
    let batch = img_input.dims[0];
    let mut correct = 0.0f32;
    let mut total = 0usize;
    let mut i = 0;
    while i < test_paths.len() {
        let end = (i + batch).min(test_paths.len());
        let idx: Vec<u32> = (i as u32..end as u32).collect();
        let (images, labels) = data::read_batch(vfs, test_paths, &idx, batch)?;
        let mut inputs: Vec<Tensor> = params.to_vec();
        inputs.push(images);
        inputs.push(Tensor::from_i32(&[batch], &labels));
        inputs.push(norm.mean_tensor());
        inputs.push(norm.std_tensor());
        let out = engine.execute("cnn_eval_step", &inputs)?;
        // out1 counts correct over the padded batch; subtract padding wins
        let batch_correct = out[1].scalar_value()?;
        // padded entries replicate the last real sample; count only real
        let real = (end - i) as f32;
        correct += batch_correct * real / batch as f32;
        total += end - i;
        i = end;
    }
    Ok(if total == 0 { 0.0 } else { correct / total as f32 })
}

/// Make a flip vector deterministically (exposed for tests).
pub fn flips(rng: &mut Prng, n: usize) -> Vec<i32> {
    (0..n).map(|_| if rng.chance(0.5) { 1 } else { 0 }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allreduce_mean_averages() {
        let a = vec![Tensor::from_f32(&[2], &[1.0, 2.0])];
        let b = vec![Tensor::from_f32(&[2], &[3.0, 6.0])];
        let m = allreduce_mean(&[a, b]).unwrap();
        assert_eq!(m[0].as_f32().unwrap(), vec![2.0, 4.0]);
    }

    #[test]
    fn allreduce_identity_for_single_replica() {
        let a = vec![Tensor::from_f32(&[3], &[1.0, 2.0, 3.0])];
        let m = allreduce_mean(&[a.clone()]).unwrap();
        assert_eq!(m[0].as_f32().unwrap(), a[0].as_f32().unwrap());
    }

    #[test]
    fn allreduce_empty_errors() {
        assert!(allreduce_mean(&[]).is_err());
    }
}
