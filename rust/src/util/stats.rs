//! Summary statistics for benchmark reporting (mean, stddev, percentiles).

/// Online-collected sample summary.  Used by the experiment harness for the
//  per-figure tables (bandwidth, files/s, per-op latency percentiles).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Summary::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.sum() / self.samples.len() as f64
    }

    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (n - 1) as f64;
        var.sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Nearest-rank percentile, `p` in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
        sorted[rank.min(sorted.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled() -> Summary {
        let mut s = Summary::new();
        for i in 1..=100 {
            s.add(i as f64);
        }
        s
    }

    #[test]
    fn mean_of_1_to_100() {
        assert!((filled().mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let s = filled();
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert!((s.percentile(50.0) - 50.0).abs() <= 1.0);
    }

    #[test]
    fn stddev_known() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert!((s.stddev() - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn empty_is_safe() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
    }
}
