//! Minimal property-testing harness (no external deps are vendored for
//! proptest, so we roll the 5% of it we need).
//!
//! A property runs against `iters` deterministic random cases; on failure it
//! performs greedy input shrinking via the case seed's bit-halving and
//! reports the smallest failing seed.  Coordinator invariants (routing,
//! batching, cache state, partition round-trips) use this.

use crate::util::prng::Prng;

/// Run `prop(case_rng)` for `iters` cases derived from `seed`.
/// Panics with the failing case seed on first violation.
pub fn check<F>(name: &str, seed: u64, iters: u32, mut prop: F)
where
    F: FnMut(&mut Prng) -> Result<(), String>,
{
    for i in 0..iters {
        let case_seed = seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(i as u64);
        let mut rng = Prng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            // Greedy shrink: try seeds with progressively fewer set bits to
            // find a "smaller" reproduction (smaller draws downstream).
            let mut best = (case_seed, msg.clone());
            let mut cand = case_seed;
            for _ in 0..16 {
                cand >>= 1;
                if cand == 0 {
                    break;
                }
                let mut r = Prng::new(cand);
                if let Err(m) = prop(&mut r) {
                    best = (cand, m);
                }
            }
            panic!(
                "property '{name}' failed (case {i}, seed {:#x}): {}",
                best.0, best.1
            );
        }
    }
}

/// Convenience: assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err(format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_iters() {
        let mut count = 0;
        check("trivial", 1, 50, |rng| {
            count += 1;
            let x = rng.below(100);
            if x < 100 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics() {
        check("fails", 1, 10, |rng| {
            if rng.below(4) != 0 {
                Ok(())
            } else {
                Err("hit zero".into())
            }
        });
    }
}
