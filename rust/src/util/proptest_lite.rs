//! Minimal property-testing harness (no external deps are vendored for
//! proptest, so we roll the 5% of it we need).
//!
//! A property runs against `iters` deterministic random cases; on failure it
//! performs greedy input shrinking via the case seed's bit-halving and
//! reports the smallest failing seed.  Coordinator invariants (routing,
//! batching, cache state, partition round-trips) use this.

use crate::util::prng::Prng;

/// Run `prop(case_rng)` for `iters` cases derived from `seed`.
/// Panics with the failing case seed on first violation.
pub fn check<F>(name: &str, seed: u64, iters: u32, mut prop: F)
where
    F: FnMut(&mut Prng) -> Result<(), String>,
{
    for i in 0..iters {
        let case_seed = seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(i as u64);
        let mut rng = Prng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            // Greedy shrink: try seeds with progressively fewer set bits to
            // find a "smaller" reproduction (smaller draws downstream).
            let mut best = (case_seed, msg.clone());
            let mut cand = case_seed;
            for _ in 0..16 {
                cand >>= 1;
                if cand == 0 {
                    break;
                }
                let mut r = Prng::new(cand);
                if let Err(m) = prop(&mut r) {
                    best = (cand, m);
                }
            }
            panic!(
                "property '{name}' failed (case {i}, seed {:#x}): {}",
                best.0, best.1
            );
        }
    }
}

/// Greedily shrink a failing byte input to a (locally) minimal one.
///
/// `fails(bytes)` must return `true` for the original input.  The shrinker
/// applies three passes to a fixpoint, keeping any candidate that still
/// fails:
///
/// 1. **Halve/truncate** — drop the back half, then the front half, then
///    progressively smaller chunks from anywhere in the input (removing a
///    chunk is how spliced/duplicated garbage disappears).
/// 2. **Simplify** — replace bytes with `0` (the "simplest" byte), one
///    chunk at a time.
/// 3. **Trim** — single-byte removals once chunks stop helping.
///
/// The result is minimal in the 1-removal / 1-zeroing neighborhood: no
/// single byte can be removed or zeroed without the failure vanishing.
/// Deterministic — no randomness; same input + predicate, same output.
pub fn shrink_bytes(input: &[u8], mut fails: impl FnMut(&[u8]) -> bool) -> Vec<u8> {
    debug_assert!(fails(input), "shrink_bytes needs a failing input");
    let mut best = input.to_vec();
    loop {
        let mut improved = false;
        // pass 1: chunk removal, chunk size halving from len/2 down to 1
        let mut chunk = (best.len() / 2).max(1);
        while chunk >= 1 {
            let mut start = 0;
            while start < best.len() {
                let end = (start + chunk).min(best.len());
                let mut cand = Vec::with_capacity(best.len() - (end - start));
                cand.extend_from_slice(&best[..start]);
                cand.extend_from_slice(&best[end..]);
                if fails(&cand) {
                    best = cand;
                    improved = true;
                    // retry the same offset: the next chunk slid into place
                } else {
                    start += chunk;
                }
            }
            if chunk == 1 {
                break;
            }
            chunk /= 2;
        }
        // pass 2: zero out bytes (chunked, then singly) — simpler content,
        // same length
        let mut chunk = (best.len() / 2).max(1);
        while chunk >= 1 {
            let mut start = 0;
            while start < best.len() {
                let end = (start + chunk).min(best.len());
                if best[start..end].iter().any(|&b| b != 0) {
                    let mut cand = best.clone();
                    cand[start..end].fill(0);
                    if fails(&cand) {
                        best = cand;
                        improved = true;
                    }
                }
                start += chunk;
            }
            if chunk == 1 {
                break;
            }
            chunk /= 2;
        }
        if !improved {
            return best;
        }
    }
}

/// [`shrink_bytes`] for op sequences: greedily remove schedule entries
/// (back-half first, then smaller chunks, then single ops) while the
/// sequence still fails.  Ops are opaque — only removal simplifies, so
/// the result is 1-removal minimal.  Used by the store fuzzer to report
/// minimal failing schedules.
pub fn shrink_seq<T: Clone>(input: &[T], mut fails: impl FnMut(&[T]) -> bool) -> Vec<T> {
    debug_assert!(fails(input), "shrink_seq needs a failing input");
    let mut best = input.to_vec();
    loop {
        let mut improved = false;
        let mut chunk = (best.len() / 2).max(1);
        while chunk >= 1 {
            let mut start = 0;
            while start < best.len() {
                let end = (start + chunk).min(best.len());
                let mut cand = Vec::with_capacity(best.len() - (end - start));
                cand.extend_from_slice(&best[..start]);
                cand.extend_from_slice(&best[end..]);
                if fails(&cand) {
                    best = cand;
                    improved = true;
                } else {
                    start += chunk;
                }
            }
            if chunk == 1 {
                break;
            }
            chunk /= 2;
        }
        if !improved {
            return best;
        }
    }
}

/// Convenience: assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err(format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_iters() {
        let mut count = 0;
        check("trivial", 1, 50, |rng| {
            count += 1;
            let x = rng.below(100);
            if x < 100 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics() {
        check("fails", 1, 10, |rng| {
            if rng.below(4) != 0 {
                Ok(())
            } else {
                Err("hit zero".into())
            }
        });
    }

    #[test]
    fn shrink_bytes_finds_the_single_poison_byte() {
        // failure = "contains 0x42": the minimal reproduction is [0x42]
        let mut input = vec![7u8; 300];
        input[137] = 0x42;
        let shrunk = shrink_bytes(&input, |b| b.contains(&0x42));
        assert_eq!(shrunk, vec![0x42]);
    }

    #[test]
    fn shrink_bytes_minimizes_length_and_content() {
        // failure = "at least 5 bytes": minimal is 5 bytes, all zeroed
        let input: Vec<u8> = (1..=200u8).collect();
        let shrunk = shrink_bytes(&input, |b| b.len() >= 5);
        assert_eq!(shrunk, vec![0u8; 5]);
    }

    #[test]
    fn shrink_bytes_handles_multi_byte_dependencies() {
        // failure needs BOTH a 0x10 and a later 0x20 — the pair survives
        let mut input = vec![0xFFu8; 64];
        input[10] = 0x10;
        input[50] = 0x20;
        let shrunk = shrink_bytes(&input, |b| {
            b.iter()
                .position(|&x| x == 0x10)
                .is_some_and(|i| b[i..].contains(&0x20))
        });
        assert_eq!(shrunk, vec![0x10, 0x20]);
    }

    #[test]
    fn shrink_seq_removes_irrelevant_ops() {
        // failure = "contains op 3 after op 1"
        let input = vec![0, 1, 2, 9, 9, 3, 4, 5];
        let shrunk = shrink_seq(&input, |s: &[i32]| {
            s.iter()
                .position(|&x| x == 1)
                .is_some_and(|i| s[i..].contains(&3))
        });
        assert_eq!(shrunk, vec![1, 3]);
    }

    #[test]
    fn shrink_is_identity_on_already_minimal_input() {
        let shrunk = shrink_bytes(&[0x42], |b| b.contains(&0x42));
        assert_eq!(shrunk, vec![0x42]);
        let shrunk = shrink_seq(&[7], |s: &[u8]| !s.is_empty());
        assert_eq!(shrunk, vec![7]);
    }
}
