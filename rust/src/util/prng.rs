//! Deterministic xorshift* PRNG.
//!
//! Every stochastic choice in the repo (dataset synthesis, mini-batch
//! sampling, flip augmentation, property-test inputs) flows through this so
//! runs are exactly reproducible from a seed — the same property the paper's
//! experiments rely on when comparing storage backends on identical
//! workloads.

/// xorshift64* generator (Vigna 2016); passes BigCrush for our purposes and
/// costs a handful of cycles per draw.
#[derive(Clone, Debug)]
pub struct Prng {
    state: u64,
}

impl Prng {
    /// Create a generator; `seed` may be any value (0 is remapped).
    pub fn new(seed: u64) -> Self {
        Prng {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Next raw 64-bit draw.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here;
        // bias is < 2^-32 for the ranges we use.
        ((self.next_u64() >> 32).wrapping_mul(n)) >> 32
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fill a byte buffer with draws.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }

    /// Derive an independent child generator (for per-node streams).
    pub fn fork(&mut self, tag: u64) -> Prng {
        Prng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut p = Prng::new(7);
        for _ in 0..10_000 {
            assert!(p.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut p = Prng::new(9);
        for _ in 0..10_000 {
            let x = p.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_roughly_uniform() {
        let mut p = Prng::new(11);
        let mut hist = [0u32; 8];
        for _ in 0..80_000 {
            hist[p.below(8) as usize] += 1;
        }
        for h in hist {
            assert!((8_000..12_000).contains(&h), "bin count {h}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        p.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(5);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = p.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut p = Prng::new(1);
        let mut buf = [0u8; 13];
        p.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }

    #[test]
    fn fork_streams_differ() {
        let mut p = Prng::new(2);
        let mut a = p.fork(0);
        let mut b = p.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
