//! Small shared utilities: deterministic PRNG, summary statistics, byte/time
//! formatting, and a minimal property-testing harness (`proptest_lite`).

pub mod bytes;
pub mod prng;
pub mod proptest_lite;
pub mod stats;

pub use bytes::{human_bytes, human_rate};
pub use prng::Prng;
pub use stats::Summary;
