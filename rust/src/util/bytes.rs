//! Human-readable byte counts and rates for experiment tables.

/// `1536 -> "1.5 KiB"`, `3<<20 -> "3.0 MiB"`.
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Bytes/second with MB/s units matching the paper's figures (decimal MB).
pub fn human_rate(bytes_per_sec: f64) -> String {
    if bytes_per_sec >= 1e9 {
        format!("{:.2} GB/s", bytes_per_sec / 1e9)
    } else if bytes_per_sec >= 1e6 {
        format!("{:.1} MB/s", bytes_per_sec / 1e6)
    } else if bytes_per_sec >= 1e3 {
        format!("{:.1} KB/s", bytes_per_sec / 1e3)
    } else {
        format!("{bytes_per_sec:.0} B/s")
    }
}

/// Parse sizes like "128K", "2M", "8M", "512", "1G" (binary multipliers,
/// matching the benchmark file sizes of paper §6.2).
pub fn parse_size(s: &str) -> Option<u64> {
    let s = s.trim();
    if s.is_empty() {
        return None;
    }
    let (num, mult) = match s.chars().last().unwrap().to_ascii_uppercase() {
        'K' => (&s[..s.len() - 1], 1024u64),
        'M' => (&s[..s.len() - 1], 1024 * 1024),
        'G' => (&s[..s.len() - 1], 1024 * 1024 * 1024),
        _ => (s, 1),
    };
    num.trim().parse::<u64>().ok().map(|n| n * mult)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(1536), "1.5 KiB");
        assert_eq!(human_bytes(3 << 20), "3.0 MiB");
    }

    #[test]
    fn rate_formatting() {
        assert_eq!(human_rate(2.5e6), "2.5 MB/s");
        assert_eq!(human_rate(3.2e9), "3.20 GB/s");
    }

    #[test]
    fn size_parsing() {
        assert_eq!(parse_size("128K"), Some(128 * 1024));
        assert_eq!(parse_size("8M"), Some(8 << 20));
        assert_eq!(parse_size("512"), Some(512));
        assert_eq!(parse_size("1G"), Some(1 << 30));
        assert_eq!(parse_size("x"), None);
    }
}
