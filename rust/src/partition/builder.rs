//! The data-preparation program (paper §5.2 / §6.3).
//!
//! "A user will have to pass into a preparation program a list of all files
//! involved. Large datasets originally stored in the shared file system are
//! then reorganized into partitions. Each partition contains an exclusive
//! subset of the files."
//!
//! `build_partitions` packs an input list into `n_partitions` blobs
//! round-robin (which balances both file count and — for i.i.d. sizes —
//! bytes), optionally compressing each file.  It returns the blobs plus
//! [`BuildStats`] used by the §6.3 prep-cost experiment.

use std::time::Instant;

use crate::compress::{Codec, CompressPolicy};
use crate::error::Result;
use crate::metadata::record::FileStat;
use crate::partition::format::PartitionWriter;

/// One input file handed to the preparation program.
#[derive(Clone, Debug)]
pub struct InputFile {
    /// Dataset-relative path.
    pub path: String,
    /// Raw contents.
    pub data: Vec<u8>,
}

/// Prep-run accounting (paper §6.3 reports minutes per dataset ± compression).
#[derive(Clone, Debug, Default)]
pub struct BuildStats {
    pub files: usize,
    pub raw_bytes: u64,
    pub stored_bytes: u64,
    pub compressed_files: usize,
    pub wall_seconds: f64,
}

impl BuildStats {
    /// Overall ratio (≥ 1.0; 1.0 when nothing compressed).
    pub fn ratio(&self) -> f64 {
        if self.stored_bytes == 0 {
            1.0
        } else {
            self.raw_bytes as f64 / self.stored_bytes as f64
        }
    }
}

/// Pack `files` into `n_partitions` blobs.  File `i` goes to partition
/// `i % n_partitions` (exclusive subsets).  Inode numbers are assigned
/// sequentially, mirroring the prep program's single pass.
pub fn build_partitions(
    files: &[InputFile],
    n_partitions: u32,
    codec: Codec,
) -> Result<(Vec<Vec<u8>>, BuildStats)> {
    build_partitions_with(files, n_partitions, codec, &CompressPolicy::default())
}

/// [`build_partitions`] with an explicit per-extension compression policy
/// (paper §5.2): files whose extension the policy skips are stored verbatim
/// regardless of `codec`.
pub fn build_partitions_with(
    files: &[InputFile],
    n_partitions: u32,
    codec: Codec,
    policy: &CompressPolicy,
) -> Result<(Vec<Vec<u8>>, BuildStats)> {
    assert!(n_partitions > 0);
    let start = Instant::now();
    let mut writers: Vec<PartitionWriter> =
        (0..n_partitions).map(|_| PartitionWriter::new()).collect();
    let mut stats = BuildStats {
        files: files.len(),
        ..Default::default()
    };
    for (i, f) in files.iter().enumerate() {
        let w = &mut writers[i % n_partitions as usize];
        let stat = FileStat::regular(i as u64 + 1, f.data.len() as u64);
        let file_codec = if policy.should_compress(&f.path) {
            codec
        } else {
            Codec::None
        };
        let before = w.len();
        w.push(&f.path, stat, &f.data, file_codec)?;
        stats.raw_bytes += f.data.len() as u64;
        let entry_bytes = w.len() - before;
        let stored = entry_bytes - super::format::ENTRY_FIXED_BYTES;
        stats.stored_bytes += stored as u64;
        if stored < f.data.len() {
            stats.compressed_files += 1;
        }
    }
    stats.wall_seconds = start.elapsed().as_secs_f64();
    Ok((writers.into_iter().map(|w| w.finish()).collect(), stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::format::PartitionReader;
    use crate::util::prng::Prng;

    fn gen_files(n: usize, size: usize, seed: u64) -> Vec<InputFile> {
        let mut rng = Prng::new(seed);
        (0..n)
            .map(|i| {
                let mut data = vec![0u8; size];
                rng.fill_bytes(&mut data);
                InputFile {
                    path: format!("d{}/f{i}", i % 7),
                    data,
                }
            })
            .collect()
    }

    #[test]
    fn exclusive_round_robin_subsets() {
        let files = gen_files(26, 100, 1);
        let (blobs, stats) = build_partitions(&files, 4, Codec::None).unwrap();
        assert_eq!(blobs.len(), 4);
        assert_eq!(stats.files, 26);
        let mut seen = std::collections::HashSet::new();
        let mut counts = Vec::new();
        for blob in &blobs {
            let entries = PartitionReader::new(blob).unwrap().read_all().unwrap();
            counts.push(entries.len());
            for e in entries {
                assert!(seen.insert(e.name.clone()), "duplicate {}", e.name);
            }
        }
        assert_eq!(seen.len(), 26);
        // round-robin balance: 26 files over 4 partitions = 7,7,6,6
        counts.sort_unstable();
        assert_eq!(counts, vec![6, 6, 7, 7]);
    }

    #[test]
    fn stats_track_bytes() {
        let files = gen_files(10, 500, 2);
        let (_, stats) = build_partitions(&files, 2, Codec::None).unwrap();
        assert_eq!(stats.raw_bytes, 5000);
        assert_eq!(stats.stored_bytes, 5000);
        assert_eq!(stats.compressed_files, 0);
        assert!((stats.ratio() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn compression_reduces_stored_bytes() {
        // compressible: constant blocks
        let files: Vec<InputFile> = (0..8)
            .map(|i| InputFile {
                path: format!("c/f{i}"),
                data: vec![i as u8; 4096],
            })
            .collect();
        let (blobs, stats) = build_partitions(&files, 2, Codec::Lzss(5)).unwrap();
        assert!(stats.ratio() > 10.0, "ratio {}", stats.ratio());
        assert_eq!(stats.compressed_files, 8);
        // and the blobs decode back to the originals
        for blob in &blobs {
            let mut r = PartitionReader::new(blob).unwrap();
            while let Some((e, _)) = r.next_entry().unwrap() {
                let raw = crate::compress::lzss::decompress(&e.data, e.stat.size as usize).unwrap();
                assert!(raw.iter().all(|&b| b == raw[0]));
                assert_eq!(raw.len(), 4096);
            }
        }
    }

    #[test]
    fn policy_keeps_skip_listed_extensions_raw() {
        // same compressible bytes, different extensions: the policy decides
        let files: Vec<InputFile> = ["train/a.npy", "train/b.JPEG", "train/c.png", "train/d"]
            .iter()
            .map(|p| InputFile {
                path: p.to_string(),
                data: vec![0x42u8; 4096],
            })
            .collect();
        let (blobs, stats) =
            build_partitions_with(&files, 1, Codec::Lzss(5), &CompressPolicy::default()).unwrap();
        assert_eq!(stats.compressed_files, 2, "only .npy and extensionless");
        let entries = PartitionReader::new(&blobs[0]).unwrap().read_all().unwrap();
        for e in &entries {
            let skip = e.name.ends_with(".JPEG") || e.name.ends_with(".png");
            assert_eq!(e.is_compressed(), !skip, "{}", e.name);
            assert_eq!(e.codec.is_none(), skip, "{}", e.name);
        }
    }

    #[test]
    fn property_partition_roundtrip() {
        crate::util::proptest_lite::check("partition roundtrip", 0xBEEF, 25, |rng| {
            let n = rng.index(40) + 1;
            let parts = (rng.index(8) + 1) as u32;
            let mut files = Vec::new();
            for i in 0..n {
                let len = rng.index(2048);
                let mut data = vec![0u8; len];
                if rng.chance(0.5) {
                    rng.fill_bytes(&mut data);
                } else {
                    data.fill(rng.next_u64() as u8);
                }
                files.push(InputFile {
                    path: format!("p/{i}"),
                    data,
                });
            }
            let codec = if rng.chance(0.5) {
                Codec::Lzss((rng.index(9) + 1) as u8)
            } else {
                Codec::None
            };
            let (blobs, stats) = build_partitions(&files, parts, codec)
                .map_err(|e| e.to_string())?;
            crate::prop_assert!(blobs.len() == parts as usize, "blob count");
            let mut total = 0usize;
            for blob in &blobs {
                let entries = PartitionReader::new(blob)
                    .map_err(|e| e.to_string())?
                    .read_all()
                    .map_err(|e| e.to_string())?;
                for e in &entries {
                    let idx: usize = e.name[2..].parse().unwrap();
                    let raw = if e.is_compressed() {
                        crate::compress::lzss::decompress(&e.data, e.stat.size as usize)
                            .map_err(|e| e.to_string())?
                    } else {
                        e.data.clone()
                    };
                    crate::prop_assert!(
                        raw == files[idx].data,
                        "content mismatch for {}",
                        e.name
                    );
                }
                total += entries.len();
            }
            crate::prop_assert!(total == n, "lost files: {total} != {n}");
            crate::prop_assert!(stats.files == n, "stats.files");
            Ok(())
        });
    }
}
