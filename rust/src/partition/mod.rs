//! Dataset partitions (paper §5.2, Table 3).
//!
//! The data-preparation step reorganizes a dataset (millions of small files)
//! into a handful of partition blobs — "the preprocessed dataset has a fixed
//! number of files: 48 for the GPU cluster and 512 for the CPU cluster"
//! (§6.5.2) — which is what turns the shared-FS workload into a constant,
//! scale-independent cost.
//!
//! [`format`] is the byte-exact Table 3 layout; [`builder`] is the
//! preparation program (pack + optional LZSS); [`PartitionIndex`] is the
//! load-time index of file → (offset, length) built when a node dumps a
//! partition to its local storage.

pub mod builder;
pub mod format;

pub use builder::{build_partitions, build_partitions_with, BuildStats, InputFile};
pub use format::{PartitionEntry, PartitionReader, PartitionWriter, NAME_BYTES};
