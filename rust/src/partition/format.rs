//! Byte-exact Table 3 partition layout.
//!
//! ```text
//! field      | num_files | file_name | stat      | compressed_size | data
//! byte_range | 0 - 3     | 4 - 259   | 260 - 403 | 404 - 411       | 412 - 411+data.size
//! ```
//! `num_files` is a 4-byte LE count (Table 3's byte range; the prose says
//! "eight bytes" — we follow the table and unit-test the exact offsets).
//! Each entry is a 256-byte NUL-padded path, the 144-byte stat image, an
//! 8-byte `compressed_size` (0 = stored raw; otherwise the stored length),
//! then the data bytes.  Entries repeat back-to-back.
//!
//! Compressed entries additionally record *which* codec produced them in
//! byte [`CODEC_STAT_OFFSET`] of the stat image (the first reserved byte,
//! 120..144 being zeros in every stat we write).  Raw entries keep the byte
//! at 0, so Table 3's exact offsets and raw-entry images are unchanged;
//! legacy compressed blobs with a zero byte decode under the historical
//! default `Lzss(5)`.

use crate::compress::Codec;
use crate::error::{FanError, Result};
use crate::metadata::record::{FileStat, STAT_BYTES};

/// Length of the fixed file-name field.
pub const NAME_BYTES: usize = 256;
/// Header length (the num_files field).
pub const HEADER_BYTES: usize = 4;
/// Per-entry fixed overhead before the data bytes.
pub const ENTRY_FIXED_BYTES: usize = NAME_BYTES + STAT_BYTES + 8;
/// Offset inside the 144-byte stat image where a compressed entry records
/// its codec id (`Codec::to_wire`); the stat's reserved region starts here.
pub const CODEC_STAT_OFFSET: usize = 120;

/// One packed file.
#[derive(Clone, Debug, PartialEq)]
pub struct PartitionEntry {
    /// Dataset-relative path (e.g. `ILSVRC2012_img_train/n015/x.JPEG`).
    pub name: String,
    /// POSIX stat of the *original* file (`stat.size` = raw length).
    pub stat: FileStat,
    /// 0 when `data` holds raw bytes; else the stored (compressed) length.
    pub compressed_size: u64,
    /// Codec the stored bytes are encoded under (`Codec::None` when
    /// `compressed_size == 0`).
    pub codec: Codec,
    /// Stored bytes (compressed when `compressed_size != 0`).
    pub data: Vec<u8>,
}

impl PartitionEntry {
    pub fn is_compressed(&self) -> bool {
        self.compressed_size != 0
    }

    /// Stored length on disk.
    pub fn stored_len(&self) -> u64 {
        if self.is_compressed() {
            self.compressed_size
        } else {
            self.stat.size
        }
    }
}

/// Streaming writer for a partition blob.
pub struct PartitionWriter {
    buf: Vec<u8>,
    count: u32,
}

impl PartitionWriter {
    pub fn new() -> Self {
        PartitionWriter {
            buf: vec![0u8; HEADER_BYTES],
            count: 0,
        }
    }

    /// Append one file; `codec` decides whether data is stored compressed.
    pub fn push(&mut self, name: &str, stat: FileStat, raw: &[u8], codec: Codec) -> Result<()> {
        if name.len() > NAME_BYTES - 1 {
            return Err(FanError::Format(format!(
                "file name longer than {} bytes: {name}",
                NAME_BYTES - 1
            )));
        }
        debug_assert_eq!(stat.size as usize, raw.len(), "stat.size must match data");
        let mut namebuf = [0u8; NAME_BYTES];
        namebuf[..name.len()].copy_from_slice(name.as_bytes());
        self.buf.extend_from_slice(&namebuf);
        let mut statbuf = stat.encode();
        match codec.compress(raw) {
            Some(c) => {
                // stamp the codec id into the stat image's reserved region
                // (raw entries keep the zero, so their images are unchanged)
                statbuf[CODEC_STAT_OFFSET] = codec.to_wire();
                self.buf.extend_from_slice(&statbuf);
                self.buf.extend_from_slice(&(c.len() as u64).to_le_bytes());
                self.buf.extend_from_slice(&c);
            }
            None => {
                self.buf.extend_from_slice(&statbuf);
                self.buf.extend_from_slice(&0u64.to_le_bytes());
                self.buf.extend_from_slice(raw);
            }
        }
        self.count += 1;
        Ok(())
    }

    /// Finish: patch the header count and return the blob.
    pub fn finish(mut self) -> Vec<u8> {
        self.buf[0..4].copy_from_slice(&self.count.to_le_bytes());
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn count(&self) -> u32 {
        self.count
    }
}

impl Default for PartitionWriter {
    fn default() -> Self {
        Self::new()
    }
}

/// Reader over a partition blob; yields entries and their data offsets.
pub struct PartitionReader<'a> {
    blob: &'a [u8],
    pos: usize,
    remaining: u32,
}

impl<'a> PartitionReader<'a> {
    pub fn new(blob: &'a [u8]) -> Result<Self> {
        if blob.len() < HEADER_BYTES {
            return Err(FanError::Format("partition shorter than header".into()));
        }
        let count = u32::from_le_bytes(blob[0..4].try_into().unwrap());
        Ok(PartitionReader {
            blob,
            pos: HEADER_BYTES,
            remaining: count,
        })
    }

    pub fn count(&self) -> u32 {
        self.remaining
    }

    /// Next entry plus the absolute byte offset of its data within the blob.
    pub fn next_entry(&mut self) -> Result<Option<(PartitionEntry, u64)>> {
        if self.remaining == 0 {
            return Ok(None);
        }
        let b = self.blob;
        if self.pos + ENTRY_FIXED_BYTES > b.len() {
            return Err(FanError::Format(format!(
                "entry header truncated at {}",
                self.pos
            )));
        }
        let name_raw = &b[self.pos..self.pos + NAME_BYTES];
        let name_end = name_raw.iter().position(|&c| c == 0).unwrap_or(NAME_BYTES);
        let name = std::str::from_utf8(&name_raw[..name_end])
            .map_err(|_| FanError::Format("non-utf8 file name".into()))?
            .to_string();
        let stat = FileStat::decode(&b[self.pos + NAME_BYTES..self.pos + NAME_BYTES + STAT_BYTES])?;
        let cs_off = self.pos + NAME_BYTES + STAT_BYTES;
        let compressed_size = u64::from_le_bytes(b[cs_off..cs_off + 8].try_into().unwrap());
        let codec = if compressed_size == 0 {
            Codec::None
        } else {
            match b[self.pos + NAME_BYTES + CODEC_STAT_OFFSET] {
                // legacy compressed blobs predate the codec byte
                0 => Codec::Lzss(5),
                id => Codec::from_wire(id)?,
            }
        };
        let data_off = cs_off + 8;
        let stored = if compressed_size != 0 {
            compressed_size
        } else {
            stat.size
        } as usize;
        if data_off + stored > b.len() {
            return Err(FanError::Format(format!(
                "entry data truncated: need {} at {}",
                stored, data_off
            )));
        }
        let data = b[data_off..data_off + stored].to_vec();
        self.pos = data_off + stored;
        self.remaining -= 1;
        Ok(Some((
            PartitionEntry {
                name,
                stat,
                compressed_size,
                codec,
                data,
            },
            data_off as u64,
        )))
    }

    /// Read all entries (convenience for tests / prep verification).
    pub fn read_all(mut self) -> Result<Vec<PartitionEntry>> {
        let mut v = Vec::new();
        while let Some((e, _)) = self.next_entry()? {
            v.push(e);
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn entry_bytes(name: &str, data: &[u8]) -> Vec<u8> {
        let mut w = PartitionWriter::new();
        w.push(name, FileStat::regular(1, data.len() as u64), data, Codec::None)
            .unwrap();
        w.finish()
    }

    #[test]
    fn table3_byte_offsets_exact() {
        // Paper Table 3: num_files 0-3, file_name 4-259, stat 260-403,
        // compressed_size 404-411, data 412-(411+size).
        let blob = entry_bytes("train/a.jpg", b"PIXELDATA");
        assert_eq!(&blob[0..4], &1u32.to_le_bytes());
        assert_eq!(&blob[4..15], b"train/a.jpg");
        assert!(blob[15..260].iter().all(|&b| b == 0));
        let stat = FileStat::decode(&blob[260..404]).unwrap();
        assert_eq!(stat.size, 9);
        assert_eq!(&blob[404..412], &0u64.to_le_bytes());
        assert_eq!(&blob[412..421], b"PIXELDATA");
        assert_eq!(blob.len(), 421);
    }

    #[test]
    fn roundtrip_multiple_files() {
        let mut rng = Prng::new(1);
        let mut w = PartitionWriter::new();
        let mut originals = Vec::new();
        for i in 0..50 {
            let mut data = vec![0u8; rng.index(2000)];
            rng.fill_bytes(&mut data);
            let name = format!("dir{}/file_{i}.bin", i % 5);
            w.push(&name, FileStat::regular(i as u64, data.len() as u64), &data, Codec::None)
                .unwrap();
            originals.push((name, data));
        }
        let blob = w.finish();
        let entries = PartitionReader::new(&blob).unwrap().read_all().unwrap();
        assert_eq!(entries.len(), 50);
        for (e, (name, data)) in entries.iter().zip(&originals) {
            assert_eq!(&e.name, name);
            assert_eq!(&e.data, data);
            assert!(!e.is_compressed());
        }
    }

    #[test]
    fn compressed_entry_roundtrip() {
        let data: Vec<u8> = b"0123456789".iter().cycle().take(4096).copied().collect();
        let mut w = PartitionWriter::new();
        w.push("c.bin", FileStat::regular(1, 4096), &data, Codec::Lzss(5))
            .unwrap();
        let blob = w.finish();
        let mut r = PartitionReader::new(&blob).unwrap();
        let (e, _) = r.next_entry().unwrap().unwrap();
        assert!(e.is_compressed());
        assert!(e.stored_len() < 4096);
        assert_eq!(e.codec, Codec::Lzss(5));
        let raw = e.codec.decompress(&e.data, 4096).unwrap();
        assert_eq!(raw, data);
    }

    #[test]
    fn codec_byte_rides_the_stat_reserved_region() {
        let data: Vec<u8> = b"0123456789".iter().cycle().take(4096).copied().collect();
        for level in [1u8, 3, 9] {
            let mut w = PartitionWriter::new();
            w.push("c.bin", FileStat::regular(1, 4096), &data, Codec::Lzss(level)).unwrap();
            w.push("r.bin", FileStat::regular(2, 0), b"", Codec::None).unwrap();
            let blob = w.finish();
            // compressed entry: byte 120 of the stat image carries the level
            assert_eq!(blob[HEADER_BYTES + NAME_BYTES + CODEC_STAT_OFFSET], level);
            let mut r = PartitionReader::new(&blob).unwrap();
            let (e, _) = r.next_entry().unwrap().unwrap();
            assert_eq!(e.codec, Codec::Lzss(level));
            // the stat decodes identically despite the stamped byte
            assert_eq!(e.stat, FileStat::regular(1, 4096));
            // raw entry: codec byte stays zero, codec is None
            let (raw_e, _) = r.next_entry().unwrap().unwrap();
            assert_eq!(raw_e.codec, Codec::None);
        }
    }

    #[test]
    fn incompressible_stored_raw() {
        let mut rng = Prng::new(9);
        let mut data = vec![0u8; 1024];
        rng.fill_bytes(&mut data);
        let mut w = PartitionWriter::new();
        w.push("r.bin", FileStat::regular(1, 1024), &data, Codec::Lzss(9))
            .unwrap();
        let blob = w.finish();
        let (e, _) = PartitionReader::new(&blob).unwrap().next_entry().unwrap().unwrap();
        assert_eq!(e.compressed_size, 0, "random data must be stored raw");
        assert_eq!(e.codec, Codec::None);
        assert_eq!(e.data, data);
    }

    #[test]
    fn long_name_rejected() {
        let mut w = PartitionWriter::new();
        let name = "x".repeat(NAME_BYTES);
        assert!(w
            .push(&name, FileStat::regular(1, 0), b"", Codec::None)
            .is_err());
    }

    #[test]
    fn truncated_blob_rejected() {
        let blob = entry_bytes("a", b"abcdef");
        assert!(PartitionReader::new(&blob[..blob.len() - 2])
            .unwrap()
            .read_all()
            .is_err());
        assert!(PartitionReader::new(&blob[..2]).is_err());
    }

    #[test]
    fn data_offset_reported_correctly() {
        let blob = entry_bytes("a", b"XYZ");
        let mut r = PartitionReader::new(&blob).unwrap();
        let (_, off) = r.next_entry().unwrap().unwrap();
        assert_eq!(&blob[off as usize..off as usize + 3], b"XYZ");
    }

    #[test]
    fn empty_partition() {
        let blob = PartitionWriter::new().finish();
        let entries = PartitionReader::new(&blob).unwrap().read_all().unwrap();
        assert!(entries.is_empty());
    }
}
