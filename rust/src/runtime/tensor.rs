//! Host tensors crossing the PJRT boundary.

use crate::error::{FanError, Result};

/// Element types used by the artifacts (manifest vocabulary).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    U8,
    I32,
    F32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "u8" => Ok(DType::U8),
            "i32" => Ok(DType::I32),
            "f32" => Ok(DType::F32),
            other => Err(FanError::Manifest(format!("unknown dtype {other}"))),
        }
    }

    pub fn size(&self) -> usize {
        match self {
            DType::U8 => 1,
            DType::I32 => 4,
            DType::F32 => 4,
        }
    }
}

/// A host-side dense tensor (row-major bytes).
#[derive(Clone, Debug)]
pub struct Tensor {
    pub dtype: DType,
    pub dims: Vec<usize>,
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn zeros(dtype: DType, dims: &[usize]) -> Tensor {
        let n: usize = dims.iter().product();
        Tensor {
            dtype,
            dims: dims.to_vec(),
            data: vec![0u8; n * dtype.size()],
        }
    }

    pub fn from_f32(dims: &[usize], values: &[f32]) -> Tensor {
        assert_eq!(dims.iter().product::<usize>(), values.len());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor {
            dtype: DType::F32,
            dims: dims.to_vec(),
            data,
        }
    }

    pub fn from_i32(dims: &[usize], values: &[i32]) -> Tensor {
        assert_eq!(dims.iter().product::<usize>(), values.len());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor {
            dtype: DType::I32,
            dims: dims.to_vec(),
            data,
        }
    }

    pub fn from_u8(dims: &[usize], values: Vec<u8>) -> Tensor {
        assert_eq!(dims.iter().product::<usize>(), values.len());
        Tensor {
            dtype: DType::U8,
            dims: dims.to_vec(),
            data: values,
        }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::from_f32(&[], &[v])
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            return Err(FanError::Runtime("tensor is not f32".into()));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn scalar_value(&self) -> Result<f32> {
        let v = self.as_f32()?;
        v.first()
            .copied()
            .ok_or_else(|| FanError::Runtime("empty tensor".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let t = Tensor::from_f32(&[2, 2], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.element_count(), 4);
        assert_eq!(t.as_f32().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn scalar() {
        let t = Tensor::scalar_f32(3.5);
        assert_eq!(t.dims, Vec::<usize>::new());
        assert_eq!(t.scalar_value().unwrap(), 3.5);
    }

    #[test]
    fn zeros_size() {
        let t = Tensor::zeros(DType::I32, &[3, 5]);
        assert_eq!(t.data.len(), 60);
        let u = Tensor::zeros(DType::U8, &[7]);
        assert_eq!(u.data.len(), 7);
    }

    #[test]
    #[should_panic]
    fn mismatched_dims_panics() {
        Tensor::from_f32(&[3], &[1.0]);
    }
}
