//! PJRT execution engine (adapts /opt/xla-example/load_hlo).
//!
//! `Engine::load` compiles every artifact once on the PJRT CPU client;
//! `execute` runs a compiled step with host [`Tensor`]s.  HLO *text* is the
//! interchange format — see python/compile/aot.py for why.

use std::collections::HashMap;

use crate::error::{FanError, Result};
use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::runtime::tensor::{DType, Tensor};

fn xe(e: xla::Error) -> FanError {
    FanError::Runtime(e.to_string())
}

fn element_type(dt: DType) -> xla::ElementType {
    match dt {
        DType::U8 => xla::ElementType::U8,
        DType::I32 => xla::ElementType::S32,
        DType::F32 => xla::ElementType::F32,
    }
}

fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(element_type(t.dtype), &t.dims, &t.data)
        .map_err(xe)
}

fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(xe)?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let ty = lit.ty().map_err(xe)?;
    let dtype = match ty {
        xla::ElementType::U8 => DType::U8,
        xla::ElementType::S32 => DType::I32,
        xla::ElementType::F32 => DType::F32,
        other => {
            return Err(FanError::Runtime(format!(
                "unsupported output element type {other:?}"
            )))
        }
    };
    let mut data = vec![0u8; lit.size_bytes()];
    match dtype {
        DType::U8 => lit.copy_raw_to::<u8>(&mut data).map_err(xe)?,
        DType::I32 => {
            let mut tmp = vec![0i32; lit.element_count()];
            lit.copy_raw_to::<i32>(&mut tmp).map_err(xe)?;
            data.clear();
            for v in tmp {
                data.extend_from_slice(&v.to_le_bytes());
            }
        }
        DType::F32 => {
            let mut tmp = vec![0f32; lit.element_count()];
            lit.copy_raw_to::<f32>(&mut tmp).map_err(xe)?;
            data.clear();
            for v in tmp {
                data.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
    Ok(Tensor { dtype, dims, data })
}

struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    spec: ArtifactSpec,
}

/// All compiled artifacts + the PJRT client.
pub struct Engine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    compiled: HashMap<String, Compiled>,
    pub manifest: Manifest,
}

impl Engine {
    /// Load + compile every artifact under `dir` (usually `artifacts/`).
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(xe)?;
        let mut compiled = HashMap::new();
        for spec in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(
                spec.hlo_path
                    .to_str()
                    .ok_or_else(|| FanError::Manifest("non-utf8 path".into()))?,
            )
            .map_err(xe)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(xe)?;
            compiled.insert(
                spec.name.clone(),
                Compiled {
                    exe,
                    spec: spec.clone(),
                },
            );
        }
        Ok(Engine {
            client,
            compiled,
            manifest,
        })
    }

    /// Load only the named artifacts (faster startup for examples).
    pub fn load_subset(dir: impl AsRef<std::path::Path>, names: &[&str]) -> Result<Engine> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(xe)?;
        let mut compiled = HashMap::new();
        for name in names {
            let spec = manifest.get(name)?.clone();
            let proto = xla::HloModuleProto::from_text_file(
                spec.hlo_path
                    .to_str()
                    .ok_or_else(|| FanError::Manifest("non-utf8 path".into()))?,
            )
            .map_err(xe)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(xe)?;
            compiled.insert(spec.name.clone(), Compiled { exe, spec });
        }
        Ok(Engine {
            client,
            compiled,
            manifest,
        })
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.compiled
            .get(name)
            .map(|c| &c.spec)
            .ok_or_else(|| FanError::Manifest(format!("artifact {name} not loaded")))
    }

    /// Execute `name` with `inputs` (declared order), returning the output
    /// tuple as host tensors.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let c = self
            .compiled
            .get(name)
            .ok_or_else(|| FanError::Manifest(format!("artifact {name} not loaded")))?;
        if inputs.len() != c.spec.inputs.len() {
            return Err(FanError::Runtime(format!(
                "{name}: expected {} inputs, got {}",
                c.spec.inputs.len(),
                inputs.len()
            )));
        }
        for (t, spec) in inputs.iter().zip(&c.spec.inputs) {
            if t.dims != spec.dims || t.dtype != spec.dtype {
                return Err(FanError::Runtime(format!(
                    "{name}: input {} expects {:?}{:?}, got {:?}{:?}",
                    spec.name, spec.dtype, spec.dims, t.dtype, t.dims
                )));
            }
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(to_literal)
            .collect::<Result<Vec<_>>>()?;
        let result = c.exe.execute::<xla::Literal>(&literals).map_err(xe)?;
        let out_lit = result[0][0].to_literal_sync().map_err(xe)?;
        // aot.py lowers with return_tuple=True: always a tuple
        let parts = out_lit.to_tuple().map_err(xe)?;
        let mut out = Vec::with_capacity(parts.len());
        for p in &parts {
            out.push(from_literal(p)?);
        }
        if out.len() != c.spec.outputs.len() {
            return Err(FanError::Runtime(format!(
                "{name}: manifest declares {} outputs, got {}",
                c.spec.outputs.len(),
                out.len()
            )));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.txt").exists()
    }

    #[test]
    fn preprocess_batch_executes() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let engine = Engine::load_subset(artifacts_dir(), &["preprocess_batch"]).unwrap();
        let spec = engine.spec("preprocess_batch").unwrap().clone();
        let imgs = Tensor::from_u8(&spec.inputs[0].dims, vec![128u8; spec.inputs[0].element_count()]);
        let flip = Tensor::zeros(DType::I32, &spec.inputs[1].dims);
        let out = engine.execute("preprocess_batch", &[imgs, flip]).unwrap();
        assert_eq!(out.len(), 1);
        let vals = out[0].as_f32().unwrap();
        // (128 - mean)/std for channel 0: (128-125.3)/63.0 ≈ 0.0429
        assert!((vals[0] - 0.04285).abs() < 1e-3, "got {}", vals[0]);
    }

    #[test]
    fn cnn_train_step_reduces_loss() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let engine = Engine::load_subset(artifacts_dir(), &["cnn_train_step"]).unwrap();
        let spec = engine.spec("cnn_train_step").unwrap().clone();
        let mut params = spec.load_params().unwrap();
        let n = params.len();
        // learnable batch: label = bright band position
        let b = spec.inputs[n].dims[0];
        let hw = spec.inputs[n].dims[1];
        let mut img = vec![30u8; spec.inputs[n].element_count()];
        let mut labels = vec![0i32; b];
        for i in 0..b {
            let lbl = (i % 10) as i32;
            labels[i] = lbl;
            // brighten a vertical band
            let band = hw / 10;
            for y in 0..hw {
                for x in (lbl as usize * band)..((lbl as usize + 1) * band) {
                    for ch in 0..3 {
                        img[((i * hw + y) * hw + x) * 3 + ch] = 220;
                    }
                }
            }
        }
        let images = Tensor::from_u8(&spec.inputs[n].dims, img);
        let labels_t = Tensor::from_i32(&spec.inputs[n + 1].dims, &labels);
        let flip = Tensor::zeros(DType::I32, &spec.inputs[n + 2].dims);
        let mean = Tensor::from_f32(&[3], &[125.3, 123.0, 113.9]);
        let std = Tensor::from_f32(&[3], &[63.0, 62.1, 66.7]);
        let lr = Tensor::scalar_f32(0.05);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..12 {
            let mut inputs = params.clone();
            inputs.push(images.clone());
            inputs.push(labels_t.clone());
            inputs.push(flip.clone());
            inputs.push(mean.clone());
            inputs.push(std.clone());
            inputs.push(lr.clone());
            let out = engine.execute("cnn_train_step", &inputs).unwrap();
            params = out[..n].to_vec();
            last = out[n].scalar_value().unwrap();
            if first.is_none() {
                first = Some(last);
            }
        }
        let first = first.unwrap();
        assert!(
            last < first * 0.8,
            "loss did not drop through PJRT: {first} -> {last}"
        );
    }
}
