//! PJRT runtime: load AOT artifacts and execute them from the hot path.
//!
//! Python runs once (`make artifacts`); afterwards the Rust binary is
//! self-contained — [`manifest`] parses `artifacts/manifest.txt`, [`pjrt`]
//! compiles each HLO-text module on the PJRT CPU client and exposes a typed
//! `execute` for the trainer.

pub mod manifest;
pub mod pjrt;
pub mod tensor;

pub use manifest::{ArtifactSpec, Manifest, TensorSpec};
pub use pjrt::Engine;
pub use tensor::{DType, Tensor};
