//! Parser for `artifacts/manifest.txt` (grammar in python/compile/aot.py).

use std::path::{Path, PathBuf};

use crate::error::{FanError, Result};
use crate::runtime::tensor::DType;

/// What role an input plays.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArgKind {
    /// Trainable parameter (the step returns its new value positionally).
    Param,
    /// Per-iteration data (batch, labels, learning rate, ...).
    Data,
}

/// One declared input/output tensor.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub dims: Vec<usize>,
    pub kind: ArgKind,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.element_count() * self.dtype.size()
    }
}

/// One AOT-compiled graph.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub hlo_path: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub params_path: Option<PathBuf>,
}

impl ArtifactSpec {
    pub fn param_count(&self) -> usize {
        self.inputs
            .iter()
            .filter(|t| t.kind == ArgKind::Param)
            .count()
    }

    /// Load the initial parameters binary (f32 arrays, declared order).
    pub fn load_params(&self) -> Result<Vec<crate::runtime::tensor::Tensor>> {
        let path = self
            .params_path
            .as_ref()
            .ok_or_else(|| FanError::Manifest(format!("{} has no params", self.name)))?;
        let bytes = std::fs::read(path)?;
        let mut out = Vec::new();
        let mut off = 0usize;
        for spec in self.inputs.iter().filter(|t| t.kind == ArgKind::Param) {
            let len = spec.byte_len();
            if off + len > bytes.len() {
                return Err(FanError::Manifest(format!(
                    "{}: params file too short",
                    self.name
                )));
            }
            out.push(crate::runtime::tensor::Tensor {
                dtype: spec.dtype,
                dims: spec.dims.clone(),
                data: bytes[off..off + len].to_vec(),
            });
            off += len;
        }
        if off != bytes.len() {
            return Err(FanError::Manifest(format!(
                "{}: params file has {} trailing bytes",
                self.name,
                bytes.len() - off
            )));
        }
        Ok(out)
    }
}

/// The whole artifact set.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
}

fn parse_dims(s: &str) -> Result<Vec<usize>> {
    if s == "scalar" {
        return Ok(Vec::new());
    }
    s.split('x')
        .map(|d| {
            d.parse()
                .map_err(|_| FanError::Manifest(format!("bad dim {d}")))
        })
        .collect()
}

impl Manifest {
    /// Parse `dir/manifest.txt`; paths are resolved relative to `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref();
        let text = std::fs::read_to_string(dir.join("manifest.txt")).map_err(|e| {
            FanError::Manifest(format!(
                "cannot read {}/manifest.txt (run `make artifacts`): {e}",
                dir.display()
            ))
        })?;
        let mut artifacts = Vec::new();
        let mut cur: Option<ArtifactSpec> = None;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            let err = |msg: &str| {
                FanError::Manifest(format!("manifest line {}: {msg}", lineno + 1))
            };
            match toks[0] {
                "artifact" => {
                    if cur.is_some() {
                        return Err(err("nested artifact"));
                    }
                    cur = Some(ArtifactSpec {
                        name: toks.get(1).ok_or_else(|| err("missing name"))?.to_string(),
                        hlo_path: PathBuf::new(),
                        inputs: Vec::new(),
                        outputs: Vec::new(),
                        params_path: None,
                    });
                }
                "hlo" => {
                    let a = cur.as_mut().ok_or_else(|| err("hlo outside artifact"))?;
                    a.hlo_path = dir.join(toks.get(1).ok_or_else(|| err("missing path"))?);
                }
                "in" | "out" => {
                    let a = cur.as_mut().ok_or_else(|| err("field outside artifact"))?;
                    if toks.len() < 4 {
                        return Err(err("short tensor line"));
                    }
                    let kind = if toks[0] == "in" {
                        match *toks.get(4).unwrap_or(&"data") {
                            "param" => ArgKind::Param,
                            _ => ArgKind::Data,
                        }
                    } else {
                        ArgKind::Data
                    };
                    let spec = TensorSpec {
                        name: toks[1].to_string(),
                        dtype: DType::parse(toks[2])?,
                        dims: parse_dims(toks[3])?,
                        kind,
                    };
                    if toks[0] == "in" {
                        a.inputs.push(spec);
                    } else {
                        a.outputs.push(spec);
                    }
                }
                "params" => {
                    let a = cur.as_mut().ok_or_else(|| err("params outside artifact"))?;
                    a.params_path =
                        Some(dir.join(toks.get(1).ok_or_else(|| err("missing path"))?));
                }
                "end" => {
                    artifacts.push(cur.take().ok_or_else(|| err("end without artifact"))?);
                }
                other => return Err(err(&format!("unknown token {other}"))),
            }
        }
        if cur.is_some() {
            return Err(FanError::Manifest("unterminated artifact".into()));
        }
        Ok(Manifest { artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| FanError::Manifest(format!("no artifact named {name}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(body: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "fanstore_manifest_{}_{}",
            std::process::id(),
            body.len()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), body).unwrap();
        dir
    }

    #[test]
    fn parse_minimal() {
        let dir = write_manifest(
            "# comment\nartifact step\nhlo step.hlo.txt\nin w f32 2x3 param\nin x u8 4 data\nout out0 f32 scalar\nparams step.params.bin\nend\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.get("step").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].kind, ArgKind::Param);
        assert_eq!(a.inputs[0].dims, vec![2, 3]);
        assert_eq!(a.inputs[1].dtype, DType::U8);
        assert_eq!(a.outputs[0].dims, Vec::<usize>::new());
        assert_eq!(a.param_count(), 1);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn unknown_token_rejected() {
        let dir = write_manifest("artifact a\nbogus x\nend\n");
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn unterminated_rejected() {
        let dir = write_manifest("artifact a\nhlo a.hlo.txt\n");
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn params_loading_checks_length() {
        let dir = write_manifest(
            "artifact s\nhlo s.hlo.txt\nin w f32 2 param\nout o f32 scalar\nparams p.bin\nend\n",
        );
        std::fs::write(dir.join("p.bin"), [0u8; 8]).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let params = m.get("s").unwrap().load_params().unwrap();
        assert_eq!(params.len(), 1);
        assert_eq!(params[0].data.len(), 8);
        // wrong size
        std::fs::write(dir.join("p.bin"), [0u8; 9]).unwrap();
        assert!(m.get("s").unwrap().load_params().is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn real_artifacts_manifest_parses_if_built() {
        // integration-ish: only runs when `make artifacts` has been run
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.get("cnn_train_step").is_ok());
            assert_eq!(m.artifacts.len(), 5);
            let params = m.get("cnn_train_step").unwrap().load_params().unwrap();
            assert_eq!(params.len(), 7);
        }
    }
}
