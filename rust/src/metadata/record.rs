//! On-disk/in-RAM metadata records.
//!
//! [`FileStat`] is the 144-byte POSIX `struct stat` image stored verbatim in
//! every partition entry (paper Table 3: bytes 260–403).  [`FileMeta`] is the
//! RAM record: the stat plus FanStore's location fields (which node holds the
//! bytes, at which partition offset, under which codec).

use crate::compress::Codec;
use crate::error::{FanError, Result};

/// Size of the serialized stat record — matches x86-64 glibc `struct stat`.
pub const STAT_BYTES: usize = 144;

/// Sentinel partition id for files replicated on *every* node (the paper's
/// user-specified replicated directory, §5.4 — typically the test set).
pub const REPLICATED_PARTITION: u32 = u32::MAX - 1;

/// POSIX-shaped stat, serialized little-endian into exactly 144 bytes.
///
/// Field layout (offsets in the 144-byte image):
/// ```text
///   0  dev        8  ino       16 nlink     24 mode(u32) 28 uid(u32)
///  32  gid(u32)  36 pad(u32)  40 rdev      48 size      56 blksize
///  64  blocks    72 atime     80 atime_ns  88 mtime     96 mtime_ns
/// 104  ctime    112 ctime_ns 120..144 reserved (zeros)
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FileStat {
    pub dev: u64,
    pub ino: u64,
    pub nlink: u64,
    pub mode: u32,
    pub uid: u32,
    pub gid: u32,
    pub rdev: u64,
    pub size: u64,
    pub blksize: u64,
    pub blocks: u64,
    pub atime: i64,
    pub atime_ns: i64,
    pub mtime: i64,
    pub mtime_ns: i64,
    pub ctime: i64,
    pub ctime_ns: i64,
}

impl FileStat {
    /// A regular file of `size` bytes with sensible defaults.
    pub fn regular(ino: u64, size: u64) -> Self {
        FileStat {
            dev: 0xFA57,
            ino,
            nlink: 1,
            mode: 0o100644, // S_IFREG | rw-r--r--
            uid: 1000,
            gid: 1000,
            rdev: 0,
            size,
            blksize: 4096,
            blocks: size.div_ceil(512),
            atime: 1_530_000_000,
            atime_ns: 0,
            mtime: 1_530_000_000,
            mtime_ns: 0,
            ctime: 1_530_000_000,
            ctime_ns: 0,
        }
    }

    /// A directory entry.
    pub fn directory(ino: u64) -> Self {
        let mut s = Self::regular(ino, 4096);
        s.mode = 0o040755; // S_IFDIR | rwxr-xr-x
        s.nlink = 2;
        s
    }

    pub fn is_dir(&self) -> bool {
        self.mode & 0o170000 == 0o040000
    }

    /// Serialize into the 144-byte partition image.
    pub fn encode(&self) -> [u8; STAT_BYTES] {
        let mut b = [0u8; STAT_BYTES];
        b[0..8].copy_from_slice(&self.dev.to_le_bytes());
        b[8..16].copy_from_slice(&self.ino.to_le_bytes());
        b[16..24].copy_from_slice(&self.nlink.to_le_bytes());
        b[24..28].copy_from_slice(&self.mode.to_le_bytes());
        b[28..32].copy_from_slice(&self.uid.to_le_bytes());
        b[32..36].copy_from_slice(&self.gid.to_le_bytes());
        // bytes 36..40: pad
        b[40..48].copy_from_slice(&self.rdev.to_le_bytes());
        b[48..56].copy_from_slice(&self.size.to_le_bytes());
        b[56..64].copy_from_slice(&self.blksize.to_le_bytes());
        b[64..72].copy_from_slice(&self.blocks.to_le_bytes());
        b[72..80].copy_from_slice(&self.atime.to_le_bytes());
        b[80..88].copy_from_slice(&self.atime_ns.to_le_bytes());
        b[88..96].copy_from_slice(&self.mtime.to_le_bytes());
        b[96..104].copy_from_slice(&self.mtime_ns.to_le_bytes());
        b[104..112].copy_from_slice(&self.ctime.to_le_bytes());
        b[112..120].copy_from_slice(&self.ctime_ns.to_le_bytes());
        b
    }

    /// Parse the 144-byte partition image.
    pub fn decode(b: &[u8]) -> Result<Self> {
        if b.len() < STAT_BYTES {
            return Err(FanError::Format(format!(
                "stat record truncated: {} < {STAT_BYTES}",
                b.len()
            )));
        }
        let u64at = |o: usize| u64::from_le_bytes(b[o..o + 8].try_into().unwrap());
        let u32at = |o: usize| u32::from_le_bytes(b[o..o + 4].try_into().unwrap());
        let i64at = |o: usize| i64::from_le_bytes(b[o..o + 8].try_into().unwrap());
        Ok(FileStat {
            dev: u64at(0),
            ino: u64at(8),
            nlink: u64at(16),
            mode: u32at(24),
            uid: u32at(28),
            gid: u32at(32),
            rdev: u64at(40),
            size: u64at(48),
            blksize: u64at(56),
            blocks: u64at(64),
            atime: i64at(72),
            atime_ns: i64at(80),
            mtime: i64at(88),
            mtime_ns: i64at(96),
            ctime: i64at(104),
            ctime_ns: i64at(112),
        })
    }
}

/// Where a file's bytes live.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FileLocation {
    /// Node that stores the (primary copy of the) data.
    pub node: u32,
    /// Partition id on that node.
    pub partition: u32,
    /// Byte offset of the data inside the dumped partition blob.
    pub offset: u64,
    /// Stored length (== compressed length when a codec applies).
    pub stored_len: u64,
    /// Codec the stored bytes are encoded under (`Codec::None` = verbatim).
    pub codec: Codec,
}

/// RAM metadata record: POSIX stat + FanStore location (paper §5.3 "besides
/// the POSIX-compliant information, each metadata record maintains the file
/// location").
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FileMeta {
    pub stat: FileStat,
    pub location: FileLocation,
    /// Commit generation of an output file, stamped by its *home* node when
    /// the `CommitOutput` lands (0 = input / never committed).  Two commits
    /// of the same path always carry different generations, so a reader can
    /// tell a same-origin same-size rewrite from the bytes it has cached.
    pub generation: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stat_roundtrip() {
        let s = FileStat::regular(42, 123_456);
        let b = s.encode();
        assert_eq!(b.len(), STAT_BYTES);
        assert_eq!(FileStat::decode(&b).unwrap(), s);
    }

    #[test]
    fn dir_roundtrip_and_flags() {
        let d = FileStat::directory(7);
        assert!(d.is_dir());
        assert!(!FileStat::regular(1, 0).is_dir());
        assert_eq!(FileStat::decode(&d.encode()).unwrap(), d);
    }

    #[test]
    fn truncated_decode_fails() {
        let s = FileStat::regular(1, 1);
        let b = s.encode();
        assert!(FileStat::decode(&b[..100]).is_err());
    }

    #[test]
    fn blocks_match_size() {
        let s = FileStat::regular(1, 1025);
        assert_eq!(s.blocks, 3); // ceil(1025/512)
    }
}
