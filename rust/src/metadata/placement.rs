//! Placement: which node serves a path.
//!
//! * Input files: assigned to partitions round-robin at prep time; with a
//!   replication factor `r`, partition `p` is hosted by nodes
//!   `{(p + i·P/r) mod N}` so each node holds `r` different partitions
//!   (paper §5.4 "each node can host N different partitions").
//! * Output files: the paper's consistent hash — "a particular file maps to
//!   a node using the modulo of the path hash value and the node count"
//!   (§5.3).  We use FNV-1a, which is stable across runs and platforms.

/// FNV-1a 64-bit path hash (stable; used for output-file homes).
pub fn path_hash(path: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in path.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Cluster-wide placement policy.
#[derive(Clone, Debug)]
pub struct Placement {
    pub nodes: u32,
    /// Number of partitions the dataset was packed into.
    pub partitions: u32,
    /// Replication factor for input partitions (1 = single copy).
    pub replication: u32,
}

impl Placement {
    pub fn new(nodes: u32, partitions: u32, replication: u32) -> Self {
        assert!(nodes > 0 && partitions > 0 && replication > 0);
        Placement {
            nodes,
            partitions,
            replication: replication.min(nodes),
        }
    }

    /// Home node of an *output* file (paper §5.3 consistent hash).
    pub fn output_home(&self, path: &str) -> u32 {
        (path_hash(path) % self.nodes as u64) as u32
    }

    /// All `r` homes of an *output* file.  The first entry is always
    /// [`Self::output_home`] (the generation-stamping primary); replicas
    /// follow the same stride pattern as [`Self::partition_holders`] so the
    /// copies land on distinct nodes whenever `nodes >= replication`.
    pub fn output_homes(&self, path: &str) -> Vec<u32> {
        let primary = self.output_home(path);
        let mut homes = Vec::with_capacity(self.replication as usize);
        let stride = (self.nodes / self.replication).max(1);
        for i in 0..self.replication {
            let n = (primary + i * stride) % self.nodes;
            if !homes.contains(&n) {
                homes.push(n);
            }
        }
        homes
    }

    /// Deterministic replacement holder after a failure: the first node,
    /// scanning upward from `start`, that is not already in `exclude` and
    /// not down.  Every node that observes the same down-set computes the
    /// same adoptee, so repair needs no coordination round.  Returns `None`
    /// when no eligible node exists (cluster too small or everyone down).
    pub fn adopt_node(
        &self,
        exclude: &[u32],
        start: u32,
        is_down: impl Fn(u32) -> bool,
    ) -> Option<u32> {
        (0..self.nodes)
            .map(|i| (start + i) % self.nodes)
            .find(|&n| !exclude.contains(&n) && !is_down(n))
    }

    /// Primary node hosting input partition `p`.
    pub fn partition_primary(&self, p: u32) -> u32 {
        p % self.nodes
    }

    /// All nodes hosting input partition `p` (primary + replicas).
    pub fn partition_holders(&self, p: u32) -> Vec<u32> {
        let mut holders = Vec::with_capacity(self.replication as usize);
        let stride = (self.nodes / self.replication).max(1);
        for i in 0..self.replication {
            let n = (self.partition_primary(p) + i * stride) % self.nodes;
            if !holders.contains(&n) {
                holders.push(n);
            }
        }
        holders
    }

    /// The holder of partition `p` nearest to `reader` (prefers `reader`
    /// itself — local hit — else deterministic choice by reader id so load
    /// spreads across replicas).
    pub fn choose_holder(&self, p: u32, reader: u32) -> u32 {
        let holders = self.partition_holders(p);
        if holders.contains(&reader) {
            return reader;
        }
        holders[(reader as usize) % holders.len()]
    }

    /// Is any copy of partition `p` local to `node`?
    pub fn is_local(&self, p: u32, node: u32) -> bool {
        self.partition_holders(p).contains(&node)
    }

    /// Expected local-hit probability for a uniform-random file read from
    /// `node` — the quantity the paper uses to explain scaling efficiency
    /// (25% → 6.25% on the GPU cluster, 1.56% → 0.2% on the CPU cluster).
    pub fn local_hit_rate(&self) -> f64 {
        let local_parts = (0..self.partitions)
            .filter(|&p| self.is_local(p, 0))
            .count() as f64;
        local_parts / self.partitions as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn hash_is_stable() {
        assert_eq!(path_hash("a/b"), path_hash("a/b"));
        assert_ne!(path_hash("a/b"), path_hash("a/c"));
    }

    #[test]
    fn output_home_in_range() {
        let p = Placement::new(16, 16, 1);
        for i in 0..1000 {
            assert!(p.output_home(&format!("/out/ckpt_{i}")) < 16);
        }
    }

    #[test]
    fn single_copy_hit_rate() {
        // 16 nodes, 16 partitions, 1 copy: each node holds 1/16 of data.
        let p = Placement::new(16, 16, 1);
        assert!((p.local_hit_rate() - 1.0 / 16.0).abs() < 1e-9);
    }

    #[test]
    fn replication_raises_hit_rate() {
        let p1 = Placement::new(16, 16, 1);
        let p4 = Placement::new(16, 16, 4);
        assert!(p4.local_hit_rate() > p1.local_hit_rate());
        let pb = Placement::new(16, 16, 16); // broadcast
        assert!((pb.local_hit_rate() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn holders_count_matches_replication() {
        let p = Placement::new(8, 32, 4);
        for part in 0..32 {
            assert_eq!(p.partition_holders(part).len(), 4);
        }
    }

    #[test]
    fn choose_holder_prefers_local() {
        let p = Placement::new(8, 8, 2);
        for part in 0..8u32 {
            for holder in p.partition_holders(part) {
                assert_eq!(p.choose_holder(part, holder), holder);
            }
        }
    }

    #[test]
    fn output_homes_first_is_primary_and_distinct() {
        let p = Placement::new(8, 8, 3);
        for i in 0..200 {
            let path = format!("/ckpt/model_{i}.h5");
            let homes = p.output_homes(&path);
            assert_eq!(homes[0], p.output_home(&path));
            assert_eq!(homes.len(), 3, "replicas must land on distinct nodes");
            let mut uniq = homes.clone();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(uniq.len(), homes.len());
        }
        // r = 1 degenerates to the single-home contract
        let p1 = Placement::new(8, 8, 1);
        assert_eq!(p1.output_homes("/a"), vec![p1.output_home("/a")]);
    }

    #[test]
    fn adopt_node_is_deterministic_and_skips_down() {
        let p = Placement::new(6, 12, 2);
        let holders = p.partition_holders(4); // e.g. [4, 1]
        let down = holders[1];
        let adoptee = p
            .adopt_node(&holders, (holders[0] + 1) % 6, |n| n == down)
            .unwrap();
        assert!(!holders.contains(&adoptee));
        assert_ne!(adoptee, down);
        // same inputs -> same answer, no matter who computes it
        let again = p
            .adopt_node(&holders, (holders[0] + 1) % 6, |n| n == down)
            .unwrap();
        assert_eq!(adoptee, again);
        // everyone down or excluded -> None
        assert_eq!(p.adopt_node(&[0, 1, 2, 3, 4, 5], 0, |_| false), None);
        assert_eq!(p.adopt_node(&[], 0, |_| true), None);
    }

    #[test]
    fn output_homes_roughly_balanced() {
        let p = Placement::new(8, 8, 1);
        let mut counts = [0u32; 8];
        let mut rng = Prng::new(1);
        for _ in 0..8000 {
            let path = format!("/ckpt/model_{}.h5", rng.next_u64());
            counts[p.output_home(&path) as usize] += 1;
        }
        for c in counts {
            assert!((700..1300).contains(&c), "unbalanced: {c}");
        }
    }
}
