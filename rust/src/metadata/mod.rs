//! Metadata management (paper §5.3).
//!
//! Input-file metadata is **replicated** on every node: each node holds the
//! full path → [`FileMeta`] hashtable plus a per-directory cache so
//! `readdir()` returns immediately.  Output-file metadata is **distributed**
//! by a consistent hash of the path (modulo node count in the paper); the
//! entry lives only on its home node and becomes visible only after
//! `close()` (visible-until-finish, §5.4).

pub mod placement;
pub mod record;
pub mod table;

pub use placement::Placement;
pub use record::{FileLocation, FileMeta, FileStat, STAT_BYTES};
pub use table::MetaTable;
