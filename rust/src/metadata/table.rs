//! The in-RAM metadata hashtable + readdir cache (paper §5.3).
//!
//! One `MetaTable` lives on every node.  Input metadata is loaded identically
//! everywhere (replication = broadcast at prep time); output metadata is
//! inserted only on the path's home node after `close()`.  The directory
//! cache is precomputed so `readdir()` "returns immediately" — the paper's
//! answer to the 4·N simultaneous `readdir()/stat()` storms of §3.3.

use std::collections::HashMap;

use crate::error::{FanError, Result};
use crate::metadata::record::{FileMeta, FileStat};

/// Per-node metadata store.
#[derive(Debug, Default)]
pub struct MetaTable {
    /// path -> record, for files.
    files: HashMap<String, FileMeta>,
    /// dir path -> sorted child names (files and subdirs).
    dirs: HashMap<String, Vec<String>>,
    /// dir path -> stat (directories carry their own stat records).
    dir_stats: HashMap<String, FileStat>,
    next_ino: u64,
}

/// Normalize `a/b/../c`-free paths: strip trailing '/', collapse "//".
pub fn normalize(path: &str) -> String {
    let mut out = String::with_capacity(path.len() + 1);
    if !path.starts_with('/') {
        out.push('/');
    }
    let mut prev_slash = false;
    for ch in path.chars() {
        if ch == '/' {
            if prev_slash {
                continue;
            }
            prev_slash = true;
        } else {
            prev_slash = false;
        }
        out.push(ch);
    }
    while out.len() > 1 && out.ends_with('/') {
        out.pop();
    }
    out
}

/// Parent directory of a normalized path ("/a/b/c" -> "/a/b").
pub fn parent(path: &str) -> &str {
    match path.rfind('/') {
        Some(0) => "/",
        Some(i) => &path[..i],
        None => "/",
    }
}

/// Base name of a normalized path.
pub fn basename(path: &str) -> &str {
    path.rsplit('/').next().unwrap_or(path)
}

impl MetaTable {
    pub fn new() -> Self {
        let mut t = MetaTable {
            next_ino: 2,
            ..Default::default()
        };
        t.dirs.insert("/".into(), Vec::new());
        t.dir_stats.insert("/".into(), FileStat::directory(1));
        t
    }

    fn alloc_ino(&mut self) -> u64 {
        self.next_ino += 1;
        self.next_ino
    }

    /// Ensure every ancestor directory of `path` exists.
    pub fn mkdirs(&mut self, dir: &str) {
        let dir = normalize(dir);
        if self.dirs.contains_key(&dir) {
            return;
        }
        let mut cur = String::from("/");
        for comp in dir.split('/').filter(|c| !c.is_empty()) {
            let parent_path = cur.clone();
            if cur.len() > 1 {
                cur.push('/');
            }
            cur.push_str(comp);
            if !self.dirs.contains_key(&cur) {
                let ino = self.alloc_ino();
                self.dirs.insert(cur.clone(), Vec::new());
                self.dir_stats.insert(cur.clone(), FileStat::directory(ino));
                let children = self.dirs.get_mut(&parent_path).expect("parent exists");
                if let Err(pos) = children.binary_search(&comp.to_string()) {
                    children.insert(pos, comp.to_string());
                }
            }
        }
    }

    /// Insert (or replace) a file record, creating parent directories.
    pub fn insert(&mut self, path: &str, meta: FileMeta) {
        let path = normalize(path);
        let dir = parent(&path).to_string();
        self.mkdirs(&dir);
        let name = basename(&path).to_string();
        let children = self.dirs.get_mut(&dir).expect("mkdirs created it");
        if let Err(pos) = children.binary_search(&name) {
            children.insert(pos, name);
        }
        self.files.insert(path, meta);
    }

    /// Remove a file record (used by failure-injection tests and `unlink`).
    pub fn remove(&mut self, path: &str) -> Result<FileMeta> {
        let path = normalize(path);
        let meta = self
            .files
            .remove(&path)
            .ok_or_else(|| FanError::NotFound(path.clone()))?;
        if let Some(children) = self.dirs.get_mut(parent(&path)) {
            let name = basename(&path).to_string();
            if let Ok(pos) = children.binary_search(&name) {
                children.remove(pos);
            }
        }
        Ok(meta)
    }

    /// Look up a file.
    pub fn get(&self, path: &str) -> Option<&FileMeta> {
        self.files.get(&normalize(path))
    }

    /// POSIX `stat()`: file or directory.
    pub fn stat(&self, path: &str) -> Result<FileStat> {
        let path = normalize(path);
        if let Some(m) = self.files.get(&path) {
            return Ok(m.stat);
        }
        if let Some(s) = self.dir_stats.get(&path) {
            return Ok(*s);
        }
        Err(FanError::NotFound(path))
    }

    /// POSIX `readdir()`: sorted child names, served from the cache.
    pub fn readdir(&self, dir: &str) -> Result<&[String]> {
        let dir = normalize(dir);
        if self.files.contains_key(&dir) {
            return Err(FanError::NotDirectory(dir));
        }
        self.dirs
            .get(&dir)
            .map(|v| v.as_slice())
            .ok_or(FanError::NotFound(dir))
    }

    pub fn is_dir(&self, path: &str) -> bool {
        self.dirs.contains_key(&normalize(path))
    }

    pub fn file_count(&self) -> usize {
        self.files.len()
    }

    pub fn dir_count(&self) -> usize {
        self.dirs.len()
    }

    /// Iterate all file paths (deterministic order not guaranteed).
    pub fn paths(&self) -> impl Iterator<Item = &String> {
        self.files.keys()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::metadata::record::{FileLocation, FileStat};

    fn meta(size: u64) -> FileMeta {
        FileMeta {
            stat: FileStat::regular(9, size),
            location: FileLocation {
                node: 0,
                partition: 0,
                offset: 0,
                stored_len: size,
                codec: Codec::None,
            },
            generation: 0,
        }
    }

    #[test]
    fn normalize_paths() {
        assert_eq!(normalize("a/b"), "/a/b");
        assert_eq!(normalize("/a//b/"), "/a/b");
        assert_eq!(normalize("/"), "/");
    }

    #[test]
    fn parent_and_basename() {
        assert_eq!(parent("/a/b/c"), "/a/b");
        assert_eq!(parent("/a"), "/");
        assert_eq!(basename("/a/b/c"), "c");
    }

    #[test]
    fn insert_creates_dirs_and_readdir_sorted() {
        let mut t = MetaTable::new();
        t.insert("/data/train/z.jpg", meta(10));
        t.insert("/data/train/a.jpg", meta(10));
        t.insert("/data/val/b.jpg", meta(10));
        assert_eq!(t.readdir("/data/train").unwrap(), &["a.jpg", "z.jpg"]);
        assert_eq!(t.readdir("/data").unwrap(), &["train", "val"]);
        assert_eq!(t.readdir("/").unwrap(), &["data"]);
        assert!(t.stat("/data/train").unwrap().is_dir());
        assert!(!t.stat("/data/train/a.jpg").unwrap().is_dir());
    }

    #[test]
    fn stat_missing_is_enoent() {
        let t = MetaTable::new();
        assert!(matches!(t.stat("/nope"), Err(FanError::NotFound(_))));
    }

    #[test]
    fn readdir_on_file_is_enotdir() {
        let mut t = MetaTable::new();
        t.insert("/f", meta(1));
        assert!(matches!(t.readdir("/f"), Err(FanError::NotDirectory(_))));
    }

    #[test]
    fn remove_updates_listing() {
        let mut t = MetaTable::new();
        t.insert("/d/x", meta(1));
        t.insert("/d/y", meta(1));
        t.remove("/d/x").unwrap();
        assert_eq!(t.readdir("/d").unwrap(), &["y"]);
        assert!(t.remove("/d/x").is_err());
    }

    #[test]
    fn counts() {
        let mut t = MetaTable::new();
        t.insert("/a/b/c1", meta(1));
        t.insert("/a/b/c2", meta(1));
        assert_eq!(t.file_count(), 2);
        assert_eq!(t.dir_count(), 3); // /, /a, /a/b
    }

    #[test]
    fn reinsert_replaces() {
        let mut t = MetaTable::new();
        t.insert("/f", meta(1));
        t.insert("/f", meta(99));
        assert_eq!(t.stat("/f").unwrap().size, 99);
        assert_eq!(t.readdir("/").unwrap().len(), 1);
    }
}
