//! FanStore CLI — the leader entrypoint.
//!
//! ```text
//! fanstore prepare   --files N --partitions P [--codec lzss --level L]
//! fanstore bench-io  --nodes N [--cluster gpu|cpu] [--scale S] [--ratio R]
//! fanstore train     --nodes N --epochs E [--view global|partitioned]
//! fanstore experiment <fig1|fig3|fig4|fig5|fig6|fig7|fig8|fig9|fig10|fig11|prep-cost|all>
//! ```

use fanstore::compress::Codec;
use fanstore::config::{ArgMap, ClusterConfig};
use fanstore::coordinator::Cluster;
use fanstore::error::Result;
use fanstore::experiments as exp;
use fanstore::runtime::Engine;
use fanstore::trainer::{self, DatasetView, TrainConfig};
use fanstore::workload::datasets::DatasetSpec;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("fanstore: error: {e}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "usage: fanstore <prepare|bench-io|train|experiment> [--key value ...]\n\
         \n\
         prepare     pack a synthetic dataset into partitions (§5.2)\n\
         bench-io    run the §6.2 benchmark on the in-proc cluster\n\
         train       train the CNN surrogate through FanStore + PJRT\n\
         experiment  regenerate a paper figure: fig1 fig3 fig4 fig5 fig6\n\
                     fig7 fig8 fig9 fig10 fig11 prep-cost pipeline all"
    );
}

fn codec_of(m: &ArgMap) -> Result<Codec> {
    Ok(match m.get("codec") {
        Some("lzss") => Codec::Lzss(m.get_u32("level", 5)? as u8),
        Some("none") | None => Codec::None,
        Some(other) => {
            return Err(fanstore::FanError::Config(format!(
                "unknown codec {other}"
            )))
        }
    })
}

fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("FANSTORE_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

fn run(args: &[String]) -> Result<()> {
    let m = ArgMap::parse(args);
    let Some(cmd) = m.positional.first().map(|s| s.as_str()) else {
        usage();
        return Ok(());
    };
    match cmd {
        "prepare" => cmd_prepare(&m),
        "bench-io" => cmd_bench_io(&m),
        "train" => cmd_train(&m),
        "experiment" => cmd_experiment(&m),
        _ => {
            usage();
            Err(fanstore::FanError::Config(format!("unknown command {cmd}")))
        }
    }
}

fn cmd_prepare(m: &ArgMap) -> Result<()> {
    let files = m.get_u64("files", 2000)? as usize;
    let partitions = m.get_u32("partitions", 16)?;
    let codec = codec_of(m)?;
    let spec = match m.get("dataset").unwrap_or("imagenet") {
        "srgan" => DatasetSpec::srgan(),
        "frnn" => DatasetSpec::frnn(),
        _ => DatasetSpec::imagenet(),
    };
    let divisor = m.get_u64("size-divisor", 64)?;
    println!("generating {files} files ({} profile)...", spec.name);
    let data = spec.generate(files, divisor, m.get_u64("seed", 1)?);
    let (blobs, stats) =
        fanstore::partition::builder::build_partitions(&data, partitions, codec)?;
    println!(
        "packed {} files ({}) into {} partitions in {:.2}s — stored {} (ratio {:.2}x)",
        stats.files,
        fanstore::util::human_bytes(stats.raw_bytes),
        blobs.len(),
        stats.wall_seconds,
        fanstore::util::human_bytes(stats.stored_bytes),
        stats.ratio(),
    );
    if let Some(dir) = m.get("out") {
        std::fs::create_dir_all(dir)?;
        for (i, b) in blobs.iter().enumerate() {
            std::fs::write(format!("{dir}/partition_{i:05}.fan"), b)?;
        }
        println!("wrote {} blobs to {dir}", blobs.len());
    }
    Ok(())
}

fn cmd_bench_io(m: &ArgMap) -> Result<()> {
    // real in-proc benchmark (wall clock) on this host
    let nodes = m.get_u32("nodes", 4)?;
    let files = m.get_u64("files", 512)? as usize;
    let size = fanstore::util::bytes::parse_size(m.get("size").unwrap_or("128K"))
        .ok_or_else(|| fanstore::FanError::Config("bad --size".into()))?;
    let codec = codec_of(m)?;
    let spec = fanstore::workload::bench::BenchSpec {
        points: vec![fanstore::workload::bench::BenchPoint {
            file_size: size,
            file_count: files as u64,
        }],
        redundancy: if matches!(codec, Codec::Lzss(_)) { 0.72 } else { 0.0 },
    };
    let data = spec.generate_point(spec.points[0], 3);
    let cfg = ClusterConfig {
        nodes,
        partitions: nodes * 2,
        codec,
        ..Default::default()
    };
    let mount = cfg.mount.clone();
    let cluster = Cluster::launch(&data, cfg)?;
    let paths: Vec<String> = data.iter().map(|f| format!("{mount}/{}", f.path)).collect();
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for node in 0..nodes {
        let mut vfs = cluster.client(node);
        let paths = paths.clone();
        handles.push(std::thread::spawn(move || -> Result<u64> {
            use fanstore::vfs::Vfs;
            let mut bytes = 0u64;
            for p in &paths {
                bytes += vfs.read_all(p)?.len() as u64;
            }
            Ok(bytes)
        }));
    }
    let mut total = 0u64;
    for h in handles {
        total += h.join().expect("bench thread")?;
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "in-proc: {nodes} nodes read {} in {secs:.3}s — {} aggregated, {:.0} files/s",
        fanstore::util::human_bytes(total),
        fanstore::util::human_rate(total as f64 / secs),
        (files as u64 * nodes as u64) as f64 / secs,
    );
    let report = cluster.shutdown();
    let remote: u64 = report.per_node.iter().map(|s| s.remote_reads_issued).sum();
    println!(
        "remote reads: {remote} / {} ({:.1}%)",
        files as u64 * nodes as u64,
        100.0 * remote as f64 / (files as u64 * nodes as u64) as f64
    );
    Ok(())
}

fn cmd_train(m: &ArgMap) -> Result<()> {
    let nodes = m.get_u32("nodes", 4)?;
    let epochs = m.get_u32("epochs", 3)?;
    let train_files = m.get_u64("train-files", 640)? as usize;
    let test_files = m.get_u64("test-files", 160)? as usize;
    let view = match m.get("view").unwrap_or("global") {
        "partitioned" => DatasetView::Partitioned,
        _ => DatasetView::Global,
    };
    println!("loading PJRT engine from {:?}...", artifacts_dir());
    let engine = Engine::load_subset(artifacts_dir(), &["cnn_train_step", "cnn_eval_step"])?;
    let mut files = trainer::data::gen_classification_dataset(train_files, "train", 11);
    files.extend(trainer::data::gen_classification_dataset(test_files, "test", 23));
    let cfg = ClusterConfig {
        nodes,
        partitions: nodes * 2,
        codec: codec_of(m)?,
        replicate_dirs: vec!["test".into()],
        ..Default::default()
    };
    let mount = cfg.mount.clone();
    let cluster = Cluster::launch(&files, cfg)?;
    let train_paths: Vec<String> = files
        .iter()
        .filter(|f| f.path.starts_with("train"))
        .map(|f| format!("{mount}/{}", f.path))
        .collect();
    let test_paths: Vec<String> = files
        .iter()
        .filter(|f| f.path.starts_with("test"))
        .map(|f| format!("{mount}/{}", f.path))
        .collect();
    let tc = TrainConfig {
        epochs,
        view,
        max_steps_per_epoch: m.get("max-steps").map(|s| s.parse().unwrap()),
        ..Default::default()
    };
    let log = trainer::train_cnn(&cluster, &engine, &train_paths, &test_paths, &tc)?;
    for e in &log.epochs {
        println!(
            "epoch {:>2}: loss {:.4}  train-acc {:.1}%  test-acc {:.1}%  {} files in {:.2}s ({:.0} files/s)",
            e.epoch,
            e.mean_loss,
            e.train_acc * 100.0,
            e.test_acc * 100.0,
            e.files_read,
            e.seconds,
            e.files_read as f64 / e.seconds
        );
    }
    println!("final test accuracy: {:.1}%", log.final_test_acc() * 100.0);
    cluster.shutdown();
    Ok(())
}

fn cmd_experiment(m: &ArgMap) -> Result<()> {
    let which = m
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let scale = m.get_u64("scale", 8)?;
    let run_one = |id: &str| -> Result<()> {
        println!("\n###### experiment {id} ######");
        match id {
            "fig1" => {
                let engine =
                    Engine::load_subset(artifacts_dir(), &["cnn_train_step", "cnn_eval_step"])?;
                let runs = exp::views::run(&engine, 4, 640, 160, 5, None)?;
                exp::views::report(&runs);
            }
            "fig3" => {
                let rows = exp::single_node::run(scale);
                exp::single_node::report(&rows);
            }
            "fig4" => {
                let rows = exp::apps::run();
                exp::apps::report(&rows);
            }
            "fig5" => {
                let res = exp::scaling::run(exp::scaling::ClusterKind::Gpu, scale, 1.0);
                exp::scaling::report(&res);
            }
            "fig6" => {
                let res = exp::scaling::run(exp::scaling::ClusterKind::Cpu, scale * 8, 1.0);
                exp::scaling::report(&res);
            }
            "fig7" => {
                let series = exp::apps_scaling::run_fig7();
                exp::apps_scaling::report_series("Fig 7 (ResNet-50)", &series);
                exp::apps_scaling::shape_checks_fig7(&series);
            }
            "fig8" => {
                let series = exp::apps_scaling::run_fig8();
                exp::apps_scaling::report_series("Fig 8 (SRGAN)", &series);
            }
            "fig9" => {
                let series = exp::apps_scaling::run_fig9();
                exp::apps_scaling::report_series("Fig 9 (FRNN)", &series);
            }
            "fig10" => {
                let rows = exp::compression::run_fig10();
                exp::compression::report_fig10(&rows);
            }
            "fig11" => {
                let res = exp::compression::run_fig11(scale * 8);
                exp::compression::report_fig11(&res);
            }
            "prep-cost" => {
                let rows = exp::prep::run(1500, 32)?;
                exp::prep::report(&rows);
            }
            "pipeline" => {
                // real-cluster remote-read strategies (not a figure in the
                // paper — measures the §5.4 overlap/batching machinery)
                let rows = exp::scaling::run_inproc_pipeline(4, 512, 64 << 10, 16)?;
                exp::scaling::report_inproc_pipeline(&rows);
            }
            other => {
                return Err(fanstore::FanError::Config(format!(
                    "unknown experiment {other}"
                )))
            }
        }
        Ok(())
    };
    if which == "all" {
        for id in [
            "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11",
            "prep-cost", "pipeline", "fig1",
        ] {
            run_one(id)?;
        }
        Ok(())
    } else {
        run_one(which)
    }
}
