//! FanStore CLI — the leader entrypoint.
//!
//! ```text
//! fanstore prepare   --files N --partitions P [--compress lzss-L] [--compress-ext jpg,png|none]
//! fanstore bench-io  --nodes N [--cluster gpu|cpu] [--scale S] [--ratio R]
//! fanstore train     --nodes N --epochs E [--view global|partitioned]
//! fanstore cluster   serve --node-id I --nodes N --listen HOST:PORT
//! fanstore cluster   join  --node-id I --nodes N --peers a:p,b:p,... [--shutdown]
//! fanstore experiment <fig1|fig3|fig4|fig5|fig6|fig7|fig8|fig9|fig10|fig11|prep-cost|pipeline|transport|failover|all>
//! ```

use std::sync::Arc;

use fanstore::compress::{Codec, CompressPolicy};
use fanstore::config::{ArgMap, ClusterConfig, TransportKind};
use fanstore::coordinator::Cluster;
use fanstore::error::Result;
use fanstore::experiments as exp;
use fanstore::runtime::Engine;
use fanstore::trainer::{self, DatasetView, TrainConfig};
use fanstore::workload::datasets::DatasetSpec;

// The counting allocator powers the wire fuzzer's allocation-amplification
// oracle (`fanstore fuzz wire`); outside `alloc_guard::measure` it is a
// passthrough over the system allocator with one thread-local read of
// overhead per allocation.
#[global_allocator]
static ALLOC: fanstore::fuzz::alloc_guard::CountingAlloc =
    fanstore::fuzz::alloc_guard::CountingAlloc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("fanstore: error: {e}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "usage: fanstore <prepare|bench-io|train|cluster|experiment|fuzz> [--key value ...]\n\
         \n\
         prepare     pack a synthetic dataset into partitions (§5.2)\n\
                     (--compress none|lzss|lzss-1..9 picks the codec;\n\
                      --compress-ext jpg,png,... overrides the skip list,\n\
                      --compress-ext none compresses every file)\n\
         bench-io    run the §6.2 benchmark on the in-proc cluster\n\
                     (--spill-dir DIR --spill-read-mode reopen|pread|mmap\n\
                      for real file I/O instead of RAM backing;\n\
                      --ram-budget BYTES --placement noop|freq\n\
                      --migrate-interval-ms MS for heat-based RAM tiering;\n\
                      --replication R --retry-budget N --call-timeout-ms MS\n\
                      tune read-path failover;\n\
                      --probe-interval-ms MS --repair-max-inflight N\n\
                      enable keepalive probing + background re-replication)\n\
         train       train the CNN surrogate through FanStore + PJRT\n\
         cluster     run one FanStore node over real TCP:\n\
                       serve --node-id I --nodes N --listen HOST:PORT\n\
                       join  --node-id I --nodes N --peers a:p,b:p,... [--shutdown]\n\
                     (every host passes the same --files/--size/--seed/--partitions)\n\
         experiment  regenerate a paper figure: fig1 fig3 fig4 fig5 fig6\n\
                     fig7 fig8 fig9 fig10 fig11 prep-cost pipeline transport\n\
                     failover all\n\
         fuzz        deterministic fuzzing (--seed N --iters N):\n\
                       wire   adversarial wire-codec decode fuzzing\n\
                       store  op-schedule fuzzing of a live cluster against\n\
                              an in-memory shadow model"
    );
}

fn codec_of(m: &ArgMap) -> Result<Codec> {
    // `--compress lzss-7` is the one-knob spelling; `--codec lzss --level 7`
    // stays supported for older scripts.
    if let Some(spec) = m.get("compress") {
        return Codec::parse(spec);
    }
    Ok(match m.get("codec") {
        Some("lzss") => Codec::Lzss(m.get_u32("level", 5)? as u8),
        Some("none") | None => Codec::None,
        Some(other) => {
            return Err(fanstore::FanError::Config(format!(
                "unknown codec {other}"
            )))
        }
    })
}

/// `--compress-ext jpg,png,...` (skip list) or `--compress-ext none`
/// (compress everything) — which extensions the codec is applied to.
/// Unset means the default skip list of entropy-coded formats.
fn compress_policy_of(m: &ArgMap) -> CompressPolicy {
    match m.get("compress-ext") {
        None => CompressPolicy::default(),
        Some(spec) => CompressPolicy::parse(spec),
    }
}

/// `--spill-dir DIR` / `--spill-read-mode reopen|pread|mmap` options for
/// commands that can run the cluster against real file I/O.
fn spill_opts(m: &ArgMap) -> Result<(Option<String>, fanstore::storage::SpillReadMode)> {
    let dir = m.get("spill-dir").map(|s| s.to_string());
    let mode = match m.get("spill-read-mode") {
        None => fanstore::storage::SpillReadMode::default(),
        Some(s) => fanstore::storage::SpillReadMode::parse(s).ok_or_else(|| {
            fanstore::FanError::Config(format!(
                "--spill-read-mode expects reopen|pread|mmap, got {s}"
            ))
        })?,
    };
    Ok((dir, mode))
}

/// `--ram-budget SIZE` / `--placement noop|freq` / `--migrate-interval-ms MS`
/// options for commands that can run heat-based RAM↔spill tiering.
fn tier_opts(m: &ArgMap) -> Result<(u64, fanstore::storage::PlacementKind, u64)> {
    let budget = match m.get("ram-budget") {
        None => 0,
        Some(s) => fanstore::util::bytes::parse_size(s)
            .ok_or_else(|| fanstore::FanError::Config(format!("bad --ram-budget {s}")))?,
    };
    let policy = match m.get("placement") {
        None => fanstore::storage::PlacementKind::default(),
        Some(s) => fanstore::storage::PlacementKind::parse(s).ok_or_else(|| {
            fanstore::FanError::Config(format!("--placement expects noop|freq, got {s}"))
        })?,
    };
    let interval = m.get_u64("migrate-interval-ms", 50)?;
    Ok((budget, policy, interval))
}

fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("FANSTORE_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

fn run(args: &[String]) -> Result<()> {
    let m = ArgMap::parse(args);
    let Some(cmd) = m.positional.first().map(|s| s.as_str()) else {
        usage();
        return Ok(());
    };
    match cmd {
        "prepare" => cmd_prepare(&m),
        "bench-io" => cmd_bench_io(&m),
        "train" => cmd_train(&m),
        "cluster" => cmd_cluster(&m),
        "experiment" => cmd_experiment(&m),
        "fuzz" => cmd_fuzz(&m),
        _ => {
            usage();
            Err(fanstore::FanError::Config(format!("unknown command {cmd}")))
        }
    }
}

// ---------------------------------------------------------------------------
// `fanstore fuzz wire|store` — deterministic fuzzing entrypoints.
//
// Both targets are pure functions of (--seed, --iters); a failure prints
// the seed and a shrunk minimal reproducer, and re-running with the same
// flags replays it exactly.  This binary registers the counting allocator,
// so the wire target's allocation-amplification oracle is live.
// ---------------------------------------------------------------------------

fn cmd_fuzz(m: &ArgMap) -> Result<()> {
    let Some(target) = m.positional.get(1).map(|s| s.as_str()) else {
        usage();
        return Err(fanstore::FanError::Config(
            "fuzz needs a target: wire | store".into(),
        ));
    };
    let seed = m.get_u64("seed", 0xFA57_F0CC)?;
    match target {
        "wire" => {
            let iters = m.get_u64("iters", 10_000)?;
            let report = fanstore::fuzz::run_wire_fuzz(seed, iters)
                .map_err(fanstore::FanError::Runtime)?;
            println!(
                "wire fuzz clean: seed={seed:#x} iters={} accepted={} rejected={} \
                 max_alloc={}B alloc_guarded={}",
                report.iters,
                report.accepted,
                report.rejected,
                report.max_alloc,
                report.alloc_guarded
            );
            Ok(())
        }
        "store" => {
            let iters = m.get_u64("iters", 2_000)?;
            let report = fanstore::fuzz::run_store_fuzz(seed, iters)
                .map_err(fanstore::FanError::Runtime)?;
            println!(
                "store fuzz clean: seed={seed:#x} rounds={} ops={} kill_rounds={} strict_rounds={}",
                report.rounds, report.ops, report.kills, report.strict_rounds
            );
            Ok(())
        }
        other => {
            usage();
            Err(fanstore::FanError::Config(format!(
                "unknown fuzz target {other}"
            )))
        }
    }
}

// ---------------------------------------------------------------------------
// `fanstore cluster serve|join` — one real-TCP FanStore node per process.
//
// Every participant runs the same deterministic §5.2 prep (seeded synthetic
// dataset → partitions → metadata broadcast), loads only the partitions
// placement assigns its node id, and serves them over a TCP listener.
// `join` additionally acts as a reading client: it sweeps the whole global
// namespace through the transport, verifies every byte against the
// generator, and (with --shutdown) stops the cluster.
// ---------------------------------------------------------------------------

fn cluster_dataset(files: usize, size: usize, seed: u64) -> Vec<fanstore::partition::builder::InputFile> {
    use fanstore::partition::builder::InputFile;
    let mut rng = fanstore::util::prng::Prng::new(seed);
    (0..files)
        .map(|i| {
            let mut data = vec![0u8; size];
            rng.fill_bytes(&mut data);
            InputFile {
                path: format!("train/f{i:05}"),
                data,
            }
        })
        .collect()
}

fn cmd_cluster(m: &ArgMap) -> Result<()> {
    use fanstore::coordinator::{build_global_meta, build_node_shared, prepare_partitions};
    use fanstore::metadata::placement::Placement;
    use fanstore::net::tcp::{TcpServer, TcpTransport};
    use fanstore::net::transport::Transport;
    use fanstore::node::FanStoreNode;
    use fanstore::vfs::{FanStoreVfs, Vfs};

    let Some(sub) = m.positional.get(1).map(|s| s.as_str()) else {
        usage();
        return Err(fanstore::FanError::Config(
            "cluster needs a subcommand: serve | join".into(),
        ));
    };
    let node_id = m.get_u32("node-id", 0)?;
    let nodes = m.get_u32("nodes", 3)?;
    let n_files = m.get_u64("files", 256)? as usize;
    let size = m.get_u64("size", 64 << 10)? as usize;
    let seed = m.get_u64("seed", 0xFA57)?;
    let (spill_dir, spill_read_mode) = spill_opts(m)?;
    let (ram_budget_bytes, tier_policy, migrate_interval_ms) = tier_opts(m)?;
    let defaults = ClusterConfig::default();
    let cfg = ClusterConfig {
        nodes,
        partitions: m.get_u32("partitions", nodes * 2)?,
        replication: m.get_u32("replication", 1)?,
        codec: codec_of(m)?,
        compress_policy: compress_policy_of(m),
        spill_dir,
        spill_read_mode,
        ram_budget_bytes,
        tier_policy,
        migrate_interval_ms,
        retry_budget: m.get_u32("retry-budget", defaults.retry_budget)?,
        call_timeout_ms: m.get_u64("call-timeout-ms", defaults.call_timeout_ms)?,
        probe_interval_ms: m.get_u64("probe-interval-ms", defaults.probe_interval_ms)?,
        repair_max_inflight: m.get_u32("repair-max-inflight", defaults.repair_max_inflight)?,
        ..Default::default()
    };
    cfg.validate()?;
    if node_id >= nodes {
        return Err(fanstore::FanError::Config(format!(
            "--node-id {node_id} out of range for --nodes {nodes}"
        )));
    }

    // identical on every host: same seed → same partitions → same metadata
    let files = cluster_dataset(n_files, size, seed);
    let data = prepare_partitions(&files, &cfg)?;
    let placement = Placement::new(cfg.nodes, cfg.partitions, cfg.replication);
    let global_meta = Arc::new(build_global_meta(&data, &cfg, &placement)?);
    let shared = build_node_shared(node_id, &data, global_meta, &placement, &cfg)?;

    match sub {
        "serve" => {
            let listen = m.get("listen").unwrap_or("127.0.0.1:0").to_string();
            let (server, endpoint) = TcpServer::bind_counted(
                node_id,
                listen.as_str(),
                Arc::clone(&shared.stats.decode_rejects),
            )?;
            println!(
                "node {node_id}/{nodes}: serving {} files ({} partitions dumped) on {}",
                n_files,
                shared.store.partition_count(),
                server.local_addr()
            );
            let node = FanStoreNode::spawn(shared, endpoint);
            // blocks until a peer sends Shutdown (fanstore cluster join --shutdown)
            let served = node.join();
            println!("node {node_id}: served {served} requests, exiting");
            drop(server);
            Ok(())
        }
        "join" => {
            let peers = m.get("peers").ok_or_else(|| {
                fanstore::FanError::Config(
                    "join needs --peers host:port,host:port,... (node-id order)".into(),
                )
            })?;
            let addrs: Vec<std::net::SocketAddr> = peers
                .split(',')
                .map(|s| {
                    s.trim().parse().map_err(|_| {
                        fanstore::FanError::Config(format!("bad peer address {s}"))
                    })
                })
                .collect::<Result<_>>()?;
            if addrs.len() != nodes as usize {
                return Err(fanstore::FanError::Config(format!(
                    "--peers lists {} addresses for --nodes {nodes}",
                    addrs.len()
                )));
            }
            // optionally serve our own share too (peers may read from us)
            let server_node = match m.get("listen") {
                Some(listen) => {
                    let (server, endpoint) = TcpServer::bind_counted(
                        node_id,
                        listen,
                        Arc::clone(&shared.stats.decode_rejects),
                    )?;
                    println!("node {node_id}: also serving on {}", server.local_addr());
                    Some((server, FanStoreNode::spawn(Arc::clone(&shared), endpoint)))
                }
                None => None,
            };
            let transport: Arc<dyn Transport> = Arc::new(TcpTransport::connect(&addrs)?);
            // keepalive prober + re-replicator, now that a fabric exists
            // (no-op unless --probe-interval-ms is set)
            shared.start_recovery(Arc::clone(&transport));
            let mut vfs = FanStoreVfs::new(node_id, shared, Arc::clone(&transport));
            let mount = cfg.mount.clone();
            let listing = vfs.readdir(&format!("{mount}/train"))?;
            println!(
                "node {node_id}: joined; global namespace lists {} files",
                listing.len()
            );
            let batch = m.get_u64("batch", 16)? as usize;
            let t0 = std::time::Instant::now();
            let mut bytes = 0u64;
            for chunk in files.chunks(batch) {
                let hint: Vec<String> = chunk
                    .iter()
                    .map(|f| format!("{mount}/{}", f.path))
                    .collect();
                vfs.prefetch(&hint)?;
                for (f, p) in chunk.iter().zip(&hint) {
                    let got = vfs.read_all(p)?;
                    if got != f.data {
                        return Err(fanstore::FanError::Transport(format!(
                            "byte mismatch reading {p} over TCP"
                        )));
                    }
                    bytes += got.len() as u64;
                }
            }
            let secs = t0.elapsed().as_secs_f64();
            println!(
                "node {node_id}: read+verified {} files ({}) over TCP in {secs:.3}s — {}",
                files.len(),
                fanstore::util::human_bytes(bytes),
                fanstore::util::human_rate(bytes as f64 / secs),
            );
            drop(vfs);
            if m.get_flag("shutdown") {
                println!("node {node_id}: broadcasting shutdown to {} peers", nodes);
                transport.shutdown_all();
            }
            if let Some((server, node)) = server_node {
                if m.get_flag("shutdown") {
                    // stop our listener first: its inbox sender drops, so
                    // the worker exits even if our own --peers slot did not
                    // point at our real address
                    drop(server);
                    let served = node.join();
                    println!("node {node_id}: served {served} requests");
                } else {
                    // symmetric deployment: peers may still be reading our
                    // partitions, so keep serving until some joiner
                    // broadcasts the cluster shutdown
                    println!("node {node_id}: serving until cluster shutdown...");
                    let served = node.join();
                    println!("node {node_id}: served {served} requests");
                    drop(server);
                }
            }
            Ok(())
        }
        other => Err(fanstore::FanError::Config(format!(
            "unknown cluster subcommand {other}"
        ))),
    }
}

fn cmd_prepare(m: &ArgMap) -> Result<()> {
    let files = m.get_u64("files", 2000)? as usize;
    let partitions = m.get_u32("partitions", 16)?;
    let codec = codec_of(m)?;
    let spec = match m.get("dataset").unwrap_or("imagenet") {
        "srgan" => DatasetSpec::srgan(),
        "frnn" => DatasetSpec::frnn(),
        _ => DatasetSpec::imagenet(),
    };
    let divisor = m.get_u64("size-divisor", 64)?;
    println!("generating {files} files ({} profile)...", spec.name);
    let data = spec.generate(files, divisor, m.get_u64("seed", 1)?);
    let (blobs, stats) = fanstore::partition::builder::build_partitions_with(
        &data,
        partitions,
        codec,
        &compress_policy_of(m),
    )?;
    println!(
        "packed {} files ({}) into {} partitions in {:.2}s — stored {} (ratio {:.2}x)",
        stats.files,
        fanstore::util::human_bytes(stats.raw_bytes),
        blobs.len(),
        stats.wall_seconds,
        fanstore::util::human_bytes(stats.stored_bytes),
        stats.ratio(),
    );
    if let Some(dir) = m.get("out") {
        std::fs::create_dir_all(dir)?;
        for (i, b) in blobs.iter().enumerate() {
            std::fs::write(format!("{dir}/partition_{i:05}.fan"), b)?;
        }
        println!("wrote {} blobs to {dir}", blobs.len());
    }
    Ok(())
}

fn cmd_bench_io(m: &ArgMap) -> Result<()> {
    // real in-proc benchmark (wall clock) on this host
    let nodes = m.get_u32("nodes", 4)?;
    let files = m.get_u64("files", 512)? as usize;
    let size = fanstore::util::bytes::parse_size(m.get("size").unwrap_or("128K"))
        .ok_or_else(|| fanstore::FanError::Config("bad --size".into()))?;
    let codec = codec_of(m)?;
    let spec = fanstore::workload::bench::BenchSpec {
        points: vec![fanstore::workload::bench::BenchPoint {
            file_size: size,
            file_count: files as u64,
        }],
        redundancy: if matches!(codec, Codec::Lzss(_)) { 0.72 } else { 0.0 },
    };
    let data = spec.generate_point(spec.points[0], 3);
    let (spill_dir, spill_read_mode) = spill_opts(m)?;
    let (ram_budget_bytes, tier_policy, migrate_interval_ms) = tier_opts(m)?;
    let defaults = ClusterConfig::default();
    let cfg = ClusterConfig {
        nodes,
        partitions: nodes * 2,
        replication: m.get_u32("replication", 1)?,
        codec,
        compress_policy: compress_policy_of(m),
        spill_dir,
        spill_read_mode,
        ram_budget_bytes,
        tier_policy,
        migrate_interval_ms,
        retry_budget: m.get_u32("retry-budget", defaults.retry_budget)?,
        call_timeout_ms: m.get_u64("call-timeout-ms", defaults.call_timeout_ms)?,
        probe_interval_ms: m.get_u64("probe-interval-ms", defaults.probe_interval_ms)?,
        repair_max_inflight: m.get_u32("repair-max-inflight", defaults.repair_max_inflight)?,
        ..Default::default()
    };
    let mount = cfg.mount.clone();
    let cluster = Cluster::launch(&data, cfg)?;
    let paths: Vec<String> = data.iter().map(|f| format!("{mount}/{}", f.path)).collect();
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for node in 0..nodes {
        let mut vfs = cluster.client(node);
        let paths = paths.clone();
        handles.push(std::thread::spawn(move || -> Result<u64> {
            use fanstore::vfs::Vfs;
            let mut bytes = 0u64;
            for p in &paths {
                bytes += vfs.read_all(p)?.len() as u64;
            }
            Ok(bytes)
        }));
    }
    let mut total = 0u64;
    for h in handles {
        total += h.join().expect("bench thread")?;
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "in-proc: {nodes} nodes read {} in {secs:.3}s — {} aggregated, {:.0} files/s",
        fanstore::util::human_bytes(total),
        fanstore::util::human_rate(total as f64 / secs),
        (files as u64 * nodes as u64) as f64 / secs,
    );
    let report = cluster.shutdown();
    let remote: u64 = report.per_node.iter().map(|s| s.remote_reads_issued).sum();
    println!(
        "remote reads: {remote} / {} ({:.1}%)",
        files as u64 * nodes as u64,
        100.0 * remote as f64 / (files as u64 * nodes as u64) as f64
    );
    if ram_budget_bytes > 0 {
        let (promos, demos, moved, hot): (u64, u64, u64, u64) = report.per_node.iter().fold(
            (0, 0, 0, 0),
            |(p, d, m, h), s| {
                (
                    p + s.promotions,
                    d + s.demotions,
                    m + s.migrated_bytes,
                    h + s.tier_hot_hits,
                )
            },
        );
        println!(
            "tiering ({}, budget {}): {promos} promotions, {demos} demotions, {} migrated, {hot} RAM-tier hits",
            tier_policy.name(),
            fanstore::util::human_bytes(ram_budget_bytes),
            fanstore::util::human_bytes(moved),
        );
    }
    Ok(())
}

fn cmd_train(m: &ArgMap) -> Result<()> {
    let nodes = m.get_u32("nodes", 4)?;
    let epochs = m.get_u32("epochs", 3)?;
    let train_files = m.get_u64("train-files", 640)? as usize;
    let test_files = m.get_u64("test-files", 160)? as usize;
    let view = match m.get("view").unwrap_or("global") {
        "partitioned" => DatasetView::Partitioned,
        _ => DatasetView::Global,
    };
    println!("loading PJRT engine from {:?}...", artifacts_dir());
    let engine = Engine::load_subset(artifacts_dir(), &["cnn_train_step", "cnn_eval_step"])?;
    let mut files = trainer::data::gen_classification_dataset(train_files, "train", 11);
    files.extend(trainer::data::gen_classification_dataset(test_files, "test", 23));
    let cfg = ClusterConfig {
        nodes,
        partitions: nodes * 2,
        replication: m.get_u32("replication", 1)?,
        codec: codec_of(m)?,
        compress_policy: compress_policy_of(m),
        replicate_dirs: vec!["test".into()],
        ..Default::default()
    };
    let mount = cfg.mount.clone();
    let cluster = Cluster::launch(&files, cfg)?;
    let train_paths: Vec<String> = files
        .iter()
        .filter(|f| f.path.starts_with("train"))
        .map(|f| format!("{mount}/{}", f.path))
        .collect();
    let test_paths: Vec<String> = files
        .iter()
        .filter(|f| f.path.starts_with("test"))
        .map(|f| format!("{mount}/{}", f.path))
        .collect();
    let tc = TrainConfig {
        epochs,
        view,
        max_steps_per_epoch: m.get("max-steps").map(|s| s.parse().unwrap()),
        ..Default::default()
    };
    let log = trainer::train_cnn(&cluster, &engine, &train_paths, &test_paths, &tc)?;
    for e in &log.epochs {
        println!(
            "epoch {:>2}: loss {:.4}  train-acc {:.1}%  test-acc {:.1}%  {} files in {:.2}s ({:.0} files/s)",
            e.epoch,
            e.mean_loss,
            e.train_acc * 100.0,
            e.test_acc * 100.0,
            e.files_read,
            e.seconds,
            e.files_read as f64 / e.seconds
        );
    }
    println!("final test accuracy: {:.1}%", log.final_test_acc() * 100.0);
    cluster.shutdown();
    Ok(())
}

fn cmd_experiment(m: &ArgMap) -> Result<()> {
    let which = m
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let scale = m.get_u64("scale", 8)?;
    let run_one = |id: &str| -> Result<()> {
        println!("\n###### experiment {id} ######");
        match id {
            "fig1" => {
                let engine =
                    Engine::load_subset(artifacts_dir(), &["cnn_train_step", "cnn_eval_step"])?;
                let runs = exp::views::run(&engine, 4, 640, 160, 5, None)?;
                exp::views::report(&runs);
            }
            "fig3" => {
                let rows = exp::single_node::run(scale);
                exp::single_node::report(&rows);
            }
            "fig4" => {
                let rows = exp::apps::run();
                exp::apps::report(&rows);
            }
            "fig5" => {
                let res = exp::scaling::run(exp::scaling::ClusterKind::Gpu, scale, 1.0);
                exp::scaling::report(&res);
            }
            "fig6" => {
                let res = exp::scaling::run(exp::scaling::ClusterKind::Cpu, scale * 8, 1.0);
                exp::scaling::report(&res);
            }
            "fig7" => {
                let series = exp::apps_scaling::run_fig7();
                exp::apps_scaling::report_series("Fig 7 (ResNet-50)", &series);
                exp::apps_scaling::shape_checks_fig7(&series);
            }
            "fig8" => {
                let series = exp::apps_scaling::run_fig8();
                exp::apps_scaling::report_series("Fig 8 (SRGAN)", &series);
            }
            "fig9" => {
                let series = exp::apps_scaling::run_fig9();
                exp::apps_scaling::report_series("Fig 9 (FRNN)", &series);
            }
            "fig10" => {
                let rows = exp::compression::run_fig10();
                exp::compression::report_fig10(&rows);
            }
            "fig11" => {
                let res = exp::compression::run_fig11(scale * 8);
                exp::compression::report_fig11(&res);
            }
            "prep-cost" => {
                let rows = exp::prep::run(1500, 32)?;
                exp::prep::report(&rows);
            }
            "pipeline" => {
                // real-cluster remote-read strategies (not a figure in the
                // paper — measures the §5.4 overlap/batching machinery)
                let rows = exp::scaling::run_inproc_pipeline(4, 512, 64 << 10, 16)?;
                exp::scaling::report_inproc_pipeline(&rows);
            }
            "transport" => {
                // same workload over mpsc channels vs real loopback TCP:
                // byte-identical reads, identical counter algebra
                let runs = exp::scaling::run_transport_equivalence(
                    &[TransportKind::InProc, TransportKind::TcpLoopback],
                    4,
                    256,
                    64 << 10,
                    16,
                )?;
                exp::scaling::report_transport_equivalence(&runs);
            }
            "failover" => {
                // kill node 1 mid-sweep on both fabrics: replicas must keep
                // the reads byte-identical while the failover counters fire
                let runs = exp::failover::run_failover(
                    &[TransportKind::InProc, TransportKind::TcpLoopback],
                    128,
                    16 << 10,
                )?;
                exp::failover::report_failover(&runs);
            }
            other => {
                return Err(fanstore::FanError::Config(format!(
                    "unknown experiment {other}"
                )))
            }
        }
        Ok(())
    };
    if which == "all" {
        for id in [
            "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11",
            "prep-cost", "pipeline", "transport", "failover", "fig1",
        ] {
            run_one(id)?;
        }
        Ok(())
    } else {
        run_one(which)
    }
}
