//! The §6.2 synthetic read benchmark.
//!
//! "This benchmark has four file sizes: 128 KB, 512 KB, 2 MB, and 8 MB.
//! Each file size has {128K, 32K, 8K, 2K} file count, respectively.  At
//! each scale, each node reads all files in the directory, and reports
//! time-to-solution and bandwidth."

use crate::partition::builder::InputFile;
use crate::util::prng::Prng;
use crate::workload::datasets::synth_content;

/// The paper's four benchmark file sizes (bytes).
pub const BENCH_FILE_SIZES: [u64; 4] = [128 << 10, 512 << 10, 2 << 20, 8 << 20];

/// Full-scale file counts paired with [`BENCH_FILE_SIZES`].
pub const BENCH_FILE_COUNTS: [u64; 4] = [128 << 10, 32 << 10, 8 << 10, 2 << 10];

/// One benchmark configuration point.
#[derive(Clone, Copy, Debug)]
pub struct BenchPoint {
    pub file_size: u64,
    pub file_count: u64,
}

/// Benchmark workload description.
#[derive(Clone, Debug)]
pub struct BenchSpec {
    pub points: Vec<BenchPoint>,
    /// Redundancy of generated content (0 = incompressible; §6.6 uses a
    /// corpus "sampled from the SRGAN dataset" at 2.8×).
    pub redundancy: f64,
}

impl BenchSpec {
    /// The paper's four points, file counts divided by `scale` (≥1) so the
    /// in-proc runs stay tractable; the simulator uses `scale = 1`.
    pub fn paper(scale: u64) -> Self {
        let points = BENCH_FILE_SIZES
            .iter()
            .zip(BENCH_FILE_COUNTS.iter())
            .map(|(&s, &c)| BenchPoint {
                file_size: s,
                file_count: (c / scale.max(1)).max(1),
            })
            .collect();
        BenchSpec {
            points,
            redundancy: 0.0,
        }
    }

    /// §6.6 variant: same sizes, SRGAN-like compressibility.
    pub fn paper_compressible(scale: u64) -> Self {
        let mut s = Self::paper(scale);
        s.redundancy = 0.72;
        s
    }

    /// Materialize the files for one point (`/bench/<size>/f_<i>`).
    pub fn generate_point(&self, point: BenchPoint, seed: u64) -> Vec<InputFile> {
        let mut rng = Prng::new(seed ^ point.file_size);
        (0..point.file_count)
            .map(|i| {
                let data = if self.redundancy == 0.0 {
                    let mut d = vec![0u8; point.file_size as usize];
                    rng.fill_bytes(&mut d);
                    d
                } else {
                    synth_content(&mut rng, point.file_size as usize, self.redundancy)
                };
                InputFile {
                    path: format!("bench/s{}/f_{i:06}", point.file_size),
                    data,
                }
            })
            .collect()
    }
}

/// Result row of one benchmark point (matches the paper's reporting:
/// aggregated bandwidth MB/s + throughput files/s).
#[derive(Clone, Copy, Debug)]
pub struct BenchResult {
    pub file_size: u64,
    pub files_read: u64,
    pub seconds: f64,
}

impl BenchResult {
    pub fn bandwidth_mbs(&self) -> f64 {
        (self.files_read * self.file_size) as f64 / 1e6 / self.seconds
    }

    pub fn files_per_sec(&self) -> f64 {
        self.files_read as f64 / self.seconds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_points_match_section_6_2() {
        let spec = BenchSpec::paper(1);
        assert_eq!(spec.points.len(), 4);
        assert_eq!(spec.points[0].file_size, 128 << 10);
        assert_eq!(spec.points[0].file_count, 128 << 10);
        assert_eq!(spec.points[3].file_size, 8 << 20);
        assert_eq!(spec.points[3].file_count, 2 << 10);
        // total bytes per point is constant (16 GiB) by design of the paper
        for p in &spec.points {
            assert_eq!(p.file_size * p.file_count, 16 << 30);
        }
    }

    #[test]
    fn scaling_divides_counts() {
        let spec = BenchSpec::paper(1024);
        assert_eq!(spec.points[0].file_count, 128);
        assert_eq!(spec.points[3].file_count, 2);
    }

    #[test]
    fn generate_point_sizes() {
        let spec = BenchSpec::paper(16 << 10);
        let files = spec.generate_point(spec.points[0], 1);
        assert_eq!(files.len(), 8);
        for f in &files {
            assert_eq!(f.data.len(), 128 << 10);
        }
    }

    #[test]
    fn result_math() {
        let r = BenchResult {
            file_size: 1 << 20,
            files_read: 100,
            seconds: 2.0,
        };
        assert!((r.bandwidth_mbs() - 52.4288).abs() < 1e-3);
        assert!((r.files_per_sec() - 50.0).abs() < 1e-9);
    }
}
