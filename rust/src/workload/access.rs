//! DL access patterns (paper §3).
//!
//! Training: each process draws a random mini-batch per iteration; across an
//! epoch every file is visited exactly once per *cluster* under the global
//! view (shuffled partition of the index space), or once per *node* over its
//! exclusive shard under the partitioned view (the Fig 1 ablation).
//! Validation: every process reads the full test set (§5.4).

use crate::util::prng::Prng;

/// Epoch-shuffled mini-batch sampler over `n` files for `nodes` consumers.
#[derive(Clone, Debug)]
pub struct EpochSampler {
    order: Vec<u32>,
    cursor: usize,
    rng: Prng,
}

impl EpochSampler {
    pub fn new(n: usize, seed: u64) -> Self {
        let mut rng = Prng::new(seed ^ 0x5A3E);
        let mut order: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut order);
        EpochSampler {
            order,
            cursor: 0,
            rng,
        }
    }

    /// Remaining items this epoch.
    pub fn remaining(&self) -> usize {
        self.order.len() - self.cursor
    }

    /// The not-yet-consumed remainder of the current epoch, in draw order —
    /// exactly what a prefetch pipeline should fetch ahead of the cursor.
    pub fn upcoming(&self) -> &[u32] {
        &self.order[self.cursor..]
    }

    /// Next mini-batch of up to `batch` indices; reshuffles when the epoch
    /// ends (returns `None` exactly at the epoch boundary so callers can
    /// run validation/checkpointing, §3.1).
    pub fn next_batch(&mut self, batch: usize) -> Option<Vec<u32>> {
        if self.cursor >= self.order.len() {
            self.rng.shuffle(&mut self.order);
            self.cursor = 0;
            return None;
        }
        let end = (self.cursor + batch).min(self.order.len());
        let out = self.order[self.cursor..end].to_vec();
        self.cursor = end;
        Some(out)
    }

    /// The global-vs-partitioned ablation (Fig 1): restrict this sampler to
    /// an exclusive *contiguous* shard of the (directory-ordered) file list.
    /// Contiguous is what a partitioned view actually looks like: files land
    /// on nodes in traversal order, and ImageNet's traversal order is
    /// class-directory order — which is exactly why the partitioned view
    /// hurts accuracy (§3.2).
    pub fn partitioned(n: usize, node: u32, nodes: u32, seed: u64) -> Self {
        let mut rng = Prng::new(seed ^ 0x9A27);
        let lo = (n as u64 * node as u64 / nodes as u64) as u32;
        let hi = (n as u64 * (node as u64 + 1) / nodes as u64) as u32;
        let mut order: Vec<u32> = (lo..hi).collect();
        rng.shuffle(&mut order);
        EpochSampler {
            order,
            cursor: 0,
            rng,
        }
    }
}

/// Full sequential sweep of the test set (each process reads everything).
#[derive(Clone, Debug)]
pub struct TestSweep {
    pub n: usize,
}

impl TestSweep {
    pub fn indices(&self) -> impl Iterator<Item = u32> + '_ {
        0..self.n as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_visits_every_file_once() {
        let mut s = EpochSampler::new(103, 1);
        let mut seen = vec![0u32; 103];
        while let Some(batch) = s.next_batch(16) {
            for i in batch {
                seen[i as usize] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "epoch must be a permutation");
    }

    #[test]
    fn epochs_reshuffle() {
        let mut s = EpochSampler::new(64, 2);
        let mut e1 = Vec::new();
        while let Some(b) = s.next_batch(64) {
            e1.extend(b);
        }
        let mut e2 = Vec::new();
        while let Some(b) = s.next_batch(64) {
            e2.extend(b);
        }
        assert_ne!(e1, e2, "different epoch order");
        let mut s1 = e1.clone();
        let mut s2 = e2.clone();
        s1.sort_unstable();
        s2.sort_unstable();
        assert_eq!(s1, s2, "same contents");
    }

    #[test]
    fn partitioned_shards_are_exclusive_and_cover() {
        let mut all = Vec::new();
        for node in 0..4 {
            let mut s = EpochSampler::partitioned(101, node, 4, 3);
            while let Some(b) = s.next_batch(8) {
                all.extend(b);
            }
        }
        all.sort_unstable();
        assert_eq!(all, (0..101).collect::<Vec<_>>());
    }

    #[test]
    fn partitioned_shards_are_contiguous_blocks() {
        let mut s = EpochSampler::partitioned(100, 1, 4, 3);
        let mut idx = Vec::new();
        while let Some(b) = s.next_batch(100) {
            idx.extend(b);
        }
        idx.sort_unstable();
        assert_eq!(idx, (25..50).collect::<Vec<_>>());
    }

    #[test]
    fn upcoming_matches_future_draws() {
        let mut s = EpochSampler::new(20, 9);
        assert_eq!(s.next_batch(6).unwrap().len(), 6);
        let promised: Vec<u32> = s.upcoming().to_vec();
        assert_eq!(promised.len(), 14);
        let mut drawn = Vec::new();
        while let Some(b) = s.next_batch(6) {
            drawn.extend(b);
        }
        assert_eq!(promised, drawn, "upcoming must be the exact draw order");
    }

    #[test]
    fn batch_sizes() {
        let mut s = EpochSampler::new(10, 4);
        assert_eq!(s.next_batch(4).unwrap().len(), 4);
        assert_eq!(s.next_batch(4).unwrap().len(), 4);
        assert_eq!(s.next_batch(4).unwrap().len(), 2); // tail
        assert!(s.next_batch(4).is_none()); // epoch boundary
        assert_eq!(s.next_batch(4).unwrap().len(), 4); // new epoch
    }

    #[test]
    fn test_sweep_covers_all() {
        let sweep = TestSweep { n: 7 };
        let v: Vec<u32> = sweep.indices().collect();
        assert_eq!(v, vec![0, 1, 2, 3, 4, 5, 6]);
    }
}
