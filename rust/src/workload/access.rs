//! DL access patterns (paper §3).
//!
//! Training: each process draws a random mini-batch per iteration; across an
//! epoch every file is visited exactly once per *cluster* under the global
//! view (shuffled partition of the index space), or once per *node* over its
//! exclusive shard under the partitioned view (the Fig 1 ablation).
//! Validation: every process reads the full test set (§5.4).

use crate::util::prng::Prng;

/// Epoch-shuffled mini-batch sampler over `n` files for `nodes` consumers.
#[derive(Clone, Debug)]
pub struct EpochSampler {
    order: Vec<u32>,
    cursor: usize,
    rng: Prng,
    /// Next epoch's order, fixed ahead of the wrap by
    /// [`EpochSampler::precommit_next`] (cross-epoch prefetch needs the
    /// order *before* the epoch boundary).  Adopted by the wrap in
    /// [`EpochSampler::next_batch`]; the RNG is drawn identically either
    /// way, so pre-committing never changes the sampled sequence.
    next_order: Option<Vec<u32>>,
}

impl EpochSampler {
    pub fn new(n: usize, seed: u64) -> Self {
        let mut rng = Prng::new(seed ^ 0x5A3E);
        let mut order: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut order);
        EpochSampler {
            order,
            cursor: 0,
            rng,
            next_order: None,
        }
    }

    /// Fix (and return) the next epoch's shuffled order without consuming
    /// the current one.  Idempotent until the wrap adopts it.  Scheduling
    /// the head of this order into the prefetch pipeline while the current
    /// epoch's tail drains removes the per-epoch cold start.
    pub fn precommit_next(&mut self) -> &[u32] {
        if self.next_order.is_none() {
            let mut next = self.order.clone();
            self.rng.shuffle(&mut next);
            self.next_order = Some(next);
        }
        self.next_order.as_deref().expect("just committed")
    }

    /// Remaining items this epoch.
    pub fn remaining(&self) -> usize {
        self.order.len() - self.cursor
    }

    /// The not-yet-consumed remainder of the current epoch, in draw order —
    /// exactly what a prefetch pipeline should fetch ahead of the cursor.
    pub fn upcoming(&self) -> &[u32] {
        &self.order[self.cursor..]
    }

    /// The next `take` indices of the *effective* draw order, starting
    /// `skip` entries ahead of the cursor: the remainder of the current
    /// epoch, or — at an exact epoch boundary — the pre-committed
    /// next-epoch order the wrap will adopt.  Exactly what a prefetch
    /// scheduler should queue; the `skip` lets it avoid re-queueing a
    /// head it already warmed.
    pub fn draw_window(&mut self, skip: usize, take: usize) -> Vec<u32> {
        let order: &[u32] = if self.remaining() == 0 {
            self.precommit_next()
        } else {
            self.upcoming()
        };
        order.iter().skip(skip).take(take).copied().collect()
    }

    /// Next mini-batch of up to `batch` indices; reshuffles when the epoch
    /// ends (returns `None` exactly at the epoch boundary so callers can
    /// run validation/checkpointing, §3.1).
    pub fn next_batch(&mut self, batch: usize) -> Option<Vec<u32>> {
        if self.cursor >= self.order.len() {
            // adopt a pre-committed order when one exists (same RNG draw
            // the in-place reshuffle would have made)
            match self.next_order.take() {
                Some(next) => self.order = next,
                None => self.rng.shuffle(&mut self.order),
            }
            self.cursor = 0;
            return None;
        }
        let end = (self.cursor + batch).min(self.order.len());
        let out = self.order[self.cursor..end].to_vec();
        self.cursor = end;
        Some(out)
    }

    /// The global-vs-partitioned ablation (Fig 1): restrict this sampler to
    /// an exclusive *contiguous* shard of the (directory-ordered) file list.
    /// Contiguous is what a partitioned view actually looks like: files land
    /// on nodes in traversal order, and ImageNet's traversal order is
    /// class-directory order — which is exactly why the partitioned view
    /// hurts accuracy (§3.2).
    pub fn partitioned(n: usize, node: u32, nodes: u32, seed: u64) -> Self {
        let mut rng = Prng::new(seed ^ 0x9A27);
        let lo = (n as u64 * node as u64 / nodes as u64) as u32;
        let hi = (n as u64 * (node as u64 + 1) / nodes as u64) as u32;
        let mut order: Vec<u32> = (lo..hi).collect();
        rng.shuffle(&mut order);
        EpochSampler {
            order,
            cursor: 0,
            rng,
            next_order: None,
        }
    }
}

/// Full sequential sweep of the test set (each process reads everything).
#[derive(Clone, Debug)]
pub struct TestSweep {
    pub n: usize,
}

impl TestSweep {
    pub fn indices(&self) -> impl Iterator<Item = u32> + '_ {
        0..self.n as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_visits_every_file_once() {
        let mut s = EpochSampler::new(103, 1);
        let mut seen = vec![0u32; 103];
        while let Some(batch) = s.next_batch(16) {
            for i in batch {
                seen[i as usize] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "epoch must be a permutation");
    }

    #[test]
    fn epochs_reshuffle() {
        let mut s = EpochSampler::new(64, 2);
        let mut e1 = Vec::new();
        while let Some(b) = s.next_batch(64) {
            e1.extend(b);
        }
        let mut e2 = Vec::new();
        while let Some(b) = s.next_batch(64) {
            e2.extend(b);
        }
        assert_ne!(e1, e2, "different epoch order");
        let mut s1 = e1.clone();
        let mut s2 = e2.clone();
        s1.sort_unstable();
        s2.sort_unstable();
        assert_eq!(s1, s2, "same contents");
    }

    #[test]
    fn partitioned_shards_are_exclusive_and_cover() {
        let mut all = Vec::new();
        for node in 0..4 {
            let mut s = EpochSampler::partitioned(101, node, 4, 3);
            while let Some(b) = s.next_batch(8) {
                all.extend(b);
            }
        }
        all.sort_unstable();
        assert_eq!(all, (0..101).collect::<Vec<_>>());
    }

    #[test]
    fn partitioned_shards_are_contiguous_blocks() {
        let mut s = EpochSampler::partitioned(100, 1, 4, 3);
        let mut idx = Vec::new();
        while let Some(b) = s.next_batch(100) {
            idx.extend(b);
        }
        idx.sort_unstable();
        assert_eq!(idx, (25..50).collect::<Vec<_>>());
    }

    #[test]
    fn upcoming_matches_future_draws() {
        let mut s = EpochSampler::new(20, 9);
        assert_eq!(s.next_batch(6).unwrap().len(), 6);
        let promised: Vec<u32> = s.upcoming().to_vec();
        assert_eq!(promised.len(), 14);
        let mut drawn = Vec::new();
        while let Some(b) = s.next_batch(6) {
            drawn.extend(b);
        }
        assert_eq!(promised, drawn, "upcoming must be the exact draw order");
    }

    #[test]
    fn batch_sizes() {
        let mut s = EpochSampler::new(10, 4);
        assert_eq!(s.next_batch(4).unwrap().len(), 4);
        assert_eq!(s.next_batch(4).unwrap().len(), 4);
        assert_eq!(s.next_batch(4).unwrap().len(), 2); // tail
        assert!(s.next_batch(4).is_none()); // epoch boundary
        assert_eq!(s.next_batch(4).unwrap().len(), 4); // new epoch
    }

    #[test]
    fn precommit_never_changes_the_sequence() {
        // a sampler that pre-commits draws the exact sequence of one that
        // reshuffles lazily at every wrap
        let mut lazy = EpochSampler::new(37, 11);
        let mut eager = EpochSampler::new(37, 11);
        let mut lazy_seq = Vec::new();
        let mut eager_seq = Vec::new();
        for round in 0..5 {
            // pre-commit at a different point each epoch (including before
            // any draw, and twice — idempotence)
            if round % 2 == 0 {
                let head: Vec<u32> = eager.precommit_next().iter().take(4).copied().collect();
                assert_eq!(head.len(), 4);
                assert_eq!(&eager.precommit_next()[..4], &head[..], "idempotent");
            }
            loop {
                let (a, b) = (lazy.next_batch(8), eager.next_batch(8));
                assert_eq!(a, b, "sequences must match at every draw");
                match a {
                    Some(v) => {
                        lazy_seq.extend(v);
                        eager_seq.extend(b.unwrap_or_default());
                    }
                    None => break,
                }
            }
        }
        assert_eq!(lazy_seq, eager_seq);
        assert_eq!(lazy_seq.len(), 5 * 37);
    }

    #[test]
    fn precommitted_order_is_what_the_wrap_adopts() {
        let mut s = EpochSampler::new(16, 3);
        // drain epoch 0
        while s.next_batch(16).is_some() {}
        // cursor is at the boundary: commit epoch 1's order
        let promised: Vec<u32> = s.precommit_next().to_vec();
        // draw_window sees the committed order across the boundary, and
        // skip composes with an already-warmed head
        assert_eq!(s.draw_window(0, 4), &promised[..4]);
        assert_eq!(s.draw_window(4, 16), &promised[4..]);
        assert_eq!(s.next_batch(16), None, "boundary signal");
        let drawn = s.next_batch(16).expect("fresh epoch");
        assert_eq!(drawn, promised, "the wrap must adopt the committed order");
    }

    #[test]
    fn test_sweep_covers_all() {
        let sweep = TestSweep { n: 7 };
        let v: Vec<u32> = sweep.indices().collect();
        assert_eq!(v, vec![0, 1, 2, 3, 4, 5, 6]);
    }
}
