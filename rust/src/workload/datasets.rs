//! Synthetic dataset generators matching the paper's Table 2:
//!
//! | App       | # files      | # dirs | total_size | file_size |
//! |-----------|--------------|--------|------------|-----------|
//! | ResNet-50 | 1.3 million  | 2,002  | 140 GB     | KB–MB     |
//! | SRGAN     | 0.6 million  | 6      | 500 GB     | MB        |
//! | FRNN      | 0.17 million | 1      | 54 GB      | KB        |
//!
//! We cannot (and need not) materialize terabytes: `generate(scale)`
//! produces a structurally-identical dataset shrunk by `scale` — same dir
//! fan-out pattern, same file-size *distribution*, controlled
//! compressibility (SRGAN ≈ 2.8×, ImageNet ≈ none, §6.6) — while
//! [`DatasetSpec::full_scale`] keeps the true statistics for the
//! virtual-time simulator.

use crate::partition::builder::InputFile;
use crate::util::prng::Prng;

/// Which paper application a dataset mimics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AppKind {
    ResNet50,
    SrganInit,
    SrganTrain,
    Frnn,
}

impl AppKind {
    pub fn name(&self) -> &'static str {
        match self {
            AppKind::ResNet50 => "ResNet-50",
            AppKind::SrganInit => "SRGAN-Init",
            AppKind::SrganTrain => "SRGAN-Train",
            AppKind::Frnn => "FRNN",
        }
    }
}

/// Full-scale dataset statistics + synthesis knobs.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// Full-scale file count (Table 2).
    pub full_files: u64,
    /// Full-scale directory count (Table 2).
    pub full_dirs: u64,
    /// Full-scale total bytes (Table 2).
    pub full_bytes: u64,
    /// Log-normal file-size parameters (of ln bytes).
    pub size_mu: f64,
    pub size_sigma: f64,
    /// Minimum/maximum file size clamp.
    pub min_size: u64,
    pub max_size: u64,
    /// Fraction of each file that is repeated motif (drives LZSS ratio).
    pub redundancy: f64,
}

impl DatasetSpec {
    /// ImageNet-1k: 1.3 M files, 2002 dirs, 140 GB, KB–MB JPEG-like
    /// (already-compressed: no redundancy, §6.6 "does not have additional
    /// room for compression").
    pub fn imagenet() -> Self {
        DatasetSpec {
            name: "imagenet-1k",
            full_files: 1_300_000,
            full_dirs: 2_002,
            full_bytes: 140 << 30,
            // mean ≈ 108 KB (§6.7), long right tail
            size_mu: (100.0f64 * 1024.0).ln(),
            size_sigma: 0.55,
            min_size: 4 * 1024,
            max_size: 2 << 20,
            redundancy: 0.02,
        }
    }

    /// SRGAN EM imagery: 0.6 M files, 6 dirs, 500 GB, MB-sized, 2.8×
    /// compressible (§6.6).
    pub fn srgan() -> Self {
        DatasetSpec {
            name: "srgan-em",
            full_files: 600_000,
            full_dirs: 6,
            full_bytes: 500 << 30,
            size_mu: (800.0f64 * 1024.0).ln(),
            size_sigma: 0.35,
            min_size: 128 * 1024,
            max_size: 4 << 20,
            redundancy: 0.72,
        }
    }

    /// FRNN tokamak shots: 0.17 M files, 1 dir, 54 GB, KB-sized.
    pub fn frnn() -> Self {
        DatasetSpec {
            name: "frnn",
            full_files: 171_264,
            full_dirs: 1,
            full_bytes: 54 << 30,
            size_mu: (300.0f64 * 1024.0).ln(),
            size_sigma: 0.25,
            min_size: 64 * 1024,
            max_size: 1 << 20,
            redundancy: 0.35,
        }
    }

    pub fn for_app(app: AppKind) -> Self {
        match app {
            AppKind::ResNet50 => Self::imagenet(),
            AppKind::SrganInit | AppKind::SrganTrain => Self::srgan(),
            AppKind::Frnn => Self::frnn(),
        }
    }

    /// Mean full-scale file size.
    pub fn mean_file_size(&self) -> u64 {
        self.full_bytes / self.full_files.max(1)
    }

    /// Draw one file size from the spec's distribution.
    pub fn draw_size(&self, rng: &mut Prng) -> u64 {
        let ln = self.size_mu + self.size_sigma * rng.normal();
        (ln.exp() as u64).clamp(self.min_size, self.max_size)
    }

    /// Materialize a scaled-down dataset: `files` files spread over
    /// `min(full_dirs, files)` directories with the full-scale size
    /// distribution divided by `size_divisor` (keeps tests fast while
    /// preserving the distribution's *shape*).
    pub fn generate(&self, files: usize, size_divisor: u64, seed: u64) -> Vec<InputFile> {
        let mut rng = Prng::new(seed ^ 0xDA7A5E7);
        let dirs = (self.full_dirs as usize).min(files.max(1)).max(1);
        let mut out = Vec::with_capacity(files);
        for i in 0..files {
            let size = (self.draw_size(&mut rng) / size_divisor.max(1)).max(16);
            let data = synth_content(&mut rng, size as usize, self.redundancy);
            let dir = i % dirs;
            out.push(InputFile {
                path: format!("{}/d{dir:04}/f{i:06}.bin", self.name),
                data,
            });
        }
        out
    }
}

/// Synthesize `len` bytes whose LZSS compressibility tracks `redundancy`:
/// a stream interleaving fresh random bytes with re-emissions of a recent
/// motif (what EM imagery's smooth regions look like to a byte-level LZ).
pub fn synth_content(rng: &mut Prng, len: usize, redundancy: f64) -> Vec<u8> {
    let mut out = Vec::with_capacity(len);
    let motif_len = 48;
    let mut motif = vec![0u8; motif_len];
    rng.fill_bytes(&mut motif);
    while out.len() < len {
        if rng.chance(redundancy) {
            // re-emit the motif (compressible)
            out.extend_from_slice(&motif);
        } else {
            // fresh noise, occasionally refresh the motif
            let n = 16 + rng.index(32);
            let start = out.len();
            out.resize(start + n, 0);
            rng.fill_bytes(&mut out[start..]);
            if rng.chance(0.25) {
                rng.fill_bytes(&mut motif);
            }
        }
    }
    out.truncate(len);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::lzss;

    #[test]
    fn table2_statistics() {
        let im = DatasetSpec::imagenet();
        assert_eq!(im.full_files, 1_300_000);
        assert_eq!(im.full_dirs, 2_002);
        // §6.7: average ImageNet file ≈ 108 KB
        let mean = im.mean_file_size();
        assert!((100_000..130_000).contains(&mean), "mean {mean}");
        assert_eq!(DatasetSpec::frnn().full_dirs, 1);
        assert_eq!(DatasetSpec::srgan().full_dirs, 6);
    }

    #[test]
    fn generate_respects_count_and_dirs() {
        let files = DatasetSpec::imagenet().generate(100, 1024, 1);
        assert_eq!(files.len(), 100);
        let dirs: std::collections::HashSet<_> = files
            .iter()
            .map(|f| f.path.rsplit_once('/').unwrap().0.to_string())
            .collect();
        assert_eq!(dirs.len(), 100); // 2002 dirs clamped to file count
        let frnn = DatasetSpec::frnn().generate(50, 1024, 2);
        let fdirs: std::collections::HashSet<_> = frnn
            .iter()
            .map(|f| f.path.rsplit_once('/').unwrap().0.to_string())
            .collect();
        assert_eq!(fdirs.len(), 1);
    }

    #[test]
    fn srgan_compressibility_in_band() {
        let mut rng = Prng::new(7);
        let data = synth_content(&mut rng, 256 * 1024, DatasetSpec::srgan().redundancy);
        let c = lzss::compress(&data, 5);
        let ratio = data.len() as f64 / c.len() as f64;
        // paper: 2.8x on the SRGAN dataset — accept a generous band
        assert!((1.9..4.5).contains(&ratio), "srgan ratio {ratio}");
    }

    #[test]
    fn imagenet_incompressible() {
        let mut rng = Prng::new(8);
        let data = synth_content(&mut rng, 128 * 1024, DatasetSpec::imagenet().redundancy);
        let c = lzss::compress(&data, 5);
        let ratio = data.len() as f64 / c.len() as f64;
        assert!(ratio < 1.25, "imagenet ratio {ratio}");
    }

    #[test]
    fn sizes_clamped() {
        let spec = DatasetSpec::frnn();
        let mut rng = Prng::new(3);
        for _ in 0..1000 {
            let s = spec.draw_size(&mut rng);
            assert!((spec.min_size..=spec.max_size).contains(&s));
        }
    }

    #[test]
    fn deterministic_generation() {
        let a = DatasetSpec::srgan().generate(10, 4096, 42);
        let b = DatasetSpec::srgan().generate(10, 4096, 42);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.path, y.path);
            assert_eq!(x.data, y.data);
        }
    }
}
