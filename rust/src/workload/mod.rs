//! Workload generation: synthetic datasets with the paper's Table 2
//! statistics, the §6.2 read benchmark, and the DL access patterns of §3.

pub mod access;
pub mod bench;
pub mod datasets;

pub use access::{EpochSampler, TestSweep};
pub use bench::{BenchPoint, BenchSpec, BENCH_FILE_SIZES};
pub use datasets::{AppKind, DatasetSpec};
