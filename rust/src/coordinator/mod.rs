//! Cluster bring-up and lifecycle (the leader's job).
//!
//! `Cluster::launch` performs the paper's full §5.2 pipeline in process:
//! data preparation (partition packing ± LZSS), partition distribution by
//! placement (replication factor, replicated directories), input-metadata
//! broadcast, and worker-thread startup.  The result serves POSIX-shaped
//! traffic from any number of [`FanStoreVfs`] clients per node.
//!
//! The fabric is pluggable ([`crate::config::TransportKind`]): `InProc`
//! wires the workers over mpsc channels; `TcpLoopback` binds one real TCP
//! listener per node on 127.0.0.1 and runs the identical protocol through
//! the wire codec — the workers, clients and prefetchers cannot tell the
//! difference.  The standalone building blocks ([`prepare_partitions`],
//! [`build_global_meta`], [`build_node_shared`]) are shared with the
//! multi-process `fanstore cluster serve|join` CLI, where each host runs
//! exactly one node of the same pipeline.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::config::{ClusterConfig, TransportKind};
use crate::error::Result;
use crate::metadata::placement::Placement;
use crate::metadata::record::{FileLocation, FileMeta, REPLICATED_PARTITION};
use crate::metadata::table::MetaTable;
use crate::net::tcp::{TcpServer, TcpTransport, DEFAULT_POOL_SIZE};
use crate::net::transport::{InProcTransport, NodeEndpoint, Request, Transport};
use crate::node::{FanStoreNode, NodeBuilder, NodeShared, NodeStats};
use crate::partition::builder::{build_partitions_with, BuildStats, InputFile};
use crate::partition::format::PartitionReader;
use crate::prefetch::{PrefetchConfig, PrefetchHandle, PrefetchStats, Prefetcher};
use crate::storage::disk::DiskStore;
use crate::vfs::FanStoreVfs;

/// Packed dataset ready for distribution: the exclusive partition blobs,
/// the optional replicated-directory blob, and the prep accounting.
pub struct PreparedData {
    pub blobs: Vec<(u32, Vec<u8>)>,
    pub repl_blob: Option<Vec<u8>>,
    pub prep_stats: BuildStats,
}

/// §5.2 data preparation: pack `files` into `config.partitions` exclusive
/// partitions (± LZSS) plus one replicated partition for everything under
/// a `config.replicate_dirs` prefix.  Deterministic given identical input,
/// so every host of a multi-process cluster can prepare independently.
pub fn prepare_partitions(files: &[InputFile], config: &ClusterConfig) -> Result<PreparedData> {
    let (replicated, partitioned): (Vec<_>, Vec<_>) = files.iter().cloned().partition(|f| {
        config
            .replicate_dirs
            .iter()
            .any(|d| f.path.starts_with(d.trim_end_matches('/')))
    });

    let (blobs, mut prep_stats) = build_partitions_with(
        &partitioned,
        config.partitions,
        config.codec,
        &config.compress_policy,
    )?;
    let blobs: Vec<(u32, Vec<u8>)> = blobs
        .into_iter()
        .enumerate()
        .map(|(i, b)| (i as u32, b))
        .collect();

    let repl_blob = if replicated.is_empty() {
        None
    } else {
        let (mut rb, rstats) =
            build_partitions_with(&replicated, 1, config.codec, &config.compress_policy)?;
        prep_stats.files += rstats.files;
        prep_stats.raw_bytes += rstats.raw_bytes;
        prep_stats.stored_bytes += rstats.stored_bytes;
        prep_stats.compressed_files += rstats.compressed_files;
        Some(rb.pop().unwrap())
    };
    Ok(PreparedData {
        blobs,
        repl_blob,
        prep_stats,
    })
}

/// §5.3 metadata broadcast content: the global input table every node
/// replicates (identical on all of them).
pub fn build_global_meta(
    data: &PreparedData,
    config: &ClusterConfig,
    placement: &Placement,
) -> Result<MetaTable> {
    let mut global_meta = MetaTable::new();
    crate::node::index_input_metadata(&mut global_meta, &data.blobs, &config.mount, placement)?;
    if let Some(rb) = &data.repl_blob {
        let mut reader = PartitionReader::new(rb)?;
        while let Some((e, data_off)) = reader.next_entry()? {
            let path = format!("{}/{}", config.mount.trim_end_matches('/'), e.name);
            global_meta.insert(
                &path,
                FileMeta {
                    stat: e.stat,
                    location: FileLocation {
                        node: u32::MAX,
                        partition: REPLICATED_PARTITION,
                        offset: data_off,
                        stored_len: e.stored_len(),
                        codec: e.codec,
                    },
                    generation: 0,
                },
            );
        }
    }
    Ok(global_meta)
}

/// Build and seal one node's shared state: dump the partitions placement
/// assigns it (plus the replicated blob), attach the metadata replica.
/// Used per node by [`Cluster::launch`] and once per host by the
/// `fanstore cluster` CLI.
pub fn build_node_shared(
    id: u32,
    data: &PreparedData,
    global_meta: Arc<MetaTable>,
    placement: &Placement,
    config: &ClusterConfig,
) -> Result<Arc<NodeShared>> {
    let store = match &config.spill_dir {
        Some(dir) => {
            DiskStore::on_disk_with_mode(format!("{dir}/node{id:03}"), config.spill_read_mode)?
        }
        None => DiskStore::in_memory(),
    };
    let mut builder = NodeBuilder::new(id, store, placement.clone());
    builder.cache_shards = config.cache_shards;
    builder.health_policy.retry_budget = config.retry_budget;
    builder.tier_policy = config.tier_policy;
    builder.ram_budget_bytes = config.ram_budget_bytes;
    builder.migrate_interval_ms = config.migrate_interval_ms;
    builder.mount = config.mount.clone();
    builder.probe_interval_ms = config.probe_interval_ms;
    builder.repair_max_inflight = config.repair_max_inflight;
    // dump the partitions this node hosts
    for (pid, blob) in &data.blobs {
        if placement.is_local(*pid, id) {
            builder
                .store
                .load_partition(*pid, blob.clone(), &config.mount)?;
        }
    }
    if let Some(rb) = &data.repl_blob {
        builder
            .store
            .load_partition(REPLICATED_PARTITION, rb.clone(), &config.mount)?;
    }
    builder.input_meta = global_meta;
    Ok(builder.seal())
}

/// A running FanStore cluster (single process; fabric per
/// `config.transport`).
pub struct Cluster {
    pub transport: Arc<dyn Transport>,
    pub placement: Placement,
    pub config: ClusterConfig,
    pub prep_stats: BuildStats,
    nodes: Vec<FanStoreNode>,
    /// Per-node background prefetch engines, started on first use and
    /// stopped (pins released) before the workers shut down.
    prefetchers: Mutex<Vec<Option<Arc<Prefetcher>>>>,
    /// Loopback-TCP listeners (one slot per node; empty in `InProc` mode,
    /// `None` once [`Cluster::kill_node`] took that node down).  Stopped in
    /// `shutdown` after the shutdown broadcast but *before* the worker
    /// joins, so a worker whose `Shutdown` message was lost still exits
    /// via inbox-channel close instead of deadlocking the join.
    tcp_servers: Vec<Option<TcpServer>>,
}

/// Post-shutdown accounting.
#[derive(Clone, Debug, Default)]
pub struct ClusterReport {
    pub per_node: Vec<NodeStats>,
    pub requests_served: u64,
}

impl Cluster {
    /// Prepare `files` and launch the cluster.
    ///
    /// Files under any `config.replicate_dirs` prefix are packed into a
    /// dedicated partition loaded on *every* node (§5.4's replicated
    /// directory); the rest are packed into `config.partitions` exclusive
    /// partitions distributed per the replication factor.
    pub fn launch(files: &[InputFile], config: ClusterConfig) -> Result<Cluster> {
        config.validate()?;
        let data = prepare_partitions(files, &config)?;
        let placement = Placement::new(config.nodes, config.partitions, config.replication);

        // fabric bring-up: the endpoints feed the worker threads the same
        // way whichever transport delivers into them.  Both fabrics honor
        // the bounded per-call reply wait (`--call-timeout-ms`; 0 = never).
        let call_timeout = match config.call_timeout_ms {
            0 => None,
            ms => Some(Duration::from_millis(ms)),
        };
        // metadata broadcast: every node gets the full table.  Built once,
        // sealed immutable, and shared as one Arc — in-proc, a single RAM
        // copy stands in for the N identical replicas of the real wire
        // broadcast (§5.3).  Node state is built BEFORE the fabric so the
        // TCP accept loops can share each node's `decode_rejects` counter.
        let global_meta = Arc::new(build_global_meta(&data, &config, &placement)?);
        let mut shareds = Vec::with_capacity(config.nodes as usize);
        for id in 0..config.nodes {
            shareds.push(build_node_shared(
                id,
                &data,
                Arc::clone(&global_meta),
                &placement,
                &config,
            )?);
        }

        let mut tcp_servers: Vec<Option<TcpServer>> = Vec::new();
        let (transport, endpoints): (Arc<dyn Transport>, Vec<NodeEndpoint>) =
            match config.transport {
                TransportKind::InProc => {
                    let (mut t, eps) = InProcTransport::fully_connected(config.nodes);
                    if let Some(timeout) = call_timeout {
                        t = t.with_call_timeout(timeout);
                    }
                    let t: Arc<dyn Transport> = Arc::new(t);
                    (t, eps)
                }
                TransportKind::TcpLoopback => {
                    let mut endpoints = Vec::with_capacity(config.nodes as usize);
                    let mut addrs = Vec::with_capacity(config.nodes as usize);
                    for id in 0..config.nodes {
                        let (srv, ep) = TcpServer::bind_counted(
                            id,
                            "127.0.0.1:0",
                            Arc::clone(&shareds[id as usize].stats.decode_rejects),
                        )?;
                        addrs.push(srv.local_addr());
                        tcp_servers.push(Some(srv));
                        endpoints.push(ep);
                    }
                    let t: Arc<dyn Transport> = Arc::new(TcpTransport::connect_with(
                        &addrs,
                        DEFAULT_POOL_SIZE,
                        call_timeout,
                    )?);
                    (t, endpoints)
                }
            };

        let mut nodes = Vec::with_capacity(config.nodes as usize);
        for (shared, ep) in shareds.into_iter().zip(endpoints) {
            debug_assert_eq!(shared.id, ep.node_id);
            nodes.push(FanStoreNode::spawn(shared, ep));
        }
        // recovery threads last — probing needs the fabric, so unlike the
        // migrator this cannot start at seal time.  No-op unless
        // `probe_interval_ms` is set.
        for n in &nodes {
            n.shared.start_recovery(Arc::clone(&transport));
        }

        let prefetchers = Mutex::new((0..config.nodes).map(|_| None).collect());
        Ok(Cluster {
            transport,
            placement,
            config,
            prep_stats: data.prep_stats,
            nodes,
            prefetchers,
            tcp_servers,
        })
    }

    pub fn node_count(&self) -> u32 {
        self.config.nodes
    }

    /// New VFS client ("training process") bound to `node`.
    pub fn client(&self, node: u32) -> FanStoreVfs {
        FanStoreVfs::new(
            node,
            Arc::clone(&self.nodes[node as usize].shared),
            Arc::clone(&self.transport),
        )
    }

    /// New VFS client with the node's background prefetch engine attached:
    /// input opens claim prefetched content instead of fetching inline.
    pub fn prefetching_client(&self, node: u32) -> FanStoreVfs {
        let mut c = self.client(node);
        c.attach_prefetcher(self.prefetch_handle(node));
        c
    }

    /// Handle to `node`'s prefetch engine, starting it on first use with
    /// the cluster's `prefetch_window` / `prefetch_fetchers` settings.
    pub fn prefetch_handle(&self, node: u32) -> PrefetchHandle {
        let mut engines = self.prefetchers.lock().unwrap();
        let slot = &mut engines[node as usize];
        if slot.is_none() {
            *slot = Some(Arc::new(Prefetcher::spawn(
                node,
                Arc::clone(&self.nodes[node as usize].shared),
                Arc::clone(&self.transport),
                PrefetchConfig {
                    window: self.config.prefetch_window,
                    fetchers: self.config.prefetch_fetchers,
                },
            )));
        }
        slot.as_ref().expect("just created").handle()
    }

    /// Prefetch accounting for `node` (zeros if its engine never started).
    pub fn prefetch_stats(&self, node: u32) -> PrefetchStats {
        self.prefetchers.lock().unwrap()[node as usize]
            .as_ref()
            .map(|p| p.stats())
            .unwrap_or_default()
    }

    /// Stop every prefetch engine, releasing unclaimed cache pins.  Called
    /// by [`Cluster::shutdown`]; also useful for draining mid-run (a later
    /// `prefetch_handle` starts a fresh engine).
    pub fn stop_prefetchers(&self) {
        let mut engines = self.prefetchers.lock().unwrap();
        for slot in engines.iter_mut() {
            *slot = None;
        }
    }

    /// Shared state handle (tests / stats).  No lock: components of
    /// [`NodeShared`] synchronize individually.
    pub fn node_state(&self, node: u32) -> Arc<NodeShared> {
        Arc::clone(&self.nodes[node as usize].shared)
    }

    /// Kill node `n` mid-run (the chaos tests' node failure): ask its
    /// worker to exit, stop its TCP listener, evict its pooled sockets,
    /// and join the worker thread.  Surviving readers fail over to the
    /// partition replicas; reads whose every holder is gone degrade with
    /// an error.  Returns the requests the dead worker had served.
    pub fn kill_node(&mut self, n: u32) -> u64 {
        // the recovery thread first: a dead node must not keep probing and
        // repairing the cluster it just "left"
        self.nodes[n as usize].shared.stop_recovery();
        // the migrator next: a dead node's store should not keep
        // shuffling tiers underneath the failover reads of the survivors
        self.nodes[n as usize].shared.stop_migrator();
        // best-effort shutdown request — over TCP the worker may already be
        // unreachable, and the listener teardown below covers that case
        let _ = self.transport.call(u32::MAX, n, Request::Shutdown);
        if let Some(slot) = self.tcp_servers.get_mut(n as usize) {
            *slot = None;
        }
        // dropping pooled sockets makes the bridge threads EOF, so the
        // worker's inbox senders vanish even if the Shutdown frame was lost
        self.transport.evict(n);
        self.nodes[n as usize].join_worker()
    }

    /// Orderly shutdown; returns per-node stats.
    pub fn shutdown(mut self) -> ClusterReport {
        // prefetch engines first: their fetcher threads talk to the node
        // workers, and their unclaimed pins must drain before stats settle
        self.stop_prefetchers();
        // recovery threads next: no probes or repairs may race the teardown
        for n in &self.nodes {
            n.shared.stop_recovery();
        }
        // migrators next, so tier counters are settled before the snapshot
        for n in &self.nodes {
            n.shared.stop_migrator();
        }
        let per_node: Vec<NodeStats> = self
            .nodes
            .iter()
            .map(|n| n.shared.stats_snapshot())
            .collect();
        // transport second: workers receive Shutdown and exit; over TCP
        // this also closes the client sockets, so bridge threads drain
        self.transport.shutdown_all();
        // TCP listeners third, BEFORE the worker joins: stopping the
        // accept loops drops the last inbox senders, so a worker whose
        // Shutdown message was lost (peer dial failure, torn frame) exits
        // via channel close instead of deadlocking the join below
        self.tcp_servers.clear();
        let requests_served = self.nodes.into_iter().map(|n| n.join()).sum();
        ClusterReport {
            per_node,
            requests_served,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::util::prng::Prng;
    use crate::vfs::Vfs;

    fn dataset(n: usize, size: usize, seed: u64) -> Vec<InputFile> {
        let mut rng = Prng::new(seed);
        (0..n)
            .map(|i| {
                let mut data = vec![0u8; size];
                rng.fill_bytes(&mut data);
                InputFile {
                    path: format!("train/class{:02}/img{i:04}.raw", i % 10),
                    data,
                }
            })
            .collect()
    }

    #[test]
    fn launch_read_everything_from_every_node() {
        let files = dataset(40, 256, 1);
        let cfg = ClusterConfig {
            nodes: 4,
            partitions: 8,
            ..Default::default()
        };
        let cluster = Cluster::launch(&files, cfg).unwrap();
        for node in 0..4 {
            let mut vfs = cluster.client(node);
            for f in &files {
                let path = format!("/fanstore/user/{}", f.path);
                assert_eq!(vfs.read_all(&path).unwrap(), f.data, "{path} via node {node}");
            }
        }
        let report = cluster.shutdown();
        // with 4 nodes and single-copy placement, remote traffic must exist
        let remote: u64 = report.per_node.iter().map(|s| s.remote_reads_issued).sum();
        assert!(remote > 0);
    }

    #[test]
    fn compressed_cluster_roundtrip() {
        // compressible content
        let files: Vec<InputFile> = (0..20)
            .map(|i| InputFile {
                path: format!("train/f{i}"),
                data: vec![(i % 7) as u8; 4096],
            })
            .collect();
        let cfg = ClusterConfig {
            nodes: 2,
            partitions: 4,
            codec: Codec::Lzss(5),
            ..Default::default()
        };
        let cluster = Cluster::launch(&files, cfg).unwrap();
        assert!(cluster.prep_stats.ratio() > 5.0);
        let mut vfs = cluster.client(1);
        for f in &files {
            assert_eq!(
                vfs.read_all(&format!("/fanstore/user/{}", f.path)).unwrap(),
                f.data
            );
        }
        let report = cluster.shutdown();
        let decomp: u64 = report.per_node.iter().map(|s| s.decompressions).sum();
        assert_eq!(decomp, 20);
    }

    #[test]
    fn replicated_dir_served_locally() {
        let mut files = dataset(16, 128, 3);
        files.extend((0..8).map(|i| InputFile {
            path: format!("val/v{i}.raw"),
            data: vec![i as u8; 64],
        }));
        let cfg = ClusterConfig {
            nodes: 4,
            partitions: 4,
            replicate_dirs: vec!["val".into()],
            ..Default::default()
        };
        let cluster = Cluster::launch(&files, cfg).unwrap();
        // read the whole val/ dir from every node: must cause NO remote reads
        for node in 0..4 {
            let mut vfs = cluster.client(node);
            for i in 0..8 {
                assert_eq!(
                    vfs.read_all(&format!("/fanstore/user/val/v{i}.raw")).unwrap(),
                    vec![i as u8; 64]
                );
            }
        }
        let report = cluster.shutdown();
        for s in &report.per_node {
            assert_eq!(s.remote_reads_issued, 0, "val reads must be local");
        }
    }

    #[test]
    fn global_namespace_readdir() {
        let files = dataset(12, 64, 4);
        let cluster = Cluster::launch(
            &files,
            ClusterConfig {
                nodes: 3,
                partitions: 3,
                ..Default::default()
            },
        )
        .unwrap();
        let mut a = cluster.client(0);
        let mut b = cluster.client(2);
        let da = a.readdir("/fanstore/user/train").unwrap();
        let db = b.readdir("/fanstore/user/train").unwrap();
        assert_eq!(da, db, "global view must be identical on all nodes");
        assert_eq!(da.len(), 10); // class00..class09
        cluster.shutdown();
    }

    #[test]
    fn output_write_visible_cluster_wide_after_close() {
        let files = dataset(8, 64, 5);
        let cluster = Cluster::launch(
            &files,
            ClusterConfig {
                nodes: 4,
                partitions: 4,
                ..Default::default()
            },
        )
        .unwrap();
        let mut writer = cluster.client(1);
        let ckpt = vec![0xAB; 5000];
        writer.write_file("/ckpt/model_epoch01.bin", &ckpt).unwrap();
        // visible (stat + read) from every other node
        for node in 0..4 {
            let mut v = cluster.client(node);
            assert_eq!(v.stat("/ckpt/model_epoch01.bin").unwrap().size, 5000);
            assert_eq!(v.read_all("/ckpt/model_epoch01.bin").unwrap(), ckpt);
        }
        // single-write: re-creating the same output must fail
        assert!(writer.write_file("/ckpt/model_epoch01.bin", b"x").is_err());
        cluster.shutdown();
    }

    #[test]
    fn prefetching_client_reads_everything_and_drains() {
        let files = dataset(48, 512, 21);
        let cluster = Cluster::launch(
            &files,
            ClusterConfig {
                nodes: 4,
                partitions: 8,
                prefetch_window: 8,
                prefetch_fetchers: 2,
                ..Default::default()
            },
        )
        .unwrap();
        let handle = cluster.prefetch_handle(0);
        let paths: Vec<String> = files
            .iter()
            .map(|f| format!("/fanstore/user/{}", f.path))
            .collect();
        handle.schedule(paths.iter().cloned());
        let mut vfs = cluster.prefetching_client(0);
        for (f, p) in files.iter().zip(&paths) {
            assert_eq!(vfs.read_all(p).unwrap(), f.data, "{p}");
        }
        let pf = cluster.prefetch_stats(0);
        assert_eq!(pf.scheduled, 48);
        assert_eq!(
            pf.claimed + pf.stolen,
            48,
            "every read claims or steals its path: {pf:?}"
        );
        cluster.stop_prefetchers();
        let st = cluster.node_state(0);
        assert_eq!(st.cache.resident_files(), 0, "pins drained");
        drop(st);
        cluster.shutdown();
    }

    #[test]
    fn tcp_loopback_cluster_serves_reads() {
        let files = dataset(24, 31, 7);
        let cluster = Cluster::launch(
            &files,
            ClusterConfig {
                nodes: 3,
                partitions: 6,
                transport: TransportKind::TcpLoopback,
                ..Default::default()
            },
        )
        .unwrap();
        for node in 0..3 {
            let mut vfs = cluster.client(node);
            for f in &files {
                let path = format!("/fanstore/user/{}", f.path);
                assert_eq!(vfs.read_all(&path).unwrap(), f.data, "{path} via node {node}");
            }
        }
        let report = cluster.shutdown();
        let remote: u64 = report.per_node.iter().map(|s| s.remote_reads_issued).sum();
        assert!(remote > 0, "3-node single-copy placement must go remote");
    }

    #[test]
    fn custom_cache_shards_are_applied() {
        let files = dataset(10, 128, 22);
        let cluster = Cluster::launch(
            &files,
            ClusterConfig {
                nodes: 2,
                partitions: 2,
                cache_shards: 3,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(cluster.node_state(0).cache.shard_count(), 3);
        let mut vfs = cluster.client(1);
        for f in &files {
            assert_eq!(
                vfs.read_all(&format!("/fanstore/user/{}", f.path)).unwrap(),
                f.data
            );
        }
        cluster.shutdown();
    }

    #[test]
    fn broadcast_replication_all_local() {
        let files = dataset(20, 128, 6);
        let cfg = ClusterConfig {
            nodes: 4,
            partitions: 4,
            replication: 4, // broadcast (FRNN mode, Fig 9)
            ..Default::default()
        };
        let cluster = Cluster::launch(&files, cfg).unwrap();
        for node in 0..4 {
            let mut vfs = cluster.client(node);
            for f in &files {
                vfs.read_all(&format!("/fanstore/user/{}", f.path)).unwrap();
            }
        }
        let report = cluster.shutdown();
        for s in &report.per_node {
            assert_eq!(s.remote_reads_issued, 0, "broadcast mode must be all-local");
        }
    }
}
