//! From-scratch LZSS (Lempel–Ziv–Storer–Szymanski) codec.
//!
//! The paper compresses partitions with LZSSE8; this is the same algorithm
//! implemented portably: a sliding window with (offset, length) back
//! references and literal passthrough, token flags packed 8-to-a-byte.
//!
//! Stream format (little-endian):
//! ```text
//! [flags: u8] then 8 items, LSB-first; flag bit 0 = literal (1 byte),
//! flag bit 1 = match: u16 offset (1-based, <= 65535) + u8 len (len-4,
//! so match lengths span 4..=259).  The final group may be short.
//! ```
//! The encoder uses a hash-head + chain match finder; `level` bounds the
//! chain walk (1 → 4 probes, 9 → 256 probes), the paper's "various
//! compression levels as a tradeoff between compression speed and ratio".

use crate::error::{FanError, Result};

const MIN_MATCH: usize = 4;
const MAX_MATCH: usize = 259; // MIN_MATCH + u8::MAX
const WINDOW: usize = 65_535; // u16 offset, 1-based
const HASH_BITS: u32 = 16;
const HASH_SIZE: usize = 1 << HASH_BITS;

#[inline]
fn hash4(data: &[u8], i: usize) -> usize {
    // 4-byte prefix hash (Fibonacci multiply).
    let v = u32::from_le_bytes([data[i], data[i + 1], data[i + 2], data[i + 3]]);
    ((v.wrapping_mul(0x9E3779B1)) >> (32 - HASH_BITS)) as usize
}

/// Probe budget per position for a given level.
fn probes_for_level(level: u8) -> usize {
    match level.clamp(1, 9) {
        1 => 4,
        2 => 8,
        3 => 16,
        4 => 24,
        5 => 32,
        6 => 64,
        7 => 96,
        8 => 128,
        _ => 256,
    }
}

/// Length of the common prefix of `data[a..]` and `data[b..]`, capped at
/// `max_len`, compared 8 bytes at a time (§Perf: ~2.4× over the byte loop).
#[inline]
fn match_len(data: &[u8], a: usize, b: usize, max_len: usize) -> usize {
    let mut l = 0usize;
    while l + 8 <= max_len {
        let x = u64::from_le_bytes(data[a + l..a + l + 8].try_into().unwrap());
        let y = u64::from_le_bytes(data[b + l..b + l + 8].try_into().unwrap());
        let diff = x ^ y;
        if diff != 0 {
            return l + (diff.trailing_zeros() / 8) as usize;
        }
        l += 8;
    }
    while l < max_len && data[a + l] == data[b + l] {
        l += 1;
    }
    l
}

/// Compress `data`; always produces a valid stream (possibly larger than the
/// input — the caller decides whether to keep it, see `Codec::compress`).
pub fn compress(data: &[u8], level: u8) -> Vec<u8> {
    let n = data.len();
    let mut out = Vec::with_capacity(n / 2 + 16);
    if n == 0 {
        return out;
    }
    let probes = probes_for_level(level);

    // hash-head + chain tables over input positions
    let mut head = vec![u32::MAX; HASH_SIZE];
    let mut chain = vec![u32::MAX; n];

    let mut i = 0usize;
    // token staging: flags byte position + count of tokens in current group
    let mut flags_pos = out.len();
    out.push(0);
    let mut ntok = 0u8;
    // literal-run acceleration (LZ4-style): after a long run of literals the
    // data is probably incompressible — probe less often, emitting the
    // skipped bytes as literals.  Keeps the reject path fast (§Perf).
    let mut literal_run = 0usize;

    macro_rules! begin_token {
        () => {
            if ntok == 8 {
                flags_pos = out.len();
                out.push(0);
                ntok = 0;
            }
        };
    }

    while i < n {
        let mut best_len = 0usize;
        let mut best_off = 0usize;
        if i + MIN_MATCH <= n {
            let h = hash4(data, i);
            let mut cand = head[h];
            let mut budget = probes;
            let max_len = (n - i).min(MAX_MATCH);
            while cand != u32::MAX && budget > 0 {
                let c = cand as usize;
                let off = i - c;
                if off > WINDOW {
                    break; // chain positions only get older
                }
                // quick reject on the byte after the current best
                if best_len == 0 || data[c + best_len] == data[i + best_len] {
                    let l = match_len(data, c, i, max_len);
                    if l > best_len {
                        best_len = l;
                        best_off = off;
                        if l >= max_len {
                            break;
                        }
                    }
                }
                cand = chain[c];
                budget -= 1;
            }
            // insert current position into the chain
            chain[i] = head[h];
            head[h] = i as u32;
        }

        if best_len >= MIN_MATCH {
            literal_run = 0;
            begin_token!();
            out[flags_pos] |= 1 << ntok;
            ntok += 1;
            out.extend_from_slice(&(best_off as u16).to_le_bytes());
            out.push((best_len - MIN_MATCH) as u8);
            // index skipped positions into the chains; stride-2 for long
            // matches (§Perf iteration 2: halves insert cost inside long
            // matches for <0.5% ratio loss)
            let end = (i + best_len).min(n.saturating_sub(MIN_MATCH - 1));
            let stride = if best_len > 32 { 2 } else { 1 };
            let mut j = i + 1;
            while j < end {
                let h = hash4(data, j);
                chain[j] = head[h];
                head[h] = j as u32;
                j += stride;
            }
            i += best_len;
        } else {
            // emit 1 + run/32 literals per probe once the run grows
            let skip = 1 + (literal_run >> 5);
            let end = (i + skip).min(n);
            while i < end {
                begin_token!();
                ntok += 1; // flag bit stays 0 = literal
                out.push(data[i]);
                i += 1;
            }
            literal_run += skip;
        }
    }
    out
}

/// Decompress a stream produced by [`compress`]; `raw_len` is the exact
/// original length (stored in the partition's stat record).
pub fn decompress(stored: &[u8], raw_len: usize) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(raw_len);
    let mut i = 0usize;
    while out.len() < raw_len {
        if i >= stored.len() {
            return Err(FanError::Codec("stream truncated (flags)".into()));
        }
        let flags = stored[i];
        i += 1;
        for bit in 0..8 {
            if out.len() == raw_len {
                break;
            }
            if flags & (1 << bit) != 0 {
                if i + 3 > stored.len() {
                    return Err(FanError::Codec("stream truncated (match)".into()));
                }
                let off = u16::from_le_bytes([stored[i], stored[i + 1]]) as usize;
                let len = stored[i + 2] as usize + MIN_MATCH;
                i += 3;
                if off == 0 || off > out.len() {
                    return Err(FanError::Codec(format!(
                        "bad match offset {off} at out len {}",
                        out.len()
                    )));
                }
                if out.len() + len > raw_len {
                    return Err(FanError::Codec("match overruns raw_len".into()));
                }
                let start = out.len() - off;
                if off >= len {
                    // non-overlapping: one memcpy (§Perf: the common case)
                    out.extend_from_within(start..start + len);
                } else {
                    // overlapping (RLE-like): copy a prefix of the already
                    // materialized window; the window doubles each round, so
                    // this is O(log(len/off)) memcpys and byte-exact with
                    // the sequential-copy semantics
                    let mut remaining = len;
                    while remaining > 0 {
                        let avail = out.len() - start;
                        let take = avail.min(remaining);
                        out.extend_from_within(start..start + take);
                        remaining -= take;
                    }
                }
            } else {
                if i >= stored.len() {
                    return Err(FanError::Codec("stream truncated (literal)".into()));
                }
                out.push(stored[i]);
                i += 1;
            }
        }
    }
    Ok(out)
}

/// Compression ratio helper (raw / stored).
pub fn ratio(raw_len: usize, stored_len: usize) -> f64 {
    if stored_len == 0 {
        return 1.0;
    }
    raw_len as f64 / stored_len as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn roundtrip(data: &[u8], level: u8) -> Vec<u8> {
        let c = compress(data, level);
        decompress(&c, data.len()).expect("valid stream")
    }

    #[test]
    fn empty() {
        assert_eq!(roundtrip(b"", 5), b"");
    }

    #[test]
    fn short_literal_only() {
        assert_eq!(roundtrip(b"abc", 5), b"abc");
    }

    #[test]
    fn repetitive_compresses_well() {
        let data: Vec<u8> = b"FanStore!".iter().cycle().take(64 * 1024).copied().collect();
        let c = compress(&data, 5);
        assert!(c.len() < data.len() / 8, "ratio too weak: {}", c.len());
        assert_eq!(decompress(&c, data.len()).unwrap(), data);
    }

    #[test]
    fn overlapping_match_rle() {
        // "aaaa..." forces offset-1 overlapping copies.
        let data = vec![b'a'; 1000];
        let c = compress(&data, 5);
        assert!(c.len() < 32);
        assert_eq!(decompress(&c, 1000).unwrap(), data);
    }

    #[test]
    fn incompressible_random_roundtrips() {
        let mut rng = Prng::new(99);
        let mut data = vec![0u8; 10_000];
        rng.fill_bytes(&mut data);
        assert_eq!(roundtrip(&data, 9), data);
    }

    #[test]
    fn all_levels_roundtrip() {
        let mut rng = Prng::new(5);
        // half-compressible: random 16-byte blocks repeated
        let mut block = vec![0u8; 16];
        let mut data = Vec::new();
        for _ in 0..200 {
            if rng.chance(0.5) {
                rng.fill_bytes(&mut block);
            }
            data.extend_from_slice(&block);
        }
        for level in 1..=9u8 {
            assert_eq!(roundtrip(&data, level), data, "level {level}");
        }
    }

    #[test]
    fn higher_level_no_worse_ratio() {
        let mut rng = Prng::new(17);
        let mut data = Vec::new();
        let mut block = vec![0u8; 64];
        for _ in 0..300 {
            if rng.chance(0.3) {
                rng.fill_bytes(&mut block);
            }
            data.extend_from_slice(&block);
        }
        let c1 = compress(&data, 1).len();
        let c9 = compress(&data, 9).len();
        assert!(c9 <= c1, "level 9 ({c9}) worse than level 1 ({c1})");
    }

    #[test]
    fn truncated_stream_is_error() {
        let data = vec![b'x'; 500];
        let c = compress(&data, 5);
        assert!(decompress(&c[..c.len() - 1], 500).is_err());
    }

    #[test]
    fn corrupt_offset_is_error() {
        // flags byte says "match", but offset points before stream start.
        let stream = [0b0000_0001u8, 0xFF, 0xFF, 10];
        assert!(decompress(&stream, 50).is_err());
    }

    #[test]
    fn long_match_cap() {
        let data = vec![b'z'; MAX_MATCH * 3 + 7];
        assert_eq!(roundtrip(&data, 9), data);
    }

    #[test]
    fn property_roundtrip_random_structured() {
        crate::util::proptest_lite::check("lzss roundtrip", 0xC0DEC, 40, |rng| {
            let n = rng.index(4096);
            let mut data = Vec::with_capacity(n);
            // mix of runs, repeats and noise
            while data.len() < n {
                match rng.below(3) {
                    0 => {
                        let b = rng.next_u64() as u8;
                        let run = rng.index(64) + 1;
                        data.extend(std::iter::repeat(b).take(run));
                    }
                    1 => {
                        let len = rng.index(32) + 1;
                        for _ in 0..len {
                            data.push(rng.next_u64() as u8);
                        }
                    }
                    _ => {
                        if !data.is_empty() {
                            let start = rng.index(data.len());
                            let len = rng.index(data.len() - start) + 1;
                            let copy: Vec<u8> = data[start..start + len].to_vec();
                            data.extend(copy);
                        }
                    }
                }
            }
            data.truncate(n);
            let level = (rng.index(9) + 1) as u8;
            let c = compress(&data, level);
            let d = decompress(&c, data.len())
                .map_err(|e| format!("decode failed: {e}"))?;
            crate::prop_assert!(d == data, "roundtrip mismatch len={n} level={level}");
            Ok(())
        });
    }
}
