//! Generic data compression (paper §5.4, §6.6).
//!
//! The paper uses LZSSE8 (an SSE-optimized LZSS) to trade CPU cycles for
//! storage/network bytes, reporting a 2.8× ratio on the SRGAN dataset.  We
//! implement the same algorithm family from scratch ([`lzss`]) with levels
//! 1–9 trading match-search depth for ratio, plus a [`Codec`] abstraction so
//! the ablation bench can compare against zstd-class ratios analytically.

pub mod lzss;

use crate::error::Result;

/// Compression codec used by the partition builder and the node read path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Codec {
    /// Store bytes verbatim.
    None,
    /// From-scratch LZSS at the given level (1 = fastest, 9 = best ratio).
    Lzss(u8),
}

impl Codec {
    /// Compress `data`. Returns `None` when the codec is `None` or when
    /// compression would not shrink the buffer (the partition format then
    /// stores the raw bytes and sets `compressed_size = 0`, paper §5.2).
    pub fn compress(&self, data: &[u8]) -> Option<Vec<u8>> {
        match self {
            Codec::None => None,
            Codec::Lzss(level) => {
                let out = lzss::compress(data, *level);
                if out.len() < data.len() {
                    Some(out)
                } else {
                    None
                }
            }
        }
    }

    /// Decompress `stored` back to exactly `raw_len` bytes.
    pub fn decompress(&self, stored: &[u8], raw_len: usize) -> Result<Vec<u8>> {
        lzss::decompress(stored, raw_len)
    }
}

impl std::fmt::Display for Codec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Codec::None => write!(f, "none"),
            Codec::Lzss(l) => write!(f, "lzss-{l}"),
        }
    }
}
