//! Generic data compression (paper §5.4, §6.6).
//!
//! The paper uses LZSSE8 (an SSE-optimized LZSS) to trade CPU cycles for
//! storage/network bytes, reporting a 2.8× ratio on the SRGAN dataset.  We
//! implement the same algorithm family from scratch ([`lzss`]) with levels
//! 1–9 trading match-search depth for ratio, plus a [`Codec`] abstraction so
//! the ablation bench can compare against zstd-class ratios analytically.
//!
//! Compression is transparent end to end: partitions store per-entry codec
//! metadata, the wire protocol carries a one-byte codec id next to every
//! payload (see [`Codec::to_wire`]), and the consuming node performs the
//! single decode at VFS pickup.  [`CompressPolicy`] implements the paper's
//! per-extension rule — compress `.npy`/`.txt`-class data, skip formats that
//! are already entropy-coded (`.jpeg`, `.png`, …).

pub mod lzss;

use crate::error::{FanError, Result};

/// Compression codec used by the partition builder and the node read path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Codec {
    /// Store bytes verbatim.
    None,
    /// From-scratch LZSS at the given level (1 = fastest, 9 = best ratio).
    Lzss(u8),
}

impl Codec {
    /// Compress `data`. Returns `None` when the codec is `None` or when
    /// compression would not shrink the buffer (the partition format then
    /// stores the raw bytes and sets `compressed_size = 0`, paper §5.2).
    pub fn compress(&self, data: &[u8]) -> Option<Vec<u8>> {
        match self {
            Codec::None => None,
            Codec::Lzss(level) => {
                let out = lzss::compress(data, *level);
                if out.len() < data.len() {
                    Some(out)
                } else {
                    None
                }
            }
        }
    }

    /// Decompress `stored` back to exactly `raw_len` bytes.  Dispatches on
    /// the codec: `Codec::None` entries are stored verbatim and must NOT go
    /// through the LZSS decoder (whose bitstream framing would reject or
    /// corrupt them) — they are returned as-is after a length check.
    pub fn decompress(&self, stored: &[u8], raw_len: usize) -> Result<Vec<u8>> {
        match self {
            Codec::None => {
                if stored.len() != raw_len {
                    return Err(FanError::Codec(format!(
                        "raw entry length mismatch: stored {} bytes, expected {raw_len}",
                        stored.len()
                    )));
                }
                Ok(stored.to_vec())
            }
            Codec::Lzss(_) => lzss::decompress(stored, raw_len),
        }
    }

    /// `true` when this codec stores bytes verbatim.
    pub fn is_none(&self) -> bool {
        matches!(self, Codec::None)
    }

    /// One-byte wire/partition id: 0 = none, 1..=9 = LZSS level.
    pub fn to_wire(self) -> u8 {
        match self {
            Codec::None => 0,
            Codec::Lzss(l) => l.clamp(1, 9),
        }
    }

    /// Decode a wire/partition codec id; anything outside 0..=9 is a
    /// malformed frame, never a silent fallback.
    pub fn from_wire(b: u8) -> Result<Codec> {
        match b {
            0 => Ok(Codec::None),
            1..=9 => Ok(Codec::Lzss(b)),
            other => Err(FanError::Codec(format!("unknown codec id {other}"))),
        }
    }

    /// Parse a CLI spec: `none`, `lzss` (level 5), or `lzss-N`.
    pub fn parse(s: &str) -> Result<Codec> {
        match s {
            "none" => Ok(Codec::None),
            "lzss" => Ok(Codec::Lzss(5)),
            other => match other.strip_prefix("lzss-").and_then(|l| l.parse::<u8>().ok()) {
                Some(l @ 1..=9) => Ok(Codec::Lzss(l)),
                _ => Err(FanError::Config(format!(
                    "unknown codec spec {s} (expected none | lzss | lzss-1..9)"
                ))),
            },
        }
    }
}

impl std::fmt::Display for Codec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Codec::None => write!(f, "none"),
            Codec::Lzss(l) => write!(f, "lzss-{l}"),
        }
    }
}

/// Per-extension compression policy (paper §5.2): file formats that are
/// already entropy-coded gain nothing from LZSS, so the partition builder
/// stores them verbatim and spends the CPU only where bytes come back.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CompressPolicy {
    /// Lowercased extensions (no leading dot) stored verbatim.
    skip: Vec<String>,
}

impl Default for CompressPolicy {
    fn default() -> Self {
        CompressPolicy::parse("jpg,jpeg,png,gif,webp,bmp,jp2,zip,gz,tgz,bz2,xz,zst,mp4")
    }
}

impl CompressPolicy {
    /// Policy that compresses everything (empty skip list).
    pub fn compress_all() -> CompressPolicy {
        CompressPolicy { skip: Vec::new() }
    }

    /// Parse a CLI spec: a comma-separated skip list of extensions, or
    /// `none` to skip nothing (compress everything the codec is given).
    pub fn parse(spec: &str) -> CompressPolicy {
        if spec == "none" {
            return CompressPolicy::compress_all();
        }
        CompressPolicy {
            skip: spec
                .split(',')
                .map(|s| s.trim().trim_start_matches('.').to_ascii_lowercase())
                .filter(|s| !s.is_empty())
                .collect(),
        }
    }

    /// Should `path` be compressed?  Extensionless paths are compressed;
    /// the decision keys on the (lowercased) extension after the last dot.
    pub fn should_compress(&self, path: &str) -> bool {
        let name = path.rsplit('/').next().unwrap_or(path);
        match name.rsplit_once('.') {
            Some((stem, ext)) if !stem.is_empty() => {
                let ext = ext.to_ascii_lowercase();
                !self.skip.iter().any(|s| *s == ext)
            }
            _ => true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decompress_dispatches_on_codec() {
        // regression: Codec::None must return verbatim bytes, not feed them
        // through the LZSS decoder
        let raw = b"stored verbatim, not an LZSS bitstream".to_vec();
        assert_eq!(Codec::None.decompress(&raw, raw.len()).unwrap(), raw);
        assert!(Codec::None.decompress(&raw, raw.len() + 1).is_err());

        let compressed = Codec::Lzss(5).compress(&vec![7u8; 4096]).unwrap();
        assert_eq!(
            Codec::Lzss(5).decompress(&compressed, 4096).unwrap(),
            vec![7u8; 4096]
        );
    }

    #[test]
    fn wire_ids_roundtrip() {
        for c in [Codec::None, Codec::Lzss(1), Codec::Lzss(5), Codec::Lzss(9)] {
            assert_eq!(Codec::from_wire(c.to_wire()).unwrap(), c);
        }
        assert!(Codec::from_wire(10).is_err());
        assert!(Codec::from_wire(0x7F).is_err());
    }

    #[test]
    fn codec_spec_parses() {
        assert_eq!(Codec::parse("none").unwrap(), Codec::None);
        assert_eq!(Codec::parse("lzss").unwrap(), Codec::Lzss(5));
        assert_eq!(Codec::parse("lzss-9").unwrap(), Codec::Lzss(9));
        assert!(Codec::parse("lzss-0").is_err());
        assert!(Codec::parse("lzss-10").is_err());
        assert!(Codec::parse("zstd").is_err());
    }

    #[test]
    fn policy_skips_entropy_coded_extensions() {
        let p = CompressPolicy::default();
        assert!(p.should_compress("train/c0/f0001.npy"));
        assert!(p.should_compress("train/notes.txt"));
        assert!(p.should_compress("train/no_extension"));
        assert!(p.should_compress("train/.hidden")); // dotfile, not an ext
        assert!(!p.should_compress("val/img0001.JPEG"));
        assert!(!p.should_compress("val/img0001.png"));
        assert!(!p.should_compress("ckpt/weights.zip"));
    }

    #[test]
    fn policy_spec_parses() {
        let p = CompressPolicy::parse("raw, .BIN");
        assert!(!p.should_compress("a/b.raw"));
        assert!(!p.should_compress("a/b.bin"));
        assert!(p.should_compress("a/b.jpeg")); // custom list replaces default
        let all = CompressPolicy::parse("none");
        assert!(all.should_compress("a/b.jpeg"));
    }
}
