//! Counting allocator: the fuzzers' memory-amplification oracle.
//!
//! The wire fuzzer's core resource claim is that decoding a hostile frame
//! never allocates more than a small multiple of the frame's length (no
//! `with_capacity(attacker_number)`).  Proving that needs visibility into
//! the allocator, so fuzz-capable binaries register [`CountingAlloc`] as
//! their `#[global_allocator]`: a passthrough over [`System`] that, while
//! a thread is inside [`measure`], adds every allocation's size to a
//! thread-local byte counter.
//!
//! Registration is deliberately *per-binary* (the `fanstore` CLI and the
//! `fuzz_corpus` test target), never crate-wide — the library must not
//! impose allocator shims on every consumer.  Code that asserts bounds
//! first asks [`installed`] whether the counting allocator is actually
//! serving this process and degrades to a no-op when it is not, so the
//! same fuzz entry points stay runnable (minus the allocation oracle)
//! from binaries using the default allocator.
//!
//! Outside `measure` the overhead per allocation is one thread-local
//! `bool` read; inside it, one more thread-local add.  The counter sums
//! *gross* allocations (frees are not subtracted): the oracle bounds the
//! allocator traffic a decode can generate, which is the quantity an
//! amplification attack inflates.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

/// Passthrough [`System`] allocator with opt-in per-thread byte counting.
pub struct CountingAlloc;

thread_local! {
    static TRACKING: Cell<bool> = const { Cell::new(false) };
    static ALLOCATED: Cell<u64> = const { Cell::new(0) };
}

#[inline]
fn record(bytes: usize) {
    // `try_with`: the allocator can be entered during thread teardown,
    // after this thread's TLS slots are gone — never panic there.
    let _ = TRACKING.try_with(|t| {
        if t.get() {
            let _ = ALLOCATED.try_with(|a| a.set(a.get().saturating_add(bytes as u64)));
        }
    });
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        record(layout.size());
        unsafe { System.alloc(layout) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        record(layout.size());
        unsafe { System.alloc_zeroed(layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // counts the full new size, not the delta: a grow-by-doubling
        // `Vec` is charged its geometric series (≈ 2× the final length),
        // which is exactly the allocator traffic the resize generated
        record(new_size);
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }
}

/// Run `f` and return its result plus the bytes allocated by this thread
/// while it ran (0 when [`CountingAlloc`] is not this process's global
/// allocator).  Nesting measures is fine — the inner measure's bytes are
/// also seen by the outer one.  `f` must not unwind past `measure`; wrap
/// panicking candidates in `catch_unwind` *inside* the closure.
pub fn measure<R>(f: impl FnOnce() -> R) -> (R, u64) {
    let was_tracking = TRACKING.with(|t| t.replace(true));
    let start = ALLOCATED.with(|a| a.get());
    let out = f();
    let used = ALLOCATED.with(|a| a.get()).saturating_sub(start);
    TRACKING.with(|t| t.set(was_tracking));
    (out, used)
}

/// Is [`CountingAlloc`] actually serving this process?  Probes with a
/// measured test allocation (`black_box` keeps the optimizer from eliding
/// it): bounds asserted by the fuzzers are skipped when the binary runs on
/// the default allocator, so library test targets stay oracle-free.
pub fn installed() -> bool {
    let (_, bytes) = measure(|| std::hint::black_box(Vec::<u8>::with_capacity(64)));
    bytes > 0
}

#[cfg(test)]
mod tests {
    use super::*;

    // The library's own test binary does NOT register the counting
    // allocator, so in-module tests can only pin down the no-op contract;
    // the positive path (counts observed, bounds enforced) is exercised
    // end-to-end by the `fuzz_corpus` test target, which does register it.
    #[test]
    fn measure_is_a_safe_noop_without_the_allocator_registered() {
        let (v, bytes) = measure(|| std::hint::black_box(vec![0u8; 4096]));
        assert_eq!(v.len(), 4096);
        if !installed() {
            assert_eq!(bytes, 0, "no counting without the global allocator");
        } else {
            assert!(bytes >= 4096);
        }
    }

    #[test]
    fn measure_restores_the_tracking_flag_when_nested() {
        let ((), outer) = measure(|| {
            let (_, _inner) = measure(|| std::hint::black_box(Vec::<u8>::with_capacity(8)));
        });
        // whatever the allocator, the flags must unwind cleanly: a second
        // measure still works and tracking is off afterwards
        let (_, again) = measure(|| std::hint::black_box(Vec::<u8>::with_capacity(8)));
        if installed() {
            assert!(outer >= 8 && again >= 8);
        } else {
            assert_eq!((outer, again), (0, 0));
        }
    }
}
