//! Shadow model for the stateful store fuzzer: a ~200-line in-memory
//! re-implementation of the VFS contract that the real cluster is diffed
//! against after every operation.
//!
//! Two regimes:
//!
//! * **Healthy** (no node ever killed): the contract is *strict*.  Reads
//!   return the exact committed bytes, stats the exact size, listings the
//!   exact sorted child set; errors carry the exact errno class (ENOENT
//!   for missing paths, EPERM for immutability violations, ENOTDIR for
//!   readdir-on-file).
//! * **Degraded** (any kill happened; permanent for the round): the
//!   contract is *relaxed but still falsifiable*.  An operation may fail
//!   with EIO where the healthy model would succeed — that is what losing
//!   copies means — but data can never be *wrong*: a successful read must
//!   return bytes some write actually produced, a successful listing may
//!   only contain names the model knows, a stat size must match a real
//!   content length.  Commits/unlinks that error after a kill leave the
//!   path *indeterminate* (the mutation may or may not have landed); the
//!   model then accepts either world but still rejects invented data.
//!
//! The model deliberately tracks output *directories* forever once
//! created: the real metadata tables keep a dir entry alive after its
//! last file is unlinked, so listings legitimately show empty-able child
//! dirs while the dirs themselves stat as ENOENT (outputs have no dir
//! inodes).

use std::collections::{BTreeMap, BTreeSet};

use crate::error::FanError;
use crate::metadata::record::FileStat;

const S_IFMT: u32 = 0o170000;
const S_IFDIR: u32 = 0o040000;

pub struct ShadowModel {
    /// Input files: path → bytes (immutable for the whole round).
    inputs: BTreeMap<String, Vec<u8>>,
    /// Every ancestor directory of every input path (these have inodes).
    input_dirs: BTreeSet<String>,
    /// Committed outputs the model believes exist: path → bytes.
    outputs: BTreeMap<String, Vec<u8>>,
    /// Ancestor dirs of every output ever committed (never removed — the
    /// real tables keep them, see module docs).
    output_dirs: BTreeSet<String>,
    /// Paths whose post-kill mutation errored: the bytes each failed or
    /// superseded attempt carried.  A read of such a path may see any of
    /// these, or the committed bytes, or an error — but nothing else.
    limbo: BTreeMap<String, Vec<Vec<u8>>>,
    degraded: bool,
}

/// Ancestor directories of `path`, including "/" but not `path` itself.
fn ancestors(path: &str) -> Vec<String> {
    let mut out = vec!["/".to_string()];
    let mut acc = String::new();
    let mut parts = path.split('/').filter(|p| !p.is_empty()).peekable();
    while let Some(part) = parts.next() {
        if parts.peek().is_none() {
            break; // the leaf is not its own ancestor
        }
        acc.push('/');
        acc.push_str(part);
        out.push(acc.clone());
    }
    out
}

/// First path component of `path` strictly under directory `dir`.
fn child_of<'a>(dir: &str, path: &'a str) -> Option<&'a str> {
    let rest = if dir == "/" {
        path.strip_prefix('/')?
    } else {
        path.strip_prefix(dir)?.strip_prefix('/')?
    };
    let first = rest.split('/').next()?;
    if first.is_empty() {
        None
    } else {
        Some(first)
    }
}

impl ShadowModel {
    pub fn new(inputs: &[(String, Vec<u8>)]) -> ShadowModel {
        let mut input_dirs = BTreeSet::new();
        for (p, _) in inputs {
            input_dirs.extend(ancestors(p));
        }
        ShadowModel {
            inputs: inputs.iter().cloned().collect(),
            input_dirs,
            outputs: BTreeMap::new(),
            output_dirs: BTreeSet::new(),
            limbo: BTreeMap::new(),
            degraded: false,
        }
    }

    pub fn note_kill(&mut self) {
        self.degraded = true;
    }

    pub fn degraded(&self) -> bool {
        self.degraded
    }

    fn known_content_lens(&self, path: &str) -> Vec<u64> {
        let mut lens: Vec<u64> = self
            .limbo
            .get(path)
            .map(|cands| cands.iter().map(|c| c.len() as u64).collect())
            .unwrap_or_default();
        if let Some(d) = self.inputs.get(path).or_else(|| self.outputs.get(path)) {
            lens.push(d.len() as u64);
        }
        lens
    }

    // ------------------------------------------------------------- reads

    pub fn check_read(&self, path: &str, got: &Result<Vec<u8>, FanError>) -> Result<(), String> {
        let expected = self.inputs.get(path).or_else(|| self.outputs.get(path));
        match got {
            Ok(bytes) => {
                if let Some(want) = expected {
                    if bytes == want {
                        return Ok(());
                    }
                }
                if self.degraded {
                    // a limbo candidate that actually landed is fine; an
                    // unlinked-under-failure stale copy is the documented
                    // residual window (see DESIGN.md) — also a candidate
                    if self.limbo.get(path).is_some_and(|c| c.iter().any(|w| w == bytes)) {
                        return Ok(());
                    }
                }
                Err(format!(
                    "read {path}: got {} unexpected bytes (expected {})",
                    bytes.len(),
                    expected.map_or("ENOENT".into(), |w| format!("{} bytes", w.len())),
                ))
            }
            Err(e) => self.check_absent_or_degraded_err("read", path, expected.is_some(), e),
        }
    }

    pub fn check_stat(&self, path: &str, got: &Result<FileStat, FanError>) -> Result<(), String> {
        // input dirs have real (directory) inodes; output dirs do not
        if self.input_dirs.contains(path) {
            return match got {
                Ok(s) if s.mode & S_IFMT == S_IFDIR => Ok(()),
                Ok(s) => Err(format!("stat {path}: input dir came back mode {:o}", s.mode)),
                Err(e) if self.degraded => self.allow_degraded_err("stat", path, e),
                Err(e) => Err(format!("stat {path}: input dir errored: {e}")),
            };
        }
        let expected = self
            .inputs
            .get(path)
            .or_else(|| self.outputs.get(path))
            .map(|d| d.len() as u64);
        match got {
            Ok(s) => {
                if expected == Some(s.size) {
                    return Ok(());
                }
                if self.degraded && self.known_content_lens(path).contains(&s.size) {
                    return Ok(());
                }
                Err(format!(
                    "stat {path}: got size {}, expected {expected:?}",
                    s.size
                ))
            }
            Err(e) => self.check_absent_or_degraded_err("stat", path, expected.is_some(), e),
        }
    }

    pub fn check_readdir(
        &self,
        dir: &str,
        got: &Result<Vec<String>, FanError>,
    ) -> Result<(), String> {
        // readdir on an input *file* is ENOTDIR; on an output file the
        // real gather sees no children and degrades to ENOENT
        let expected_errno = if self.inputs.contains_key(dir) {
            Some(FanError::NotDirectory(String::new()).errno())
        } else {
            let listing = self.expected_listing(dir);
            if listing.is_empty() && !self.input_dirs.contains(dir) {
                Some(FanError::NotFound(String::new()).errno())
            } else {
                None
            }
        };
        match (got, expected_errno) {
            (Ok(names), None) => {
                let want: Vec<String> =
                    self.expected_listing(dir).into_iter().collect();
                if *names == want {
                    return Ok(());
                }
                if self.degraded {
                    // dead homes drop names from the gather: require a
                    // sorted deduped subset of what the model knows
                    let known = self.listable_superset(dir);
                    let sorted = names.windows(2).all(|w| w[0] < w[1]);
                    if sorted && names.iter().all(|n| known.contains(n)) {
                        return Ok(());
                    }
                }
                Err(format!("readdir {dir}: got {names:?}, want {want:?}"))
            }
            (Ok(names), Some(errno)) => {
                if self.degraded {
                    // a limbo commit that landed can make the dir appear
                    let known = self.listable_superset(dir);
                    if !names.is_empty() && names.iter().all(|n| known.contains(n)) {
                        return Ok(());
                    }
                }
                Err(format!("readdir {dir}: got {names:?}, want errno {errno}"))
            }
            (Err(e), Some(errno)) => {
                if e.errno() == errno {
                    return Ok(());
                }
                if self.degraded {
                    return self.allow_degraded_err("readdir", dir, e);
                }
                Err(format!("readdir {dir}: got errno {}, want {errno}: {e}", e.errno()))
            }
            (Err(e), None) => {
                if self.degraded {
                    return self.allow_degraded_err("readdir", dir, e);
                }
                Err(format!("readdir {dir}: unexpected error: {e}"))
            }
        }
    }

    /// The exact healthy listing: immediate children from input files and
    /// dirs, committed outputs, and ever-created output dirs.
    fn expected_listing(&self, dir: &str) -> BTreeSet<String> {
        let mut names = BTreeSet::new();
        for p in self.inputs.keys().chain(self.outputs.keys()) {
            if let Some(c) = child_of(dir, p) {
                names.insert(c.to_string());
            }
        }
        for d in self.input_dirs.iter().chain(self.output_dirs.iter()) {
            if let Some(c) = child_of(dir, d) {
                names.insert(c.to_string());
            }
        }
        names
    }

    /// Every name a degraded listing may legally show: the healthy set
    /// plus children of limbo paths (commits that landed despite the
    /// error, unlinks that did not).
    fn listable_superset(&self, dir: &str) -> BTreeSet<String> {
        let mut names = self.expected_listing(dir);
        for p in self.limbo.keys() {
            for a in ancestors(p) {
                if let Some(c) = child_of(dir, &a) {
                    names.insert(c.to_string());
                }
            }
            if let Some(c) = child_of(dir, p) {
                names.insert(c.to_string());
            }
        }
        names
    }

    // --------------------------------------------------------- mutations

    /// Account for a `write_file` outcome; checks the outcome against the
    /// model and updates the model's world.
    pub fn apply_write(
        &mut self,
        path: &str,
        data: &[u8],
        got: &Result<(), FanError>,
    ) -> Result<(), String> {
        let eperm = FanError::Consistency(String::new()).errno();
        let exists =
            self.inputs.contains_key(path) || self.outputs.contains_key(path);
        match got {
            Ok(()) => {
                if self.inputs.contains_key(path) {
                    return Err(format!("write {path}: an input file accepted a write"));
                }
                if self.outputs.contains_key(path) && !self.degraded {
                    return Err(format!("write {path}: single-write output rewritten"));
                }
                // degraded rewrite of an existing output is the known
                // stat-blind window; the new bytes are now the truth and
                // the old bytes stay acceptable as a stale serve
                if let Some(old) = self.outputs.insert(path.to_string(), data.to_vec()) {
                    self.limbo.entry(path.to_string()).or_default().push(old);
                }
                self.output_dirs.extend(ancestors(path));
                Ok(())
            }
            Err(e) if e.errno() == eperm => {
                if exists || self.limbo.contains_key(path) {
                    Ok(())
                } else {
                    Err(format!("write {path}: EPERM for a path that never existed"))
                }
            }
            Err(e) => {
                if !self.degraded {
                    return Err(format!("write {path}: healthy write errored: {e}"));
                }
                // may or may not have landed: remember the bytes
                self.limbo.entry(path.to_string()).or_default().push(data.to_vec());
                self.output_dirs.extend(ancestors(path));
                Ok(())
            }
        }
    }

    pub fn apply_unlink(&mut self, path: &str, got: &Result<(), FanError>) -> Result<(), String> {
        let eperm = FanError::Consistency(String::new()).errno();
        let enoent = FanError::NotFound(String::new()).errno();
        match got {
            Ok(()) => {
                if self.inputs.contains_key(path) {
                    return Err(format!("unlink {path}: an input file was unlinked"));
                }
                let removed = self.outputs.remove(path);
                if removed.is_none() && !self.degraded && !self.limbo.contains_key(path) {
                    return Err(format!("unlink {path}: Ok for a missing path"));
                }
                if self.degraded {
                    // a straggler copy on a node the unlinker could not
                    // reach may still serve the old bytes (documented
                    // residual window) — keep them as a limbo candidate
                    if let Some(old) = removed {
                        self.limbo.entry(path.to_string()).or_default().push(old);
                    }
                } else {
                    self.limbo.remove(path);
                }
                Ok(())
            }
            Err(e) if e.errno() == eperm => {
                if self.inputs.contains_key(path) {
                    Ok(())
                } else {
                    Err(format!("unlink {path}: EPERM for a non-input: {e}"))
                }
            }
            Err(e) if e.errno() == enoent => {
                if !self.outputs.contains_key(path) || self.degraded {
                    Ok(())
                } else {
                    Err(format!("unlink {path}: ENOENT for an existing output"))
                }
            }
            Err(e) => {
                if !self.degraded {
                    return Err(format!("unlink {path}: healthy unlink errored: {e}"));
                }
                // indeterminate: the name may be gone, half-gone, or intact
                if let Some(old) = self.outputs.remove(path) {
                    self.limbo.entry(path.to_string()).or_default().push(old);
                }
                Ok(())
            }
        }
    }

    // ----------------------------------------------------------- helpers

    /// Error verdict for a path the model says is absent/present.
    fn check_absent_or_degraded_err(
        &self,
        what: &str,
        path: &str,
        present: bool,
        e: &FanError,
    ) -> Result<(), String> {
        let enoent = FanError::NotFound(String::new()).errno();
        if !present && e.errno() == enoent {
            return Ok(()); // exact ENOENT for a missing path, any regime
        }
        if self.degraded {
            return self.allow_degraded_err(what, path, e);
        }
        if present {
            Err(format!("{what} {path}: healthy op errored: {e}"))
        } else {
            Err(format!("{what} {path}: want ENOENT, got errno {}: {e}", e.errno()))
        }
    }

    /// Degraded regime: losing copies may surface ENOENT or EIO, never a
    /// "you did something wrong" errno like EPERM/EBADF.
    pub(super) fn allow_degraded_err(
        &self,
        what: &str,
        path: &str,
        e: &FanError,
    ) -> Result<(), String> {
        let enoent = FanError::NotFound(String::new()).errno();
        let eio = FanError::Runtime(String::new()).errno();
        if e.errno() == enoent || e.errno() == eio {
            Ok(())
        } else {
            Err(format!(
                "{what} {path}: degraded errno must be ENOENT/EIO, got {}: {e}",
                e.errno()
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ShadowModel {
        ShadowModel::new(&[
            ("/m/train/a.raw".to_string(), vec![1, 2, 3]),
            ("/m/train/b.raw".to_string(), vec![4; 10]),
        ])
    }

    #[test]
    fn healthy_contract_is_strict() {
        let mut m = model();
        assert!(m.check_read("/m/train/a.raw", &Ok(vec![1, 2, 3])).is_ok());
        assert!(m.check_read("/m/train/a.raw", &Ok(vec![9, 9])).is_err());
        assert!(m
            .check_read("/nope", &Err(FanError::NotFound("/nope".into())))
            .is_ok());
        assert!(m
            .check_read("/nope", &Err(FanError::Runtime("eio".into())))
            .is_err());
        assert!(m.apply_write("/out/x.bin", &[7; 5], &Ok(())).is_ok());
        assert!(m.check_read("/out/x.bin", &Ok(vec![7; 5])).is_ok());
        // single-write: a second Ok is a divergence, EPERM is correct
        assert!(m.apply_write("/out/x.bin", &[8], &Ok(())).is_err());
        let m2 = model();
        assert!(m2
            .check_read("/m/train/a.raw", &Err(FanError::Runtime("eio".into())))
            .is_err());
    }

    #[test]
    fn listings_track_inputs_outputs_and_sticky_dirs() {
        let mut m = model();
        assert!(m
            .check_readdir("/m/train", &Ok(vec!["a.raw".into(), "b.raw".into()]))
            .is_ok());
        assert!(m.check_readdir("/m/train", &Ok(vec!["a.raw".into()])).is_err());
        m.apply_write("/out/sub/c.bin", &[1], &Ok(())).unwrap();
        assert!(m.check_readdir("/", &Ok(vec!["m".into(), "out".into()])).is_ok());
        assert!(m.check_readdir("/out", &Ok(vec!["sub".into()])).is_ok());
        m.apply_unlink("/out/sub/c.bin", &Ok(())).unwrap();
        // the file is gone but the dir chain sticks; the now-empty leaf
        // dir lists as a child while itself answering ENOENT to a gather
        assert!(m.check_readdir("/out", &Ok(vec!["sub".into()])).is_ok());
        assert!(m
            .check_readdir("/out/sub", &Err(FanError::NotFound("/out/sub".into())))
            .is_ok());
        // readdir on an input file is ENOTDIR
        assert!(m
            .check_readdir(
                "/m/train/a.raw",
                &Err(FanError::NotDirectory("/m/train/a.raw".into()))
            )
            .is_ok());
    }

    #[test]
    fn degraded_contract_allows_loss_but_not_invention() {
        let mut m = model();
        m.apply_write("/out/x.bin", &[7; 5], &Ok(())).unwrap();
        m.note_kill();
        // loss: EIO where healthy would succeed
        assert!(m
            .check_read("/out/x.bin", &Err(FanError::Runtime("eio".into())))
            .is_ok());
        // but never wrong bytes
        assert!(m.check_read("/out/x.bin", &Ok(vec![1])).is_err());
        // a failed degraded write leaves the path in limbo: both worlds OK
        m.apply_write("/out/y.bin", &[9; 4], &Err(FanError::Runtime("eio".into())))
            .unwrap();
        assert!(m.check_read("/out/y.bin", &Ok(vec![9; 4])).is_ok());
        assert!(m
            .check_read("/out/y.bin", &Err(FanError::NotFound("y".into())))
            .is_ok());
        assert!(m.check_read("/out/y.bin", &Ok(vec![5])).is_err());
        // degraded errno discipline: EBADF is never a loss signal
        assert!(m
            .check_read("/out/x.bin", &Err(FanError::BadFd(3)))
            .is_err());
    }

    #[test]
    fn stat_distinguishes_input_dirs_from_output_dirs() {
        let mut m = model();
        m.apply_write("/out/x.bin", &[7; 5], &Ok(())).unwrap();
        let mut dir = FileStat::regular(1, 4096);
        dir.mode = 0o040755;
        assert!(m.check_stat("/m/train", &Ok(dir)).is_ok());
        assert!(m.check_stat("/m/train", &Ok(FileStat::regular(1, 4096))).is_err());
        assert!(m
            .check_stat("/out", &Err(FanError::NotFound("/out".into())))
            .is_ok());
        assert!(m.check_stat("/out/x.bin", &Ok(FileStat::regular(2, 5))).is_ok());
        assert!(m.check_stat("/out/x.bin", &Ok(FileStat::regular(2, 6))).is_err());
    }
}
