//! Stateful store/cluster fuzzer (PR 10 tentpole, part 2).
//!
//! Generates PRNG-driven operation schedules — opens/reads, commits,
//! unlinks, listings, batched stats, prefetch hints, tier-migration
//! ticks, node kills, and probe/repair ticks — and executes them against
//! a *real* in-process cluster while a [`super::model::ShadowModel`]
//! predicts every outcome.  Contents, metadata, and errno classes are
//! diffed after each op; the first divergence is shrunk with
//! [`crate::util::proptest_lite::shrink_seq`] to a minimal reproducing
//! schedule (each candidate replays against a fresh cluster) and reported
//! with the round's seed and parameters.
//!
//! Determinism: clusters run the in-proc fabric with background probe /
//! repair / migration threads disabled (`*_interval_ms = 0`); all ticks
//! are schedule ops, so a seed fully determines the run.  Rounds rotate
//! through cluster shapes — RAM-resident, compressed-at-rest, and
//! spill-to-disk with a tiny RAM budget so `MigrateTick` ops churn
//! partitions between tiers mid-schedule.  Kill-free rounds hold the
//! model's *strict* contract; rounds with kills drop to the relaxed
//! degraded contract (see the model docs for exactly what each regime
//! rejects).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::compress::Codec;
use crate::config::{ClusterConfig, TransportKind};
use crate::coordinator::Cluster;
use crate::fuzz::model::ShadowModel;
use crate::partition::builder::InputFile;
use crate::util::prng::Prng;
use crate::util::proptest_lite::shrink_seq;
use crate::vfs::{FanStoreVfs, Vfs};

/// One schedule step.  Paths index the round's palette so shrinking an
/// op never invalidates another (ops are self-contained and replayable).
#[derive(Clone, Debug)]
pub enum Op {
    /// `write_file(palette[path], bytes(fill, len))`.
    Write { path: usize, len: u16, fill: u64 },
    ReadAll { path: usize },
    Stat { path: usize },
    StatMany { paths: Vec<usize> },
    Readdir { path: usize },
    Unlink { path: usize },
    Prefetch { paths: Vec<usize> },
    /// Kill a node (never node 0 — the client lives there; skipped if it
    /// would leave fewer than two nodes alive).
    Kill { node: u32 },
    Probe { node: u32 },
    Repair { node: u32 },
    Migrate { node: u32 },
}

/// Counters for a full store-fuzz run.
#[derive(Debug, Default, Clone)]
pub struct StoreFuzzReport {
    pub rounds: u64,
    pub ops: u64,
    pub kills: u64,
    pub strict_rounds: u64,
}

/// Cluster shape for one round; regenerated per round from the seed.
#[derive(Clone, Debug)]
struct RoundParams {
    nodes: u32,
    codec: Codec,
    spill: bool,
    ram_budget: u64,
    data_seed: u64,
    with_kills: bool,
}

/// Unique spill dirs across rounds *and* shrink replays of one round.
static SPILL_SERIAL: AtomicU64 = AtomicU64::new(0);

const MOUNT: &str = "/fanstore/user";

/// Run the store fuzzer: schedules totalling ~`iters` ops derived from
/// `seed`.  `Err` carries the round seed, params, and a shrunk minimal
/// schedule on the first model divergence.
pub fn run_store_fuzz(seed: u64, iters: u64) -> Result<StoreFuzzReport, String> {
    let mut rng = Prng::new(seed);
    let mut report = StoreFuzzReport::default();
    while report.ops < iters {
        let round = report.rounds;
        let mut round_rng = rng.fork(round);
        let params = gen_params(&mut round_rng, round);
        let budget = (iters - report.ops).clamp(8, 64);
        let ops = gen_schedule(&mut round_rng, &params, budget as usize);
        if let Err(div) = run_round(&params, &ops) {
            let minimal = shrink_seq(&ops, |cand| run_round(&params, cand).is_err());
            let last = run_round(&params, &minimal).err().unwrap_or(div);
            return Err(format!(
                "store fuzz diverged (seed {seed:#x}, round {round}, {params:?}): {last}\n\
                 minimal schedule ({} ops): {minimal:?}",
                minimal.len()
            ));
        }
        report.rounds += 1;
        report.ops += ops.len() as u64;
        if params.with_kills {
            report.kills += 1;
        } else {
            report.strict_rounds += 1;
        }
    }
    Ok(report)
}

fn gen_params(rng: &mut Prng, round: u64) -> RoundParams {
    let spill = rng.chance(0.35);
    RoundParams {
        nodes: if rng.chance(0.5) { 3 } else { 4 },
        codec: if rng.chance(0.4) { Codec::Lzss(3) } else { Codec::None },
        spill,
        // a tiny budget with spill forces real RAM<->disk churn under
        // MigrateTick; without spill the store is all-RAM
        ram_budget: if spill && rng.chance(0.7) { 4096 } else { 0 },
        data_seed: rng.next_u64() | 1,
        with_kills: round != 0 && rng.chance(0.3),
    }
}

/// The round's path universe.  Disjoint file/dir namespaces on purpose:
/// writing to a live directory name would alias files over dirs in the
/// real tables, a namespace the paper's workload never exercises.
struct Palette {
    paths: Vec<String>,
    /// Indices eligible as `Write`/`Unlink`/`Stat`-file targets.
    files: Vec<usize>,
}

fn palette(inputs: &[(String, Vec<u8>)]) -> Palette {
    let mut paths: Vec<String> = inputs.iter().map(|(p, _)| p.clone()).collect();
    let n_inputs = paths.len();
    let outputs = [
        "/out/a.bin",
        "/out/b.bin",
        "/out/sub/c.bin",
        "/out/sub/d.bin",
        "/ckpt/model_001.bin",
        "/ckpt/model_002.bin",
    ];
    paths.extend(outputs.iter().map(|s| s.to_string()));
    let files: Vec<usize> = (0..paths.len()).collect();
    // read/stat/readdir-only targets: dirs, a missing file, a bogus root
    paths.push(format!("{MOUNT}/train"));
    paths.push(format!("{MOUNT}/train/class0"));
    paths.push("/".to_string());
    paths.push("/out".to_string());
    paths.push("/out/sub".to_string());
    paths.push("/ckpt".to_string());
    paths.push("/out/missing.bin".to_string());
    paths.push("/nope".to_string());
    debug_assert!(n_inputs > 0);
    Palette { paths, files }
}

fn input_set(params: &RoundParams) -> Vec<(String, Vec<u8>)> {
    let mut rng = Prng::new(params.data_seed);
    (0..8)
        .map(|i| {
            let mut data = vec![0u8; 200 + 37 * i];
            rng.fill_bytes(&mut data);
            (format!("{MOUNT}/train/class{}/img{i:03}.raw", i % 2), data)
        })
        .collect()
}

fn op_bytes(len: u16, fill: u64) -> Vec<u8> {
    let mut data = vec![0u8; len as usize];
    Prng::new(fill | 1).fill_bytes(&mut data);
    data
}

fn gen_schedule(rng: &mut Prng, params: &RoundParams, budget: usize) -> Vec<Op> {
    let inputs = input_set(params);
    let pal = palette(&inputs);
    let any_path = |rng: &mut Prng| rng.index(pal.paths.len());
    let file_path = |rng: &mut Prng| pal.files[rng.index(pal.files.len())];
    let peer = |rng: &mut Prng| 1 + rng.below(u64::from(params.nodes) - 1) as u32;
    let mut ops = Vec::with_capacity(budget);
    while ops.len() < budget {
        let op = match rng.below(100) {
            0..=17 => Op::Write {
                path: file_path(rng),
                len: rng.below(5000) as u16,
                fill: rng.next_u64(),
            },
            18..=42 => Op::ReadAll { path: any_path(rng) },
            43..=55 => Op::Stat { path: any_path(rng) },
            56..=61 => Op::StatMany {
                paths: (0..1 + rng.below(6)).map(|_| any_path(rng)).collect(),
            },
            62..=72 => Op::Readdir { path: any_path(rng) },
            73..=82 => Op::Unlink { path: file_path(rng) },
            83..=87 => Op::Prefetch {
                paths: (0..1 + rng.below(6)).map(|_| any_path(rng)).collect(),
            },
            // migration ticks only make sense with a spill tier and a RAM
            // budget; an all-RAM round trades them for extra reads
            88..=91 if params.ram_budget > 0 => {
                Op::Migrate { node: rng.below(u64::from(params.nodes)) as u32 }
            }
            88..=91 => Op::ReadAll { path: any_path(rng) },
            92..=94 => Op::Probe { node: rng.below(u64::from(params.nodes)) as u32 },
            95..=96 => Op::Repair { node: rng.below(u64::from(params.nodes)) as u32 },
            _ => {
                if !params.with_kills {
                    continue;
                }
                ops.push(Op::Kill { node: peer(rng) });
                // a kill is usually followed by detection + repair so the
                // schedule exercises adoption, not just loss
                ops.push(Op::Probe { node: 0 });
                ops.push(Op::Probe { node: 0 });
                ops.push(Op::Repair { node: 0 });
                continue;
            }
        };
        ops.push(op);
    }
    ops.truncate(budget);
    ops
}

/// Execute one schedule against a fresh cluster, diffing the shadow model
/// after every op.  `Err` is the first divergence, with op index and op.
fn run_round(params: &RoundParams, ops: &[Op]) -> Result<(), String> {
    let inputs = input_set(params);
    let pal = palette(&inputs);
    let files: Vec<InputFile> = inputs
        .iter()
        .map(|(p, d)| InputFile {
            path: p.strip_prefix(&format!("{MOUNT}/")).expect("mounted").to_string(),
            data: d.clone(),
        })
        .collect();
    let spill_dir = params.spill.then(|| {
        let serial = SPILL_SERIAL.fetch_add(1, Ordering::Relaxed);
        let dir = std::env::temp_dir()
            .join(format!("fanstore-fuzz-{}-{serial}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("create spill dir");
        dir.to_string_lossy().into_owned()
    });
    let config = ClusterConfig {
        nodes: params.nodes,
        partitions: params.nodes,
        replication: 2,
        codec: params.codec,
        transport: TransportKind::InProc,
        spill_dir: spill_dir.clone(),
        ram_budget_bytes: params.ram_budget,
        migrate_interval_ms: 0,
        probe_interval_ms: 0,
        ..ClusterConfig::default()
    };
    let result = (|| {
        let mut cluster = Cluster::launch(&files, config)
            .map_err(|e| format!("cluster launch failed: {e}"))?;
        let mut model = ShadowModel::new(&inputs);
        let mut alive: Vec<bool> = vec![true; params.nodes as usize];
        let mut vfs = cluster.client(0);
        for (i, op) in ops.iter().enumerate() {
            step(&mut cluster, &mut vfs, &mut model, &mut alive, &pal, op)
                .map_err(|what| format!("op {i} {op:?}: {what}"))?;
        }
        drop(vfs);
        let _ = cluster.shutdown();
        Ok(())
    })();
    if let Some(dir) = spill_dir {
        let _ = std::fs::remove_dir_all(dir);
    }
    result
}

/// Execute one op against the live cluster and diff it with the model.
/// Fault-injection ops (`Kill`/`Probe`/`Repair`/`Migrate`) that no longer
/// apply — dead target, last-two-survivors guard — degrade to no-ops so
/// shrinking can delete the ops *around* them without invalidating the
/// schedule.
fn step(
    cluster: &mut Cluster,
    vfs: &mut FanStoreVfs,
    model: &mut ShadowModel,
    alive: &mut [bool],
    pal: &Palette,
    op: &Op,
) -> Result<(), String> {
    match op {
        Op::Write { path, len, fill } => {
            let p = &pal.paths[*path];
            let data = op_bytes(*len, *fill);
            let got = vfs.write_file(p, &data);
            model.apply_write(p, &data, &got)
        }
        Op::ReadAll { path } => {
            let p = &pal.paths[*path];
            let got = vfs.read_all(p);
            model.check_read(p, &got)
        }
        Op::Stat { path } => {
            let p = &pal.paths[*path];
            let got = vfs.stat(p);
            model.check_stat(p, &got)
        }
        Op::StatMany { paths } => {
            let ps: Vec<String> =
                paths.iter().map(|&i| pal.paths[i].clone()).collect();
            let got = vfs.stat_many(&ps);
            if got.len() != ps.len() {
                return Err(format!(
                    "stat_many returned {} results for {} paths",
                    got.len(),
                    ps.len()
                ));
            }
            for (p, g) in ps.iter().zip(got.iter()) {
                model
                    .check_stat(p, g)
                    .map_err(|what| format!("stat_many[{p}]: {what}"))?;
            }
            Ok(())
        }
        Op::Readdir { path } => {
            let p = &pal.paths[*path];
            let got = vfs.readdir(p);
            model.check_readdir(p, &got)
        }
        Op::Unlink { path } => {
            let p = &pal.paths[*path];
            let got = vfs.unlink(p);
            model.apply_unlink(p, &got)
        }
        Op::Prefetch { paths } => {
            let ps: Vec<String> =
                paths.iter().map(|&i| pal.paths[i].clone()).collect();
            let got = vfs.prefetch(&ps);
            match got {
                Ok(()) => Ok(()),
                Err(e) if model.degraded() => {
                    model.allow_degraded_err("prefetch", "(batch)", &e)
                }
                Err(e) => Err(format!("healthy prefetch errored: {e}")),
            }
        }
        Op::Kill { node } => {
            let n = *node as usize;
            let survivors = alive.iter().filter(|a| **a).count();
            if *node == 0 || n >= alive.len() || !alive[n] || survivors <= 2 {
                return Ok(());
            }
            let _ = cluster.kill_node(*node);
            alive[n] = false;
            model.note_kill();
            Ok(())
        }
        Op::Probe { node } => {
            let n = *node as usize;
            if n < alive.len() && alive[n] {
                let tp = Arc::clone(&cluster.transport);
                let _ = cluster.node_state(*node).probe_tick(&*tp);
            }
            Ok(())
        }
        Op::Repair { node } => {
            let n = *node as usize;
            if n < alive.len() && alive[n] {
                let tp = Arc::clone(&cluster.transport);
                let _ = cluster.node_state(*node).repair_tick(&*tp);
            }
            Ok(())
        }
        Op::Migrate { node } => {
            let n = *node as usize;
            if n < alive.len() && alive[n] {
                let _ = cluster.node_state(*node).migrate_tick();
            }
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_store_fuzz_run_is_clean() {
        // small but real: several rounds across cluster shapes, including
        // (for this seed budget) at least one strict kill-free round
        let report = run_store_fuzz(0x570_12E5_EED, 120)
            .expect("store fuzz diverged on a pinned seed");
        assert!(report.ops >= 120);
        assert!(report.rounds >= 2);
        assert!(report.strict_rounds >= 1, "need strict-contract coverage");
    }

    #[test]
    fn schedules_are_deterministic_in_the_seed() {
        let params = RoundParams {
            nodes: 4,
            codec: Codec::None,
            spill: false,
            ram_budget: 0,
            data_seed: 7,
            with_kills: true,
        };
        let a = gen_schedule(&mut Prng::new(42), &params, 48);
        let b = gen_schedule(&mut Prng::new(42), &params, 48);
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        assert_eq!(a.len(), 48);
    }

    #[test]
    fn killing_rounds_replay_without_divergence() {
        // force a degraded round directly: 4 nodes, kill one, then keep
        // operating through probes and repairs
        let params = RoundParams {
            nodes: 4,
            codec: Codec::Lzss(3),
            spill: false,
            ram_budget: 0,
            data_seed: 11,
            with_kills: true,
        };
        let mut ops = vec![
            Op::Write { path: 8, len: 900, fill: 5 },
            Op::ReadAll { path: 8 },
            Op::Kill { node: 2 },
            Op::Probe { node: 0 },
            Op::Probe { node: 0 },
            Op::Repair { node: 0 },
        ];
        ops.extend((0..12).map(|i| Op::ReadAll { path: i }));
        ops.push(Op::Readdir { path: 16 });
        ops.push(Op::Unlink { path: 8 });
        run_round(&params, &ops).expect("degraded round diverged");
    }
}
