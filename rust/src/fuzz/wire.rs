//! Adversarial wire-codec fuzzer (PR 10 tentpole, part 1).
//!
//! Feeds the `net::wire` decoders six families of inputs — raw random
//! bytes, truncations of valid bodies, bit-flips, structure-aware
//! mutations (splices, range duplication/deletion, varint tampering),
//! fully valid frames, and `MAX_FRAME`-adjacent framed streams — and
//! holds decode to three oracles on every single input:
//!
//! 1. **No panic.**  Every decode runs under `catch_unwind`; an unwind is
//!    a finding, not a crash.
//! 2. **No hang.**  A watchdog thread aborts the process (printing the
//!    seed) if the fuzz loop stops making progress for ~2 s — a decode
//!    that spins can never look like a pass.
//! 3. **No memory amplification.**  When the binary registers the
//!    [`crate::fuzz::alloc_guard::CountingAlloc`] global allocator, every
//!    decode's gross allocation is measured and bounded:
//!
//!    * rejected input → `≤ REJECT_FACTOR × len + SLACK` — hard-linear,
//!      covering the worst legal element density (a 2-byte `MetaFetch::
//!      NotFound` entry materializes a ~160-byte tuple, doubled by `Vec`
//!      growth) plus interner and error-string overhead;
//!    * accepted frame → `≤ ACCEPT_FACTOR × len + ITEM_OVERHEAD × items
//!      + SLACK` — the headline "small multiple of input" bound, with a
//!      per-decoded-element term for the unavoidable in-memory width of
//!      batch entries (an element's struct is wider than its minimal
//!      encoding, so a pure byte multiple is unsatisfiable for degenerate
//!      but *legal* batches of empty names/paths).
//!
//! Valid frames additionally face a **differential oracle**: decode must
//! succeed and re-encoding the decoded value must reproduce the original
//! body byte-for-byte (the generators only emit canonical encodings, so
//! any drift is a codec bug).
//!
//! On a violation the input is shrunk with
//! [`crate::util::proptest_lite::shrink_bytes`] to a 1-removal/1-zeroing
//! minimal reproducer and reported as hex, ready to be checked into
//! `rust/tests/corpus/`.

use std::io::Cursor;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use crate::compress::Codec;
use crate::fuzz::alloc_guard;
use crate::metadata::record::{FileLocation, FileMeta, FileStat};
use crate::net::transport::{FileFetch, MetaFetch, Request, Response};
use crate::net::wire::{
    decode_request, decode_response, encode_request, encode_response, read_frame, PathInterner,
    MAX_FRAME, READ_CHUNK,
};
use crate::storage::payload::Payload;
use crate::util::prng::Prng;
use crate::util::proptest_lite::shrink_bytes;

/// Accepted frames: byte multiple of the input length.
const ACCEPT_FACTOR: u64 = 4;
/// Accepted frames: per-decoded-element allowance (struct width + `Vec`
/// doubling + interner entry for the densest legal elements).
const ITEM_OVERHEAD: u64 = 512;
/// Rejected input: hard-linear multiple covering elements decoded before
/// the error surfaced (an element can be ~80× wider in memory than on the
/// wire; ×2 for `Vec` growth; rounded up to a power of two).
const REJECT_FACTOR: u64 = 256;
/// Constant slack: error strings, small preallocations, `HashMap` seeds.
const SLACK: u64 = 16 * 1024;
/// `read_frame` slack: the chunked reader may hold one `READ_CHUNK` of
/// capacity (plus its doubling) beyond the bytes actually delivered.
const STREAM_SLACK: u64 = (2 * READ_CHUNK + 4096) as u64;

/// Outcome counters for one fuzz run (all inputs, all modes).
#[derive(Debug, Default, Clone)]
pub struct WireFuzzReport {
    /// Inputs fed to the decoders.
    pub iters: u64,
    /// Inputs that decoded into a valid `Request`/`Response`.
    pub accepted: u64,
    /// Inputs rejected with a structured error (the common case).
    pub rejected: u64,
    /// Largest measured decode allocation, in bytes (0 without the
    /// counting allocator).
    pub max_alloc: u64,
    /// Whether the allocation oracle was live (counting allocator
    /// registered by this binary).
    pub alloc_guarded: bool,
}

/// Run the wire fuzzer: `iters` adversarial inputs derived from `seed`.
/// Returns counters on success; on the first oracle violation returns a
/// shrunk, hex-encoded minimal reproducer (the process aborts instead if
/// a decode hangs).
pub fn run_wire_fuzz(seed: u64, iters: u64) -> Result<WireFuzzReport, String> {
    let progress = Arc::new(AtomicU64::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    let watchdog = spawn_watchdog(Arc::clone(&progress), Arc::clone(&stop), seed);

    let mut rng = Prng::new(seed);
    let mut report = WireFuzzReport {
        alloc_guarded: alloc_guard::installed(),
        ..WireFuzzReport::default()
    };
    let mut paths = PathInterner::default();
    let result = (0..iters).try_for_each(|i| {
        // a long-lived interner is part of the attack surface, but bound
        // its growth across a big run
        if i % 4096 == 0 {
            paths = PathInterner::default();
        }
        let verdict = fuzz_one(&mut rng, &mut paths, &mut report).map_err(|what| {
            format!("wire fuzz failed (seed {seed:#x}, iter {i}): {what}")
        });
        progress.store(i + 1, Ordering::Relaxed);
        report.iters = i + 1;
        verdict
    });

    stop.store(true, Ordering::Relaxed);
    let _ = watchdog.join();
    result.map(|()| report)
}

/// One fuzz input: pick a mode, build the input, run every applicable
/// oracle.  `Err` carries a shrunk reproducer description.
fn fuzz_one(
    rng: &mut Prng,
    paths: &mut PathInterner,
    report: &mut WireFuzzReport,
) -> Result<(), String> {
    match rng.below(6) {
        // raw random bytes
        0 => {
            let mut body = vec![0u8; 1 + rng.below(1024) as usize];
            rng.fill_bytes(&mut body);
            check_body(&body, paths, report)
        }
        // truncation of a valid body
        1 => {
            let body = gen_valid_body(rng);
            let cut = rng.index(body.len());
            check_body(&body[..cut], paths, report)
        }
        // bit flips in a valid body
        2 => {
            let mut body = gen_valid_body(rng);
            for _ in 0..1 + rng.below(8) {
                let bit = rng.below(body.len() as u64 * 8);
                body[(bit / 8) as usize] ^= 1 << (bit % 8);
            }
            check_body(&body, paths, report)
        }
        // structure-aware mutations
        3 => {
            let body = mutate_structured(rng);
            check_body(&body, paths, report)
        }
        // fully valid frame: must decode AND re-encode byte-identically
        4 => {
            let body = gen_valid_body(rng);
            check_body(&body, paths, report)?;
            roundtrip_check(&body, paths)
        }
        // framed stream with a MAX_FRAME-adjacent (or lying) length prefix
        _ => check_stream(rng, report),
    }
}

// ---------------------------------------------------------------- oracles

/// Feed one body to both decoders under the panic + allocation oracles.
fn check_body(
    body: &[u8],
    paths: &mut PathInterner,
    report: &mut WireFuzzReport,
) -> Result<(), String> {
    if let Err(what) = decode_once(body, paths, report) {
        // shrink against fresh interners so the reproducer stands alone
        let shrunk = shrink_bytes(body, |b| {
            let mut p = PathInterner::default();
            let mut r = WireFuzzReport {
                alloc_guarded: report.alloc_guarded,
                ..WireFuzzReport::default()
            };
            decode_once(b, &mut p, &mut r).is_err()
        });
        return Err(format!("{what}; shrunk to {} bytes: {}", shrunk.len(), hex(&shrunk)));
    }
    Ok(())
}

/// The unshrunk single-shot check: decode `body` as a request and as a
/// response, each under `catch_unwind` and the allocation guard.
fn decode_once(
    body: &[u8],
    paths: &mut PathInterner,
    report: &mut WireFuzzReport,
) -> Result<(), String> {
    // as a request --------------------------------------------------
    let before = paths.len();
    let (outcome, alloc) = alloc_guard::measure(|| {
        catch_unwind(AssertUnwindSafe(|| decode_request(body, paths)))
    });
    report.max_alloc = report.max_alloc.max(alloc);
    let new_paths = paths.len().saturating_sub(before);
    match outcome {
        Err(_) => return Err(format!("decode_request panicked on {}-byte body", body.len())),
        Ok(Ok((_, _, req))) => {
            report.accepted += 1;
            let items = (request_items(&req) + new_paths) as u64;
            check_alloc("decode_request accept", body.len(), alloc, accept_bound(body.len(), items))?;
        }
        Ok(Err(_)) => {
            report.rejected += 1;
            check_alloc("decode_request reject", body.len(), alloc, reject_bound(body.len()))?;
        }
    }

    // as a response -------------------------------------------------
    let before = paths.len();
    let (outcome, alloc) = alloc_guard::measure(|| {
        catch_unwind(AssertUnwindSafe(|| decode_response(body, paths)))
    });
    report.max_alloc = report.max_alloc.max(alloc);
    let new_paths = paths.len().saturating_sub(before);
    match outcome {
        Err(_) => return Err(format!("decode_response panicked on {}-byte body", body.len())),
        Ok(Ok((_, resp))) => {
            report.accepted += 1;
            let items = (response_items(&resp) + new_paths) as u64;
            check_alloc("decode_response accept", body.len(), alloc, accept_bound(body.len(), items))?;
        }
        Ok(Err(_)) => {
            report.rejected += 1;
            check_alloc("decode_response reject", body.len(), alloc, reject_bound(body.len()))?;
        }
    }
    Ok(())
}

fn accept_bound(len: usize, items: u64) -> u64 {
    ACCEPT_FACTOR * len as u64 + ITEM_OVERHEAD * items + SLACK
}

fn reject_bound(len: usize) -> u64 {
    REJECT_FACTOR * len as u64 + SLACK
}

fn check_alloc(what: &str, len: usize, alloc: u64, bound: u64) -> Result<(), String> {
    if alloc > bound {
        return Err(format!(
            "{what}: allocated {alloc} bytes decoding {len} input bytes (bound {bound})"
        ));
    }
    Ok(())
}

/// Differential oracle for generator-produced bodies: decode must accept,
/// and re-encoding the decoded value must reproduce the body exactly.
fn roundtrip_check(body: &[u8], paths: &mut PathInterner) -> Result<(), String> {
    let fail = |what: &str| {
        Err(format!("roundtrip: {what} on valid {}-byte body: {}", body.len(), hex(body)))
    };
    match body.first() {
        Some(1) => match decode_request(body, paths) {
            Ok((corr, from, req)) => {
                let re = encode_request(corr, from, &req).to_body_bytes();
                if re != body {
                    return fail("re-encoded request differs");
                }
                Ok(())
            }
            Err(e) => fail(&format!("decode_request rejected: {e}")),
        },
        Some(2) => match decode_response(body, paths) {
            Ok((corr, resp)) => {
                let re = encode_response(corr, &resp).to_body_bytes();
                if re != body {
                    return fail("re-encoded response differs");
                }
                Ok(())
            }
            Err(e) => fail(&format!("decode_response rejected: {e}")),
        },
        _ => fail("generator produced an unknown frame kind"),
    }
}

/// Framed-stream oracle: a length prefix near (or beyond) `MAX_FRAME`
/// backed by far fewer delivered bytes must fail cheaply — bounded
/// allocation, correct error class, no panic.
fn check_stream(rng: &mut Prng, report: &mut WireFuzzReport) -> Result<(), String> {
    let claimed: u32 = match rng.below(5) {
        0 => MAX_FRAME,
        1 => MAX_FRAME - 1,
        2 => MAX_FRAME + 1,
        3 => u32::MAX,
        _ => rng.below(u64::from(MAX_FRAME)) as u32,
    };
    let delivered = (rng.below(4096) as usize).min(claimed as usize);
    let mut stream = Vec::with_capacity(4 + delivered);
    stream.extend_from_slice(&claimed.to_le_bytes());
    let start = stream.len();
    stream.resize(start + delivered, 0);
    rng.fill_bytes(&mut stream[start..]);

    let run = |bytes: &[u8]| {
        alloc_guard::measure(|| {
            catch_unwind(AssertUnwindSafe(|| read_frame(&mut Cursor::new(bytes))))
        })
    };
    let (outcome, alloc) = run(&stream);
    report.max_alloc = report.max_alloc.max(alloc);
    let bound = ACCEPT_FACTOR * stream.len() as u64 + STREAM_SLACK;
    let verdict = match outcome {
        Err(_) => Some("read_frame panicked".to_string()),
        Ok(Ok(body)) => {
            // only possible when the stream delivered the whole claimed body
            if body.len() != claimed as usize || claimed > MAX_FRAME {
                Some(format!("read_frame accepted a torn frame ({} of {claimed})", body.len()))
            } else if alloc > bound {
                Some(format!("read_frame allocated {alloc} for {} stream bytes", stream.len()))
            } else {
                None
            }
        }
        Ok(Err(_)) => {
            report.rejected += 1;
            if alloc > bound {
                Some(format!(
                    "read_frame allocated {alloc} rejecting a {claimed}-byte claim with {} stream bytes",
                    stream.len()
                ))
            } else {
                None
            }
        }
    };
    if let Some(what) = verdict {
        let shrunk = shrink_bytes(&stream, |b| {
            let (o, a) = run(b);
            match o {
                Err(_) => true,
                Ok(_) => a > ACCEPT_FACTOR * b.len() as u64 + STREAM_SLACK,
            }
        });
        return Err(format!("{what}; shrunk to {} bytes: {}", shrunk.len(), hex(&shrunk)));
    }
    Ok(())
}

// ---------------------------------------------------------- corpus replay

/// Replay one checked-in corpus *body* (the bytes inside a frame) under
/// the full decode oracle set — panic containment and, when the counting
/// allocator is registered, the allocation bounds.  Used by the
/// `fuzz_corpus` test target; failures come back shrunk exactly like live
/// fuzz findings.  Returns whether either decoder accepted the body.
pub fn replay_body(body: &[u8]) -> Result<bool, String> {
    let mut paths = PathInterner::default();
    let mut report = WireFuzzReport {
        alloc_guarded: alloc_guard::installed(),
        ..WireFuzzReport::default()
    };
    check_body(body, &mut paths, &mut report)?;
    Ok(report.accepted > 0)
}

/// Replay one corpus byte *stream* (length prefix + however much of the
/// body the "peer" delivered) through [`read_frame`] under the panic and
/// streaming-allocation oracles.  Returns whether a frame was produced.
pub fn replay_stream(stream: &[u8]) -> Result<bool, String> {
    let (outcome, alloc) = alloc_guard::measure(|| {
        catch_unwind(AssertUnwindSafe(|| read_frame(&mut Cursor::new(stream))))
    });
    let bound = ACCEPT_FACTOR * stream.len() as u64 + STREAM_SLACK;
    if alloc > bound {
        return Err(format!(
            "read_frame allocated {alloc} bytes on a {}-byte stream (bound {bound})",
            stream.len()
        ));
    }
    match outcome {
        Err(_) => Err(format!("read_frame panicked on a {}-byte stream", stream.len())),
        Ok(Ok(body)) => {
            let framed = stream.len().saturating_sub(4);
            if body.len() == framed {
                Ok(true)
            } else {
                Err(format!(
                    "read_frame returned {} bytes from a {framed}-byte delivery",
                    body.len()
                ))
            }
        }
        Ok(Err(_)) => Ok(false),
    }
}

// ------------------------------------------------------------- watchdog

/// Abort (loudly, with the seed) if the fuzz loop makes no progress for
/// ~2 s: a hung decode must fail CI, not idle until the job times out.
fn spawn_watchdog(
    progress: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    seed: u64,
) -> thread::JoinHandle<()> {
    thread::spawn(move || {
        let mut last = u64::MAX;
        let mut stalled = 0u32;
        loop {
            thread::sleep(Duration::from_millis(250));
            if stop.load(Ordering::Relaxed) {
                return;
            }
            let now = progress.load(Ordering::Relaxed);
            if now == last {
                stalled += 1;
                if stalled >= 8 {
                    eprintln!(
                        "wire fuzz watchdog: no progress for 2s after iter {now} \
                         (seed {seed:#x}); aborting"
                    );
                    std::process::abort();
                }
            } else {
                stalled = 0;
                last = now;
            }
        }
    })
}

// ----------------------------------------------------------- generators

/// How many batch elements a decoded request materialized (for the
/// per-element allocation allowance).
fn request_items(req: &Request) -> usize {
    match req {
        Request::ReadFiles { paths } | Request::StatOutputs { paths } => paths.len().max(1),
        _ => 1,
    }
}

fn response_items(resp: &Response) -> usize {
    match resp {
        Response::FilesData(v) => v.len().max(1),
        Response::Metas(v) => v.len().max(1),
        Response::Names(v) => v.len().max(1),
        _ => 1,
    }
}

/// A canonical encoded body for a random valid request or response.
fn gen_valid_body(rng: &mut Prng) -> Vec<u8> {
    if rng.chance(0.5) {
        encode_request(rng.next_u64(), rng.below(64) as u32, &gen_request(rng)).to_body_bytes()
    } else {
        encode_response(rng.next_u64(), &gen_response(rng)).to_body_bytes()
    }
}

fn gen_path(rng: &mut Prng) -> Arc<str> {
    const DIRS: [&str; 4] = ["/fanstore/user/train/class0", "/out", "/ckpt", "/a/b/c"];
    if rng.chance(0.05) {
        return Arc::from("");
    }
    let dir = DIRS[rng.index(DIRS.len())];
    Arc::from(format!("{dir}/f{:03}.bin", rng.below(200)))
}

fn gen_paths(rng: &mut Prng) -> Vec<Arc<str>> {
    (0..rng.below(9)).map(|_| gen_path(rng)).collect()
}

fn gen_string(rng: &mut Prng) -> String {
    if rng.chance(0.1) {
        String::new()
    } else {
        format!("entry {:04x}", rng.below(1 << 16))
    }
}

/// Random payload; a claimed compression wrapper rides the wire without
/// being decoded, so `raw_len` is free to disagree with the byte count.
fn gen_payload(rng: &mut Prng) -> Payload {
    let mut bytes = vec![0u8; rng.below(257) as usize];
    rng.fill_bytes(&mut bytes);
    if rng.chance(0.5) {
        let raw_len = rng.below(1 << 20);
        Payload::compressed(Codec::Lzss(1 + rng.below(9) as u8), raw_len, bytes.into())
    } else {
        bytes.into()
    }
}

fn gen_stat(rng: &mut Prng) -> FileStat {
    let mut s = FileStat::regular(rng.next_u64(), rng.below(1 << 30));
    s.mode = rng.next_u64() as u32;
    s.uid = rng.next_u64() as u32;
    s.mtime = rng.next_u64() as i64;
    s.blocks = rng.next_u64();
    s
}

fn gen_codec(rng: &mut Prng) -> Codec {
    if rng.chance(0.4) {
        Codec::None
    } else {
        Codec::Lzss(1 + rng.below(9) as u8)
    }
}

fn gen_meta(rng: &mut Prng) -> FileMeta {
    FileMeta {
        stat: gen_stat(rng),
        location: FileLocation {
            node: rng.below(64) as u32,
            partition: rng.next_u64() as u32,
            offset: rng.next_u64() >> rng.below(64) as u32,
            stored_len: rng.next_u64() >> rng.below(64) as u32,
            codec: gen_codec(rng),
        },
        generation: rng.next_u64() >> rng.below(64) as u32,
    }
}

fn gen_fetch(rng: &mut Prng) -> FileFetch {
    match rng.below(3) {
        0 => FileFetch::Data { stored: gen_payload(rng) },
        1 => FileFetch::NotFound,
        _ => FileFetch::Fault(gen_string(rng)),
    }
}

fn gen_meta_fetch(rng: &mut Prng) -> MetaFetch {
    if rng.chance(0.5) {
        MetaFetch::Meta {
            stat: gen_stat(rng),
            origin: rng.below(64) as u32,
            generation: rng.next_u64() >> rng.below(64) as u32,
        }
    } else {
        MetaFetch::NotFound
    }
}

fn gen_request(rng: &mut Prng) -> Request {
    match rng.below(13) {
        0 => Request::ReadFile { path: gen_path(rng) },
        1 => Request::ReadFiles { paths: gen_paths(rng) },
        2 => Request::StatOutput { path: gen_path(rng) },
        3 => Request::StatOutputs { paths: gen_paths(rng) },
        4 => Request::CommitOutput {
            path: gen_path(rng),
            meta: gen_meta(rng),
            data: gen_payload(rng),
            stamped: rng.chance(0.5),
        },
        5 => Request::ListOutputs { dir: gen_path(rng) },
        6 => Request::UnlinkOutput { path: gen_path(rng) },
        7 => Request::DropOutput { path: gen_path(rng) },
        8 => Request::InvalidateListings { path: gen_path(rng) },
        9 => Request::Ping { epoch: rng.next_u64() },
        10 => Request::FetchPartition { pid: rng.next_u64() as u32 },
        11 => Request::InstallPartition {
            pid: rng.next_u64() as u32,
            blob: gen_payload(rng),
        },
        _ => Request::Shutdown,
    }
}

fn gen_response(rng: &mut Prng) -> Response {
    match rng.below(9) {
        0 => Response::FileData { stored: gen_payload(rng) },
        1 => Response::FilesData(
            (0..rng.below(9)).map(|_| (gen_path(rng), gen_fetch(rng))).collect(),
        ),
        2 => Response::Meta {
            stat: gen_stat(rng),
            origin: rng.below(64) as u32,
            generation: rng.next_u64() >> rng.below(64) as u32,
        },
        3 => Response::Metas(
            (0..rng.below(9)).map(|_| (gen_path(rng), gen_meta_fetch(rng))).collect(),
        ),
        4 => Response::Names((0..rng.below(17)).map(|_| gen_string(rng)).collect()),
        5 => Response::Pong { epoch: rng.next_u64() },
        6 => Response::PartitionData { blob: gen_payload(rng) },
        7 => Response::Ok,
        _ => Response::Err(gen_string(rng)),
    }
}

/// Structure-aware mutation of valid bodies: splice two bodies, duplicate
/// or delete a range, tamper with a run of bytes (0x00 / 0xFF floods bend
/// varint continuation bits and length prefixes).
fn mutate_structured(rng: &mut Prng) -> Vec<u8> {
    let a = gen_valid_body(rng);
    match rng.below(4) {
        // splice: prefix of one body + suffix of another
        0 => {
            let b = gen_valid_body(rng);
            let cut_a = rng.index(a.len() + 1);
            let cut_b = rng.index(b.len() + 1);
            let mut out = a[..cut_a].to_vec();
            out.extend_from_slice(&b[cut_b..]);
            out
        }
        // duplicate a range in place
        1 => {
            let start = rng.index(a.len());
            let len = 1 + rng.index(a.len() - start);
            let mut out = a.clone();
            let dup = a[start..start + len].to_vec();
            out.splice(start..start, dup);
            out
        }
        // delete a range
        2 => {
            let start = rng.index(a.len());
            let len = 1 + rng.index(a.len() - start);
            let mut out = a.clone();
            out.drain(start..start + len);
            out
        }
        // flood a run with 0x00 / 0xFF / a random byte
        _ => {
            let start = rng.index(a.len());
            let len = 1 + rng.index((a.len() - start).min(16));
            let fill = match rng.below(3) {
                0 => 0x00,
                1 => 0xFF,
                _ => rng.next_u64() as u8,
            };
            let mut out = a;
            out[start..start + len].fill(fill);
            out
        }
    }
}

fn hex(bytes: &[u8]) -> String {
    const SHOWN: usize = 256;
    let mut s = String::with_capacity(bytes.len().min(SHOWN) * 2 + 16);
    for b in bytes.iter().take(SHOWN) {
        s.push_str(&format!("{b:02x}"));
    }
    if bytes.len() > SHOWN {
        s.push_str(&format!("... ({} more bytes)", bytes.len() - SHOWN));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    // A short deterministic run of every mode.  The library test binary
    // has no counting allocator, so this exercises the panic, hang,
    // differential, and error-class oracles; the allocation oracle runs
    // for real in the `fuzz_corpus` test target and the CLI.
    #[test]
    fn short_wire_fuzz_run_is_clean() {
        let report = run_wire_fuzz(0xF0CC_AC1A, 600).expect("no oracle violations");
        assert_eq!(report.iters, 600);
        assert!(report.rejected > 0, "mutation modes must exercise rejects");
        assert!(report.accepted > 0, "valid mode must exercise accepts");
    }

    #[test]
    fn generated_bodies_always_roundtrip() {
        let mut rng = Prng::new(0x5EED);
        let mut paths = PathInterner::default();
        for _ in 0..300 {
            let body = gen_valid_body(&mut rng);
            roundtrip_check(&body, &mut paths).expect("canonical roundtrip");
        }
    }
}
