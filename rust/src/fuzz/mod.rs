//! Deterministic, dependency-free fuzzing harness (PR 10 tentpole).
//!
//! Two fuzzers, both seeded and fully reproducible:
//!
//! * [`wire`] — adversarial wire-codec fuzzing: raw random bodies,
//!   truncations, bit-flips, and structure-aware mutations of valid
//!   encoded frames are fed to the decoders under panic containment, a
//!   wall-clock watchdog, and (when [`alloc_guard::CountingAlloc`] is the
//!   process's global allocator) an allocation-amplification oracle.
//!   Valid frames also get a differential re-encode check: decode ∘
//!   encode must be the identity on canonical bytes.
//! * [`store`] — stateful store/cluster fuzzing: PRNG-generated op
//!   schedules run against a *real* in-process cluster while an
//!   in-memory shadow model predicts contents, metadata, and errno
//!   classes; the first divergence is shrunk to a minimal schedule.
//!
//! Failures print the seed; `fanstore fuzz wire|store --seed N` replays
//! them exactly.  Regression inputs live in `rust/tests/corpus/` and are
//! replayed by the `fuzz_corpus` test target on every `cargo test`.

pub mod alloc_guard;
pub(crate) mod model;
pub mod store;
pub mod wire;

pub use store::{run_store_fuzz, Op, StoreFuzzReport};
pub use wire::{run_wire_fuzz, WireFuzzReport};
