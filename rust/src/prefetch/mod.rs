//! Asynchronous prefetch pipeline (paper §5.4: worker threads fetch the
//! next mini-batches in the background while the trainer computes).
//!
//! A [`Prefetcher`] runs per node: N fetcher threads drain a queue of
//! scheduled paths (the epoch's shuffled access sequence from
//! [`crate::workload::access::EpochSampler`]), and resolve each pickup
//! through the node's shared batched-fetch body
//! ([`NodeShared::fetch_inputs_batched`]: cache acquire, overlapped local
//! reads, **one batched `ReadFiles` round trip per peer** with the
//! per-peer requests overlapped through `Transport::send`).  Fetched
//! content lands in the node's sharded refcount cache with the pin held by
//! the prefetcher until a reader claims it, so `FanStoreVfs::open` is a
//! cache hit in steady state.  The engine is fabric-agnostic: it holds an
//! `Arc<dyn Transport>`, so the same pipeline runs over mpsc channels or
//! real TCP sockets.
//!
//! # Scheduling (interned, index-based)
//!
//! Epoch schedules go through an [`EpochPathTable`]: the caller interns
//! the path set once (`Arc<str>` per distinct path) and pushes the epoch's
//! access order as `u32` indices.  The queue holds `(table, index)` pairs
//! and every membership structure (queued/stolen/slots multiset) keys on
//! `Arc<str>` clones into the table, so scheduling a million-file epoch
//! costs one table build plus index pushes — no per-path `String` clone
//! anywhere on the queue path.  The wire protocol carries `Arc<str>` too,
//! so even pickups fetch with clones of the interned handles: no path
//! materializes as a `String` anywhere in the pipeline.
//!
//! # Backpressure
//!
//! The engine never holds more than `window` unclaimed pins: `inflight`
//! counts Pending + Ready slots, fetchers block on `work_cv` while the
//! window is full, and every claim frees a slot.  This bounds the cache
//! memory the pipeline can pin regardless of how far the schedule runs
//! ahead of the trainer cursor.
//!
//! # Claim protocol (deadlock-free by construction)
//!
//! [`PrefetchHandle::wait`] resolves a path in exactly one of four ways:
//!
//! * **Ready** — transfer the cache pin to the caller (no cache traffic).
//! * **Pending** — block until the in-flight fetch resolves.  Fetchers
//!   never block while holding Pending slots, so this always terminates.
//! * **Queued** — the reader got there before any fetcher: steal the entry
//!   back (the fetcher will skip it) and return `None`; the caller fetches
//!   synchronously.  A reader can therefore never wait on a path that no
//!   fetcher is working on.
//! * **Unknown / Failed** — return `None`; the caller falls back to the
//!   ordinary synchronous read path, which surfaces the real error.
//!
//! # Failure semantics (PR 7)
//!
//! Fetchers inherit node-failure handling from the shared batched-fetch
//! body: a peer that errors is recorded in the node's
//! [`crate::net::health::HealthMap`] and the affected paths are re-queued
//! to the next live holder (bounded by the retry budget).  A path whose
//! holders are all down resolves to **Failed**, the reader's claim returns
//! `None`, and the synchronous fallback surfaces the degraded-read error
//! (`EIO`) — a dead peer never parks a fetcher thread or wedges the
//! claim protocol.
//!
//! # Counter algebra
//!
//! Each picked path performs exactly one cache `acquire` (hit → Ready
//! immediately; miss → one fetch).  Claims transfer pins without touching
//! the cache.  So the node-wide invariants the stress tests assert stay
//! exact even with the pipeline running:
//!
//! ```text
//! local_reads + remote_reads_issued == cache misses          (fault-free)
//! read_opens == claims + cache hits + cache misses - picked
//! picked == prehits + fetched_local + fetched_remote + failed
//! ```

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::net::transport::Transport;
use crate::node::{FetchSource, NodeShared};
use crate::storage::payload::Payload;

/// Engine sizing (validated upstream by `ClusterConfig::validate`).
#[derive(Clone, Copy, Debug)]
pub struct PrefetchConfig {
    /// Max fetched-but-unclaimed files pinned in the cache (pin budget).
    pub window: usize,
    /// Background fetcher threads.
    pub fetchers: usize,
}

impl Default for PrefetchConfig {
    fn default() -> Self {
        PrefetchConfig {
            window: 64,
            fetchers: 4,
        }
    }
}

/// Accounting snapshot (see the module-level algebra).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefetchStats {
    /// Paths handed to `schedule`.
    pub scheduled: u64,
    /// Paths a fetcher picked up (each does exactly one cache acquire).
    pub picked: u64,
    /// Picked paths already resident in the cache (acquire hit → Ready).
    pub prehits: u64,
    /// Picked paths fetched from this node's own store.
    pub fetched_local: u64,
    /// Picked paths fetched from a peer via a batched `ReadFiles`.
    pub fetched_remote: u64,
    /// Batched `ReadFiles` requests issued to peers.
    pub batches_issued: u64,
    /// Ready pins transferred to readers.
    pub claimed: u64,
    /// Queued paths claimed back by a reader before any fetcher got there.
    pub stolen: u64,
    /// Queue entries skipped because the path already had a live slot.
    pub coalesced: u64,
    /// Picked paths that could not be fetched (reader falls back and
    /// surfaces the real error on its own synchronous read).
    pub failed: u64,
}

#[derive(Default)]
struct AtomicPrefetchStats {
    scheduled: AtomicU64,
    picked: AtomicU64,
    prehits: AtomicU64,
    fetched_local: AtomicU64,
    fetched_remote: AtomicU64,
    batches_issued: AtomicU64,
    claimed: AtomicU64,
    stolen: AtomicU64,
    coalesced: AtomicU64,
    failed: AtomicU64,
}

impl AtomicPrefetchStats {
    fn snapshot(&self) -> PrefetchStats {
        let ld = |c: &AtomicU64| c.load(Ordering::Relaxed);
        PrefetchStats {
            scheduled: ld(&self.scheduled),
            picked: ld(&self.picked),
            prehits: ld(&self.prehits),
            fetched_local: ld(&self.fetched_local),
            fetched_remote: ld(&self.fetched_remote),
            batches_issued: ld(&self.batches_issued),
            claimed: ld(&self.claimed),
            stolen: ld(&self.stolen),
            coalesced: ld(&self.coalesced),
            failed: ld(&self.failed),
        }
    }
}

/// Interned epoch access order: every path stored once as an `Arc<str>`,
/// addressed by its dense `u32` index.  Build one per epoch (or one per
/// run when the path set is stable) and schedule *indices* through
/// [`PrefetchHandle::schedule_table`]: the queue then holds bare
/// `(table, index)` pairs and the membership multiset clones `Arc`
/// handles, so scheduling a million-file epoch performs zero per-path
/// `String` clones.
pub struct EpochPathTable {
    paths: Vec<Arc<str>>,
    /// path → first index (dedup at build time + reverse lookups).
    index: HashMap<Arc<str>, u32>,
}

impl EpochPathTable {
    /// Intern `paths` in order; duplicate paths share one allocation but
    /// keep their positional slots (so caller-side sampler indices map 1:1).
    pub fn from_paths<I>(paths: I) -> EpochPathTable
    where
        I: IntoIterator,
        I::Item: AsRef<str>,
    {
        let mut table = EpochPathTable {
            paths: Vec::new(),
            index: HashMap::new(),
        };
        for p in paths {
            let p = p.as_ref();
            let interned = match table.index.get(p) {
                Some(&i) => Arc::clone(&table.paths[i as usize]),
                None => {
                    let a: Arc<str> = Arc::from(p);
                    table.index.insert(Arc::clone(&a), table.paths.len() as u32);
                    a
                }
            };
            table.paths.push(interned);
        }
        table
    }

    pub fn len(&self) -> usize {
        self.paths.len()
    }

    pub fn is_empty(&self) -> bool {
        self.paths.is_empty()
    }

    /// The interned path at `idx`.
    pub fn path(&self, idx: u32) -> Option<&Arc<str>> {
        self.paths.get(idx as usize)
    }

    /// First index of `path`, if interned here.
    pub fn index_of(&self, path: &str) -> Option<u32> {
        self.index.get(path).copied()
    }
}

/// A picked path's lifecycle entry.
enum Slot {
    /// A fetcher is working on it right now.
    Pending,
    /// Fetched; the handle is the cache pin held for the eventual claimer.
    Ready(Payload),
    /// Fetch failed; the claimer falls back to the synchronous path.
    Failed,
}

/// One live scheduled table: the shared paths plus how many queue entries
/// still reference it (retired when the last entry pops).
struct TableSlot {
    table: Arc<EpochPathTable>,
    remaining: u64,
}

#[derive(Default)]
struct PfState {
    /// Scheduled, not yet picked up (FIFO = the trainer's access order):
    /// `(table id, path index)` — 8 bytes per entry, no path clones.
    queue: VecDeque<(u32, u32)>,
    /// Live schedule tables by id (typically one or two: the current
    /// epoch, plus the next one's head once cross-epoch scheduling lands).
    tables: HashMap<u32, TableSlot>,
    next_table: u32,
    /// Multiset view of `queue` for O(1) membership on the claim path.
    /// Keys are `Arc` clones into the tables, never fresh strings.
    queued: HashMap<Arc<str>, u32>,
    /// Queue entries a reader stole back; fetchers skip them on pop.
    stolen: HashMap<Arc<str>, u32>,
    /// Picked paths: in flight, ready, or failed.
    slots: HashMap<Arc<str>, Slot>,
    /// Pending + Ready slots — the pins/window currently held.
    inflight: usize,
    shutdown: bool,
}

/// State shared by the fetcher threads and every handle.
struct Inner {
    shared: Arc<NodeShared>,
    transport: Arc<dyn Transport>,
    window: usize,
    max_batch: usize,
    state: Mutex<PfState>,
    /// Fetchers wait here for work/window; claims and schedules notify.
    work_cv: Condvar,
    /// Claimers wait here for Pending → Ready/Failed transitions.
    ready_cv: Condvar,
    stats: AtomicPrefetchStats,
}

/// Per-node prefetch engine.  Dropping it stops the fetcher threads and
/// releases every unclaimed cache pin, so the refcount cache drains to
/// zero once all descriptors close.
pub struct Prefetcher {
    inner: Arc<Inner>,
    fetchers: Vec<JoinHandle<()>>,
}

/// Cheap cloneable handle for schedulers and readers.  Outlives the
/// engine safely: after shutdown every `wait` returns `None` (callers
/// fall back to synchronous reads).
#[derive(Clone)]
pub struct PrefetchHandle {
    inner: Arc<Inner>,
}

impl Prefetcher {
    /// Start `cfg.fetchers` background threads for `node_id`.
    pub fn spawn(
        node_id: u32,
        shared: Arc<NodeShared>,
        transport: Arc<dyn Transport>,
        cfg: PrefetchConfig,
    ) -> Prefetcher {
        let window = cfg.window.max(1);
        let nfetchers = cfg.fetchers.max(1);
        // one pickup should neither starve sibling fetchers nor exceed a
        // sensible per-request payload count
        let max_batch = (window / nfetchers).clamp(1, 16);
        let inner = Arc::new(Inner {
            shared,
            transport,
            window,
            max_batch,
            state: Mutex::new(PfState::default()),
            work_cv: Condvar::new(),
            ready_cv: Condvar::new(),
            stats: AtomicPrefetchStats::default(),
        });
        let fetchers = (0..nfetchers)
            .map(|i| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("fanstore-prefetch-{node_id}-{i}"))
                    .spawn(move || fetch_loop(&inner))
                    .expect("spawn prefetch fetcher")
            })
            .collect();
        Prefetcher { inner, fetchers }
    }

    pub fn handle(&self) -> PrefetchHandle {
        PrefetchHandle {
            inner: Arc::clone(&self.inner),
        }
    }

    pub fn stats(&self) -> PrefetchStats {
        self.inner.stats.snapshot()
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
        }
        self.inner.work_cv.notify_all();
        self.inner.ready_cv.notify_all();
        for h in self.fetchers.drain(..) {
            let _ = h.join();
        }
        // fetchers are gone, so no slot can change under us: release every
        // unclaimed pin and clear the backlog
        let mut st = self.inner.state.lock().unwrap();
        let slots = std::mem::take(&mut st.slots);
        st.queue.clear();
        st.tables.clear();
        st.queued.clear();
        st.stolen.clear();
        st.inflight = 0;
        drop(st);
        for (path, slot) in slots {
            if let Slot::Ready(pin) = slot {
                self.inner.shared.cache.release(&path, &pin);
            }
        }
        // claimers blocked on a Pending slot must re-check and bail
        self.inner.ready_cv.notify_all();
    }
}

impl PrefetchHandle {
    /// Append the access order `order` (indices into `table`) to the fetch
    /// queue.  Duplicates are legal; redundant fetches coalesce.  The
    /// queue stores `(table, index)` pairs and the membership multiset
    /// clones `Arc<str>` handles out of the table, so an epoch-scale
    /// schedule costs the (caller-owned, reusable) table build plus index
    /// pushes — zero per-path `String` clones.  Out-of-range indices are
    /// ignored.
    pub fn schedule_table<I>(&self, table: &Arc<EpochPathTable>, order: I)
    where
        I: IntoIterator<Item = u32>,
    {
        let mut n = 0u64;
        {
            let mut st = self.inner.state.lock().unwrap();
            if st.shutdown {
                return;
            }
            let tid = st.next_table;
            for idx in order {
                let Some(path) = table.path(idx) else { continue };
                let path = Arc::clone(path);
                *st.queued.entry(path).or_insert(0) += 1;
                st.queue.push_back((tid, idx));
                n += 1;
            }
            if n > 0 {
                st.next_table = st.next_table.wrapping_add(1);
                st.tables.insert(
                    tid,
                    TableSlot {
                        table: Arc::clone(table),
                        remaining: n,
                    },
                );
            }
        }
        self.inner.stats.scheduled.fetch_add(n, Ordering::Relaxed);
        self.inner.work_cv.notify_all();
    }

    /// Convenience for small schedules and tests: intern `paths` into a
    /// fresh table and schedule it in order.  Epoch-scale callers build
    /// one [`EpochPathTable`] up front and use
    /// [`PrefetchHandle::schedule_table`] with sampler indices.
    pub fn schedule<I>(&self, paths: I)
    where
        I: IntoIterator<Item = String>,
    {
        let table = EpochPathTable::from_paths(paths);
        let n = table.len() as u32;
        self.schedule_table(&Arc::new(table), 0..n);
    }

    /// Claim `path` from the pipeline (see the module-level protocol).
    /// `Some(pin)` transfers the cache pin to the caller — it must be
    /// `release`d like any other descriptor pin.  `None` means the caller
    /// should read synchronously.
    pub fn wait(&self, path: &str) -> Option<Payload> {
        enum Act {
            Block,
            TakeReady,
            TakeFailed,
            TrySteal,
        }
        let mut st = self.inner.state.lock().unwrap();
        loop {
            let act = match st.slots.get(path) {
                Some(Slot::Pending) => Act::Block,
                Some(Slot::Ready(_)) => Act::TakeReady,
                Some(Slot::Failed) => Act::TakeFailed,
                None => Act::TrySteal,
            };
            match act {
                Act::Block => {
                    if st.shutdown {
                        // the in-flight fetch may still resolve, but the
                        // engine is going away — read synchronously
                        return None;
                    }
                    st = self.inner.ready_cv.wait(st).unwrap();
                }
                Act::TakeReady => {
                    if let Some(Slot::Ready(pin)) = st.slots.remove(path) {
                        st.inflight -= 1;
                        drop(st);
                        self.inner.stats.claimed.fetch_add(1, Ordering::Relaxed);
                        self.inner.work_cv.notify_all();
                        return Some(pin);
                    }
                    unreachable!("slot type changed under the lock");
                }
                Act::TakeFailed => {
                    st.slots.remove(path);
                    return None;
                }
                Act::TrySteal => {
                    // clone the interned key out of the multiset instead of
                    // allocating a fresh string for the stolen marker
                    let key = st
                        .queued
                        .get_key_value(path)
                        .filter(|(_, c)| **c > 0)
                        .map(|(k, _)| Arc::clone(k));
                    if let Some(key) = key {
                        let c = st.queued.get_mut(path).expect("key just found");
                        *c -= 1;
                        if *c == 0 {
                            st.queued.remove(path);
                        }
                        *st.stolen.entry(key).or_insert(0) += 1;
                        self.inner.stats.stolen.fetch_add(1, Ordering::Relaxed);
                    }
                    return None;
                }
            }
        }
    }

    pub fn stats(&self) -> PrefetchStats {
        self.inner.stats.snapshot()
    }
}

/// Fetcher thread body: pick up to `max_batch` paths within the window,
/// fetch them (cache-aware, holder-grouped, batched per peer), mark the
/// slots, repeat until shutdown.
fn fetch_loop(inner: &Inner) {
    loop {
        let picked = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if !st.queue.is_empty() && st.inflight < inner.window {
                    break;
                }
                st = inner.work_cv.wait(st).unwrap();
            }
            let room = inner.window - st.inflight;
            let take = room.min(inner.max_batch);
            let mut picked: Vec<Arc<str>> = Vec::with_capacity(take);
            while picked.len() < take {
                let Some((tid, idx)) = st.queue.pop_front() else { break };
                // resolve the interned path; retire the table slot once
                // its last queue entry pops
                let (p, drained) = {
                    let slot = st
                        .tables
                        .get_mut(&tid)
                        .expect("queued entry's table is live");
                    let p = slot
                        .table
                        .path(idx)
                        .cloned()
                        .expect("queued index validated at schedule time");
                    slot.remaining -= 1;
                    (p, slot.remaining == 0)
                };
                if drained {
                    st.tables.remove(&tid);
                }
                // claimed back by a reader before we got here?
                if let Some(c) = st.stolen.get_mut(&*p) {
                    *c -= 1;
                    if *c == 0 {
                        st.stolen.remove(&*p);
                    }
                    continue;
                }
                if let Some(c) = st.queued.get_mut(&*p) {
                    *c -= 1;
                    if *c == 0 {
                        st.queued.remove(&*p);
                    }
                }
                if st.slots.contains_key(&*p) {
                    // an earlier schedule of the same path is in flight or
                    // unclaimed — a second fetch buys nothing
                    inner.stats.coalesced.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                st.slots.insert(Arc::clone(&p), Slot::Pending);
                st.inflight += 1;
                picked.push(p);
            }
            picked
        };
        if picked.is_empty() {
            continue;
        }
        inner
            .stats
            .picked
            .fetch_add(picked.len() as u64, Ordering::Relaxed);
        fetch_batch(inner, picked);
    }
}

/// Fetch one pickup through the node's shared batched-fetch body (cache
/// acquire, overlapped local reads, one batched request per peer), then
/// mark the slots with the outcomes.
///
/// The wire protocol carries `Arc<str>` paths, so the picked interned
/// handles ride straight through the batched fetch and come back as the
/// outcome keys — no `String` materialization, no re-mapping.
fn fetch_batch(inner: &Inner, picked: Vec<Arc<str>>) {
    let mut done: Vec<(Arc<str>, Option<Payload>)> = Vec::with_capacity(picked.len());
    let mut items: Vec<(Arc<str>, crate::metadata::record::FileLocation)> = Vec::new();
    for p in picked {
        match inner.shared.input_meta.get(&p) {
            // not an input file: fail WITHOUT touching the cache — the
            // reader's fallback handles outputs, and a fetchless acquire
            // here would skew the node-wide miss/fetch algebra
            None => done.push((p, None)),
            Some(m) => items.push((p, m.location)),
        }
    }

    // tiering hint (PR 8): a pickup is the earliest moment we *know* these
    // bytes are about to be read, so tell the kernel to fault the spilled
    // pages in now — by the time the fetch (or the trainer behind it) gets
    // there the pages are warm.  No-op for RAM-backed or remote paths.
    for (p, _) in &items {
        inner.shared.store.advise_willneed(p);
    }

    let batch = inner
        .shared
        .fetch_inputs_batched(inner.transport.as_ref(), items);
    inner
        .stats
        .batches_issued
        .fetch_add(batch.remote_batches, Ordering::Relaxed);
    for (p, outcome) in batch.outcomes {
        match outcome {
            Ok((pin, src)) => {
                // exactly one cache acquire happened per picked input (hit
                // → Ready immediately; miss → exactly one fetch), so the
                // engine's own accounting mirrors the node-wide algebra
                let ctr = match src {
                    FetchSource::Cache => &inner.stats.prehits,
                    FetchSource::Local => &inner.stats.fetched_local,
                    FetchSource::Remote => &inner.stats.fetched_remote,
                };
                ctr.fetch_add(1, Ordering::Relaxed);
                done.push((p, Some(pin)));
            }
            // fetch failed (ENOENT, fault, dead peer, decode error):
            // readers fall back synchronously and surface the real error
            Err(_) => done.push((p, None)),
        }
    }

    let mut st = inner.state.lock().unwrap();
    for (p, outcome) in done {
        match outcome {
            Some(pin) => {
                st.slots.insert(p, Slot::Ready(pin));
            }
            None => {
                st.slots.insert(p, Slot::Failed);
                // failed slots hold no pin, so they release window space now
                st.inflight -= 1;
                inner.stats.failed.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    drop(st);
    inner.ready_cv.notify_all();
    inner.work_cv.notify_all();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::metadata::placement::Placement;
    use crate::node::NodeBuilder;
    use crate::partition::builder::{build_partitions, InputFile};
    use crate::storage::disk::DiskStore;

    use crate::net::transport::InProcTransport;

    /// Single-node world: everything is a local fetch, which is all these
    /// unit tests need (the remote/batched path is covered by the
    /// integration tests over a full cluster).
    fn one_node(n_files: usize) -> (Arc<NodeShared>, Arc<dyn Transport>, Vec<String>) {
        let files: Vec<InputFile> = (0..n_files)
            .map(|i| InputFile {
                path: format!("train/f{i}"),
                data: vec![(i % 251) as u8; 64 + i],
            })
            .collect();
        let (blobs, _) = build_partitions(&files, 1, Codec::None).unwrap();
        let placement = Placement::new(1, 1, 1);
        let mut b = NodeBuilder::new(0, DiskStore::in_memory(), placement);
        b.store.load_partition(0, blobs[0].clone(), "/m").unwrap();
        // index the input metadata so the prefetcher can place paths
        let mut table = crate::metadata::table::MetaTable::new();
        let blobs: Vec<(u32, Vec<u8>)> = blobs
            .into_iter()
            .enumerate()
            .map(|(i, x)| (i as u32, x))
            .collect();
        crate::node::index_input_metadata(&mut table, &blobs, "/m", &b.placement).unwrap();
        b.input_meta = Arc::new(table);
        let shared = b.seal();
        let (tp, _eps) = InProcTransport::fully_connected(1);
        let tp: Arc<dyn Transport> = Arc::new(tp);
        let paths = (0..n_files).map(|i| format!("/m/train/f{i}")).collect();
        (shared, tp, paths)
    }

    fn poll_until(mut cond: impl FnMut() -> bool, ms: u64) -> bool {
        let t0 = std::time::Instant::now();
        while t0.elapsed().as_millis() < ms as u128 {
            if cond() {
                return true;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        cond()
    }

    #[test]
    fn window_bounds_unclaimed_pins() {
        let (shared, tp, paths) = one_node(32);
        let pf = Prefetcher::spawn(
            0,
            Arc::clone(&shared),
            tp,
            PrefetchConfig {
                window: 4,
                fetchers: 2,
            },
        );
        let h = pf.handle();
        h.schedule(paths.iter().cloned());
        // fetchers fill the window...
        assert!(
            poll_until(|| pf.stats().fetched_local == 4, 3000),
            "window should fill: {:?}",
            pf.stats()
        );
        // ...and then stall: no claims -> no further fetches
        std::thread::sleep(std::time::Duration::from_millis(50));
        let st = pf.stats();
        assert_eq!(st.fetched_local, 4, "window must hold without claims");
        assert!(shared.cache.resident_files() <= 4);

        // claiming drains the queue end to end
        let mut claimed = 0u64;
        let mut stolen = 0u64;
        for (i, p) in paths.iter().enumerate() {
            match h.wait(p) {
                Some(pin) => {
                    assert_eq!(&pin[..], &vec![(i % 251) as u8; 64 + i][..]);
                    shared.cache.release(p, &pin);
                    claimed += 1;
                }
                None => stolen += 1, // reader beat the fetchers to it
            }
        }
        assert_eq!(claimed + stolen, 32);
        assert_eq!(pf.stats().claimed, claimed);
        assert_eq!(pf.stats().stolen, stolen);
        drop(pf);
        assert_eq!(shared.cache.resident_files(), 0, "drop releases pins");
    }

    #[test]
    fn duplicate_schedules_coalesce_and_unknown_wait_is_fallback() {
        let (shared, tp, paths) = one_node(4);
        let pf = Prefetcher::spawn(0, Arc::clone(&shared), tp, PrefetchConfig::default());
        let h = pf.handle();
        // schedule the same path three times
        h.schedule(vec![paths[0].clone(), paths[0].clone(), paths[0].clone()]);
        assert!(
            poll_until(
                || {
                    let s = h.stats();
                    s.fetched_local + s.prehits >= 1 && s.coalesced + s.stolen >= 2
                },
                3000
            ),
            "{:?}",
            h.stats()
        );
        // a path that was never scheduled falls back immediately
        assert!(h.wait("/m/train/f3").is_none());
        // the single live slot is claimable exactly once
        let pin = h.wait(&paths[0]).expect("ready slot");
        shared.cache.release(&paths[0], &pin);
        assert!(h.wait(&paths[0]).is_none(), "second claim falls back");
        drop(pf);
        assert_eq!(shared.cache.resident_files(), 0);
    }

    #[test]
    fn epoch_table_interns_and_indexes() {
        let dup = EpochPathTable::from_paths(["/a", "/b", "/a", "/c", "/b"]);
        assert_eq!(dup.len(), 5);
        assert!(!dup.is_empty());
        // duplicates share one allocation but keep positional slots
        assert!(Arc::ptr_eq(dup.path(0).unwrap(), dup.path(2).unwrap()));
        assert!(Arc::ptr_eq(dup.path(1).unwrap(), dup.path(4).unwrap()));
        assert_eq!(dup.index_of("/a"), Some(0));
        assert_eq!(dup.index_of("/c"), Some(3));
        assert_eq!(dup.index_of("/nope"), None);
        assert!(dup.path(5).is_none());
    }

    #[test]
    fn schedule_table_runs_on_indices() {
        let (shared, tp, paths) = one_node(6);
        let table = Arc::new(EpochPathTable::from_paths(&paths));
        assert_eq!(table.len(), 6);
        let pf = Prefetcher::spawn(0, Arc::clone(&shared), tp, PrefetchConfig::default());
        let h = pf.handle();
        // out-of-range indices are skipped; valid ones are scheduled
        h.schedule_table(&table, vec![2u32, 0, 99, 4]);
        assert_eq!(h.stats().scheduled, 3);
        assert!(
            poll_until(
                || {
                    let s = h.stats();
                    s.prehits + s.fetched_local + s.stolen + s.failed >= 3
                },
                3000
            ),
            "{:?}",
            h.stats()
        );
        let mut claimed = 0;
        for i in [2usize, 0, 4] {
            if let Some(pin) = h.wait(&paths[i]) {
                assert_eq!(&pin[..], &vec![(i % 251) as u8; 64 + i][..]);
                shared.cache.release(&paths[i], &pin);
                claimed += 1;
            }
        }
        assert_eq!(h.stats().claimed, claimed);
        // a path in the table but never scheduled falls straight back
        assert!(h.wait(&paths[1]).is_none());
        drop(pf);
        assert_eq!(shared.cache.resident_files(), 0);
    }

    #[test]
    fn wait_during_shutdown_returns_fallback() {
        let (shared, tp, paths) = one_node(2);
        let pf = Prefetcher::spawn(
            0,
            Arc::clone(&shared),
            tp,
            PrefetchConfig {
                window: 2,
                fetchers: 1,
            },
        );
        let h = pf.handle();
        drop(pf);
        h.schedule(paths.iter().cloned()); // ignored after shutdown
        assert!(h.wait(&paths[0]).is_none());
        assert_eq!(h.stats().scheduled, 0);
        assert_eq!(shared.cache.resident_files(), 0);
    }
}
