//! Cluster / workload configuration.
//!
//! A real deployment of this repo is driven either programmatically (the
//! examples) or from the CLI (`fanstore --nodes 4 ...`).  Options map 1:1 to
//! the knobs the paper exposes: node count, partition count, replication
//! factor, compression on/off + level, and the replicated-directory list.

use crate::compress::{Codec, CompressPolicy};
use crate::error::{FanError, Result};
use crate::storage::disk::SpillReadMode;
use crate::storage::placement::PlacementKind;

/// Which fabric the cluster's request/response protocol runs over.  The
/// node workers, VFS clients and prefetchers are identical either way —
/// they program against `dyn Transport`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    /// mpsc channels inside one process (the MPI stand-in).
    #[default]
    InProc,
    /// Real TCP sockets on 127.0.0.1 — every remote read crosses the
    /// kernel socket stack with the wire codec, one listener per node.
    TcpLoopback,
}

impl TransportKind {
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::InProc => "inproc",
            TransportKind::TcpLoopback => "tcp-loopback",
        }
    }
}

/// In-process cluster bring-up options (paper §5.2/§5.4 knobs).
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Number of FanStore nodes (one worker thread each).
    pub nodes: u32,
    /// Number of partitions the dataset is packed into; the paper uses 48
    /// (GPU cluster) and 512 (CPU cluster).
    pub partitions: u32,
    /// Input replication factor N: each node hosts N different partitions
    /// (§5.4); `nodes` = broadcast.
    pub replication: u32,
    /// Compression codec applied at prep time.
    pub codec: Codec,
    /// Per-extension policy deciding which files `codec` actually applies
    /// to — entropy-coded formats (JPEG, PNG, ...) are stored raw because
    /// recompressing them wastes CPU for no size win (paper §6.6).
    pub compress_policy: CompressPolicy,
    /// Mount-point prefix of the global namespace (§5.2).
    pub mount: String,
    /// Dataset-relative directories replicated to every node (§5.4 — the
    /// test set, read completely by each process at validation).
    pub replicate_dirs: Vec<String>,
    /// Spill partitions to this directory (real file I/O) instead of RAM.
    pub spill_dir: Option<String>,
    /// How spilled partitions are read back: zero-syscall `Mmap`, pooled
    /// positioned `Pread` (default), or the `Reopen` baseline (only
    /// meaningful with `spill_dir`; see `storage::disk::SpillReadMode`).
    pub spill_read_mode: SpillReadMode,
    /// Lock-shard count of each node's refcount cache (contention knob,
    /// never semantics; see `cache::ShardedCache`).
    pub cache_shards: usize,
    /// Per-node prefetch engine: how many fetched-but-unclaimed files may
    /// be pinned in the cache at once (the in-flight window / pin budget).
    pub prefetch_window: usize,
    /// Per-node prefetch engine: background fetcher-thread count (the
    /// paper's §5.4 worker threads that overlap fetch with compute).
    pub prefetch_fetchers: usize,
    /// Fabric the cluster's protocol runs over (mpsc vs loopback TCP).
    pub transport: TransportKind,
    /// How many times a single logical read may be re-routed to another
    /// live holder before degrading to an error (`--retry-budget`).
    pub retry_budget: u32,
    /// Bounded per-call reply wait in milliseconds (`--call-timeout-ms`);
    /// `0` waits forever (the pre-PR-7 behavior).
    pub call_timeout_ms: u64,
    /// Per-node RAM-tier byte budget for heat-based placement
    /// (`--ram-budget`); `0` disables dynamic tiering entirely.
    pub ram_budget_bytes: u64,
    /// Which placement policy drives RAM↔spill migration (`--placement`).
    pub tier_policy: PlacementKind,
    /// Background migrator tick interval in milliseconds
    /// (`--migrate-interval-ms`); `0` disables the thread (tests and
    /// benches drive `NodeShared::migrate_tick` directly instead).
    pub migrate_interval_ms: u64,
    /// Background recovery (keepalive prober + re-replicator) tick interval
    /// in milliseconds (`--probe-interval-ms`); `0` disables the thread
    /// (tests drive `NodeShared::probe_tick`/`repair_tick` directly).
    pub probe_interval_ms: u64,
    /// At most this many repair transfers (partition pulls, reseeds, output
    /// re-commits) start per repair tick (`--repair-max-inflight`) — the
    /// throttle that keeps re-replication from starving training reads.
    pub repair_max_inflight: u32,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            nodes: 4,
            partitions: 8,
            replication: 1,
            codec: Codec::None,
            compress_policy: CompressPolicy::default(),
            mount: "/fanstore/user".into(),
            replicate_dirs: Vec::new(),
            spill_dir: None,
            spill_read_mode: SpillReadMode::default(),
            cache_shards: crate::cache::CACHE_SHARDS,
            prefetch_window: 64,
            prefetch_fetchers: 4,
            transport: TransportKind::InProc,
            retry_budget: 2,
            call_timeout_ms: 5000,
            ram_budget_bytes: 0,
            tier_policy: PlacementKind::Noop,
            migrate_interval_ms: 0,
            probe_interval_ms: 0,
            repair_max_inflight: 2,
        }
    }
}

impl ClusterConfig {
    pub fn validate(&self) -> Result<()> {
        if self.nodes == 0 {
            return Err(FanError::Config("nodes must be > 0".into()));
        }
        if self.partitions == 0 {
            return Err(FanError::Config("partitions must be > 0".into()));
        }
        if self.replication == 0 || self.replication > self.nodes {
            return Err(FanError::Config(format!(
                "replication must be in 1..={}",
                self.nodes
            )));
        }
        if !self.mount.starts_with('/') {
            return Err(FanError::Config("mount must be absolute".into()));
        }
        if self.cache_shards == 0 || self.cache_shards > 4096 {
            return Err(FanError::Config(format!(
                "cache_shards must be in 1..=4096, got {}",
                self.cache_shards
            )));
        }
        if self.prefetch_fetchers == 0 || self.prefetch_fetchers > 128 {
            return Err(FanError::Config(format!(
                "prefetch_fetchers must be in 1..=128, got {}",
                self.prefetch_fetchers
            )));
        }
        if self.retry_budget > 64 {
            return Err(FanError::Config(format!(
                "retry_budget must be <= 64, got {}",
                self.retry_budget
            )));
        }
        if self.ram_budget_bytes > 0 && self.spill_dir.is_none() {
            return Err(FanError::Config(
                "--ram-budget needs --spill-dir: without a spill tier there \
                 is nowhere to demote cold partitions to"
                    .into(),
            ));
        }
        if self.repair_max_inflight == 0 || self.repair_max_inflight > 64 {
            return Err(FanError::Config(format!(
                "repair_max_inflight must be in 1..=64, got {}",
                self.repair_max_inflight
            )));
        }
        if self.prefetch_window < self.prefetch_fetchers {
            return Err(FanError::Config(format!(
                "prefetch_window ({}) must be >= prefetch_fetchers ({}) or the \
                 extra fetcher threads can never hold work",
                self.prefetch_window, self.prefetch_fetchers
            )));
        }
        Ok(())
    }
}

/// Tiny `key=value` argument parser for the CLI (no clap in the vendor set).
pub struct ArgMap {
    pairs: Vec<(String, String)>,
    pub positional: Vec<String>,
}

impl ArgMap {
    pub fn parse(args: &[String]) -> Self {
        let mut pairs = Vec::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    pairs.push((k.to_string(), v.to_string()));
                } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    pairs.push((rest.to_string(), args[i + 1].clone()));
                    i += 1;
                } else {
                    pairs.push((rest.to_string(), "true".to_string()));
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        ArgMap { pairs, positional }
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.pairs
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    pub fn get_u32(&self, key: &str, default: u32) -> Result<u32> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| FanError::Config(format!("--{key} expects an integer, got {v}"))),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| FanError::Config(format!("--{key} expects an integer, got {v}"))),
        }
    }

    pub fn get_flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn default_config_is_valid() {
        ClusterConfig::default().validate().unwrap();
    }

    #[test]
    fn bad_replication_rejected() {
        let cfg = ClusterConfig {
            replication: 9,
            nodes: 4,
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn prefetch_and_shard_knobs_validated() {
        for bad in [
            ClusterConfig {
                cache_shards: 0,
                ..Default::default()
            },
            ClusterConfig {
                cache_shards: 5000,
                ..Default::default()
            },
            ClusterConfig {
                prefetch_fetchers: 0,
                ..Default::default()
            },
            ClusterConfig {
                prefetch_window: 2,
                prefetch_fetchers: 8,
                ..Default::default()
            },
            ClusterConfig {
                retry_budget: 65,
                ..Default::default()
            },
            ClusterConfig {
                ram_budget_bytes: 1 << 20,
                spill_dir: None,
                ..Default::default()
            },
            ClusterConfig {
                repair_max_inflight: 0,
                ..Default::default()
            },
            ClusterConfig {
                repair_max_inflight: 65,
                ..Default::default()
            },
        ] {
            assert!(bad.validate().is_err(), "{bad:?} must be rejected");
        }
        let ok = ClusterConfig {
            cache_shards: 1,
            prefetch_window: 8,
            prefetch_fetchers: 8,
            ..Default::default()
        };
        ok.validate().unwrap();
    }

    #[test]
    fn argmap_forms() {
        let m = ArgMap::parse(&sv(&[
            "bench", "--nodes=8", "--codec", "lzss", "--verbose", "--level", "5",
        ]));
        assert_eq!(m.positional, vec!["bench"]);
        assert_eq!(m.get("nodes"), Some("8"));
        assert_eq!(m.get("codec"), Some("lzss"));
        assert_eq!(m.get("level"), Some("5"));
        assert!(m.get_flag("verbose"));
        assert_eq!(m.get_u32("nodes", 1).unwrap(), 8);
        assert_eq!(m.get_u32("missing", 3).unwrap(), 3);
        assert!(m.get_u32("codec", 0).is_err());
    }

    #[test]
    fn last_value_wins() {
        let m = ArgMap::parse(&sv(&["--n=1", "--n=2"]));
        assert_eq!(m.get("n"), Some("2"));
    }
}
