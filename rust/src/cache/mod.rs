//! The refcounted file cache (paper §5.4).
//!
//! "FanStore implements an easier caching mechanism: a file is cached in
//! memory until the file descriptor is released. ... FanStore maintains a
//! file counter table in memory with file path as the key and the number of
//! processes that are currently accessing it as the value. ... If the
//! counter is zero, the file content is evicted from cache."
//!
//! The design goal is minimal RAM (training processes are memory hungry),
//! not hit rate — uniform-random access defeats LRU anyway (§5.4).

use std::collections::HashMap;
use std::sync::Arc;

/// Cache statistics for the experiment reports.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub resident_bytes: u64,
    pub peak_bytes: u64,
}

struct Entry {
    data: Arc<Vec<u8>>,
    refcount: u32,
}

/// Refcount cache: entries live exactly while at least one fd references
/// them.  Shared decompressed content is handed out as `Arc` so simultaneous
/// readers on the same node share one buffer ("multiple training processes
/// on the same node can access the same file simultaneously").
#[derive(Default)]
pub struct RefCountCache {
    entries: HashMap<String, Entry>,
    stats: CacheStats,
}

impl RefCountCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Try to pin `path`; on hit the refcount rises and the content is
    /// returned.  On miss the caller must fetch and call [`insert`].
    pub fn acquire(&mut self, path: &str) -> Option<Arc<Vec<u8>>> {
        match self.entries.get_mut(path) {
            Some(e) => {
                e.refcount += 1;
                self.stats.hits += 1;
                Some(Arc::clone(&e.data))
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Insert freshly-fetched content with refcount 1 and return the shared
    /// handle.  If another thread inserted in the meantime, the existing
    /// entry wins (its refcount rises instead).
    pub fn insert(&mut self, path: &str, data: Vec<u8>) -> Arc<Vec<u8>> {
        if let Some(e) = self.entries.get_mut(path) {
            e.refcount += 1;
            return Arc::clone(&e.data);
        }
        let len = data.len() as u64;
        let arc = Arc::new(data);
        self.entries.insert(
            path.to_string(),
            Entry {
                data: Arc::clone(&arc),
                refcount: 1,
            },
        );
        self.stats.resident_bytes += len;
        self.stats.peak_bytes = self.stats.peak_bytes.max(self.stats.resident_bytes);
        arc
    }

    /// Drop one reference; evicts the content at zero (fd release, §5.4).
    pub fn release(&mut self, path: &str) {
        let evict = match self.entries.get_mut(path) {
            Some(e) => {
                e.refcount = e.refcount.saturating_sub(1);
                e.refcount == 0
            }
            None => false,
        };
        if evict {
            if let Some(e) = self.entries.remove(path) {
                self.stats.resident_bytes -= e.data.len() as u64;
                self.stats.evictions += 1;
            }
        }
    }

    pub fn refcount(&self, path: &str) -> u32 {
        self.entries.get(path).map(|e| e.refcount).unwrap_or(0)
    }

    pub fn resident_files(&self) -> usize {
        self.entries.len()
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_then_insert_then_hit() {
        let mut c = RefCountCache::new();
        assert!(c.acquire("/f").is_none());
        c.insert("/f", vec![1, 2, 3]);
        let d = c.acquire("/f").expect("hit");
        assert_eq!(*d, vec![1, 2, 3]);
        assert_eq!(c.refcount("/f"), 2);
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn eviction_at_zero_refcount_only() {
        let mut c = RefCountCache::new();
        c.insert("/f", vec![0; 100]);
        c.acquire("/f").unwrap(); // rc = 2
        c.release("/f"); // rc = 1, still resident
        assert_eq!(c.resident_files(), 1);
        c.release("/f"); // rc = 0 -> evicted
        assert_eq!(c.resident_files(), 0);
        assert_eq!(c.stats().evictions, 1);
        assert_eq!(c.stats().resident_bytes, 0);
        assert!(c.acquire("/f").is_none());
    }

    #[test]
    fn concurrent_insert_coalesces() {
        let mut c = RefCountCache::new();
        let a = c.insert("/f", vec![1]);
        let b = c.insert("/f", vec![9, 9, 9]); // loser: existing entry wins
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(*b, vec![1]);
        assert_eq!(c.refcount("/f"), 2);
    }

    #[test]
    fn peak_bytes_tracks_high_water() {
        let mut c = RefCountCache::new();
        c.insert("/a", vec![0; 1000]);
        c.insert("/b", vec![0; 500]);
        c.release("/a");
        assert_eq!(c.stats().resident_bytes, 500);
        assert_eq!(c.stats().peak_bytes, 1500);
    }

    #[test]
    fn release_unknown_is_noop() {
        let mut c = RefCountCache::new();
        c.release("/nope");
        assert_eq!(c.stats().evictions, 0);
    }

    #[test]
    fn property_refcount_never_leaks() {
        crate::util::proptest_lite::check("cache refcount", 0xCACE, 30, |rng| {
            let mut c = RefCountCache::new();
            let paths = ["/a", "/b", "/c", "/d"];
            let mut live: Vec<&str> = Vec::new();
            for _ in 0..200 {
                let p = paths[rng.index(paths.len())];
                if rng.chance(0.55) {
                    if c.acquire(p).is_none() {
                        c.insert(p, vec![0; rng.index(64)]);
                    }
                    live.push(p);
                } else if let Some(pos) = live.iter().position(|&q| q == p) {
                    live.remove(pos);
                    c.release(p);
                }
            }
            // drain: after releasing everything, cache must be empty
            for p in live.drain(..) {
                c.release(p);
            }
            crate::prop_assert!(
                c.resident_files() == 0,
                "cache retained {} files after all releases",
                c.resident_files()
            );
            Ok(())
        });
    }
}
