//! The refcounted file cache (paper §5.4).
//!
//! "FanStore implements an easier caching mechanism: a file is cached in
//! memory until the file descriptor is released. ... FanStore maintains a
//! file counter table in memory with file path as the key and the number of
//! processes that are currently accessing it as the value. ... If the
//! counter is zero, the file content is evicted from cache."
//!
//! The design goal is minimal RAM (training processes are memory hungry),
//! not hit rate — uniform-random access defeats LRU anyway (§5.4).
//!
//! Two layers live here:
//!
//! * [`RefCountCache`] — the single-lock-domain refcount table.  Payloads
//!   are [`Payload`] handles (owned buffer or zero-copy region view) so a
//!   hit hands back a shared view of one buffer with no copy ("multiple
//!   training processes on the same node can access the same file
//!   simultaneously"); an mmap-backed entry keeps its region mapped for
//!   exactly as long as it is resident or pinned.
//! * [`ShardedCache`] — N independent `Mutex<RefCountCache>` shards keyed
//!   by a path hash.  Concurrent trainers on one node acquire/release
//!   different files without serializing on a single node-global lock;
//!   same-file accesses only contend with each other.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock, RwLock};

use crate::error::{FanError, Result};
use crate::storage::payload::Payload;

/// Cache statistics for the experiment reports.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub resident_bytes: u64,
    pub peak_bytes: u64,
}

struct Entry {
    data: Payload,
    refcount: u32,
}

/// Refcount cache: entries live exactly while at least one fd references
/// them.  Shared decompressed content is handed out as [`Payload`] handles
/// so simultaneous readers on the same node share one buffer (or one
/// mapped region view — no copy either way).
///
/// Releases are generation-aware: a pin is the handle handed out by
/// `acquire`/`insert`, and [`Self::release`] only decrements the entry
/// whose buffer is [`Payload::same`]-identical to that pin.  A release presented
/// against a retired generation (the entry was [`Self::invalidate`]d or
/// [`Self::retire`]d and possibly replaced) is a no-op, so stale
/// descriptors can never evict a newer entry that reuses the path.
///
/// Keys are `Arc<str>`: an insert fed a path that already lives in an
/// `Arc` (the wire decoder's per-connection interner hands those out)
/// shares that allocation instead of copying the path into a fresh
/// `String` per resident entry.
#[derive(Default)]
pub struct RefCountCache {
    entries: HashMap<Arc<str>, Entry>,
    stats: CacheStats,
}

impl RefCountCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Try to pin `path`; on hit the refcount rises and the content is
    /// returned.  On miss the caller must fetch and call [`Self::insert`].
    pub fn acquire(&mut self, path: &str) -> Option<Payload> {
        match self.entries.get_mut(path) {
            Some(e) => {
                e.refcount += 1;
                self.stats.hits += 1;
                Some(e.data.clone())
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Insert freshly-fetched content with refcount 1 and return the shared
    /// handle.  If another thread inserted in the meantime, the existing
    /// entry wins (its refcount rises instead).  Passing an `Arc<str>`
    /// (e.g. an interned wire path) keys the entry on that allocation —
    /// no per-entry path copy.
    pub fn insert(&mut self, path: impl Into<Arc<str>>, data: Payload) -> Payload {
        let key: Arc<str> = path.into();
        if let Some(e) = self.entries.get_mut(&*key) {
            e.refcount += 1;
            return e.data.clone();
        }
        let len = data.len() as u64;
        self.entries.insert(
            key,
            Entry {
                data: data.clone(),
                refcount: 1,
            },
        );
        self.stats.resident_bytes += len;
        self.stats.peak_bytes = self.stats.peak_bytes.max(self.stats.resident_bytes);
        data
    }

    /// Drop one reference — `pin` is the handle this pinner got from
    /// `acquire`/`insert`; evicts the content at zero (fd release, §5.4).
    /// A pin from a retired generation matches nothing and is a no-op.
    pub fn release(&mut self, path: &str, pin: &Payload) {
        let evict = match self.entries.get_mut(path) {
            Some(e) if e.data.same(pin) => {
                e.refcount = e.refcount.saturating_sub(1);
                e.refcount == 0
            }
            _ => false,
        };
        if evict {
            if let Some(e) = self.entries.remove(path) {
                self.stats.resident_bytes -= e.data.len() as u64;
                self.stats.evictions += 1;
            }
        }
    }

    /// Drop the entry regardless of refcount (`unlink` invalidation).
    /// Outstanding handles stay valid; their eventual releases mismatch
    /// the (gone or replaced) entry and are no-ops.
    pub fn invalidate(&mut self, path: &str) {
        if let Some(e) = self.entries.remove(path) {
            self.stats.resident_bytes -= e.data.len() as u64;
            self.stats.evictions += 1;
        }
    }

    /// Atomic stale-refresh step: drop our pin on `stale` and remove the
    /// entry only if it still holds that generation.  If another thread
    /// already refreshed the path (entry absent or newer), both our pin and
    /// the removal are moot — a single call under one lock, so concurrent
    /// refreshers can't clobber each other's fresh inserts.
    pub fn retire(&mut self, path: &str, stale: &Payload) {
        let matches = self
            .entries
            .get(path)
            .map(|e| e.data.same(stale))
            .unwrap_or(false);
        if matches {
            self.invalidate(path);
        }
    }

    pub fn refcount(&self, path: &str) -> u32 {
        self.entries.get(path).map(|e| e.refcount).unwrap_or(0)
    }

    /// Residency peek without pinning (no hit/miss accounting).
    pub fn contains(&self, path: &str) -> bool {
        self.entries.contains_key(path)
    }

    pub fn resident_files(&self) -> usize {
        self.entries.len()
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }
}

/// Default number of lock shards.  Chosen to exceed the trainer-thread
/// counts the paper runs per node (up to 68 processes/node on KNL, but 8–16
/// active readers is typical) while keeping the merge cost of `stats()`
/// trivial.  Tunable per cluster via
/// [`crate::config::ClusterConfig::cache_shards`].
pub const CACHE_SHARDS: usize = 16;

/// Hash-sharded refcount cache: the node-wide cache used by [`crate::node`].
///
/// Each shard is an independent lock domain, so acquire/release traffic
/// from K trainer threads only serializes when two threads touch paths in
/// the same shard (1/shards of the time under uniform access).
pub struct ShardedCache {
    shards: Vec<Mutex<RefCountCache>>,
}

impl Default for ShardedCache {
    fn default() -> Self {
        Self::new()
    }
}

impl ShardedCache {
    /// Cache with the default [`CACHE_SHARDS`] lock domains.
    pub fn new() -> Self {
        Self::with_shards(CACHE_SHARDS)
    }

    /// Cache with `n` lock domains (validated at cluster build time; any
    /// n ≥ 1 is correct — it only changes contention, never semantics).
    pub fn with_shards(n: usize) -> Self {
        assert!(n > 0, "cache needs at least one shard");
        ShardedCache {
            shards: (0..n).map(|_| Mutex::new(RefCountCache::new())).collect(),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Shard index by the crate's stable FNV-1a path hash — good enough to
    /// spread realistic dataset paths across the shards.
    fn shard(&self, path: &str) -> std::sync::MutexGuard<'_, RefCountCache> {
        let i = (crate::metadata::placement::path_hash(path) % self.shards.len() as u64) as usize;
        self.shards[i].lock().unwrap()
    }

    pub fn acquire(&self, path: &str) -> Option<Payload> {
        self.shard(path).acquire(path)
    }

    pub fn insert(&self, path: impl Into<Arc<str>>, data: Payload) -> Payload {
        let key: Arc<str> = path.into();
        let mut shard = self.shard(&key);
        shard.insert(key, data)
    }

    pub fn release(&self, path: &str, pin: &Payload) {
        self.shard(path).release(path, pin)
    }

    pub fn invalidate(&self, path: &str) {
        self.shard(path).invalidate(path)
    }

    pub fn retire(&self, path: &str, stale: &Payload) {
        self.shard(path).retire(path, stale)
    }

    pub fn refcount(&self, path: &str) -> u32 {
        self.shard(path).refcount(path)
    }

    /// Residency peek without pinning (no hit/miss accounting).
    pub fn contains(&self, path: &str) -> bool {
        self.shard(path).contains(path)
    }

    pub fn resident_files(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().resident_files())
            .sum()
    }

    /// Merged statistics across shards.  `peak_bytes` is the sum of the
    /// per-shard peaks — an upper bound on the true node-wide peak (shards
    /// need not peak simultaneously).
    pub fn stats(&self) -> CacheStats {
        let mut out = CacheStats::default();
        for s in &self.shards {
            let st = s.lock().unwrap().stats();
            out.hits += st.hits;
            out.misses += st.misses;
            out.evictions += st.evictions;
            out.resident_bytes += st.resident_bytes;
            out.peak_bytes += st.peak_bytes;
        }
        out
    }
}

/// Default entry cap for [`DecodedCache`].  Decoded payloads are raw
/// (expanded) bytes, so the cap bounds worst-case RAM at roughly
/// `cap × max_file_size` — small on purpose: the cache only needs to
/// cover the *concurrently hot* files, not the dataset.
pub const DECODED_CACHE_CAP: usize = 32;

/// One decoded file: the stored-form pin it was decoded from (the
/// generation key) and the once-cell the decode lands in.
struct DecodedEntry {
    stored: Payload,
    cell: Arc<OnceLock<std::result::Result<Payload, String>>>,
}

/// Decoded-payload side cache (PR 8 satellite): pin-identity-keyed, so N
/// concurrent `open()`s of one hot compressed file cost **one**
/// decompression instead of N.
///
/// The key insight is that the refcount cache already gives every reader
/// of a resident file the *same* stored-form pin ([`Payload::same`]
/// identity).  This cache maps `path → (that pin, decoded bytes)`: the
/// first pickup decodes into the entry's once-cell while concurrent
/// pickups of the same pin block on [`OnceLock::get_or_init`] and then
/// clone the decoded handle (an `Arc` clone, no copy).  A *different* pin
/// for the same path means the refcount-cache generation turned over
/// (invalidate/retire + refetch) — the stale entry is replaced, so the
/// cache can never serve bytes from a retired generation.
///
/// Failed decodes are not cached: the entry is removed so a later pickup
/// retries (corruption is generally transient here — a torn spill read).
/// At [`DecodedCache::cap`] entries the map is cleared wholesale; the
/// next pickups simply re-decode, trading a rare burst of repeat work for
/// zero bookkeeping on the hot path.
pub struct DecodedCache {
    cap: usize,
    map: RwLock<HashMap<Arc<str>, DecodedEntry>>,
}

impl Default for DecodedCache {
    fn default() -> Self {
        Self::new()
    }
}

impl DecodedCache {
    pub fn new() -> Self {
        Self::with_capacity(DECODED_CACHE_CAP)
    }

    pub fn with_capacity(cap: usize) -> Self {
        assert!(cap > 0, "decoded cache needs at least one slot");
        DecodedCache {
            cap,
            map: RwLock::new(HashMap::new()),
        }
    }

    pub fn resident_files(&self) -> usize {
        self.map.read().unwrap().len()
    }

    /// Decoded bytes for `pin` (the stored-form handle of `path`), running
    /// `decode` at most once per (path, pin generation) across any number
    /// of concurrent callers.  Returns the decoded payload and whether it
    /// was a cache hit (`decode` did not run in this call).
    pub fn get_or_decode(
        &self,
        path: &str,
        pin: &Payload,
        decode: impl FnOnce() -> Result<Payload>,
    ) -> Result<(Payload, bool)> {
        // fast path: current-generation entry already present
        let cell = {
            let map = self.map.read().unwrap();
            map.get(path)
                .filter(|e| e.stored.same(pin))
                .map(|e| Arc::clone(&e.cell))
        };
        let cell = match cell {
            Some(cell) => cell,
            None => {
                let mut map = self.map.write().unwrap();
                // re-check under the write lock: another pickup may have
                // installed this generation while we waited
                match map.get(path) {
                    Some(e) if e.stored.same(pin) => Arc::clone(&e.cell),
                    _ => {
                        if map.len() >= self.cap && !map.contains_key(path) {
                            map.clear();
                        }
                        let cell = Arc::new(OnceLock::new());
                        map.insert(
                            Arc::from(path),
                            DecodedEntry {
                                stored: pin.clone(),
                                cell: Arc::clone(&cell),
                            },
                        );
                        cell
                    }
                }
            }
        };
        // outside every lock: exactly one caller runs the decode, the rest
        // block on the cell and then share the decoded Arc
        let mut ran = false;
        let out = cell.get_or_init(|| {
            ran = true;
            decode().map_err(|e| e.to_string())
        });
        match out {
            Ok(decoded) => Ok((decoded.clone(), !ran)),
            Err(msg) => {
                // do not cache failures: drop the entry (only if it still
                // holds this cell) so a later pickup retries
                let mut map = self.map.write().unwrap();
                if let Some(e) = map.get(path) {
                    if Arc::ptr_eq(&e.cell, &cell) {
                        map.remove(path);
                    }
                }
                Err(FanError::Format(msg.clone()))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn miss_then_insert_then_hit() {
        let mut c = RefCountCache::new();
        assert!(c.acquire("/f").is_none());
        c.insert("/f", vec![1, 2, 3].into());
        let d = c.acquire("/f").expect("hit");
        assert_eq!(&d[..], &[1, 2, 3]);
        assert_eq!(c.refcount("/f"), 2);
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn eviction_at_zero_refcount_only() {
        let mut c = RefCountCache::new();
        let pin = c.insert("/f", vec![0; 100].into());
        c.acquire("/f").unwrap(); // rc = 2
        c.release("/f", &pin); // rc = 1, still resident
        assert_eq!(c.resident_files(), 1);
        c.release("/f", &pin); // rc = 0 -> evicted
        assert_eq!(c.resident_files(), 0);
        assert_eq!(c.stats().evictions, 1);
        assert_eq!(c.stats().resident_bytes, 0);
        assert!(c.acquire("/f").is_none());
    }

    #[test]
    fn concurrent_insert_coalesces() {
        let mut c = RefCountCache::new();
        let a = c.insert("/f", vec![1].into());
        let b = c.insert("/f", vec![9, 9, 9].into()); // loser: existing entry wins
        assert!(a.same(&b));
        assert_eq!(&b[..], &[1]);
        assert_eq!(c.refcount("/f"), 2);
    }

    #[test]
    fn peak_bytes_tracks_high_water() {
        let mut c = RefCountCache::new();
        let a = c.insert("/a", vec![0; 1000].into());
        c.insert("/b", vec![0; 500].into());
        c.release("/a", &a);
        assert_eq!(c.stats().resident_bytes, 500);
        assert_eq!(c.stats().peak_bytes, 1500);
    }

    #[test]
    fn arc_keys_interop_with_str_lookups() {
        // an interned Arc<str> key and plain &str lookups address the same
        // entry (Borrow<str> path), in both cache layers
        let mut c = RefCountCache::new();
        let key: Arc<str> = Arc::from("/interned/f1");
        let pin = c.insert(Arc::clone(&key), vec![3; 4].into());
        let hit = c.acquire("/interned/f1").expect("str lookup finds arc key");
        assert!(pin.same(&hit));
        assert_eq!(c.refcount(&key), 2);
        c.release(&key, &pin);
        c.release("/interned/f1", &hit);
        assert_eq!(c.resident_files(), 0);

        let s = ShardedCache::new();
        let pin = s.insert(Arc::clone(&key), vec![4; 4].into());
        assert!(s.acquire("/interned/f1").is_some());
        s.release(&key, &pin);
        s.release(&key, &pin);
        assert_eq!(s.resident_files(), 0);
    }

    #[test]
    fn release_unknown_is_noop() {
        let mut c = RefCountCache::new();
        let stray: Payload = vec![1u8].into();
        c.release("/nope", &stray);
        assert_eq!(c.stats().evictions, 0);
    }

    #[test]
    fn invalidate_drops_pinned_entry() {
        let mut c = RefCountCache::new();
        let d = c.insert("/f", vec![7; 10].into());
        c.invalidate("/f");
        assert_eq!(c.resident_files(), 0);
        assert_eq!(c.stats().resident_bytes, 0);
        // outstanding handle still readable; its release mismatches
        // (generation gone) and is a no-op
        assert_eq!(&d[..], &[7; 10][..]);
        c.release("/f", &d);
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn stale_release_cannot_evict_newer_generation() {
        let mut c = RefCountCache::new();
        // fd1 pins the first generation of /f, which is then invalidated
        let old = c.insert("/f", vec![1; 8].into());
        c.invalidate("/f");
        // a new generation of /f is written and pinned by fd2
        let new = c.insert("/f", vec![2; 8].into());
        // fd1 closes: its pin is from the retired generation -> no-op
        c.release("/f", &old);
        assert_eq!(c.refcount("/f"), 1, "fd2 still pins the new entry");
        let again = c.acquire("/f").expect("new entry resident");
        assert!(new.same(&again));
        c.release("/f", &new);
        c.release("/f", &again); // fd2 + the acquire above
        assert_eq!(c.resident_files(), 0);
    }

    #[test]
    fn retire_is_generation_aware() {
        let mut c = RefCountCache::new();
        let stale = c.insert("/f", vec![1; 8].into());
        // refresher A retires the stale generation and inserts fresh data
        c.retire("/f", &stale);
        assert_eq!(c.resident_files(), 0);
        let fresh = c.insert("/f", vec![2; 8].into());
        // refresher B, still holding the stale pin, retires after A: the
        // entry no longer matches, so A's fresh insert survives
        c.retire("/f", &stale);
        assert_eq!(c.refcount("/f"), 1, "fresh entry untouched");
        c.release("/f", &fresh);
        assert_eq!(c.resident_files(), 0);
    }

    #[test]
    fn property_refcount_never_leaks() {
        crate::util::proptest_lite::check("cache refcount", 0xCACE, 30, |rng| {
            let mut c = RefCountCache::new();
            let paths = ["/a", "/b", "/c", "/d"];
            let mut live: Vec<(&str, Payload)> = Vec::new();
            for _ in 0..200 {
                let p = paths[rng.index(paths.len())];
                if rng.chance(0.55) {
                    let pin = match c.acquire(p) {
                        Some(d) => d,
                        None => c.insert(p, vec![0; rng.index(64)].into()),
                    };
                    live.push((p, pin));
                } else if let Some(pos) = live.iter().position(|(q, _)| *q == p) {
                    let (p, pin) = live.remove(pos);
                    c.release(p, &pin);
                }
            }
            // drain: after releasing everything, cache must be empty
            for (p, pin) in live.drain(..) {
                c.release(p, &pin);
            }
            crate::prop_assert!(
                c.resident_files() == 0,
                "cache retained {} files after all releases",
                c.resident_files()
            );
            Ok(())
        });
    }

    #[test]
    fn sharded_cache_shares_entries_across_handles() {
        let c = ShardedCache::new();
        assert!(c.acquire("/x").is_none());
        let a = c.insert("/x", vec![5; 32].into());
        let b = c.acquire("/x").expect("hit");
        assert!(a.same(&b));
        assert_eq!(c.refcount("/x"), 2);
        c.release("/x", &a);
        c.release("/x", &b);
        assert_eq!(c.resident_files(), 0);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 1, 1));
    }

    #[test]
    fn sharded_cache_any_shard_count_is_correct() {
        for n in [1usize, 3, 16, 64] {
            let c = ShardedCache::with_shards(n);
            assert_eq!(c.shard_count(), n);
            let pins: Vec<_> = (0..40)
                .map(|i| {
                    let p = format!("/s{i}");
                    (p.clone(), c.insert(p.as_str(), vec![i as u8; 8].into()))
                })
                .collect();
            assert_eq!(c.resident_files(), 40);
            for (p, pin) in &pins {
                assert!(c.acquire(p).is_some());
                c.release(p, pin);
                c.release(p, pin);
            }
            assert_eq!(c.resident_files(), 0, "{n} shards must drain");
        }
    }

    #[test]
    #[should_panic]
    fn zero_shards_rejected() {
        let _ = ShardedCache::with_shards(0);
    }

    #[test]
    fn decoded_cache_decodes_once_per_generation() {
        let c = DecodedCache::new();
        let path: Arc<str> = Arc::from("/f");
        let pin: Payload = vec![1u8; 8].into();
        let mut decodes = 0;
        let (a, hit) = c
            .get_or_decode(&path, &pin, || {
                decodes += 1;
                Ok(vec![9u8; 32].into())
            })
            .unwrap();
        assert!(!hit);
        assert_eq!(decodes, 1);
        let (b, hit) = c
            .get_or_decode(&path, &pin, || {
                decodes += 1;
                Ok(vec![0u8; 32].into())
            })
            .unwrap();
        assert!(hit, "same pin generation is a hit");
        assert_eq!(decodes, 1, "second pickup shares the first decode");
        assert!(a.same(&b), "both callers share one decoded allocation");
        // a NEW generation of the path (different pin) replaces the entry
        let pin2: Payload = vec![1u8; 8].into();
        assert!(!pin.same(&pin2));
        let (d, hit) = c
            .get_or_decode(&path, &pin2, || {
                decodes += 1;
                Ok(vec![7u8; 16].into())
            })
            .unwrap();
        assert!(!hit);
        assert_eq!(decodes, 2);
        assert_eq!(&d[..], &[7u8; 16][..]);
        assert_eq!(c.resident_files(), 1, "stale generation replaced in place");
    }

    #[test]
    fn decoded_cache_concurrent_pickups_share_one_decode() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let c = Arc::new(DecodedCache::new());
        let path: Arc<str> = Arc::from("/hot");
        let pin: Payload = vec![3u8; 8].into();
        let decodes = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let c = Arc::clone(&c);
            let path = Arc::clone(&path);
            let pin = pin.clone();
            let decodes = Arc::clone(&decodes);
            handles.push(std::thread::spawn(move || {
                let (d, _) = c
                    .get_or_decode(&path, &pin, || {
                        decodes.fetch_add(1, Ordering::Relaxed);
                        // slow decode widens the race window
                        std::thread::sleep(std::time::Duration::from_millis(20));
                        Ok(vec![5u8; 64].into())
                    })
                    .unwrap();
                assert_eq!(&d[..], &[5u8; 64][..]);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(
            decodes.load(Ordering::Relaxed),
            1,
            "8 concurrent pickups must cost exactly one decode"
        );
    }

    #[test]
    fn decoded_cache_does_not_cache_failures() {
        let c = DecodedCache::new();
        let path: Arc<str> = Arc::from("/f");
        let pin: Payload = vec![1u8; 4].into();
        let err = c.get_or_decode(&path, &pin, || Err(FanError::Format("torn".into())));
        assert!(err.is_err());
        assert_eq!(c.resident_files(), 0, "failure entry removed");
        // the retry runs a fresh decode and succeeds
        let (d, hit) = c
            .get_or_decode(&path, &pin, || Ok(vec![2u8; 4].into()))
            .unwrap();
        assert!(!hit);
        assert_eq!(&d[..], &[2u8; 4][..]);
    }

    #[test]
    fn decoded_cache_cap_clears_wholesale() {
        let c = DecodedCache::with_capacity(4);
        let pins: Vec<(Arc<str>, Payload)> = (0..5)
            .map(|i| (Arc::from(format!("/f{i}").as_str()), vec![i as u8; 4].into()))
            .collect();
        for (path, pin) in &pins[..4] {
            c.get_or_decode(path, pin, || Ok(vec![0u8; 8].into())).unwrap();
        }
        assert_eq!(c.resident_files(), 4);
        // the fifth insert clears and starts over
        c.get_or_decode(&pins[4].0, &pins[4].1, || Ok(vec![0u8; 8].into()))
            .unwrap();
        assert_eq!(c.resident_files(), 1);
        // a re-pickup of a cleared entry simply re-decodes
        let (_, hit) = c
            .get_or_decode(&pins[0].0, &pins[0].1, || Ok(vec![0u8; 8].into()))
            .unwrap();
        assert!(!hit);
    }

    #[test]
    fn sharded_cache_concurrent_acquire_release() {
        let c = Arc::new(ShardedCache::new());
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::prng::Prng::new(t + 1);
                for i in 0..2000u64 {
                    let path = format!("/f{}", (t * 7 + i) % 64);
                    let pin = match c.acquire(&path) {
                        Some(d) => {
                            assert!(d.iter().all(|&b| b == 9));
                            d
                        }
                        None => c.insert(path.as_str(), vec![9u8; 16 + rng.index(16)].into()),
                    };
                    c.release(&path, &pin);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.resident_files(), 0, "all refs released");
        let s = c.stats();
        assert_eq!(s.hits + s.misses, 8 * 2000);
        assert_eq!(s.resident_bytes, 0);
    }
}
