//! Crate-wide error type.
//!
//! FanStore surfaces POSIX-shaped errors (`ENOENT`, `EBADF`, …) through the
//! VFS layer — the paper's function-interception design returns glibc error
//! codes to the unmodified application — plus internal error classes for the
//! partition format, codec, transport and PJRT runtime.

use thiserror::Error;

/// Result alias used across the crate.
pub type Result<T> = std::result::Result<T, FanError>;

/// All FanStore failure modes.
#[derive(Error, Debug)]
pub enum FanError {
    /// POSIX `ENOENT`: path not present in the global namespace.
    #[error("no such file or directory: {0}")]
    NotFound(String),
    /// POSIX `EBADF`: unknown or already-closed descriptor.
    #[error("bad file descriptor: {0}")]
    BadFd(u64),
    /// POSIX `EEXIST`.
    #[error("file exists: {0}")]
    Exists(String),
    /// POSIX `EISDIR` / `ENOTDIR` mismatches.
    #[error("is a directory: {0}")]
    IsDirectory(String),
    #[error("not a directory: {0}")]
    NotDirectory(String),
    /// Multi-read single-write violation (paper §3.5): re-opening an output
    /// file for write, or writing an input file.
    #[error("consistency violation: {0}")]
    Consistency(String),
    /// Partition file is malformed (bad magic, truncated entry, …).
    #[error("partition format error: {0}")]
    Format(String),
    /// LZSS bitstream is corrupt.
    #[error("decompression error: {0}")]
    Codec(String),
    /// Simulated-transport failure (peer gone, message too large, …).
    #[error("transport error: {0}")]
    Transport(String),
    /// PJRT/XLA runtime failure.
    #[error("runtime error: {0}")]
    Runtime(String),
    /// Artifact manifest problems.
    #[error("manifest error: {0}")]
    Manifest(String),
    /// Configuration problems (bad CLI flags, invalid cluster spec).
    #[error("config error: {0}")]
    Config(String),
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl FanError {
    /// The errno the interception layer would return to the application.
    pub fn errno(&self) -> i32 {
        match self {
            FanError::NotFound(_) => libc::ENOENT,
            FanError::BadFd(_) => libc::EBADF,
            FanError::Exists(_) => libc::EEXIST,
            FanError::IsDirectory(_) => libc::EISDIR,
            FanError::NotDirectory(_) => libc::ENOTDIR,
            FanError::Consistency(_) => libc::EPERM,
            FanError::Io(e) => e.raw_os_error().unwrap_or(libc::EIO),
            _ => libc::EIO,
        }
    }
}
