//! Crate-wide error type.
//!
//! FanStore surfaces POSIX-shaped errors (`ENOENT`, `EBADF`, …) through the
//! VFS layer — the paper's function-interception design returns glibc error
//! codes to the unmodified application — plus internal error classes for the
//! partition format, codec, transport and PJRT runtime.
//!
//! Implemented against std only (no `thiserror`/`libc`): the build
//! environment is air-gapped, so the Display/Error impls and the errno
//! constants are written out by hand.

use std::fmt;

/// Result alias used across the crate.
pub type Result<T> = std::result::Result<T, FanError>;

/// Linux x86-64 errno values returned through the interception layer.
pub mod errno {
    pub const EPERM: i32 = 1;
    pub const ENOENT: i32 = 2;
    pub const EIO: i32 = 5;
    pub const EBADF: i32 = 9;
    pub const EEXIST: i32 = 17;
    pub const ENOTDIR: i32 = 20;
    pub const EISDIR: i32 = 21;
}

/// All FanStore failure modes.
#[derive(Debug)]
pub enum FanError {
    /// POSIX `ENOENT`: path not present in the global namespace.
    NotFound(String),
    /// POSIX `EBADF`: unknown or already-closed descriptor.
    BadFd(u64),
    /// POSIX `EEXIST`.
    Exists(String),
    /// POSIX `EISDIR` / `ENOTDIR` mismatches.
    IsDirectory(String),
    NotDirectory(String),
    /// Multi-read single-write violation (paper §3.5): re-opening an output
    /// file for write, or writing an input file.
    Consistency(String),
    /// Partition file is malformed (bad magic, truncated entry, …).
    Format(String),
    /// LZSS bitstream is corrupt.
    Codec(String),
    /// Simulated-transport failure (peer gone, message too large, …).
    Transport(String),
    /// PJRT/XLA runtime failure.
    Runtime(String),
    /// Artifact manifest problems.
    Manifest(String),
    /// Configuration problems (bad CLI flags, invalid cluster spec).
    Config(String),
    Io(std::io::Error),
}

impl fmt::Display for FanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FanError::NotFound(p) => write!(f, "no such file or directory: {p}"),
            FanError::BadFd(fd) => write!(f, "bad file descriptor: {fd}"),
            FanError::Exists(p) => write!(f, "file exists: {p}"),
            FanError::IsDirectory(p) => write!(f, "is a directory: {p}"),
            FanError::NotDirectory(p) => write!(f, "not a directory: {p}"),
            FanError::Consistency(m) => write!(f, "consistency violation: {m}"),
            FanError::Format(m) => write!(f, "partition format error: {m}"),
            FanError::Codec(m) => write!(f, "decompression error: {m}"),
            FanError::Transport(m) => write!(f, "transport error: {m}"),
            FanError::Runtime(m) => write!(f, "runtime error: {m}"),
            FanError::Manifest(m) => write!(f, "manifest error: {m}"),
            FanError::Config(m) => write!(f, "config error: {m}"),
            FanError::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for FanError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FanError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for FanError {
    fn from(e: std::io::Error) -> Self {
        FanError::Io(e)
    }
}

impl FanError {
    /// The errno the interception layer would return to the application.
    pub fn errno(&self) -> i32 {
        match self {
            FanError::NotFound(_) => errno::ENOENT,
            FanError::BadFd(_) => errno::EBADF,
            FanError::Exists(_) => errno::EEXIST,
            FanError::IsDirectory(_) => errno::EISDIR,
            FanError::NotDirectory(_) => errno::ENOTDIR,
            FanError::Consistency(_) => errno::EPERM,
            FanError::Io(e) => e.raw_os_error().unwrap_or(errno::EIO),
            _ => errno::EIO,
        }
    }
}
