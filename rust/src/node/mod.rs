//! A FanStore node: local partition store, replicated input metadata,
//! homed output metadata, the refcount cache, and the worker thread that
//! services peer requests (paper §5.1, Fig 2).
//!
//! In `InProc` mode every node is a worker thread plus a shared-state
//! handle; "remote" reads between nodes are real request/response messages
//! through [`crate::net::transport`] carrying the stored (possibly
//! compressed) bytes.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::cache::RefCountCache;
use crate::error::Result;
use crate::metadata::placement::Placement;
use crate::metadata::record::{FileLocation, FileMeta};
use crate::metadata::table::MetaTable;
use crate::net::transport::{NodeEndpoint, Request, Response};
use crate::storage::disk::DiskStore;

/// Per-node I/O accounting used by the experiment reports.
#[derive(Clone, Copy, Debug, Default)]
pub struct NodeStats {
    pub local_reads: u64,
    pub remote_reads_served: u64,
    pub remote_reads_issued: u64,
    pub bytes_read_local: u64,
    pub bytes_served_remote: u64,
    pub bytes_fetched_remote: u64,
    pub decompressions: u64,
    pub outputs_committed: u64,
    pub output_bytes: u64,
}

/// Mutable node state shared by the local VFS clients and the worker thread.
pub struct NodeState {
    pub id: u32,
    /// Dumped input partitions + path index (paper §5.2).
    pub store: DiskStore,
    /// Replicated input metadata — identical on every node (§5.3).
    pub input_meta: MetaTable,
    /// Output metadata homed on this node by the consistent hash (§5.3).
    pub output_meta: MetaTable,
    /// Output file bytes kept on their originating node (§5.4: the data is
    /// buffered locally; only the metadata entry is forwarded on close()).
    pub output_data: HashMap<String, Arc<Vec<u8>>>,
    /// Refcount cache of decompressed input content (§5.4).
    pub cache: RefCountCache,
    pub placement: Placement,
    pub stats: NodeStats,
}

impl NodeState {
    pub fn new(id: u32, store: DiskStore, placement: Placement) -> Self {
        NodeState {
            id,
            store,
            input_meta: MetaTable::new(),
            output_meta: MetaTable::new(),
            output_data: HashMap::new(),
            cache: RefCountCache::new(),
            placement,
            stats: NodeStats::default(),
        }
    }

    /// Serve a peer's request (also used directly for self-requests so the
    /// local path does not pay a channel round trip).
    pub fn serve(&mut self, req: &Request) -> Response {
        match req {
            Request::ReadFile { path } => match self.store.read_stored(path) {
                Ok((stored, at)) => {
                    self.stats.remote_reads_served += 1;
                    self.stats.bytes_served_remote += stored.len() as u64;
                    Response::FileData {
                        stored,
                        raw_len: at.raw_len,
                        compressed: at.compressed,
                    }
                }
                Err(_) => match self.output_data.get(path.as_str()) {
                    Some(data) => Response::FileData {
                        stored: data.as_ref().clone(),
                        raw_len: data.len() as u64,
                        compressed: false,
                    },
                    None => Response::Err(format!("ENOENT {path}")),
                },
            },
            Request::StatOutput { path } => match self.output_meta.get(path) {
                Some(m) => Response::Meta {
                    stat: m.stat,
                    origin: m.location.node,
                },
                None => Response::Err(format!("ENOENT {path}")),
            },
            Request::CommitOutput { path, meta } => {
                self.output_meta.insert(path, meta.clone());
                Response::Ok
            }
            Request::ListOutputs { dir } => match self.output_meta.readdir(dir) {
                Ok(names) => Response::Names(names.to_vec()),
                Err(_) => Response::Names(Vec::new()),
            },
            Request::Shutdown => Response::Ok,
        }
    }
}

/// Handle to a running node: shared state + its worker thread.
pub struct FanStoreNode {
    pub id: u32,
    pub state: Arc<Mutex<NodeState>>,
    worker: Option<JoinHandle<u64>>,
}

impl FanStoreNode {
    /// Spawn the worker thread servicing `endpoint`.
    pub fn spawn(state: Arc<Mutex<NodeState>>, endpoint: NodeEndpoint) -> Self {
        let id = endpoint.node_id;
        let thread_state = Arc::clone(&state);
        let worker = std::thread::Builder::new()
            .name(format!("fanstore-node-{id}"))
            .spawn(move || {
                let mut served = 0u64;
                while let Ok(msg) = endpoint.inbox.recv() {
                    if matches!(msg.req, Request::Shutdown) {
                        let _ = msg.reply.send(Response::Ok);
                        break;
                    }
                    let resp = thread_state.lock().unwrap().serve(&msg.req);
                    served += 1;
                    let _ = msg.reply.send(resp);
                }
                served
            })
            .expect("spawn node worker");
        FanStoreNode {
            id,
            state,
            worker: Some(worker),
        }
    }

    /// Join the worker (after `Transport::shutdown_all`); returns requests
    /// served.
    pub fn join(mut self) -> u64 {
        self.worker
            .take()
            .map(|h| h.join().unwrap_or(0))
            .unwrap_or(0)
    }
}

/// Load a set of partition blobs into a node's store under `mount`.
pub fn load_partitions(
    state: &mut NodeState,
    parts: impl IntoIterator<Item = (u32, Vec<u8>)>,
    mount: &str,
) -> Result<u32> {
    let mut n = 0;
    for (pid, blob) in parts {
        n += state.store.load_partition(pid, blob, mount)?;
    }
    Ok(n)
}

/// Build the replicated input-metadata table from partition blobs.
/// Every node runs this over the *full* partition list (metadata broadcast,
/// §5.3) even though it only dumps its own partitions' data.
pub fn index_input_metadata(
    table: &mut MetaTable,
    blobs: &[(u32, Vec<u8>)],
    mount: &str,
    placement: &Placement,
) -> Result<()> {
    for (pid, blob) in blobs {
        let mut reader = crate::partition::format::PartitionReader::new(blob)?;
        while let Some((e, data_off)) = reader.next_entry()? {
            let path = format!("{}/{}", mount.trim_end_matches('/'), e.name);
            table.insert(
                &path,
                FileMeta {
                    stat: e.stat,
                    location: FileLocation {
                        node: placement.partition_primary(*pid),
                        partition: *pid,
                        offset: data_off,
                        stored_len: e.stored_len(),
                        compressed: e.is_compressed(),
                    },
                },
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::metadata::record::FileStat;
    use crate::net::transport::InProcTransport;
    use crate::partition::builder::{build_partitions, InputFile};

    fn files(n: usize) -> Vec<InputFile> {
        (0..n)
            .map(|i| InputFile {
                path: format!("train/f{i}"),
                data: vec![i as u8; 100 + i],
            })
            .collect()
    }

    #[test]
    fn serve_read_local_file() {
        let fs = files(4);
        let (blobs, _) = build_partitions(&fs, 1, Codec::None).unwrap();
        let placement = Placement::new(1, 1, 1);
        let mut st = NodeState::new(0, DiskStore::in_memory(), placement);
        st.store.load_partition(0, blobs[0].clone(), "/m").unwrap();
        let resp = st.serve(&Request::ReadFile {
            path: "/m/train/f2".into(),
        });
        match resp {
            Response::FileData { stored, raw_len, compressed } => {
                assert_eq!(stored, vec![2u8; 102]);
                assert_eq!(raw_len, 102);
                assert!(!compressed);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(st.stats.remote_reads_served, 1);
    }

    #[test]
    fn serve_missing_is_error() {
        let placement = Placement::new(1, 1, 1);
        let mut st = NodeState::new(0, DiskStore::in_memory(), placement);
        assert!(matches!(
            st.serve(&Request::ReadFile { path: "/nope".into() }),
            Response::Err(_)
        ));
    }

    #[test]
    fn worker_thread_end_to_end() {
        let fs = files(6);
        let (blobs, _) = build_partitions(&fs, 2, Codec::None).unwrap();
        let placement = Placement::new(2, 2, 1);
        let (tp, mut eps) = InProcTransport::fully_connected(2);
        let ep1 = eps.pop().unwrap();
        let _ep0 = eps.pop().unwrap();

        // node 1 holds partition 1 (files 1,3,5)
        let mut st1 = NodeState::new(1, DiskStore::in_memory(), placement.clone());
        st1.store.load_partition(1, blobs[1].clone(), "/m").unwrap();
        let node1 = FanStoreNode::spawn(Arc::new(Mutex::new(st1)), ep1);

        // node 0 fetches a remote file from node 1
        let resp = tp
            .call(0, 1, Request::ReadFile { path: "/m/train/f3".into() })
            .unwrap();
        let (stored, raw_len, compressed) = resp.into_file_data().unwrap();
        assert_eq!(stored, vec![3u8; 103]);
        assert_eq!(raw_len, 103);
        assert!(!compressed);

        tp.shutdown_all();
        assert_eq!(node1.join(), 1);
    }

    #[test]
    fn commit_and_stat_output() {
        let placement = Placement::new(1, 1, 1);
        let mut st = NodeState::new(0, DiskStore::in_memory(), placement);
        let meta = FileMeta {
            stat: FileStat::regular(1, 42),
            location: FileLocation {
                node: 0,
                partition: u32::MAX,
                offset: 0,
                stored_len: 42,
                compressed: false,
            },
        };
        st.serve(&Request::CommitOutput {
            path: "/out/ckpt_1.h5".into(),
            meta,
        });
        match st.serve(&Request::StatOutput {
            path: "/out/ckpt_1.h5".into(),
        }) {
            Response::Meta { stat, origin } => {
                assert_eq!(stat.size, 42);
                assert_eq!(origin, 0);
            }
            other => panic!("unexpected {other:?}"),
        }
        match st.serve(&Request::ListOutputs { dir: "/out".into() }) {
            Response::Names(names) => assert_eq!(names, vec!["ckpt_1.h5"]),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn index_metadata_covers_all_partitions() {
        let fs = files(10);
        let (blobs, _) = build_partitions(&fs, 4, Codec::None).unwrap();
        let placement = Placement::new(4, 4, 1);
        let blobs: Vec<(u32, Vec<u8>)> = blobs.into_iter().enumerate().map(|(i, b)| (i as u32, b)).collect();
        let mut table = MetaTable::new();
        index_input_metadata(&mut table, &blobs, "/m", &placement).unwrap();
        assert_eq!(table.file_count(), 10);
        for i in 0..10 {
            let m = table.get(&format!("/m/train/f{i}")).unwrap();
            assert_eq!(m.location.partition, (i % 4) as u32);
            assert_eq!(m.location.node, (i % 4) as u32);
        }
    }
}
