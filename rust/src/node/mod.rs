//! A FanStore node: local partition store, replicated input metadata,
//! homed output metadata, the refcount cache, and the worker thread that
//! services peer requests (paper §5.1, Fig 2).
//!
//! In `InProc` mode every node is a worker thread plus a shared-state
//! handle; "remote" reads between nodes are real request/response messages
//! through [`crate::net::transport`] carrying the stored (possibly
//! compressed) bytes.
//!
//! # Concurrency architecture
//!
//! Node state is a [`NodeShared`] with per-component synchronization matched
//! to each component's access pattern (see DESIGN.md "Node concurrency"):
//!
//! | component     | primitive            | why |
//! |---------------|----------------------|-----|
//! | `store`       | none (sealed)        | partitions are dumped at launch, immutable after |
//! | `input_meta`  | `Arc<MetaTable>`     | replicated broadcast, immutable after launch |
//! | `placement`   | none (sealed)        | pure function of the cluster shape |
//! | `cache`       | 16-way sharded locks | hot acquire/release from K trainer threads |
//! | `output_meta` | `RwLock`             | rare writes (close), frequent cheap reads |
//! | `output_data` | `RwLock`             | rare writes (close), reads on checkpoint resume |
//! | `stats`       | `AtomicU64` per ctr  | incremented on every op, read only at shutdown |
//!
//! The mutable-by-construction parts (store loading, metadata indexing) live
//! on [`NodeBuilder`]; [`NodeBuilder::seal`] freezes them into the shared,
//! lock-free `NodeShared`.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cache::{DecodedCache, ShardedCache};
use crate::error::{FanError, Result};
use crate::metadata::placement::Placement;
use crate::metadata::record::{FileLocation, FileMeta};
use crate::metadata::table::MetaTable;
use crate::net::health::{HealthMap, HealthPolicy};
use crate::net::transport::{
    FileFetch, MetaFetch, NodeEndpoint, PendingReply, Request, Response, Transport,
};
use crate::storage::disk::DiskStore;
use crate::storage::payload::Payload;
use crate::storage::placement::{PlacementKind, PlacementPolicy};

/// Per-node I/O accounting snapshot used by the experiment reports.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NodeStats {
    pub local_reads: u64,
    pub remote_reads_served: u64,
    pub remote_reads_issued: u64,
    /// `ReadFiles` batches served by this node's worker (each also counts
    /// its per-file serves in `remote_reads_served`).
    pub batched_reads_served: u64,
    /// `StatOutput` round trips avoided by the committed-output metadata
    /// cache on this (reading) node.
    pub output_meta_hits: u64,
    /// `readdir` gathers answered from this node's generation-stamped
    /// listing cache (no `ListOutputs` round trips at all).
    pub readdir_cache_hits: u64,
    /// Spilled-partition reads by mode (zero without `spill_dir`); see
    /// `storage::disk::SpillReadMode`.  Populated only by
    /// `NodeShared::stats_snapshot` (the counters live in the store, not
    /// in `AtomicNodeStats`).
    pub spill_reads_reopen: u64,
    pub spill_reads_pread: u64,
    pub spill_reads_mmap: u64,
    pub bytes_read_local: u64,
    pub bytes_served_remote: u64,
    pub bytes_fetched_remote: u64,
    pub decompressions: u64,
    /// Bytes the compressed representation saved end to end: Σ over decodes
    /// of `raw_len - stored_len` (network + cache carried the small form).
    pub compressed_bytes_saved: u64,
    /// Wall time spent decompressing at pickup, in nanoseconds.
    pub decode_nanos: u64,
    pub outputs_committed: u64,
    pub output_bytes: u64,
    /// Reads that succeeded on a *different* holder after the preferred one
    /// failed (the PR 7 recovery path actually recovering).
    pub failovers: u64,
    /// Re-routed fetch attempts (one per path re-queued to another holder;
    /// a read that fails over twice counts two retries, one failover).
    pub retries: u64,
    /// Up/Suspect → Down transitions observed by this node's health map.
    pub peers_marked_down: u64,
    /// Reads that exhausted every holder / the retry budget and degraded
    /// to a real error (EIO to the caller — never a hang).
    pub degraded_reads: u64,
    /// Keepalive pings issued by this node's prober (PR 9) — every probe,
    /// whether it answered or not.
    pub probes_sent: u64,
    /// Down peers a probe found alive again (Down → Up via the prober, not
    /// via a lucky data round trip).
    pub peers_recovered: u64,
    /// Repair transfers this node started as the adopting/driving side
    /// (partition pulls, reseed pushes, output re-commits).
    pub repairs_started: u64,
    /// Repair transfers that installed successfully.  `repairs_started -
    /// repairs_completed` = transfers still failing (retried next tick).
    pub repairs_completed: u64,
    /// Σ blob/data bytes over completed repairs — exact ledger algebra:
    /// each completed repair adds exactly its transferred size.
    pub repaired_bytes: u64,
    /// Tier migrations executed by this node's migrator (PR 8): spill→RAM
    /// promotions, RAM→spill demotions, and the bytes moved either way
    /// (`migrated_bytes` = Σ blob sizes over both directions, so
    /// per-direction byte sums reconstruct exactly from the plan sizes).
    /// Tallied inside `DiskStore`; populated only by
    /// `NodeShared::stats_snapshot`, like `spill_reads_*`.
    pub promotions: u64,
    pub demotions: u64,
    pub migrated_bytes: u64,
    /// Reads served out of the RAM tier (store-tallied, snapshot-merged).
    pub tier_hot_hits: u64,
    /// Descriptor pickups answered by the decoded-payload side cache
    /// instead of a repeat decompression (PR 8 satellite).
    pub decoded_cache_hits: u64,
    /// Frames this node's TCP server refused to decode (garbage bodies,
    /// oversize length prefixes).  Each reject kills only its own
    /// connection, never the accept loop; always zero on the in-proc
    /// fabric.
    pub decode_rejects: u64,
}

/// Lock-free accounting: every counter is a relaxed `AtomicU64`, updated on
/// the hot path without taking any lock and snapshotted at shutdown.
#[derive(Debug, Default)]
pub struct AtomicNodeStats {
    pub local_reads: AtomicU64,
    pub remote_reads_served: AtomicU64,
    pub remote_reads_issued: AtomicU64,
    pub batched_reads_served: AtomicU64,
    pub output_meta_hits: AtomicU64,
    pub readdir_cache_hits: AtomicU64,
    pub bytes_read_local: AtomicU64,
    pub bytes_served_remote: AtomicU64,
    pub bytes_fetched_remote: AtomicU64,
    pub decompressions: AtomicU64,
    pub compressed_bytes_saved: AtomicU64,
    pub decode_nanos: AtomicU64,
    pub outputs_committed: AtomicU64,
    pub output_bytes: AtomicU64,
    pub failovers: AtomicU64,
    pub retries: AtomicU64,
    pub peers_marked_down: AtomicU64,
    pub degraded_reads: AtomicU64,
    pub probes_sent: AtomicU64,
    pub peers_recovered: AtomicU64,
    pub repairs_started: AtomicU64,
    pub repairs_completed: AtomicU64,
    pub repaired_bytes: AtomicU64,
    pub decoded_cache_hits: AtomicU64,
    /// `Arc` rather than a bare atomic: the TCP accept loop is bound
    /// *before* the node is sealed, so the coordinator hands the same
    /// counter to [`crate::net::tcp::TcpServer::bind_counted`] and to the
    /// sealed stats.
    pub decode_rejects: Arc<AtomicU64>,
}

impl AtomicNodeStats {
    /// Consistent-enough snapshot for reports (individual counters are
    /// exact; cross-counter skew is possible while traffic is in flight).
    ///
    /// The `spill_reads_*` fields are NOT populated here — they are
    /// tallied inside `DiskStore`, which this struct cannot reach.  Use
    /// [`NodeShared::stats_snapshot`] for the full view (the shutdown
    /// report does); this snapshot reports them as zero.
    pub fn snapshot(&self) -> NodeStats {
        let ld = |c: &AtomicU64| c.load(Ordering::Relaxed);
        NodeStats {
            local_reads: ld(&self.local_reads),
            remote_reads_served: ld(&self.remote_reads_served),
            remote_reads_issued: ld(&self.remote_reads_issued),
            batched_reads_served: ld(&self.batched_reads_served),
            output_meta_hits: ld(&self.output_meta_hits),
            readdir_cache_hits: ld(&self.readdir_cache_hits),
            // tallied inside DiskStore; merged by NodeShared::stats_snapshot
            spill_reads_reopen: 0,
            spill_reads_pread: 0,
            spill_reads_mmap: 0,
            bytes_read_local: ld(&self.bytes_read_local),
            bytes_served_remote: ld(&self.bytes_served_remote),
            bytes_fetched_remote: ld(&self.bytes_fetched_remote),
            decompressions: ld(&self.decompressions),
            compressed_bytes_saved: ld(&self.compressed_bytes_saved),
            decode_nanos: ld(&self.decode_nanos),
            outputs_committed: ld(&self.outputs_committed),
            output_bytes: ld(&self.output_bytes),
            failovers: ld(&self.failovers),
            retries: ld(&self.retries),
            peers_marked_down: ld(&self.peers_marked_down),
            degraded_reads: ld(&self.degraded_reads),
            probes_sent: ld(&self.probes_sent),
            peers_recovered: ld(&self.peers_recovered),
            repairs_started: ld(&self.repairs_started),
            repairs_completed: ld(&self.repairs_completed),
            repaired_bytes: ld(&self.repaired_bytes),
            // tallied inside DiskStore; merged by NodeShared::stats_snapshot
            promotions: 0,
            demotions: 0,
            migrated_bytes: 0,
            tier_hot_hits: 0,
            decoded_cache_hits: ld(&self.decoded_cache_hits),
            decode_rejects: ld(&self.decode_rejects),
        }
    }
}

/// Mutable launch-time state: partitions are dumped and input metadata
/// attached here, then [`NodeBuilder::seal`] freezes everything immutable
/// into a [`NodeShared`].
///
/// `input_meta` is an `Arc` so the coordinator can build the broadcast
/// table once and hand every node the same sealed replica (in-proc, one
/// RAM copy stands in for the N identical per-node copies a real
/// deployment would hold).
pub struct NodeBuilder {
    pub id: u32,
    pub store: DiskStore,
    pub input_meta: Arc<MetaTable>,
    pub placement: Placement,
    /// Refcount-cache shard count (lock domains); tunable per cluster via
    /// [`crate::config::ClusterConfig::cache_shards`].
    pub cache_shards: usize,
    /// Failure-detection tunables (retry budget, Suspect/Down thresholds,
    /// backoff); see [`crate::config::ClusterConfig::retry_budget`].
    pub health_policy: HealthPolicy,
    /// Tiered-placement policy kind (PR 8); `Noop` preserves static
    /// placement and spawns no migrator thread.
    pub tier_policy: PlacementKind,
    /// RAM-tier byte budget for the migrator (0 = no RAM tier / disabled).
    pub ram_budget_bytes: u64,
    /// Migration-tick interval.  0 disables the background thread even
    /// with a non-noop policy — tests drive [`NodeShared::migrate_tick`]
    /// directly for determinism.
    pub migrate_interval_ms: u64,
    /// Mount prefix input paths were indexed under — needed at repair time
    /// so an installed partition's entries land at the same paths the
    /// replicated metadata names.  The coordinator sets this from
    /// `ClusterConfig::mount`.
    pub mount: String,
    /// Keepalive/repair tick interval for the recovery thread started by
    /// [`NodeShared::start_recovery`].  0 disables the thread — tests
    /// drive [`NodeShared::probe_tick`] / [`NodeShared::repair_tick`]
    /// directly for determinism.
    pub probe_interval_ms: u64,
    /// Max partition/output transfers one repair tick may start
    /// (`--repair-max-inflight`) — keeps repair from flooding the fabric
    /// the moment a node dies.
    pub repair_max_inflight: u32,
}

/// Process-global node-epoch source: every sealed [`NodeShared`] gets a
/// unique, monotonically increasing epoch, so a node restarted in the same
/// process (chaos tests, future re-launch) is a *different incarnation* to
/// the health layer — `Ping`/`Pong` carry it (ROADMAP: "peer epoch numbers
/// so a restarted peer isn't confused with a live one").
static NODE_EPOCH_SEQ: AtomicU64 = AtomicU64::new(1);

impl NodeBuilder {
    pub fn new(id: u32, store: DiskStore, placement: Placement) -> Self {
        NodeBuilder {
            id,
            store,
            input_meta: Arc::new(MetaTable::new()),
            placement,
            cache_shards: crate::cache::CACHE_SHARDS,
            health_policy: HealthPolicy::default(),
            tier_policy: PlacementKind::Noop,
            ram_budget_bytes: 0,
            migrate_interval_ms: 0,
            mount: String::new(),
            probe_interval_ms: 0,
            repair_max_inflight: 2,
        }
    }

    /// Freeze the launch-time state into the shared node handle, spawning
    /// the background migrator when tiered placement is configured (a
    /// non-noop policy, a RAM budget, somewhere to demote to, and a
    /// nonzero tick interval).
    pub fn seal(self) -> Arc<NodeShared> {
        let peer_count = self.placement.nodes;
        // deterministic per-node jitter seed: replayable backoff schedules
        let health_seed = 0x9E37_79B9_7F4A_7C15u64 ^ self.id as u64;
        let shared = Arc::new(NodeShared {
            id: self.id,
            epoch: NODE_EPOCH_SEQ.fetch_add(1, Ordering::Relaxed),
            store: self.store,
            input_meta: self.input_meta,
            placement: self.placement,
            health: HealthMap::new(peer_count, self.health_policy, health_seed),
            cache: ShardedCache::with_shards(self.cache_shards),
            decoded: DecodedCache::new(),
            ram_budget_bytes: self.ram_budget_bytes,
            tier_policy: Mutex::new(self.tier_policy.build()),
            migrator: Mutex::new(None),
            migrator_stop: Arc::new((Mutex::new(false), Condvar::new())),
            mount: self.mount,
            repair_max_inflight: self.repair_max_inflight,
            probe_interval_ms: self.probe_interval_ms,
            installed: RwLock::new(DiskStore::in_memory()),
            has_installed: AtomicBool::new(false),
            overrides: RwLock::new(HashMap::new()),
            has_overrides: AtomicBool::new(false),
            reseed: Mutex::new(Vec::new()),
            output_repairs_done: Mutex::new(HashSet::new()),
            probe_sched: Mutex::new(vec![ProbeSched::default(); peer_count as usize]),
            recovery: Mutex::new(None),
            recovery_stop: Arc::new((Mutex::new(false), Condvar::new())),
            output_meta: RwLock::new(MetaTable::new()),
            output_data: RwLock::new(HashMap::new()),
            output_meta_cache: RwLock::new(HashMap::new()),
            output_gen: RwLock::new(HashMap::new()),
            commit_seq: AtomicU64::new(1),
            listings: RwLock::new(ListingCache::default()),
            stats: AtomicNodeStats::default(),
        });
        let wants_migrator = self.tier_policy != PlacementKind::Noop
            && self.ram_budget_bytes > 0
            && self.migrate_interval_ms > 0
            && shared.store.can_demote();
        if wants_migrator {
            let weak = Arc::downgrade(&shared);
            let stop = Arc::clone(&shared.migrator_stop);
            let interval = Duration::from_millis(self.migrate_interval_ms);
            let handle = std::thread::Builder::new()
                .name(format!("fanstore-migrator-{}", shared.id))
                .spawn(move || migrator_loop(weak, stop, interval))
                .expect("spawn migrator");
            *shared.migrator.lock().unwrap() = Some(handle);
        }
        shared
    }
}

/// Background migrator body: every `interval`, upgrade the node handle and
/// run one migration tick.  Holds only a `Weak` between ticks, so the
/// thread never keeps the node alive; it exits when the node is gone or
/// [`NodeShared::stop_migrator`] rings the condvar.
fn migrator_loop(
    node: Weak<NodeShared>,
    stop: Arc<(Mutex<bool>, Condvar)>,
    interval: Duration,
) {
    let (lock, cv) = &*stop;
    let mut stopped = lock.lock().unwrap();
    loop {
        let (guard, timeout) = cv.wait_timeout(stopped, interval).unwrap();
        stopped = guard;
        if *stopped {
            return;
        }
        if timeout.timed_out() {
            // never hold the stop lock across a tick: stop_migrator must
            // always be able to ring the condvar promptly
            drop(stopped);
            match node.upgrade() {
                Some(shared) => {
                    shared.migrate_tick();
                }
                None => return,
            }
            stopped = lock.lock().unwrap();
            // a stop rung during the tick must not wait out another interval
            if *stopped {
                return;
            }
        }
    }
}

/// Node state shared by the local VFS clients and the worker thread.
///
/// There is no node-global lock: each component synchronizes (or is sealed
/// immutable) on its own, so K trainer threads plus the worker thread
/// proceed in parallel except where they genuinely touch the same data.
pub struct NodeShared {
    pub id: u32,
    /// This incarnation's epoch (unique per sealed node, carried by
    /// `Ping`/`Pong` — see [`NODE_EPOCH_SEQ`]).
    pub epoch: u64,
    /// Per-peer failure detector driving read-path failover (PR 7).
    /// Internally synchronized; the healthy hot path never touches it.
    pub health: HealthMap,
    /// Dumped input partitions + path index (paper §5.2).  Immutable after
    /// [`NodeBuilder::seal`] — reads need no lock.
    pub store: DiskStore,
    /// Replicated input metadata — identical on every node (§5.3),
    /// immutable after launch, shared lock-free.
    pub input_meta: Arc<MetaTable>,
    pub placement: Placement,
    /// Refcount cache of input content in *stored* form (§5.4), sharded 16
    /// ways.  Compressed entries stay compressed while resident — the RAM
    /// budget scales with the compressed dataset; `decode_payload` expands
    /// a pinned entry at descriptor pickup.
    pub cache: ShardedCache,
    /// Decoded-payload side cache (PR 8 satellite): pin-identity-keyed, so
    /// N concurrent `open()`s of one hot compressed file decode once — see
    /// [`NodeShared::decode_payload_cached`].
    pub decoded: DecodedCache,
    /// RAM-tier byte budget the migrator enforces (0 = tiering disabled).
    pub ram_budget_bytes: u64,
    /// The placement policy fed by [`DiskStore::take_heat`] samples.  Taken
    /// by exactly one ticker at a time ([`NodeShared::migrate_tick`]); the
    /// mutex makes direct test-driven ticks safe alongside the thread.
    tier_policy: Mutex<Box<dyn PlacementPolicy>>,
    /// Background migrator thread handle (None when tiering is off).
    migrator: Mutex<Option<JoinHandle<()>>>,
    /// Stop flag + condvar the migrator sleeps on.
    migrator_stop: Arc<(Mutex<bool>, Condvar)>,
    /// Mount prefix for re-indexing repaired partitions (see
    /// [`NodeBuilder::mount`]).
    pub mount: String,
    /// Per-tick transfer throttle for [`NodeShared::repair_tick`].
    pub repair_max_inflight: u32,
    /// Recovery-thread tick interval ([`NodeShared::start_recovery`]).
    pub probe_interval_ms: u64,
    /// Partitions this node adopted through background repair (PR 9).  A
    /// second, mutable store beside the sealed launch-time `store`: reads
    /// consult it on a sealed-store miss, `serve(FetchPartition)` serves
    /// from either.  RAM-backed — repaired replicas are a recovery
    /// measure, not a tiering concern.
    installed: RwLock<DiskStore>,
    /// Fast-path guard: false until the first install, so the healthy read
    /// path never takes the `installed` lock.
    has_installed: AtomicBool,
    /// Holder-override map: partition → adopted holders *other nodes*
    /// installed (deterministically computed by every node's repair tick
    /// from its own down-set).  Consulted by the batched read path when
    /// building the candidate list — overrides are appended to the
    /// placement holders and health-ordered with them.
    overrides: RwLock<HashMap<u32, Vec<u32>>>,
    /// Fast-path guard mirroring `has_installed` for `overrides`.
    has_overrides: AtomicBool,
    /// Peers the prober saw restart (new epoch): the next repair tick
    /// pushes their partitions back via `InstallPartition`.
    reseed: Mutex<Vec<u32>>,
    /// Output repairs already pushed, keyed by (path, adoptee) — keeps the
    /// repair ledger exact across ticks (re-pushing is idempotent but must
    /// not double-count).
    output_repairs_done: Mutex<HashSet<(String, u32)>>,
    /// Per-peer probe backoff schedule (attempt count + earliest next
    /// probe) for Down peers.
    probe_sched: Mutex<Vec<ProbeSched>>,
    /// Background recovery thread handle (prober + repairer; None until
    /// [`NodeShared::start_recovery`], or when `probe_interval_ms` is 0).
    recovery: Mutex<Option<JoinHandle<()>>>,
    /// Stop flag + condvar the recovery thread sleeps on.
    recovery_stop: Arc<(Mutex<bool>, Condvar)>,
    /// Output metadata homed on this node by the consistent hash (§5.3).
    pub output_meta: RwLock<MetaTable>,
    /// Output file bytes kept on their originating node (§5.4: the data is
    /// buffered locally; only the metadata entry is forwarded on close()).
    pub output_data: RwLock<HashMap<String, Arc<[u8]>>>,
    /// Reader-side cache of committed-output metadata fetched from remote
    /// home nodes, so a repeat `open()` skips the `StatOutput` round trip.
    /// Invalidated on any local unlink / `DropOutput`; a cross-node
    /// unlink+rewrite is corrected lazily when the stale origin read comes
    /// back ENOENT (see `FanStoreVfs::open`).
    pub output_meta_cache: RwLock<HashMap<String, FileMeta>>,
    /// Commit generation of the *output bytes currently resident in this
    /// node's refcount cache*, recorded when `fetch_output` inserts them.
    /// The authoritative stat's generation is compared against this on a
    /// resident re-open, so any rewrite — even same origin, same size —
    /// retires the stale copy (see DESIGN.md "generation stamps").
    pub output_gen: RwLock<HashMap<String, u64>>,
    /// Monotonic commit-generation source for outputs homed on this node;
    /// `serve(CommitOutput)` stamps each landed commit from it.
    pub commit_seq: AtomicU64,
    /// Generation-stamped cache of fully merged `readdir` listings (input
    /// names + the cluster-wide `ListOutputs` gather), so a steady-state
    /// listing is a local lookup.  Any commit/unlink invalidates it: the
    /// local serve path directly, remote mutators via the writer's
    /// `InvalidateListings` broadcast (see `FanStoreVfs`).  Install
    /// watermarks are **per-directory** (PR 9): a gather for `/a` can
    /// still install while a racing commit mutates `/b` — see
    /// [`ListingCache`].
    listings: RwLock<ListingCache>,
    pub stats: AtomicNodeStats,
}

/// The `readdir` listing cache with per-directory install watermarks.
///
/// A monotonic `clock` stamps every invalidation; each mutated directory
/// records the stamp it was invalidated at (`dir_gens`), and a blanket
/// invalidation raises the global `floor`.  A gather samples the clock
/// *before* collecting and may install for `dir` only if no invalidation
/// of *that directory* (and no blanket one) stamped later — so unrelated
/// in-flight gathers install even while another directory churns.
#[derive(Default)]
struct ListingCache {
    entries: HashMap<String, Arc<Vec<String>>>,
    /// Clock value at each directory's most recent invalidation.
    dir_gens: HashMap<String, u64>,
    /// Clock value at the most recent blanket invalidation.
    floor: u64,
    /// Monotonic invalidation stamp source.
    clock: u64,
}

/// Per-peer probe scheduling state: how many consecutive probes have
/// failed and the earliest instant the next one may go out (Down peers
/// are re-probed on the health map's jittered backoff schedule, not every
/// tick).
#[derive(Clone, Copy, Debug, Default)]
struct ProbeSched {
    attempts: u32,
    next_at: Option<Instant>,
}

/// What one [`NodeShared::probe_tick`] did (counters also land in
/// `probes_sent` / `peers_recovered`).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ProbeReport {
    /// Probes issued this tick.
    pub probes: u64,
    /// Down peers found alive again.
    pub recovered: u64,
    /// Peers whose pong carried a new epoch (restarted incarnations) —
    /// queued for reseeding by the next repair tick.
    pub restarted: Vec<u32>,
}

/// What one [`NodeShared::repair_tick`] did (mirrored in the
/// `repairs_started` / `repairs_completed` / `repaired_bytes` counters).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RepairReport {
    pub started: u64,
    pub completed: u64,
}

/// Where one successfully fetched input in a [`NodeShared::fetch_inputs_batched`]
/// call came from (the cache acquire, this node's own store, or a peer).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FetchSource {
    Cache,
    Local,
    Remote,
}

/// Result of one batched input fetch: per-path outcomes (each `Ok` carries
/// a live cache pin the caller must eventually `release`) plus how many
/// `ReadFiles` requests went to peers.  Paths are the caller's `Arc`
/// handles, cloned — never re-allocated — through the whole body.
pub struct BatchedFetch {
    pub outcomes: Vec<(Arc<str>, Result<(Payload, FetchSource)>)>,
    pub remote_batches: u64,
}

impl NodeShared {
    /// Full accounting snapshot: the atomic counters plus the store's
    /// per-mode spilled-read and tier-migration tallies.
    pub fn stats_snapshot(&self) -> NodeStats {
        let mut s = self.stats.snapshot();
        let (reopen, pread, mmap) = self.store.spill_read_counts();
        s.spill_reads_reopen = reopen;
        s.spill_reads_pread = pread;
        s.spill_reads_mmap = mmap;
        let (promotions, demotions, migrated_bytes, hot_hits) = self.store.tier_counts();
        s.promotions = promotions;
        s.demotions = demotions;
        s.migrated_bytes = migrated_bytes;
        s.tier_hot_hits = hot_hits;
        s
    }

    /// One migration tick: drain the heat sample, ask the policy for a
    /// plan, and execute it — demotions first so promotions fit the freed
    /// budget, then promotions with a residency backstop (a promotion that
    /// would overshoot `ram_budget_bytes` is skipped even if planned).
    /// Returns `(promotions, demotions)` executed.  Normally driven by the
    /// background thread; tests and benches call it directly for
    /// deterministic migration schedules.
    pub fn migrate_tick(&self) -> (u64, u64) {
        let heat = self.store.take_heat();
        let plan = {
            let mut policy = self.tier_policy.lock().unwrap();
            policy.plan(&heat, self.ram_budget_bytes)
        };
        if plan.is_empty() {
            return (0, 0);
        }
        let sizes: HashMap<u32, u64> = heat.iter().map(|h| (h.pid, h.bytes)).collect();
        let (mut promoted, mut demoted) = (0u64, 0u64);
        for pid in plan.demote {
            match self.store.demote_partition(pid) {
                Ok(moved) if moved > 0 => demoted += 1,
                _ => {}
            }
        }
        for pid in plan.promote {
            // backstop: trust but verify the policy's budget math against
            // live residency (concurrent ticks / skipped demotions)
            let bytes = sizes.get(&pid).copied().unwrap_or(0);
            if self.store.ram_resident_bytes() + bytes > self.ram_budget_bytes {
                continue;
            }
            match self.store.promote_partition(pid) {
                Ok(moved) if moved > 0 => promoted += 1,
                _ => {}
            }
        }
        (promoted, demoted)
    }

    /// Stop and join the background migrator (idempotent; no-op when
    /// tiering is off).  Called by the cluster teardown and by `Drop`, so
    /// the thread never outlives the node.
    pub fn stop_migrator(&self) {
        let handle = self.migrator.lock().unwrap().take();
        if let Some(handle) = handle {
            let (lock, cv) = &*self.migrator_stop;
            *lock.lock().unwrap() = true;
            cv.notify_all();
            // the migrator's tick briefly holds the last Arc in teardown
            // races; if Drop lands on the migrator thread itself, detach
            // instead of self-joining
            if handle.thread().id() != std::thread::current().id() {
                let _ = handle.join();
            }
        }
    }

    /// Current stamp of the listing-cache invalidation clock (sample it
    /// *before* starting a gather; pass it back to
    /// [`NodeShared::install_listing`]).
    pub fn listing_generation(&self) -> u64 {
        self.listings.read().unwrap().clock
    }

    /// Drop every cached listing and raise the blanket floor, so a gather
    /// that started before this point can no longer install a stale entry
    /// for *any* directory.  The blanket fallback — mutations with a known
    /// path use the directory-granular
    /// [`NodeShared::invalidate_listings_for`].
    pub fn invalidate_listings(&self) {
        let mut cache = self.listings.write().unwrap();
        cache.clock += 1;
        cache.floor = cache.clock;
        cache.dir_gens.clear(); // subsumed by the floor
        cache.entries.clear();
    }

    /// Directory-granular invalidation: stamp and drop only the cached
    /// listings a mutation of `path` can change — its ancestor directory
    /// chain (the immediate parent gains/loses the name; higher ancestors
    /// may gain/lose a subdirectory).  Unrelated hot listings stay cached
    /// across checkpoints, and — per-directory watermarks, PR 9 — an
    /// unrelated *in-flight* gather may still install when it lands.
    pub fn invalidate_listings_for(&self, path: &str) {
        let mut cache = self.listings.write().unwrap();
        cache.clock += 1;
        let stamp = cache.clock;
        let mut dir = crate::metadata::table::parent(path);
        loop {
            cache.dir_gens.insert(dir.to_string(), stamp);
            cache.entries.remove(dir);
            if dir == "/" {
                break;
            }
            dir = crate::metadata::table::parent(dir);
        }
    }

    /// Install a gathered listing for `dir` unless *that directory* (or
    /// everything, via a blanket invalidation) was invalidated after the
    /// caller sampled `gen` (both the stamp check and the insert happen
    /// under the cache lock, so they are atomic with respect to the
    /// invalidation paths).
    pub fn install_listing(&self, dir: &str, gen: u64, names: &[String]) {
        let mut cache = self.listings.write().unwrap();
        let barrier = cache
            .dir_gens
            .get(dir)
            .copied()
            .unwrap_or(0)
            .max(cache.floor);
        if barrier <= gen {
            cache.entries.insert(dir.to_string(), Arc::new(names.to_vec()));
        }
    }

    /// Cached merged listing for `dir`, if the cache holds a fresh one.
    pub fn cached_listing(&self, dir: &str) -> Option<Arc<Vec<String>>> {
        self.listings.read().unwrap().entries.get(dir).cloned()
    }

    /// Serve a peer's request (also used directly for self-requests so the
    /// local path does not pay a channel round trip).  Takes `&self`: the
    /// worker thread and any number of VFS clients call this concurrently.
    pub fn serve(&self, req: &Request) -> Response {
        match req {
            Request::ReadFile { path } => match self.fetch_stored(path) {
                FileFetch::Data { stored } => Response::FileData { stored },
                FileFetch::NotFound => Response::Err(format!("ENOENT {path}")),
                FileFetch::Fault(e) => Response::Err(format!("EIO {path}: {e}")),
            },
            Request::ReadFiles { paths } => {
                self.stats.batched_reads_served.fetch_add(1, Ordering::Relaxed);
                // reply paths are Arc clones of the request's — the batched
                // serve allocates no strings and copies no payload bytes
                Response::FilesData(
                    paths
                        .iter()
                        .map(|p| (Arc::clone(p), self.fetch_stored(p)))
                        .collect(),
                )
            }
            Request::StatOutput { path } => {
                let meta = self.output_meta.read().unwrap().get(path).cloned();
                match meta {
                    Some(m) => Response::Meta {
                        stat: m.stat,
                        origin: m.location.node,
                        generation: m.generation,
                    },
                    None => Response::Err(format!("ENOENT {path}")),
                }
            }
            Request::StatOutputs { paths } => {
                // batched stat mirroring ReadFiles: one table lock, one
                // round trip, per-path outcomes in request order
                let table = self.output_meta.read().unwrap();
                Response::Metas(
                    paths
                        .iter()
                        .map(|p| {
                            let fetch = match table.get(p) {
                                Some(m) => MetaFetch::Meta {
                                    stat: m.stat,
                                    origin: m.location.node,
                                    generation: m.generation,
                                },
                                None => MetaFetch::NotFound,
                            };
                            (Arc::clone(p), fetch)
                        })
                        .collect(),
                )
            }
            Request::CommitOutput {
                path,
                meta,
                data,
                stamped,
            } => {
                // the primary home is the serializer for a path: stamping
                // the generation here guarantees two commits of the same
                // name are distinguishable even with identical origin and
                // size.  Secondary homes and repair pushes arrive
                // pre-stamped (`stamped == true`) so every home agrees on
                // the primary's stamp.
                let mut meta = meta.clone();
                if !*stamped {
                    meta.generation = self.commit_seq.fetch_add(1, Ordering::Relaxed);
                }
                // every home keeps the bytes too (PR 9): an output must
                // survive the death of its origin, so reads can fail over
                // to any home's buffered copy
                self.output_data
                    .write()
                    .unwrap()
                    .insert(path.to_string(), data.clone().into_arc());
                let reply = Response::Meta {
                    stat: meta.stat,
                    origin: meta.location.node,
                    generation: meta.generation,
                };
                self.output_meta.write().unwrap().insert(path, meta);
                // the new name is listable: its ancestor listings are stale
                self.invalidate_listings_for(path);
                reply
            }
            Request::ListOutputs { dir } => {
                let names = self
                    .output_meta
                    .read()
                    .unwrap()
                    .readdir(dir)
                    .map(|n| n.to_vec())
                    .unwrap_or_default();
                Response::Names(names)
            }
            Request::UnlinkOutput { path } => {
                let removed = self.output_meta.write().unwrap().remove(path);
                match removed {
                    Ok(meta) => {
                        // this generation can no longer be served from here
                        self.cache.invalidate(path);
                        self.output_meta_cache.write().unwrap().remove(&**path);
                        self.output_gen.write().unwrap().remove(&**path);
                        self.invalidate_listings_for(path);
                        Response::Meta {
                            stat: meta.stat,
                            origin: meta.location.node,
                            generation: meta.generation,
                        }
                    }
                    Err(_) => Response::Err(format!("ENOENT {path}")),
                }
            }
            Request::DropOutput { path } => {
                // origin-side GC of an unlinked output's buffered bytes;
                // idempotent so a re-delivered drop is harmless
                self.output_data.write().unwrap().remove(&**path);
                self.cache.invalidate(path);
                self.output_meta_cache.write().unwrap().remove(&**path);
                self.output_gen.write().unwrap().remove(&**path);
                Response::Ok
            }
            Request::InvalidateListings { path } => {
                // a commit/unlink landed somewhere in the cluster: retire
                // this node's cached listings along its ancestor chain (the
                // writer awaits the acks, so listings taken after its
                // mutation re-gather; unrelated dirs stay cached)
                self.invalidate_listings_for(path);
                Response::Ok
            }
            Request::Ping { .. } => Response::Pong { epoch: self.epoch },
            Request::FetchPartition { pid } => match self.partition_blob(*pid) {
                Ok(blob) => {
                    self.stats
                        .bytes_served_remote
                        .fetch_add(blob.len() as u64, Ordering::Relaxed);
                    Response::PartitionData { blob }
                }
                Err(e) => Response::Err(format!("ENOPART {pid}: {e}")),
            },
            Request::InstallPartition { pid, blob } => match self.install_partition(*pid, blob) {
                Ok(_) => Response::Ok,
                Err(e) => Response::Err(format!("EINSTALL {pid}: {e}")),
            },
            Request::Shutdown => Response::Ok,
        }
    }

    /// The whole container blob of `pid`, from the sealed launch-time
    /// store or the repair-installed side store.
    pub fn partition_blob(&self, pid: u32) -> Result<Payload> {
        if self.store.has_partition(pid) {
            return self.store.partition_blob(pid);
        }
        self.installed.read().unwrap().partition_blob(pid)
    }

    /// Does this node hold partition `pid` (launch-time or repaired)?
    pub fn holds_partition(&self, pid: u32) -> bool {
        self.store.has_partition(pid)
            || (self.has_installed.load(Ordering::Relaxed)
                && self.installed.read().unwrap().has_partition(pid))
    }

    /// Index a partition blob into the repair-installed side store
    /// (idempotent: a node already holding `pid` returns `Ok(0)` without
    /// re-indexing).  Returns the number of files installed.
    pub fn install_partition(&self, pid: u32, blob: &Payload) -> Result<u32> {
        if self.holds_partition(pid) {
            return Ok(0);
        }
        let mut st = self.installed.write().unwrap();
        if st.has_partition(pid) {
            return Ok(0); // raced with another installer
        }
        let n = st.load_partition(pid, blob.to_vec(), &self.mount)?;
        drop(st);
        self.has_installed.store(true, Ordering::Release);
        Ok(n)
    }

    /// Record that `adoptee` is (or will be) an extra holder of `pid`.
    /// Advisory, per-node: every node that observes the same down-set
    /// computes the same adoptee, so readers learn the override from their
    /// own repair ticks without a coordination round.  Self-knowledge
    /// lives in the `installed` store, not here.
    pub fn register_override(&self, pid: u32, adoptee: u32) {
        if adoptee == self.id {
            return;
        }
        let mut ov = self.overrides.write().unwrap();
        let v = ov.entry(pid).or_default();
        if !v.contains(&adoptee) {
            v.push(adoptee);
            self.has_overrides.store(true, Ordering::Release);
        }
    }

    /// Placement holders of `pid` plus any repair-adopted holders from the
    /// override map — the candidate list the batched read path hands to
    /// [`HealthMap::order_candidates`].  Overrides are appended after the
    /// placement holders, so with everyone healthy the order is unchanged;
    /// the health ordering then ranks an Up adoptee ahead of Down
    /// original holders.
    pub fn candidate_holders(&self, pid: u32) -> Vec<u32> {
        let mut holders = self.placement.partition_holders(pid);
        if self.has_overrides.load(Ordering::Relaxed) {
            if let Some(extra) = self.overrides.read().unwrap().get(&pid) {
                for &n in extra {
                    if !holders.contains(&n) {
                        holders.push(n);
                    }
                }
            }
        }
        holders
    }

    /// Read one stored (or output-buffered) file for a peer, reporting the
    /// outcome per file.  Shared by the single and batched serve paths.
    /// The returned payload is self-describing: a compressed-at-rest entry
    /// ships as [`Payload::Compressed`], so the wire carries the small
    /// representation and the *reader* decides when to expand it.
    pub fn fetch_stored(&self, path: &str) -> FileFetch {
        match self.read_stored_any(path) {
            Ok((stored, _at)) => {
                self.stats.remote_reads_served.fetch_add(1, Ordering::Relaxed);
                self.stats
                    .bytes_served_remote
                    .fetch_add(stored.len() as u64, Ordering::Relaxed);
                FileFetch::Data { stored }
            }
            // not in the store: maybe an output buffered on this node
            Err(crate::error::FanError::NotFound(_)) => {
                let data = self.output_data.read().unwrap().get(path).cloned();
                match data {
                    Some(data) => {
                        self.stats.remote_reads_served.fetch_add(1, Ordering::Relaxed);
                        self.stats
                            .bytes_served_remote
                            .fetch_add(data.len() as u64, Ordering::Relaxed);
                        FileFetch::Data {
                            stored: data.into(),
                        }
                    }
                    None => FileFetch::NotFound,
                }
            }
            // real I/O / format faults must not masquerade as ENOENT —
            // spilled-file reads can fail transiently under concurrency
            Err(e) => FileFetch::Fault(e.to_string()),
        }
    }

    /// Which node this node should fetch an input's bytes from: itself for
    /// replicated directories (§5.4 test-set broadcast — always local) and
    /// for partitions it adopted through repair, else the placement's
    /// nearest holder.  Shared by every read path so a placement-policy
    /// change lands exactly once.
    pub fn holder_of(&self, loc: &FileLocation) -> u32 {
        if loc.partition == crate::metadata::record::REPLICATED_PARTITION {
            return self.id;
        }
        if self.has_installed.load(Ordering::Relaxed)
            && self.installed.read().unwrap().has_partition(loc.partition)
        {
            return self.id;
        }
        self.placement.choose_holder(loc.partition, self.id)
    }

    /// Read a stored input from the sealed launch-time store, falling back
    /// to the repair-installed side store on a miss.  The healthy path
    /// pays nothing: the fallback is gated on `has_installed`.
    fn read_stored_any(&self, path: &str) -> Result<(Payload, crate::storage::disk::StoredAt)> {
        match self.store.read_stored(path) {
            Err(crate::error::FanError::NotFound(e)) => {
                if self.has_installed.load(Ordering::Relaxed) {
                    self.installed.read().unwrap().read_stored(path)
                } else {
                    Err(crate::error::FanError::NotFound(e))
                }
            }
            r => r,
        }
    }

    /// The single decode point (§5.4: decompression happens on the reading
    /// node): expand a [`Payload::Compressed`] handle at descriptor pickup,
    /// counting the decompression, its wall time, and the bytes the
    /// compressed representation saved on the way here.  Everything before
    /// this call — serve, wire, refcount cache — carries the stored form.
    pub fn decode_payload(&self, stored: &Payload) -> Result<Payload> {
        match stored {
            Payload::Compressed {
                codec,
                raw_len,
                inner,
            } => {
                let t0 = Instant::now();
                let out = codec.decompress(inner.as_slice(), *raw_len as usize)?;
                self.stats.decompressions.fetch_add(1, Ordering::Relaxed);
                self.stats
                    .decode_nanos
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                self.stats
                    .compressed_bytes_saved
                    .fetch_add(raw_len.saturating_sub(inner.len() as u64), Ordering::Relaxed);
                Ok(out.into())
            }
            // uncompressed content is served as-is: an mmap/RAM view stays
            // a view all the way into the cache and the descriptors
            other => Ok(other.clone()),
        }
    }

    /// [`NodeShared::decode_payload`] behind the decoded-payload side
    /// cache: concurrent pickups of the same *pin* (same cache generation
    /// of `path`) share one decompression — the first caller decodes while
    /// the rest block on the entry's cell, then everyone clones the same
    /// decoded `Payload`.  A new generation of the path (pin identity
    /// changes) replaces the stale entry.  Plain payloads bypass the cache
    /// entirely: there is nothing to decode, and a clone is already free.
    pub fn decode_payload_cached(&self, path: &str, pin: &Payload) -> Result<Payload> {
        if pin.codec().is_none() {
            return self.decode_payload(pin);
        }
        let (decoded, hit) = self
            .decoded
            .get_or_decode(path, pin, || self.decode_payload(pin))?;
        if hit {
            self.stats.decoded_cache_hits.fetch_add(1, Ordering::Relaxed);
        }
        Ok(decoded)
    }

    /// The one batched input-fetch body every read path shares
    /// (`FanStoreVfs::fetch_input`, `Vfs::prefetch`, the prefetch engine's
    /// pickups): resolve each path against the refcount cache, read the
    /// local share directly, and fetch the rest with **one `ReadFiles`
    /// round trip per holder node**, all requests in flight before any
    /// reply is awaited.  Payloads are cached *in stored form* — a
    /// compressed entry stays compressed through the fetch and the cache,
    /// and [`NodeShared::decode_payload`] expands it once at descriptor
    /// pickup; every `Ok` outcome transfers that pin to the caller.
    /// Exactly one cache acquire happens per item, and every miss is
    /// exactly one fetch, so the node-wide counter algebra the stress
    /// tests assert holds no matter which caller runs this.
    ///
    /// `items` must not contain duplicate paths (every caller dedups or
    /// coalesces first): a duplicated remote path would collapse in the
    /// reply map and report a spurious transport error for its second slot.
    ///
    /// # Failure handling (PR 7)
    ///
    /// A *transport-level* batch failure (send error, timed-out or dropped
    /// reply, malformed frame) feeds the [`HealthMap`] and re-routes the
    /// batch's paths to the next replica in their health-ordered
    /// [`Placement::partition_holders`] list — counted per path in
    /// `retries`, and in `failovers` when the re-route actually delivers
    /// bytes.  A path that exhausts its holders or the retry budget
    /// degrades to `FanError::Transport` (EIO at the VFS boundary —
    /// a real errno, never a hang; counted in `degraded_reads`).  Per-file
    /// `NotFound`/`Fault` outcomes inside a *delivered* reply are final:
    /// the holder answered authoritatively, so no failover is attempted.
    pub fn fetch_inputs_batched(
        &self,
        transport: &dyn Transport,
        items: Vec<(Arc<str>, FileLocation)>,
    ) -> BatchedFetch {
        let stats = &self.stats;
        let retry_budget = self.health.policy().retry_budget;
        let mut outcomes: Vec<(Arc<str>, Result<(Payload, FetchSource)>)> =
            Vec::with_capacity(items.len());
        let mut local: Vec<Arc<str>> = Vec::new();
        // each remote item carries its remaining failover candidates
        // (health-ordered holders, preferred first) and its attempt count
        let mut work: Vec<(Arc<str>, Vec<u32>, u32)> = Vec::new();
        for (path, loc) in items {
            if let Some(pin) = self.cache.acquire(&path) {
                outcomes.push((path, Ok((pin, FetchSource::Cache))));
                continue;
            }
            let holder = self.holder_of(&loc);
            if holder == self.id {
                local.push(path);
            } else {
                // placement holders plus repair-adopted overrides,
                // health-ordered (Down holders last, adoptees ranked by
                // their own liveness)
                let holders = self.candidate_holders(loc.partition);
                let candidates = self.health.order_candidates(&holders, holder);
                work.push((path, candidates, 0));
            }
        }

        let mut remote_batches = 0u64;
        let mut round = 0u32;
        while !work.is_empty() || round == 0 {
            if round > 0 {
                // jittered exponential backoff before each retry round
                std::thread::sleep(self.health.backoff(round - 1));
            }
            // group this round's items by their next candidate holder
            let mut groups: HashMap<u32, Vec<(Arc<str>, Vec<u32>, u32)>> = HashMap::new();
            for (path, mut candidates, attempts) in work.drain(..) {
                // non-empty by construction: items out of candidates
                // degraded instead of being re-queued
                let holder = candidates.remove(0);
                groups.entry(holder).or_default().push((path, candidates, attempts));
            }

            // every batch in flight before any local work or wait: the
            // per-peer round trips overlap with each other AND the local
            // reads (the request clones Arc handles, not strings)
            let pending: Vec<(u32, Vec<(Arc<str>, Vec<u32>, u32)>, Result<PendingReply>)> = groups
                .into_iter()
                .map(|(holder, batch)| {
                    let reply = transport.send(
                        self.id,
                        holder,
                        Request::ReadFiles {
                            paths: batch.iter().map(|(p, _, _)| Arc::clone(p)).collect(),
                        },
                    );
                    (holder, batch, reply)
                })
                .collect();
            remote_batches += pending.iter().filter(|(_, _, r)| r.is_ok()).count() as u64;

            // serve the local share while the peers work (first round only)
            if round == 0 {
                for path in std::mem::take(&mut local) {
                    let outcome = match self.read_stored_any(&path) {
                        Ok((stored, _)) => {
                            stats.local_reads.fetch_add(1, Ordering::Relaxed);
                            stats
                                .bytes_read_local
                                .fetch_add(stored.len() as u64, Ordering::Relaxed);
                            Ok((self.cache.insert(Arc::clone(&path), stored), FetchSource::Local))
                        }
                        Err(e) => Err(e),
                    };
                    outcomes.push((path, outcome));
                }
            }

            // collect the batched replies, bounded by the call timeout
            for (holder, batch, reply) in pending {
                let files = reply
                    .and_then(|r| match transport.call_timeout() {
                        Some(t) => r.wait_timeout(t),
                        None => r.wait(),
                    })
                    .and_then(|resp| resp.into_files_data());
                match files {
                    Ok(files) => {
                        self.health.record_success(holder, None);
                        let mut by_path: HashMap<Arc<str>, FileFetch> = files.into_iter().collect();
                        for (path, _, attempts) in batch {
                            let outcome = match by_path.remove(&*path) {
                                Some(FileFetch::Data { stored }) => {
                                    stats.remote_reads_issued.fetch_add(1, Ordering::Relaxed);
                                    stats
                                        .bytes_fetched_remote
                                        .fetch_add(stored.len() as u64, Ordering::Relaxed);
                                    if attempts > 0 {
                                        stats.failovers.fetch_add(1, Ordering::Relaxed);
                                    }
                                    Ok((
                                        self.cache.insert(Arc::clone(&path), stored),
                                        FetchSource::Remote,
                                    ))
                                }
                                Some(FileFetch::NotFound) => {
                                    Err(FanError::NotFound(path.to_string()))
                                }
                                Some(FileFetch::Fault(e)) => {
                                    Err(FanError::Transport(format!("EIO {path}: {e}")))
                                }
                                None => Err(FanError::Transport(format!(
                                    "peer reply missing entry for {path}"
                                ))),
                            };
                            outcomes.push((path, outcome));
                        }
                    }
                    // peer down / timed out / malformed reply: feed the
                    // health map, then re-route each path to its next
                    // holder — or degrade with a real error if none remain
                    Err(e) => {
                        if self.health.record_failure(holder) {
                            stats.peers_marked_down.fetch_add(1, Ordering::Relaxed);
                            transport.evict(holder);
                        }
                        for (path, candidates, attempts) in batch {
                            if !candidates.is_empty() && attempts < retry_budget {
                                stats.retries.fetch_add(1, Ordering::Relaxed);
                                work.push((path, candidates, attempts + 1));
                            } else {
                                stats.degraded_reads.fetch_add(1, Ordering::Relaxed);
                                outcomes.push((
                                    path.clone(),
                                    Err(FanError::Transport(format!(
                                        "no live holder for {path} (node {holder} last: {e})"
                                    ))),
                                ));
                            }
                        }
                    }
                }
            }
            round += 1;
        }
        BatchedFetch {
            outcomes,
            remote_batches,
        }
    }

    /// Health probe: one `Ping`/`Pong` round trip to `peer`, feeding the
    /// outcome into the health map.  Returns `Ok(true)` iff the pong's
    /// epoch reveals the peer restarted since it was last identified.
    pub fn probe_peer(&self, transport: &dyn Transport, peer: u32) -> Result<bool> {
        match transport.call(self.id, peer, Request::Ping { epoch: self.epoch }) {
            Ok(Response::Pong { epoch }) => Ok(self.health.note_pong(peer, epoch)),
            Ok(other) => {
                self.health.record_failure(peer);
                Err(FanError::Transport(format!(
                    "peer {peer} answered ping with {other:?}"
                )))
            }
            Err(e) => {
                if self.health.record_failure(peer) {
                    self.stats.peers_marked_down.fetch_add(1, Ordering::Relaxed);
                    transport.evict(peer);
                }
                Err(e)
            }
        }
    }

    /// One keepalive tick (PR 9): probe every peer, feeding the health
    /// map.  Up/Suspect peers are probed every tick (failure *detection*
    /// between reads); Down peers only once their jittered backoff
    /// deadline passes (recovery *discovery* without hammering a corpse).
    /// A probe that finds a Down peer alive counts `peers_recovered`; a
    /// pong with a new epoch queues the restarted peer for reseeding by
    /// the next [`NodeShared::repair_tick`].  Normally driven by the
    /// recovery thread ([`NodeShared::start_recovery`]); tests call it
    /// directly for deterministic schedules.
    pub fn probe_tick(&self, transport: &dyn Transport) -> ProbeReport {
        let mut report = ProbeReport::default();
        let now = Instant::now();
        for peer in 0..self.placement.nodes {
            if peer == self.id {
                continue;
            }
            let was = self.health.state(peer);
            if was == crate::net::health::PeerState::Down {
                let sched = self.probe_sched.lock().unwrap()[peer as usize];
                if matches!(sched.next_at, Some(at) if now < at) {
                    continue; // still backing off this peer
                }
            }
            self.stats.probes_sent.fetch_add(1, Ordering::Relaxed);
            report.probes += 1;
            match self.probe_peer(transport, peer) {
                Ok(restarted) => {
                    if was == crate::net::health::PeerState::Down {
                        self.stats.peers_recovered.fetch_add(1, Ordering::Relaxed);
                        report.recovered += 1;
                    }
                    self.probe_sched.lock().unwrap()[peer as usize] = ProbeSched::default();
                    if restarted {
                        report.restarted.push(peer);
                        let mut rs = self.reseed.lock().unwrap();
                        if !rs.contains(&peer) {
                            rs.push(peer);
                        }
                    }
                }
                Err(_) => {
                    // schedule the re-probe on the seeded-jitter backoff
                    // curve; the attempt count only grows while the peer
                    // stays unreachable
                    let delay = {
                        let attempts = self.probe_sched.lock().unwrap()[peer as usize].attempts;
                        self.health.backoff(attempts)
                    };
                    let mut sched = self.probe_sched.lock().unwrap();
                    let s = &mut sched[peer as usize];
                    s.attempts = s.attempts.saturating_add(1);
                    s.next_at = Some(now + delay);
                }
            }
        }
        report
    }

    /// One repair tick (PR 9): re-converge toward full replication after
    /// the health map's view changed.
    ///
    /// * **Input partitions** — for every partition with a Down holder, a
    ///   replacement holder is computed deterministically
    ///   ([`Placement::adopt_node`]) from this node's own down-set and
    ///   recorded in the override map; if *this* node is the adoptee it
    ///   pulls the blob from the first live holder (`FetchPartition`) and
    ///   indexes it into the side store.
    /// * **Restarted peers** — partitions belonging to a peer the prober
    ///   saw restart are pushed back to it (`InstallPartition`) by its
    ///   lowest-id live co-holder.
    /// * **Outputs** — for every output homed here whose co-home set lost
    ///   a node, the lowest-id live home re-commits (pre-stamped
    ///   generation, `CommitOutput { stamped: true }`) to the adoptee.
    ///
    /// At most `repair_max_inflight` transfers start per tick; everything
    /// skipped is retried next tick (the under-replication predicate is
    /// re-derived, so the tick is idempotent and converges).
    pub fn repair_tick(&self, transport: &dyn Transport) -> RepairReport {
        let mut rep = RepairReport::default();
        let down: Vec<bool> = (0..self.placement.nodes)
            .map(|p| p != self.id && self.health.state(p) == crate::net::health::PeerState::Down)
            .collect();
        let budget = self.repair_max_inflight.max(1) as u64;
        let mut inflight = 0u64;

        // -- input partitions: pull-based adoption ----------------------
        if down.iter().any(|&d| d) {
            for pid in 0..self.placement.partitions {
                let holders = self.placement.partition_holders(pid);
                if !holders.iter().any(|&h| down[h as usize]) {
                    continue; // fully replicated (as far as we can see)
                }
                let live: Vec<u32> = holders
                    .iter()
                    .copied()
                    .filter(|&h| !down[h as usize])
                    .collect();
                if live.is_empty() {
                    continue; // no surviving copy to repair from
                }
                let start = (self.placement.partition_primary(pid) + 1) % self.placement.nodes;
                let Some(adoptee) =
                    self.placement
                        .adopt_node(&holders, start, |n| down[n as usize])
                else {
                    continue; // cluster too small / everyone else down
                };
                self.register_override(pid, adoptee);
                if adoptee != self.id || self.holds_partition(pid) {
                    continue;
                }
                if inflight >= budget {
                    continue; // throttled; next tick re-derives the need
                }
                inflight += 1;
                self.stats.repairs_started.fetch_add(1, Ordering::Relaxed);
                rep.started += 1;
                for &src in &self.health.order_candidates(&live, live[0]) {
                    if src == self.id {
                        continue;
                    }
                    let got = transport
                        .call(self.id, src, Request::FetchPartition { pid })
                        .and_then(|r| r.into_partition_data());
                    match got {
                        Ok(blob) => {
                            self.health.record_success(src, None);
                            if self.install_partition(pid, &blob).is_ok() {
                                self.stats.repairs_completed.fetch_add(1, Ordering::Relaxed);
                                self.stats
                                    .repaired_bytes
                                    .fetch_add(blob.len() as u64, Ordering::Relaxed);
                                rep.completed += 1;
                            }
                            break;
                        }
                        Err(_) => {
                            if self.health.record_failure(src) {
                                self.stats.peers_marked_down.fetch_add(1, Ordering::Relaxed);
                                transport.evict(src);
                            }
                        }
                    }
                }
            }
        }

        // -- restarted peers: push their partitions back ----------------
        let peers: Vec<u32> = std::mem::take(&mut *self.reseed.lock().unwrap());
        for peer in peers {
            let mut retry = false;
            for pid in 0..self.placement.partitions {
                let holders = self.placement.partition_holders(pid);
                if !holders.contains(&peer) {
                    continue;
                }
                // lowest-id live co-holder drives, so exactly one node
                // pushes each partition
                let driver = holders
                    .iter()
                    .copied()
                    .filter(|&h| h != peer && !down[h as usize])
                    .min();
                if driver != Some(self.id) {
                    continue;
                }
                if inflight >= budget {
                    retry = true;
                    continue;
                }
                let Ok(blob) = self.partition_blob(pid) else {
                    continue;
                };
                inflight += 1;
                self.stats.repairs_started.fetch_add(1, Ordering::Relaxed);
                rep.started += 1;
                let sent = transport.call(
                    self.id,
                    peer,
                    Request::InstallPartition {
                        pid,
                        blob: blob.clone(),
                    },
                );
                match sent {
                    Ok(Response::Ok) => {
                        self.stats.repairs_completed.fetch_add(1, Ordering::Relaxed);
                        self.stats
                            .repaired_bytes
                            .fetch_add(blob.len() as u64, Ordering::Relaxed);
                        rep.completed += 1;
                    }
                    _ => retry = true,
                }
            }
            if retry {
                let mut rs = self.reseed.lock().unwrap();
                if !rs.contains(&peer) {
                    rs.push(peer);
                }
            }
        }

        // -- outputs homed here: re-commit to an adopted home -----------
        if down.iter().any(|&d| d) {
            let my_outputs: Vec<(String, FileMeta)> = {
                let t = self.output_meta.read().unwrap();
                t.paths()
                    .filter_map(|p| t.get(p).map(|m| (p.clone(), m.clone())))
                    .collect()
            };
            for (path, meta) in my_outputs {
                let homes = self.placement.output_homes(&path);
                if !homes.contains(&self.id) {
                    continue; // adopted copies serve reads, they don't re-adopt
                }
                if !homes.iter().any(|&h| down[h as usize]) {
                    continue;
                }
                let live_min = homes
                    .iter()
                    .copied()
                    .filter(|&h| !down[h as usize])
                    .min();
                if live_min != Some(self.id) {
                    continue; // another live home drives this path
                }
                let start = (homes[0] + 1) % self.placement.nodes;
                let Some(adoptee) =
                    self.placement
                        .adopt_node(&homes, start, |n| down[n as usize])
                else {
                    continue;
                };
                let done_key = (path.clone(), adoptee);
                if self.output_repairs_done.lock().unwrap().contains(&done_key) {
                    continue;
                }
                let Some(data) = self.output_data.read().unwrap().get(&path).cloned() else {
                    continue; // meta-only entry (pre-replication commit)
                };
                if inflight >= budget {
                    continue;
                }
                inflight += 1;
                self.stats.repairs_started.fetch_add(1, Ordering::Relaxed);
                rep.started += 1;
                let bytes = data.len() as u64;
                let sent = transport.call(
                    self.id,
                    adoptee,
                    Request::CommitOutput {
                        path: path.as_str().into(),
                        meta,
                        data: data.into(),
                        stamped: true,
                    },
                );
                if matches!(sent, Ok(Response::Ok | Response::Meta { .. })) {
                    self.stats.repairs_completed.fetch_add(1, Ordering::Relaxed);
                    self.stats.repaired_bytes.fetch_add(bytes, Ordering::Relaxed);
                    rep.completed += 1;
                    self.output_repairs_done.lock().unwrap().insert(done_key);
                }
            }
        }
        rep
    }

    /// Spawn the background recovery thread (keepalive prober + repairer)
    /// once a transport exists — unlike the migrator this cannot happen at
    /// seal time, because probing needs the fabric.  No-op when
    /// `probe_interval_ms` is 0 (tests drive the ticks directly), on
    /// single-node clusters, or when already started.
    pub fn start_recovery(self: &Arc<Self>, transport: Arc<dyn Transport>) {
        if self.probe_interval_ms == 0 || self.placement.nodes < 2 {
            return;
        }
        let mut slot = self.recovery.lock().unwrap();
        if slot.is_some() {
            return;
        }
        let weak = Arc::downgrade(self);
        let stop = Arc::clone(&self.recovery_stop);
        let interval = Duration::from_millis(self.probe_interval_ms);
        let handle = std::thread::Builder::new()
            .name(format!("fanstore-recovery-{}", self.id))
            .spawn(move || recovery_loop(weak, stop, interval, transport))
            .expect("spawn recovery");
        *slot = Some(handle);
    }

    /// Stop and join the background recovery thread (idempotent; no-op
    /// when it was never started).  Called by cluster teardown,
    /// `kill_node`, and `Drop`.
    pub fn stop_recovery(&self) {
        let handle = self.recovery.lock().unwrap().take();
        if let Some(handle) = handle {
            let (lock, cv) = &*self.recovery_stop;
            *lock.lock().unwrap() = true;
            cv.notify_all();
            if handle.thread().id() != std::thread::current().id() {
                let _ = handle.join();
            }
        }
    }
}

/// Background recovery body (PR 9), shaped exactly like [`migrator_loop`]:
/// every `interval`, upgrade the node handle and run one probe tick plus
/// one repair tick.  Holds only a `Weak` between ticks and exits when the
/// node is gone or [`NodeShared::stop_recovery`] rings the condvar.
fn recovery_loop(
    node: Weak<NodeShared>,
    stop: Arc<(Mutex<bool>, Condvar)>,
    interval: Duration,
    transport: Arc<dyn Transport>,
) {
    let (lock, cv) = &*stop;
    let mut stopped = lock.lock().unwrap();
    loop {
        let (guard, timeout) = cv.wait_timeout(stopped, interval).unwrap();
        stopped = guard;
        if *stopped {
            return;
        }
        if timeout.timed_out() {
            // never hold the stop lock across a tick: stop_recovery must
            // always be able to ring the condvar promptly
            drop(stopped);
            match node.upgrade() {
                Some(shared) => {
                    shared.probe_tick(&*transport);
                    shared.repair_tick(&*transport);
                }
                None => return,
            }
            stopped = lock.lock().unwrap();
            if *stopped {
                return;
            }
        }
    }
}

impl Drop for NodeShared {
    fn drop(&mut self) {
        // belt-and-braces: the migrator only holds a Weak, so it would exit
        // on its next tick anyway, but an explicit stop keeps teardown
        // deterministic (no orphan tick racing directory cleanup)
        self.stop_recovery();
        self.stop_migrator();
    }
}

/// Handle to a running node: shared state + its worker thread.
pub struct FanStoreNode {
    pub id: u32,
    pub shared: Arc<NodeShared>,
    worker: Option<JoinHandle<u64>>,
}

impl FanStoreNode {
    /// Spawn the worker thread servicing `endpoint`.
    pub fn spawn(shared: Arc<NodeShared>, endpoint: NodeEndpoint) -> Self {
        let id = endpoint.node_id;
        let thread_shared = Arc::clone(&shared);
        let worker = std::thread::Builder::new()
            .name(format!("fanstore-node-{id}"))
            .spawn(move || {
                let mut served = 0u64;
                while let Ok(msg) = endpoint.inbox.recv() {
                    if matches!(msg.req, Request::Shutdown) {
                        msg.reply.send(Response::Ok);
                        break;
                    }
                    let resp = thread_shared.serve(&msg.req);
                    served += 1;
                    msg.reply.send(resp);
                }
                served
            })
            .expect("spawn node worker");
        FanStoreNode {
            id,
            shared,
            worker: Some(worker),
        }
    }

    /// Join the worker (after `Transport::shutdown_all`); returns requests
    /// served.
    pub fn join(mut self) -> u64 {
        self.join_worker()
    }

    /// Join the worker thread in place (after this node alone was sent
    /// `Shutdown` — `Cluster::kill_node`).  Idempotent: a later `join` /
    /// cluster-wide shutdown sees no handle and returns 0.
    pub fn join_worker(&mut self) -> u64 {
        self.worker
            .take()
            .map(|h| h.join().unwrap_or(0))
            .unwrap_or(0)
    }
}

/// Load a set of partition blobs into a node's store under `mount`
/// (launch-time only, before the builder is sealed).
pub fn load_partitions(
    builder: &mut NodeBuilder,
    parts: impl IntoIterator<Item = (u32, Vec<u8>)>,
    mount: &str,
) -> Result<u32> {
    let mut n = 0;
    for (pid, blob) in parts {
        n += builder.store.load_partition(pid, blob, mount)?;
    }
    Ok(n)
}

/// Build the replicated input-metadata table from partition blobs.
/// Every node runs this over the *full* partition list (metadata broadcast,
/// §5.3) even though it only dumps its own partitions' data.
pub fn index_input_metadata(
    table: &mut MetaTable,
    blobs: &[(u32, Vec<u8>)],
    mount: &str,
    placement: &Placement,
) -> Result<()> {
    for (pid, blob) in blobs {
        let mut reader = crate::partition::format::PartitionReader::new(blob)?;
        while let Some((e, data_off)) = reader.next_entry()? {
            let path = format!("{}/{}", mount.trim_end_matches('/'), e.name);
            table.insert(
                &path,
                FileMeta {
                    stat: e.stat,
                    location: FileLocation {
                        node: placement.partition_primary(*pid),
                        partition: *pid,
                        offset: data_off,
                        stored_len: e.stored_len(),
                        codec: e.codec,
                    },
                    generation: 0,
                },
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::metadata::record::FileStat;
    use crate::net::transport::InProcTransport;
    use crate::partition::builder::{build_partitions, InputFile};

    fn files(n: usize) -> Vec<InputFile> {
        (0..n)
            .map(|i| InputFile {
                path: format!("train/f{i}"),
                data: vec![i as u8; 100 + i],
            })
            .collect()
    }

    #[test]
    fn serve_read_local_file() {
        let fs = files(4);
        let (blobs, _) = build_partitions(&fs, 1, Codec::None).unwrap();
        let placement = Placement::new(1, 1, 1);
        let mut b = NodeBuilder::new(0, DiskStore::in_memory(), placement);
        b.store.load_partition(0, blobs[0].clone(), "/m").unwrap();
        let node = b.seal();
        let resp = node.serve(&Request::ReadFile {
            path: "/m/train/f2".into(),
        });
        match resp {
            Response::FileData { stored } => {
                assert_eq!(&stored[..], &vec![2u8; 102][..]);
                assert_eq!(stored.raw_len(), 102);
                assert_eq!(stored.codec(), Codec::None);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(node.stats.snapshot().remote_reads_served, 1);
    }

    #[test]
    fn serve_missing_is_error() {
        let placement = Placement::new(1, 1, 1);
        let node = NodeBuilder::new(0, DiskStore::in_memory(), placement).seal();
        assert!(matches!(
            node.serve(&Request::ReadFile { path: "/nope".into() }),
            Response::Err(_)
        ));
    }

    #[test]
    fn serve_is_lock_free_across_threads() {
        let fs = files(8);
        let (blobs, _) = build_partitions(&fs, 1, Codec::None).unwrap();
        let placement = Placement::new(1, 1, 1);
        let mut b = NodeBuilder::new(0, DiskStore::in_memory(), placement);
        b.store.load_partition(0, blobs[0].clone(), "/m").unwrap();
        let node = b.seal();
        let mut handles = Vec::new();
        for t in 0..8usize {
            let node = Arc::clone(&node);
            handles.push(std::thread::spawn(move || {
                for i in 0..200usize {
                    let f = (t + i) % 8;
                    let resp = node.serve(&Request::ReadFile {
                        path: format!("/m/train/f{f}").into(),
                    });
                    match resp {
                        Response::FileData { stored, .. } => {
                            assert_eq!(&stored[..], &vec![f as u8; 100 + f][..]);
                        }
                        other => panic!("unexpected {other:?}"),
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(node.stats.snapshot().remote_reads_served, 8 * 200);
    }

    #[test]
    fn worker_thread_end_to_end() {
        let fs = files(6);
        let (blobs, _) = build_partitions(&fs, 2, Codec::None).unwrap();
        let placement = Placement::new(2, 2, 1);
        let (tp, mut eps) = InProcTransport::fully_connected(2);
        let ep1 = eps.pop().unwrap();
        let _ep0 = eps.pop().unwrap();

        // node 1 holds partition 1 (files 1,3,5)
        let mut b1 = NodeBuilder::new(1, DiskStore::in_memory(), placement.clone());
        b1.store.load_partition(1, blobs[1].clone(), "/m").unwrap();
        let node1 = FanStoreNode::spawn(b1.seal(), ep1);

        // node 0 fetches a remote file from node 1
        let resp = tp
            .call(0, 1, Request::ReadFile { path: "/m/train/f3".into() })
            .unwrap();
        let stored = resp.into_file_data().unwrap();
        assert_eq!(&stored[..], &vec![3u8; 103][..]);
        assert_eq!(stored.raw_len(), 103);
        assert_eq!(stored.codec(), Codec::None);

        tp.shutdown_all();
        assert_eq!(node1.join(), 1);
    }

    #[test]
    fn commit_and_stat_output() {
        let placement = Placement::new(1, 1, 1);
        let node = NodeBuilder::new(0, DiskStore::in_memory(), placement).seal();
        let meta = FileMeta {
            stat: FileStat::regular(1, 42),
            location: FileLocation {
                node: 0,
                partition: u32::MAX,
                offset: 0,
                stored_len: 42,
                codec: Codec::None,
            },
            generation: 0,
        };
        node.serve(&Request::CommitOutput {
            path: "/out/ckpt_1.h5".into(),
            meta,
            data: vec![9u8; 42].into(),
            stamped: false,
        });
        match node.serve(&Request::StatOutput {
            path: "/out/ckpt_1.h5".into(),
        }) {
            Response::Meta { stat, origin, generation } => {
                assert_eq!(stat.size, 42);
                assert_eq!(origin, 0);
                assert!(generation > 0, "commit must stamp a generation");
            }
            other => panic!("unexpected {other:?}"),
        }
        match node.serve(&Request::ListOutputs { dir: "/out".into() }) {
            Response::Names(names) => assert_eq!(names, vec!["ckpt_1.h5"]),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn recommits_get_distinct_generations() {
        let placement = Placement::new(1, 1, 1);
        let node = NodeBuilder::new(0, DiskStore::in_memory(), placement).seal();
        let meta = FileMeta {
            stat: FileStat::regular(1, 9),
            location: FileLocation {
                node: 0,
                partition: u32::MAX,
                offset: 0,
                stored_len: 9,
                codec: Codec::None,
            },
            generation: 0,
        };
        let gen_of = |node: &NodeShared| match node.serve(&Request::StatOutput {
            path: "/o/x".into(),
        }) {
            Response::Meta { generation, .. } => generation,
            other => panic!("unexpected {other:?}"),
        };
        node.serve(&Request::CommitOutput {
            path: "/o/x".into(),
            meta: meta.clone(),
            data: vec![1u8; 8].into(),
            stamped: false,
        });
        let g1 = gen_of(&node);
        // same origin, same size, recommitted — the home must re-stamp
        node.serve(&Request::CommitOutput {
            path: "/o/x".into(),
            meta,
            data: vec![1u8; 8].into(),
            stamped: false,
        });
        let g2 = gen_of(&node);
        assert_ne!(g1, g2, "identical recommit must get a fresh generation");
    }

    #[test]
    fn serve_batched_stat_outputs_mixed() {
        let placement = Placement::new(1, 1, 1);
        let node = NodeBuilder::new(0, DiskStore::in_memory(), placement).seal();
        let meta = FileMeta {
            stat: FileStat::regular(1, 77),
            location: FileLocation {
                node: 0,
                partition: u32::MAX,
                offset: 0,
                stored_len: 77,
                codec: Codec::None,
            },
            generation: 0,
        };
        node.serve(&Request::CommitOutput {
            path: "/s/a".into(),
            meta,
            data: vec![2u8; 77].into(),
            stamped: false,
        });
        let resp = node.serve(&Request::StatOutputs {
            paths: vec!["/s/a".into(), "/s/ghost".into(), "/s/a".into()],
        });
        let metas = resp.into_metas().unwrap();
        assert_eq!(metas.len(), 3, "one outcome per path, request order");
        match &metas[0].1 {
            MetaFetch::Meta { stat, origin, generation } => {
                assert_eq!(stat.size, 77);
                assert_eq!(*origin, 0);
                assert!(*generation > 0);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(matches!(metas[1].1, MetaFetch::NotFound));
        assert!(matches!(metas[2].1, MetaFetch::Meta { .. }));
        // empty batch is a valid request
        match node.serve(&Request::StatOutputs { paths: vec![] }) {
            Response::Metas(v) => assert!(v.is_empty()),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn batched_fetch_helper_cache_local_and_error_outcomes() {
        let fs = files(4);
        let (blobs, _) = build_partitions(&fs, 1, Codec::None).unwrap();
        let placement = Placement::new(1, 1, 1);
        let mut b = NodeBuilder::new(0, DiskStore::in_memory(), placement);
        b.store.load_partition(0, blobs[0].clone(), "/m").unwrap();
        let node = b.seal();
        let (tp, _eps) = InProcTransport::fully_connected(1);
        let loc = FileLocation {
            node: 0,
            partition: 0,
            offset: 0,
            stored_len: 0,
            codec: Codec::None,
        };
        let batch = node.fetch_inputs_batched(
            &tp,
            vec![("/m/train/f1".into(), loc), ("/nope".into(), loc)],
        );
        assert_eq!(batch.remote_batches, 0, "single node: all local");
        assert_eq!(batch.outcomes.len(), 2);
        let mut pins = Vec::new();
        for (path, outcome) in batch.outcomes {
            match &*path {
                "/m/train/f1" => {
                    let (pin, src) = outcome.unwrap();
                    assert_eq!(src, FetchSource::Local);
                    assert_eq!(&pin[..], &vec![1u8; 101][..]);
                    pins.push((path, pin));
                }
                "/nope" => assert!(matches!(outcome, Err(FanError::NotFound(_)))),
                other => panic!("unexpected path {other}"),
            }
        }
        // a second fetch of the same path is a cache hit carrying its own pin
        let batch = node.fetch_inputs_batched(&tp, vec![("/m/train/f1".into(), loc)]);
        let (path, outcome) = batch.outcomes.into_iter().next().unwrap();
        let (pin, src) = outcome.unwrap();
        assert_eq!(src, FetchSource::Cache);
        pins.push((path, pin));
        for (path, pin) in pins {
            node.cache.release(&path, &pin);
        }
        assert_eq!(node.cache.resident_files(), 0, "all helper pins released");
        let st = node.stats.snapshot();
        assert_eq!(st.local_reads, 1, "one fetch despite two acquires");
    }

    #[test]
    fn batched_fetch_caches_stored_form_and_decodes_at_pickup() {
        // LZSS-at-rest files: the fetch inserts the *compressed* bytes into
        // the refcount cache (RAM scales with the compressed dataset) and
        // decode_payload is the single expand, with its counters
        let fs: Vec<InputFile> = (0..3)
            .map(|i| InputFile {
                path: format!("train/f{i}"),
                data: vec![i as u8; 4096],
            })
            .collect();
        let (blobs, bstats) = build_partitions(&fs, 1, Codec::Lzss(5)).unwrap();
        assert_eq!(bstats.compressed_files, 3);
        let placement = Placement::new(1, 1, 1);
        let mut b = NodeBuilder::new(0, DiskStore::in_memory(), placement);
        b.store.load_partition(0, blobs[0].clone(), "/m").unwrap();
        let node = b.seal();
        let (tp, _eps) = InProcTransport::fully_connected(1);
        let loc = FileLocation {
            node: 0,
            partition: 0,
            offset: 0,
            stored_len: 0,
            codec: Codec::None,
        };
        let batch = node.fetch_inputs_batched(&tp, vec![("/m/train/f2".into(), loc)]);
        let (path, outcome) = batch.outcomes.into_iter().next().unwrap();
        let (pin, src) = outcome.unwrap();
        assert_eq!(src, FetchSource::Local);
        assert_eq!(pin.codec(), Codec::Lzss(5));
        assert_eq!(pin.raw_len(), 4096);
        assert!(pin.len() < 4096 / 8, "cache pin holds the compressed bytes");
        assert!(node.cache.stats().resident_bytes < 4096 / 8);
        let raw = node.decode_payload(&pin).unwrap();
        assert_eq!(&raw[..], &vec![2u8; 4096][..]);
        let st = node.stats.snapshot();
        assert_eq!(st.decompressions, 1);
        assert_eq!(st.compressed_bytes_saved, 4096 - pin.len() as u64);
        node.cache.release(&path, &pin);
        assert_eq!(node.cache.resident_files(), 0);
    }

    #[test]
    fn serve_batched_mixed_outcomes_with_duplicates() {
        let fs = files(4);
        let (blobs, _) = build_partitions(&fs, 1, Codec::None).unwrap();
        let placement = Placement::new(1, 1, 1);
        let mut b = NodeBuilder::new(0, DiskStore::in_memory(), placement);
        b.store.load_partition(0, blobs[0].clone(), "/m").unwrap();
        let node = b.seal();
        let resp = node.serve(&Request::ReadFiles {
            paths: vec![
                "/m/train/f1".into(),
                "/nope".into(),
                "/m/train/f1".into(), // duplicate in one batch
                "/m/train/f3".into(),
            ],
        });
        let files = match resp {
            Response::FilesData(v) => v,
            other => panic!("unexpected {other:?}"),
        };
        assert_eq!(files.len(), 4);
        assert!(matches!(files[1].1, FileFetch::NotFound));
        for i in [0usize, 2] {
            match &files[i].1 {
                FileFetch::Data { stored, .. } => {
                    assert_eq!(&stored[..], &vec![1u8; 101][..])
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        match &files[3].1 {
            FileFetch::Data { stored, .. } => assert_eq!(&stored[..], &vec![3u8; 103][..]),
            other => panic!("unexpected {other:?}"),
        }
        let st = node.stats.snapshot();
        assert_eq!(st.remote_reads_served, 3, "the ENOENT entry is not a serve");
        assert_eq!(st.batched_reads_served, 1);
    }

    #[test]
    fn serve_batched_empty_is_empty() {
        let placement = Placement::new(1, 1, 1);
        let node = NodeBuilder::new(0, DiskStore::in_memory(), placement).seal();
        match node.serve(&Request::ReadFiles { paths: vec![] }) {
            Response::FilesData(v) => assert!(v.is_empty()),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn unlink_and_drop_output_requests() {
        // home removes the metadata and names the origin; the origin drops
        // its buffered bytes — both idempotence edges covered
        let placement = Placement::new(1, 1, 1);
        let node = NodeBuilder::new(0, DiskStore::in_memory(), placement).seal();
        let meta = FileMeta {
            stat: FileStat::regular(1, 5),
            location: FileLocation {
                node: 0,
                partition: u32::MAX,
                offset: 0,
                stored_len: 5,
                codec: Codec::None,
            },
            generation: 0,
        };
        node.serve(&Request::CommitOutput {
            path: "/o/x".into(),
            meta,
            data: vec![9u8; 5].into(),
            stamped: false,
        });
        match node.serve(&Request::UnlinkOutput { path: "/o/x".into() }) {
            Response::Meta { origin, stat, .. } => {
                assert_eq!(origin, 0);
                assert_eq!(stat.size, 5);
            }
            other => panic!("unexpected {other:?}"),
        }
        node.serve(&Request::DropOutput { path: "/o/x".into() });
        assert!(node.output_data.read().unwrap().is_empty(), "buffer GC'd");
        // second unlink is ENOENT; second drop is a no-op
        assert!(matches!(
            node.serve(&Request::UnlinkOutput { path: "/o/x".into() }),
            Response::Err(_)
        ));
        assert!(matches!(
            node.serve(&Request::DropOutput { path: "/o/x".into() }),
            Response::Ok
        ));
    }

    #[test]
    fn listing_cache_generation_stamp_rejects_stale_fills() {
        let placement = Placement::new(1, 1, 1);
        let node = NodeBuilder::new(0, DiskStore::in_memory(), placement).seal();
        let names = vec!["a.bin".to_string()];
        let g = node.listing_generation();
        node.install_listing("/d", g, &names);
        assert_eq!(&node.cached_listing("/d").unwrap()[..], &names[..]);
        // a commit invalidates and advances the generation...
        let meta = FileMeta {
            stat: FileStat::regular(1, 3),
            location: FileLocation {
                node: 0,
                partition: u32::MAX,
                offset: 0,
                stored_len: 3,
                codec: Codec::None,
            },
            generation: 0,
        };
        node.serve(&Request::CommitOutput {
            path: "/d/b".into(),
            meta,
            data: vec![4u8; 3].into(),
            stamped: false,
        });
        assert!(node.cached_listing("/d").is_none());
        // ...so a gather stamped before the commit cannot install stale data
        node.install_listing("/d", g, &names);
        assert!(node.cached_listing("/d").is_none(), "stale fill rejected");
        // the broadcast request invalidates too
        let g2 = node.listing_generation();
        node.install_listing("/d", g2, &names);
        assert!(node.cached_listing("/d").is_some());
        assert!(matches!(
            node.serve(&Request::InvalidateListings { path: "/d/b".into() }),
            Response::Ok
        ));
        assert!(node.cached_listing("/d").is_none());
        assert!(node.listing_generation() > g2);
        // unlink invalidates as well
        node.install_listing("/d", node.listing_generation(), &names);
        node.serve(&Request::UnlinkOutput { path: "/d/b".into() });
        assert!(node.cached_listing("/d").is_none());
    }

    #[test]
    fn listing_invalidation_is_directory_granular() {
        let placement = Placement::new(1, 1, 1);
        let node = NodeBuilder::new(0, DiskStore::in_memory(), placement).seal();
        let hot = vec!["hot.bin".to_string()];
        let deep = vec!["x".to_string()];
        // unrelated listing + every ancestor of the mutated path cached
        let g = node.listing_generation();
        node.install_listing("/other/dir", g, &hot);
        node.install_listing("/ckpt/run1", g, &deep);
        node.install_listing("/ckpt", g, &deep);
        node.install_listing("/", g, &deep);
        let meta = FileMeta {
            stat: FileStat::regular(1, 3),
            location: FileLocation {
                node: 0,
                partition: u32::MAX,
                offset: 0,
                stored_len: 3,
                codec: Codec::None,
            },
            generation: 0,
        };
        node.serve(&Request::CommitOutput {
            path: "/ckpt/run1/s0.bin".into(),
            meta,
            data: vec![5u8; 3].into(),
            stamped: false,
        });
        // the ancestor chain is retired...
        assert!(node.cached_listing("/ckpt/run1").is_none());
        assert!(node.cached_listing("/ckpt").is_none());
        assert!(node.cached_listing("/").is_none());
        // ...but the unrelated hot listing survives the checkpoint
        assert_eq!(&node.cached_listing("/other/dir").unwrap()[..], &hot[..]);
        // the targeted broadcast behaves identically
        let g = node.listing_generation();
        node.install_listing("/other/dir", g, &hot);
        node.install_listing("/ckpt/run1", g, &deep);
        node.serve(&Request::InvalidateListings { path: "/ckpt/run1/s1.bin".into() });
        assert!(node.cached_listing("/ckpt/run1").is_none());
        assert!(node.cached_listing("/other/dir").is_some(), "unrelated dir survives");
        // a dir nothing ever mutated accepts even a pre-bump stamp: the
        // generation barrier is per-directory, not a global watermark
        node.install_listing("/zzz", g, &hot);
        assert!(node.cached_listing("/zzz").is_some(), "untouched dir installs");
        // ...until a full invalidation raises the floor for every dir
        node.invalidate_listings();
        let stale = node.listing_generation() - 1;
        node.install_listing("/zzz", stale, &hot);
        assert!(node.cached_listing("/zzz").is_none(), "floor rejects pre-bump stamp");
    }

    #[test]
    fn batched_fetch_fails_over_to_replica_and_tracks_health() {
        // 3 nodes, 3 partitions, replication 2: holders(p) = {p, p+1 mod 3}.
        // Node 1 is dead before the epoch starts; reader node 0's fetches of
        // partition-1 files (preferred holder 1) must fail over to node 2
        // and walk node 1 Up → Suspect → Down in the health map.
        let fs = files(9);
        let (blobs, _) = build_partitions(&fs, 3, Codec::None).unwrap();
        let placement = Placement::new(3, 3, 2);
        let blobs: Vec<(u32, Vec<u8>)> =
            blobs.into_iter().enumerate().map(|(i, b)| (i as u32, b)).collect();
        let mut table = MetaTable::new();
        index_input_metadata(&mut table, &blobs, "/m", &placement).unwrap();
        let table = Arc::new(table);

        let (tp, mut eps) = InProcTransport::fully_connected(3);
        let ep2 = eps.pop().unwrap();
        drop(eps.pop()); // node 1: endpoint dropped = dead host
        let _ep0 = eps.pop().unwrap();

        let mut b2 = NodeBuilder::new(2, DiskStore::in_memory(), placement.clone());
        b2.store.load_partition(1, blobs[1].1.clone(), "/m").unwrap();
        b2.input_meta = Arc::clone(&table);
        let mut node2 = FanStoreNode::spawn(b2.seal(), ep2);

        let mut b0 = NodeBuilder::new(0, DiskStore::in_memory(), placement);
        b0.input_meta = Arc::clone(&table);
        let node0 = b0.seal();

        let fetch_one = |name: &str, want: Vec<u8>| {
            let path: Arc<str> = format!("/m/train/{name}").into();
            let loc = table.get(&path).unwrap().location;
            let batch = node0.fetch_inputs_batched(&tp, vec![(Arc::clone(&path), loc)]);
            let (p, outcome) = batch.outcomes.into_iter().next().unwrap();
            let (pin, src) = outcome.unwrap();
            assert_eq!(src, FetchSource::Remote);
            assert_eq!(&pin[..], &want[..]);
            node0.cache.release(&p, &pin);
        };
        // first partition-1 read: send to 1 fails, re-routed to 2
        fetch_one("f1", vec![1u8; 101]);
        let st = node0.stats.snapshot();
        assert_eq!((st.retries, st.failovers), (1, 1));
        assert_eq!(st.peers_marked_down, 0, "one failure only suspects");
        assert_eq!(node0.health.state(1), crate::net::health::PeerState::Suspect);
        // second read: node 1 tried once more (Suspect is still live),
        // second consecutive failure marks it Down
        fetch_one("f4", vec![4u8; 104]);
        let st = node0.stats.snapshot();
        assert_eq!((st.retries, st.failovers), (2, 2));
        assert_eq!(st.peers_marked_down, 1);
        assert_eq!(node0.health.state(1), crate::net::health::PeerState::Down);
        // third read: Down holder sinks to the back — node 2 is tried
        // first, no retry, no failover
        fetch_one("f7", vec![7u8; 107]);
        let st = node0.stats.snapshot();
        assert_eq!((st.retries, st.failovers), (2, 2));
        assert_eq!(st.remote_reads_issued, 3);
        assert_eq!(st.degraded_reads, 0);

        tp.shutdown_all();
        node2.join_worker();
    }

    #[test]
    fn all_holders_down_degrades_with_an_error_not_a_hang() {
        // 2 nodes, replication 1: partition 1's only holder is node 1,
        // which is dead.  The read must come back as a transport error
        // (EIO at the VFS boundary) promptly — never block.
        let fs = files(4);
        let (blobs, _) = build_partitions(&fs, 2, Codec::None).unwrap();
        let placement = Placement::new(2, 2, 1);
        let blobs: Vec<(u32, Vec<u8>)> =
            blobs.into_iter().enumerate().map(|(i, b)| (i as u32, b)).collect();
        let mut table = MetaTable::new();
        index_input_metadata(&mut table, &blobs, "/m", &placement).unwrap();

        let (tp, eps) = InProcTransport::fully_connected(2);
        drop(eps); // everyone dead; reader uses only its sealed state
        let mut b0 = NodeBuilder::new(0, DiskStore::in_memory(), placement);
        b0.input_meta = Arc::new(table);
        let node0 = b0.seal();

        let path: Arc<str> = "/m/train/f1".into();
        let loc = node0.input_meta.get(&path).unwrap().location;
        let t0 = std::time::Instant::now();
        let batch = node0.fetch_inputs_batched(&tp, vec![(Arc::clone(&path), loc)]);
        let (_, outcome) = batch.outcomes.into_iter().next().unwrap();
        assert!(matches!(outcome, Err(FanError::Transport(_))), "real errno");
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(5),
            "degraded read must be prompt"
        );
        let st = node0.stats.snapshot();
        assert_eq!(st.degraded_reads, 1);
        assert_eq!(st.retries, 0, "no other holder to retry");
        assert_eq!(batch.remote_batches, 0, "nothing was ever in flight");
    }

    #[test]
    fn ping_pong_probe_feeds_health_and_detects_restart() {
        let placement = Placement::new(2, 2, 1);
        let (tp, mut eps) = InProcTransport::fully_connected(2);
        let ep1 = eps.pop().unwrap();
        let _ep0 = eps.pop().unwrap();
        let b1 = NodeBuilder::new(1, DiskStore::in_memory(), placement.clone());
        let shared1 = b1.seal();
        let epoch1 = shared1.epoch;
        let mut node1 = FanStoreNode::spawn(shared1, ep1);

        let node0 = NodeBuilder::new(0, DiskStore::in_memory(), placement.clone()).seal();
        assert!(node0.epoch != epoch1, "every sealed node gets its own epoch");
        // first probe identifies the peer; a repeat is not a restart
        assert!(!node0.probe_peer(&tp, 1).unwrap());
        assert!(!node0.probe_peer(&tp, 1).unwrap());
        // a re-sealed node 1 (same id, new incarnation) answers with a new
        // epoch: the probe reports a restart
        tp.shutdown_all();
        node1.join_worker();
        let (tp2, mut eps2) = InProcTransport::fully_connected(2);
        let ep1b = eps2.pop().unwrap();
        let _ep0b = eps2.pop().unwrap();
        let mut node1b =
            FanStoreNode::spawn(NodeBuilder::new(1, DiskStore::in_memory(), placement).seal(), ep1b);
        assert!(node0.probe_peer(&tp2, 1).unwrap(), "new epoch = restart");
        // probing a dead peer is an error and feeds the failure counter
        tp2.shutdown_all();
        node1b.join_worker();
        assert!(node0.probe_peer(&tp2, 1).is_err());
        assert!(node0.probe_peer(&tp2, 1).is_err());
        assert_eq!(node0.health.state(1), crate::net::health::PeerState::Down);
        assert_eq!(node0.stats.snapshot().peers_marked_down, 1);
    }

    #[test]
    fn index_metadata_covers_all_partitions() {
        let fs = files(10);
        let (blobs, _) = build_partitions(&fs, 4, Codec::None).unwrap();
        let placement = Placement::new(4, 4, 1);
        let blobs: Vec<(u32, Vec<u8>)> = blobs.into_iter().enumerate().map(|(i, b)| (i as u32, b)).collect();
        let mut table = MetaTable::new();
        index_input_metadata(&mut table, &blobs, "/m", &placement).unwrap();
        assert_eq!(table.file_count(), 10);
        for i in 0..10 {
            let m = table.get(&format!("/m/train/f{i}")).unwrap();
            assert_eq!(m.location.partition, (i % 4) as u32);
            assert_eq!(m.location.node, (i % 4) as u32);
        }
    }

    #[test]
    fn probe_tick_backs_off_down_peers_and_counts_recovery() {
        use crate::net::health::PeerState;
        let placement = Placement::new(2, 2, 1);
        let (tp, mut eps) = InProcTransport::fully_connected(2);
        let ep1 = eps.pop().unwrap();
        let _ep0 = eps.pop().unwrap();
        let mut node1 =
            FanStoreNode::spawn(NodeBuilder::new(1, DiskStore::in_memory(), placement.clone()).seal(), ep1);

        // wide backoff window so "the immediate next tick skips a Down
        // peer" cannot flake on a loaded machine
        let mut b0 = NodeBuilder::new(0, DiskStore::in_memory(), placement.clone());
        b0.health_policy.backoff_base_ms = 200;
        b0.health_policy.backoff_cap_ms = 800;
        let node0 = b0.seal();

        // healthy peer: probed every tick, nothing recovered
        let r = node0.probe_tick(&tp);
        assert_eq!((r.probes, r.recovered), (1, 0));
        assert!(r.restarted.is_empty());

        // kill node 1: two failed probes walk it Suspect -> Down
        tp.shutdown_all();
        node1.join_worker();
        assert_eq!(node0.probe_tick(&tp).probes, 1);
        assert_eq!(node0.health.state(1), PeerState::Suspect);
        assert_eq!(node0.probe_tick(&tp).probes, 1);
        assert_eq!(node0.health.state(1), PeerState::Down);
        // Down peer sits on the jittered backoff schedule (>= 400ms here):
        // an immediate re-tick must not hammer the corpse
        let r = node0.probe_tick(&tp);
        assert_eq!(r.probes, 0, "down peer still backing off");

        // past the deadline, a probe goes out and finds the restarted
        // incarnation: recovery counted, reseed queued (new epoch)
        std::thread::sleep(Duration::from_millis(700));
        let (tp2, mut eps2) = InProcTransport::fully_connected(2);
        let ep1b = eps2.pop().unwrap();
        let _ep0b = eps2.pop().unwrap();
        let mut node1b =
            FanStoreNode::spawn(NodeBuilder::new(1, DiskStore::in_memory(), placement).seal(), ep1b);
        let r = node0.probe_tick(&tp2);
        assert_eq!((r.probes, r.recovered), (1, 1));
        assert_eq!(r.restarted, vec![1], "new epoch queues the peer for reseed");
        assert_eq!(node0.health.state(1), PeerState::Up);
        let st = node0.stats.snapshot();
        assert_eq!(st.probes_sent, 4, "skipped tick sent nothing");
        assert_eq!(st.peers_recovered, 1);
        assert_eq!(st.peers_marked_down, 1);
        tp2.shutdown_all();
        node1b.join_worker();
    }

    #[test]
    fn repair_tick_adopts_and_installs_missing_partition() {
        use crate::net::health::PeerState;
        // 3 nodes, 3 partitions, replication 2: holders(p) = {p, p+1 mod 3}.
        // Node 1 dies.  Deterministic adoption: partition 1 (holders {1,2},
        // scan starts after primary 1) -> node 0; partition 0 (holders
        // {0,1}) -> node 2; partition 2 has no down holder.
        let fs = files(9);
        let (blobs, _) = build_partitions(&fs, 3, Codec::None).unwrap();
        let placement = Placement::new(3, 3, 2);
        let blobs: Vec<(u32, Vec<u8>)> =
            blobs.into_iter().enumerate().map(|(i, b)| (i as u32, b)).collect();
        let mut table = MetaTable::new();
        index_input_metadata(&mut table, &blobs, "/m", &placement).unwrap();
        let table = Arc::new(table);

        let (tp, mut eps) = InProcTransport::fully_connected(3);
        let ep2 = eps.pop().unwrap();
        drop(eps.pop()); // node 1: dead host
        let _ep0 = eps.pop().unwrap();

        let mut b2 = NodeBuilder::new(2, DiskStore::in_memory(), placement.clone());
        b2.store.load_partition(1, blobs[1].1.clone(), "/m").unwrap();
        b2.store.load_partition(2, blobs[2].1.clone(), "/m").unwrap();
        b2.input_meta = Arc::clone(&table);
        let mut node2 = FanStoreNode::spawn(b2.seal(), ep2);

        let mut b0 = NodeBuilder::new(0, DiskStore::in_memory(), placement);
        b0.store.load_partition(0, blobs[0].1.clone(), "/m").unwrap();
        b0.store.load_partition(2, blobs[2].1.clone(), "/m").unwrap();
        b0.input_meta = Arc::clone(&table);
        b0.mount = "/m".to_string();
        let node0 = b0.seal();

        // node 0 has already observed node 1 Down (e.g. via failed reads)
        let _ = node0.health.record_failure(1);
        let _ = node0.health.record_failure(1);
        assert_eq!(node0.health.state(1), PeerState::Down);
        assert!(!node0.holds_partition(1));

        // one tick: node 0 adopts partition 1, pulling it from node 2, and
        // records node 2 as partition 0's adopted holder
        let rep = node0.repair_tick(&tp);
        assert_eq!(rep, RepairReport { started: 1, completed: 1 });
        assert!(node0.holds_partition(1), "adopted partition installed");
        assert_eq!(node0.candidate_holders(0), vec![0, 1, 2], "override appended");
        assert_eq!(node0.candidate_holders(1), vec![1, 2], "self-adoption is not an override");
        let st = node0.stats.snapshot();
        assert_eq!((st.repairs_started, st.repairs_completed), (1, 1));
        assert_eq!(st.repaired_bytes, blobs[1].1.len() as u64);

        // the tick is idempotent: the need re-derives to nothing
        assert_eq!(node0.repair_tick(&tp), RepairReport::default());
        assert_eq!(node0.stats.snapshot().repairs_started, 1);

        // partition-1 reads are now local on node 0...
        let path: Arc<str> = "/m/train/f4".into();
        let loc = table.get(&path).unwrap().location;
        let batch = node0.fetch_inputs_batched(&tp, vec![(Arc::clone(&path), loc)]);
        let (p, outcome) = batch.outcomes.into_iter().next().unwrap();
        let (pin, src) = outcome.unwrap();
        assert_eq!(src, FetchSource::Local);
        assert_eq!(&pin[..], &vec![4u8; 104][..]);
        node0.cache.release(&p, &pin);
        // ...and the repaired replica is itself a repair source
        match node0.serve(&Request::FetchPartition { pid: 1 }) {
            Response::PartitionData { blob } => assert_eq!(&blob[..], &blobs[1].1[..]),
            other => panic!("unexpected {other:?}"),
        }

        tp.shutdown_all();
        node2.join_worker();
    }

    #[test]
    fn install_partition_is_idempotent() {
        let fs = files(4);
        let (blobs, _) = build_partitions(&fs, 1, Codec::None).unwrap();
        let mut b = NodeBuilder::new(1, DiskStore::in_memory(), Placement::new(2, 1, 1));
        b.mount = "/m".to_string();
        let node = b.seal();
        assert!(!node.holds_partition(0));
        let blob: Payload = blobs[0].clone().into();
        assert_eq!(node.install_partition(0, &blob).unwrap(), 4);
        assert!(node.holds_partition(0));
        assert_eq!(node.install_partition(0, &blob).unwrap(), 0, "re-install is a no-op");
        // installed files land at the mount-indexed paths...
        match node.serve(&Request::ReadFile { path: "/m/train/f3".into() }) {
            Response::FileData { stored } => assert_eq!(&stored[..], &vec![3u8; 103][..]),
            other => panic!("unexpected {other:?}"),
        }
        // ...and the blob round-trips for onward repairs
        assert_eq!(&node.partition_blob(0).unwrap()[..], &blobs[0][..]);
    }

    #[test]
    fn concurrent_listing_gathers_install_per_directory() {
        let placement = Placement::new(1, 1, 1);
        let node = NodeBuilder::new(0, DiskStore::in_memory(), placement).seal();
        let meta = FileMeta {
            stat: FileStat::regular(1, 3),
            location: FileLocation {
                node: 0,
                partition: u32::MAX,
                offset: 0,
                stored_len: 3,
                codec: Codec::None,
            },
            generation: 0,
        };
        // two gathers sample the clock, then a commit lands in /ckpt while
        // both are still in flight
        let g_hot = node.listing_generation();
        let g_ckpt = node.listing_generation();
        node.serve(&Request::CommitOutput {
            path: "/ckpt/s0.bin".into(),
            meta: meta.clone(),
            data: vec![7u8; 3].into(),
            stamped: false,
        });
        // the mutated dir rejects its now-stale gather; the unrelated one
        // still installs — the watermark is per-directory, not global
        node.install_listing("/ckpt", g_ckpt, &["stale".to_string()]);
        assert!(node.cached_listing("/ckpt").is_none(), "stale gather rejected");
        let hot = vec!["hot.bin".to_string()];
        node.install_listing("/hot", g_hot, &hot);
        assert_eq!(&node.cached_listing("/hot").unwrap()[..], &hot[..]);

        // under real concurrency: a committer churns /churn while a gather
        // loop installs /stable — the untouched dir must always install
        let committer = {
            let node = Arc::clone(&node);
            let meta = meta.clone();
            std::thread::spawn(move || {
                for i in 0..50 {
                    node.serve(&Request::CommitOutput {
                        path: format!("/churn/c{i}").into(),
                        meta: meta.clone(),
                        data: vec![1u8; 3].into(),
                        stamped: false,
                    });
                }
            })
        };
        let gatherer = {
            let node = Arc::clone(&node);
            std::thread::spawn(move || {
                for _ in 0..200 {
                    let g = node.listing_generation();
                    node.install_listing("/stable", g, &["s".to_string()]);
                    assert!(
                        node.cached_listing("/stable").is_some(),
                        "unmutated dir always installs mid-churn"
                    );
                }
            })
        };
        committer.join().unwrap();
        gatherer.join().unwrap();
        // a gather that predates the churn stays rejected for /churn
        node.install_listing("/churn", g_ckpt, &["stale".to_string()]);
        assert!(node.cached_listing("/churn").is_none());
    }

    #[test]
    fn repair_tick_reseeds_restarted_peer() {
        // 2 nodes, 2 partitions, replication 2: both nodes hold everything.
        // Node 1 restarts empty; node 0 (its only live co-holder) pushes
        // both partitions back via InstallPartition.
        let fs = files(6);
        let (blobs, _) = build_partitions(&fs, 2, Codec::None).unwrap();
        let placement = Placement::new(2, 2, 2);

        let mut b0 = NodeBuilder::new(0, DiskStore::in_memory(), placement.clone());
        b0.store.load_partition(0, blobs[0].clone(), "/m").unwrap();
        b0.store.load_partition(1, blobs[1].clone(), "/m").unwrap();
        b0.mount = "/m".to_string();
        let node0 = b0.seal();

        // incarnation 1: probed once so node 0 learns its epoch
        let (tp, mut eps) = InProcTransport::fully_connected(2);
        let ep1 = eps.pop().unwrap();
        let _ep0 = eps.pop().unwrap();
        let mut b1 = NodeBuilder::new(1, DiskStore::in_memory(), placement.clone());
        b1.store.load_partition(0, blobs[0].clone(), "/m").unwrap();
        b1.store.load_partition(1, blobs[1].clone(), "/m").unwrap();
        let mut node1 = FanStoreNode::spawn(b1.seal(), ep1);
        assert!(!node0.probe_peer(&tp, 1).unwrap());
        tp.shutdown_all();
        node1.join_worker();

        // incarnation 2 comes back with nothing
        let (tp2, mut eps2) = InProcTransport::fully_connected(2);
        let ep1b = eps2.pop().unwrap();
        let _ep0b = eps2.pop().unwrap();
        let mut b1b = NodeBuilder::new(1, DiskStore::in_memory(), placement);
        b1b.mount = "/m".to_string();
        let shared1b = b1b.seal();
        let mut node1b = FanStoreNode::spawn(Arc::clone(&shared1b), ep1b);

        let r = node0.probe_tick(&tp2);
        assert_eq!((r.probes, r.recovered), (1, 0), "restart without an observed death");
        assert_eq!(r.restarted, vec![1]);
        let rep = node0.repair_tick(&tp2);
        assert_eq!(rep, RepairReport { started: 2, completed: 2 });
        let st = node0.stats.snapshot();
        assert_eq!(st.repaired_bytes, (blobs[0].len() + blobs[1].len()) as u64);
        assert!(shared1b.holds_partition(0) && shared1b.holds_partition(1));

        // the restarted peer serves reseeded data again, and the reseed
        // queue is drained
        let resp = tp2
            .call(0, 1, Request::ReadFile { path: "/m/train/f1".into() })
            .unwrap();
        assert_eq!(&resp.into_file_data().unwrap()[..], &vec![1u8; 101][..]);
        assert_eq!(node0.repair_tick(&tp2), RepairReport::default());

        tp2.shutdown_all();
        node1b.join_worker();
    }
}
