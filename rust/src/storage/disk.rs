//! Real local byte store used by in-process FanStore nodes.
//!
//! When a node loads a partition it "dumps the actual data into local
//! storage and builds an index of file path and storage place" (§5.2).
//! `DiskStore` is that local storage: one backing blob per partition, an
//! index of path → (partition, offset, stored_len, compressed, raw_len),
//! and optional spill to an actual directory on disk (tmpfs/SSD) so the
//! in-proc cluster exercises real file I/O when asked to.
//!
//! # Spilled-read modes
//!
//! Spilled partitions keep a persistent handle per blob, so a stored-range
//! read costs ([`SpillReadMode`]):
//!
//! | mode     | syscalls per read | copies | mechanism |
//! |----------|-------------------|--------|-----------|
//! | `Mmap`   | 0                 | 0      | [`Payload`] view of the mapped region |
//! | `Pread`  | 1                 | 1 (the read) | positioned read on the pooled fd |
//! | `Reopen` | 4 (open/seek/read/close) | 1 | the pre-pool baseline, kept for comparison |
//!
//! The map is created with raw libc syscalls (no crates.io in this build);
//! if mapping fails the partition silently degrades to pooled `pread`.
//! Per-mode read counters are exposed via [`DiskStore::spill_read_counts`]
//! and surface in `NodeStats`.
//!
//! [`DiskStore::read_stored`] hands out [`Payload`] handles: RAM-backed and
//! mmap-backed partitions serve **zero-copy views** whose `Arc` keeps the
//! blob/region alive (mapped) for the handle's lifetime — so the region is
//! only unmapped once the store *and* every outstanding reader, cache entry
//! and half-written frame are gone (the `Payload` ownership rules).

use std::collections::HashMap;
use std::fs;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::compress::Codec;
use crate::error::{FanError, Result};
use crate::metadata::record::FileStat;
use crate::partition::format::PartitionReader;
use crate::storage::payload::{Payload, PayloadRegion};

/// How stored ranges are read back out of spilled partition files.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SpillReadMode {
    /// open + seek + read + close per read (baseline; measurably slower).
    Reopen,
    /// One positioned read per range on a persistent per-partition handle.
    #[default]
    Pread,
    /// Zero-syscall memcpy out of an `mmap`'d region (falls back to
    /// `Pread` per partition if the map cannot be created).
    Mmap,
}

impl SpillReadMode {
    pub fn name(&self) -> &'static str {
        match self {
            SpillReadMode::Reopen => "reopen",
            SpillReadMode::Pread => "pread",
            SpillReadMode::Mmap => "mmap",
        }
    }

    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Option<SpillReadMode> {
        match s.to_ascii_lowercase().as_str() {
            "reopen" => Some(SpillReadMode::Reopen),
            "pread" => Some(SpillReadMode::Pread),
            "mmap" => Some(SpillReadMode::Mmap),
            _ => None,
        }
    }
}

/// Read-only memory map of one spilled partition file, created with raw
/// libc syscalls (the build has no crates.io, so no `memmap` crate).
/// Unmapped exactly once, on drop.
#[cfg(unix)]
mod mmap_region {
    use std::fs;
    use std::io;
    use std::os::unix::io::AsRawFd;

    const PROT_READ: i32 = 1;
    const MAP_SHARED: i32 = 1;

    extern "C" {
        fn mmap(
            addr: *mut u8,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut u8;
        fn munmap(addr: *mut u8, len: usize) -> i32;
    }

    pub struct MmapRegion {
        ptr: *mut u8,
        len: usize,
    }

    // The region is written before mapping, never mutated after, and
    // unmapped once on Drop — shared &[u8] views are safe across threads.
    unsafe impl Send for MmapRegion {}
    unsafe impl Sync for MmapRegion {}

    impl MmapRegion {
        pub fn map(file: &fs::File) -> io::Result<MmapRegion> {
            let len = file.metadata()?.len() as usize;
            if len == 0 {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidInput,
                    "cannot map an empty partition",
                ));
            }
            let ptr = unsafe {
                mmap(
                    std::ptr::null_mut(),
                    len,
                    PROT_READ,
                    MAP_SHARED,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr as isize == -1 {
                return Err(io::Error::last_os_error());
            }
            Ok(MmapRegion { ptr, len })
        }

        pub fn as_slice(&self) -> &[u8] {
            unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
        }
    }

    impl Drop for MmapRegion {
        fn drop(&mut self) {
            unsafe {
                munmap(self.ptr, self.len);
            }
        }
    }

    // lets `Payload` views borrow ranges of the map, keeping it mapped
    // (the Arc in the handle) until the last view is gone
    impl crate::storage::payload::PayloadRegion for MmapRegion {
        fn bytes(&self) -> &[u8] {
            self.as_slice()
        }
    }
}

#[cfg(unix)]
use mmap_region::MmapRegion;

/// Index entry for one stored file.
#[derive(Clone, Copy, Debug)]
pub struct StoredAt {
    pub partition: u32,
    pub offset: u64,
    pub stored_len: u64,
    pub raw_len: u64,
    pub codec: Codec,
}

/// Persistent read handles for one spilled partition: the blob path (for
/// `Reopen`), the pooled fd (`Pread` — positioned reads need no per-call
/// seek and share the handle lock-free), and the optional mapped region.
struct SpillFile {
    path: PathBuf,
    file: fs::File,
    #[cfg(unix)]
    map: Option<Arc<MmapRegion>>,
}

impl SpillFile {
    fn open(path: PathBuf, mode: SpillReadMode) -> Result<SpillFile> {
        let file = fs::File::open(&path)?;
        #[cfg(unix)]
        let map = if mode == SpillReadMode::Mmap {
            // a partition that cannot be mapped degrades to pooled pread
            MmapRegion::map(&file).ok().map(Arc::new)
        } else {
            None
        };
        #[cfg(not(unix))]
        let _ = mode;
        Ok(SpillFile {
            path,
            file,
            #[cfg(unix)]
            map,
        })
    }
}

/// Backing for partition blobs.
enum Backing {
    /// Blob kept in RAM (fast mode for tests and the simulator's "real
    /// logic" checks).  `Arc`'d so reads serve zero-copy `Payload` views.
    Ram(Arc<Vec<u8>>),
    /// Blob spilled to a file (real-I/O mode) with persistent handles.
    File(SpillFile),
}

/// Relaxed per-mode spilled-read tallies (merged into `NodeStats`).
#[derive(Debug, Default)]
struct SpillReadCounters {
    reopen: AtomicU64,
    pread: AtomicU64,
    mmap: AtomicU64,
}

/// A node's local store: dumped partitions + the path index.
pub struct DiskStore {
    partitions: HashMap<u32, Backing>,
    index: HashMap<String, StoredAt>,
    stats: HashMap<String, FileStat>,
    spill_dir: Option<PathBuf>,
    spill_mode: SpillReadMode,
    spill_counts: SpillReadCounters,
    bytes_stored: u64,
}

impl DiskStore {
    /// In-RAM store.
    pub fn in_memory() -> Self {
        DiskStore {
            partitions: HashMap::new(),
            index: HashMap::new(),
            stats: HashMap::new(),
            spill_dir: None,
            spill_mode: SpillReadMode::default(),
            spill_counts: SpillReadCounters::default(),
            bytes_stored: 0,
        }
    }

    /// Store that spills partition blobs to `dir` and reads them back with
    /// real file I/O (default [`SpillReadMode`]).
    pub fn on_disk(dir: impl Into<PathBuf>) -> Result<Self> {
        Self::on_disk_with_mode(dir, SpillReadMode::default())
    }

    /// [`DiskStore::on_disk`] with an explicit spilled-read mode.
    pub fn on_disk_with_mode(dir: impl Into<PathBuf>, mode: SpillReadMode) -> Result<Self> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        Ok(DiskStore {
            partitions: HashMap::new(),
            index: HashMap::new(),
            stats: HashMap::new(),
            spill_dir: Some(dir),
            spill_mode: mode,
            spill_counts: SpillReadCounters::default(),
            bytes_stored: 0,
        })
    }

    pub fn spill_read_mode(&self) -> SpillReadMode {
        self.spill_mode
    }

    /// Spilled reads served since launch as `(reopen, pread, mmap)`.
    pub fn spill_read_counts(&self) -> (u64, u64, u64) {
        (
            self.spill_counts.reopen.load(Ordering::Relaxed),
            self.spill_counts.pread.load(Ordering::Relaxed),
            self.spill_counts.mmap.load(Ordering::Relaxed),
        )
    }

    /// Load (dump) one partition blob, indexing every contained file under
    /// `mount`-prefixed paths (paper §5.2: `/fanstore/<user>/<orig-path>`).
    ///
    /// Atomic: a malformed/torn blob leaves the index untouched.
    pub fn load_partition(&mut self, pid: u32, blob: Vec<u8>, mount: &str) -> Result<u32> {
        let mut reader = PartitionReader::new(&blob)?;
        // stage the whole partition first; commit only on full success
        let mut staged = Vec::new();
        while let Some((e, data_off)) = reader.next_entry()? {
            let path = format!("{}/{}", mount.trim_end_matches('/'), e.name);
            staged.push((
                path,
                StoredAt {
                    partition: pid,
                    offset: data_off,
                    stored_len: e.stored_len(),
                    raw_len: e.stat.size,
                    codec: e.codec,
                },
                e.stat,
            ));
        }
        let mut n = 0u32;
        for (path, at, stat) in staged {
            self.index.insert(path.clone(), at);
            self.stats.insert(path, stat);
            n += 1;
        }
        self.bytes_stored += blob.len() as u64;
        let backing = match &self.spill_dir {
            None => Backing::Ram(Arc::new(blob)),
            Some(dir) => {
                let p = dir.join(format!("partition_{pid:05}.fan"));
                fs::write(&p, &blob)?;
                Backing::File(SpillFile::open(p, self.spill_mode)?)
            }
        };
        self.partitions.insert(pid, backing);
        Ok(n)
    }

    /// Stored-location lookup.
    pub fn locate(&self, path: &str) -> Option<&StoredAt> {
        self.index.get(path)
    }

    pub fn stat(&self, path: &str) -> Option<&FileStat> {
        self.stats.get(path)
    }

    /// Index lookup + backing handle for one stored file.
    fn backing_of(&self, path: &str) -> Result<(StoredAt, &Backing)> {
        let at = *self
            .index
            .get(path)
            .ok_or_else(|| FanError::NotFound(path.to_string()))?;
        let backing = self
            .partitions
            .get(&at.partition)
            .ok_or_else(|| FanError::Format(format!("missing partition {}", at.partition)))?;
        Ok((at, backing))
    }

    /// Read one stored range out of a spilled partition via the configured
    /// mode: a **zero-copy [`Payload`] view** of the mapped region, one
    /// positioned read on the pooled handle, or the open/seek/read
    /// baseline (those reads materialize owned bytes — the read *is* the
    /// single copy).
    fn read_spilled(&self, sf: &SpillFile, at: &StoredAt) -> Result<Payload> {
        let len = at.stored_len as usize;
        #[cfg(unix)]
        if let Some(map) = &sf.map {
            let m = map.as_slice();
            let off = at.offset as usize;
            if off.checked_add(len).map(|end| end > m.len()).unwrap_or(true) {
                return Err(FanError::Format(format!(
                    "stored range {off}+{len} exceeds mapped partition of {} bytes",
                    m.len()
                )));
            }
            self.spill_counts.mmap.fetch_add(1, Ordering::Relaxed);
            let region: Arc<dyn PayloadRegion> = Arc::clone(map) as Arc<dyn PayloadRegion>;
            return Ok(Payload::view(region, off, len));
        }
        match self.spill_mode {
            SpillReadMode::Reopen => {
                use std::io::{Read, Seek, SeekFrom};
                self.spill_counts.reopen.fetch_add(1, Ordering::Relaxed);
                let mut f = fs::File::open(&sf.path)?;
                f.seek(SeekFrom::Start(at.offset))?;
                let mut buf = vec![0u8; len];
                f.read_exact(&mut buf)?;
                Ok(buf.into())
            }
            // Pread, or Mmap whose region could not be created
            _ => {
                let mut buf = vec![0u8; len];
                #[cfg(unix)]
                {
                    use std::os::unix::fs::FileExt;
                    self.spill_counts.pread.fetch_add(1, Ordering::Relaxed);
                    sf.file.read_exact_at(&mut buf, at.offset)?;
                }
                #[cfg(not(unix))]
                {
                    // no positioned-read API: this really is a reopen, so
                    // count it honestly as one
                    use std::io::{Read, Seek, SeekFrom};
                    self.spill_counts.reopen.fetch_add(1, Ordering::Relaxed);
                    let mut f = fs::File::open(&sf.path)?;
                    f.seek(SeekFrom::Start(at.offset))?;
                    f.read_exact(&mut buf)?;
                }
                Ok(buf.into())
            }
        }
    }

    /// Lookup + backing dispatch shared by the stored and raw read paths.
    fn read_payload(&self, path: &str) -> Result<(Payload, StoredAt)> {
        let (at, backing) = self.backing_of(path)?;
        let payload = match backing {
            Backing::Ram(blob) => Payload::view(
                Arc::clone(blob) as Arc<dyn PayloadRegion>,
                at.offset as usize,
                at.stored_len as usize,
            ),
            Backing::File(sf) => self.read_spilled(sf, &at)?,
        };
        Ok((payload, at))
    }

    /// Read the *stored* bytes of `path` (compressed bytes when compressed —
    /// decompression happens on the reading node, §5.4).
    ///
    /// Returns a [`Payload`] handle: RAM and mmap backings serve a
    /// **zero-copy view** whose `Arc` keeps the blob/region alive for the
    /// handle's lifetime; pooled-pread/reopen backings serve owned bytes
    /// materialized by the disk read itself.  Compressed entries come back
    /// as a self-describing [`Payload::Compressed`] wrapper around that
    /// view, so the wire, the refcount cache and the VFS all know how (and
    /// how much) to decode without consulting the index again.  Everything
    /// downstream (worker serve path, transport response, refcount cache,
    /// VFS descriptors, the frame encoder's vectored send) clones the
    /// handle, never the bytes.
    pub fn read_stored(&self, path: &str) -> Result<(Payload, StoredAt)> {
        let (payload, at) = self.read_payload(path)?;
        Ok((Payload::compressed(at.codec, at.raw_len, payload), at))
    }

    /// Read + decompress to raw file contents.
    pub fn read_raw(&self, path: &str) -> Result<Vec<u8>> {
        let (stored, at) = self.read_payload(path)?;
        match at.codec {
            Codec::None => Ok(stored.to_vec()),
            codec => codec.decompress(&stored, at.raw_len as usize),
        }
    }

    pub fn file_count(&self) -> usize {
        self.index.len()
    }

    pub fn partition_count(&self) -> usize {
        self.partitions.len()
    }

    pub fn bytes_stored(&self) -> u64 {
        self.bytes_stored
    }

    /// Paths indexed here (unordered).
    pub fn paths(&self) -> impl Iterator<Item = &String> {
        self.index.keys()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::partition::builder::{build_partitions, InputFile};
    use crate::util::prng::Prng;
    use std::sync::atomic::AtomicU32;

    /// Unique per-test scratch directory, removed on drop, so concurrent
    /// tests in one process (or leftovers from a killed run) never collide.
    struct TestDir(PathBuf);

    impl TestDir {
        fn new(tag: &str) -> TestDir {
            static SEQ: AtomicU32 = AtomicU32::new(0);
            let dir = std::env::temp_dir().join(format!(
                "fanstore_test_{tag}_{}_{}",
                std::process::id(),
                SEQ.fetch_add(1, Ordering::Relaxed)
            ));
            std::fs::remove_dir_all(&dir).ok();
            TestDir(dir)
        }
    }

    impl Drop for TestDir {
        fn drop(&mut self) {
            std::fs::remove_dir_all(&self.0).ok();
        }
    }

    fn sample_files(n: usize) -> Vec<InputFile> {
        let mut rng = Prng::new(10);
        (0..n)
            .map(|i| {
                let mut data = vec![0u8; 256 + rng.index(512)];
                if i % 2 == 0 {
                    rng.fill_bytes(&mut data);
                } else {
                    data.fill(i as u8);
                }
                InputFile {
                    path: format!("train/class{}/img{i}.raw", i % 3),
                    data,
                }
            })
            .collect()
    }

    #[test]
    fn ram_store_roundtrip() {
        let files = sample_files(20);
        let (blobs, _) = build_partitions(&files, 2, Codec::Lzss(3)).unwrap();
        let mut store = DiskStore::in_memory();
        let mut loaded = 0;
        for (pid, blob) in blobs.into_iter().enumerate() {
            loaded += store.load_partition(pid as u32, blob, "/fanstore/u").unwrap();
        }
        assert_eq!(loaded, 20);
        assert_eq!(store.file_count(), 20);
        for f in &files {
            let path = format!("/fanstore/u/{}", f.path);
            assert_eq!(store.read_raw(&path).unwrap(), f.data, "{path}");
            assert_eq!(store.stat(&path).unwrap().size as usize, f.data.len());
        }
        assert_eq!(store.spill_read_counts(), (0, 0, 0), "RAM never spills");
    }

    #[test]
    fn disk_store_roundtrip() {
        let dir = TestDir::new("roundtrip");
        let files = sample_files(10);
        let (blobs, _) = build_partitions(&files, 3, Codec::None).unwrap();
        let mut store = DiskStore::on_disk(&dir.0).unwrap();
        for (pid, blob) in blobs.into_iter().enumerate() {
            store.load_partition(pid as u32, blob, "/fanstore/u").unwrap();
        }
        for f in &files {
            let path = format!("/fanstore/u/{}", f.path);
            assert_eq!(store.read_raw(&path).unwrap(), f.data);
        }
        // default mode pools the handle: one positioned read per file
        let (reopen, pread, mmap) = store.spill_read_counts();
        assert_eq!((reopen, mmap), (0, 0));
        assert_eq!(pread, 10);
    }

    #[test]
    fn every_spill_mode_roundtrips_and_counts() {
        let files = sample_files(12);
        let (blobs, _) = build_partitions(&files, 2, Codec::Lzss(3)).unwrap();
        for mode in [
            SpillReadMode::Reopen,
            SpillReadMode::Pread,
            SpillReadMode::Mmap,
        ] {
            let dir = TestDir::new(mode.name());
            let mut store = DiskStore::on_disk_with_mode(&dir.0, mode).unwrap();
            assert_eq!(store.spill_read_mode(), mode);
            for (pid, blob) in blobs.iter().enumerate() {
                store
                    .load_partition(pid as u32, blob.clone(), "/m")
                    .unwrap();
            }
            for f in &files {
                let path = format!("/m/{}", f.path);
                assert_eq!(store.read_raw(&path).unwrap(), f.data, "{mode:?} {path}");
                let (stored, at) = store.read_stored(&path).unwrap();
                assert_eq!(at.raw_len as usize, f.data.len());
                assert_eq!(stored.len() as u64, at.stored_len);
            }
            let (reopen, pread, mmap) = store.spill_read_counts();
            let total = reopen + pread + mmap;
            assert_eq!(total, 2 * files.len() as u64, "{mode:?}: {total}");
            match mode {
                SpillReadMode::Reopen => assert_eq!((pread, mmap), (0, 0)),
                SpillReadMode::Pread => assert_eq!((reopen, mmap), (0, 0)),
                // mmap may legitimately fall back to pread on exotic
                // filesystems, but must never reopen
                SpillReadMode::Mmap => assert_eq!(reopen, 0),
            }
        }
    }

    #[test]
    fn spill_mode_parse_roundtrip() {
        for mode in [
            SpillReadMode::Reopen,
            SpillReadMode::Pread,
            SpillReadMode::Mmap,
        ] {
            assert_eq!(SpillReadMode::parse(mode.name()), Some(mode));
        }
        assert_eq!(SpillReadMode::parse("MMAP"), Some(SpillReadMode::Mmap));
        assert_eq!(SpillReadMode::parse("nope"), None);
    }

    #[test]
    fn missing_path_is_not_found() {
        let store = DiskStore::in_memory();
        assert!(matches!(
            store.read_raw("/nope"),
            Err(FanError::NotFound(_))
        ));
    }

    #[test]
    fn read_stored_returns_compressed_bytes() {
        let files = vec![InputFile {
            path: "a/rle.bin".into(),
            data: vec![7u8; 8192],
        }];
        let (blobs, _) = build_partitions(&files, 1, Codec::Lzss(5)).unwrap();
        let mut store = DiskStore::in_memory();
        store
            .load_partition(0, blobs.into_iter().next().unwrap(), "/m")
            .unwrap();
        let (stored, at) = store.read_stored("/m/a/rle.bin").unwrap();
        assert_eq!(at.codec, Codec::Lzss(5));
        assert_eq!(stored.codec(), Codec::Lzss(5));
        assert_eq!(stored.raw_len(), 8192);
        assert!(stored.len() < 8192 / 10);
        assert_eq!(store.read_raw("/m/a/rle.bin").unwrap(), vec![7u8; 8192]);
    }
}
