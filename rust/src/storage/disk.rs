//! Real local byte store used by in-process FanStore nodes.
//!
//! When a node loads a partition it "dumps the actual data into local
//! storage and builds an index of file path and storage place" (§5.2).
//! `DiskStore` is that local storage: one backing blob per partition, an
//! index of path → (partition, offset, stored_len, compressed, raw_len),
//! and optional spill to an actual directory on disk (tmpfs/SSD) so the
//! in-proc cluster exercises real file I/O when asked to.

use std::collections::HashMap;
use std::fs;
use std::path::PathBuf;
use std::sync::Arc;

use crate::error::{FanError, Result};
use crate::metadata::record::FileStat;
use crate::partition::format::PartitionReader;

/// Index entry for one stored file.
#[derive(Clone, Copy, Debug)]
pub struct StoredAt {
    pub partition: u32,
    pub offset: u64,
    pub stored_len: u64,
    pub raw_len: u64,
    pub compressed: bool,
}

/// Backing for partition blobs.
enum Backing {
    /// Blob kept in RAM (fast mode for tests and the simulator's "real
    /// logic" checks).
    Ram(Vec<u8>),
    /// Blob spilled to a file (real-I/O mode).
    File(PathBuf),
}

/// A node's local store: dumped partitions + the path index.
pub struct DiskStore {
    partitions: HashMap<u32, Backing>,
    index: HashMap<String, StoredAt>,
    stats: HashMap<String, FileStat>,
    spill_dir: Option<PathBuf>,
    bytes_stored: u64,
}

impl DiskStore {
    /// In-RAM store.
    pub fn in_memory() -> Self {
        DiskStore {
            partitions: HashMap::new(),
            index: HashMap::new(),
            stats: HashMap::new(),
            spill_dir: None,
            bytes_stored: 0,
        }
    }

    /// Store that spills partition blobs to `dir` and reads them back with
    /// real file I/O.
    pub fn on_disk(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        Ok(DiskStore {
            partitions: HashMap::new(),
            index: HashMap::new(),
            stats: HashMap::new(),
            spill_dir: Some(dir),
            bytes_stored: 0,
        })
    }

    /// Load (dump) one partition blob, indexing every contained file under
    /// `mount`-prefixed paths (paper §5.2: `/fanstore/<user>/<orig-path>`).
    ///
    /// Atomic: a malformed/torn blob leaves the index untouched.
    pub fn load_partition(&mut self, pid: u32, blob: Vec<u8>, mount: &str) -> Result<u32> {
        let mut reader = PartitionReader::new(&blob)?;
        // stage the whole partition first; commit only on full success
        let mut staged = Vec::new();
        while let Some((e, data_off)) = reader.next_entry()? {
            let path = format!("{}/{}", mount.trim_end_matches('/'), e.name);
            staged.push((
                path,
                StoredAt {
                    partition: pid,
                    offset: data_off,
                    stored_len: e.stored_len(),
                    raw_len: e.stat.size,
                    compressed: e.is_compressed(),
                },
                e.stat,
            ));
        }
        let mut n = 0u32;
        for (path, at, stat) in staged {
            self.index.insert(path.clone(), at);
            self.stats.insert(path, stat);
            n += 1;
        }
        self.bytes_stored += blob.len() as u64;
        let backing = match &self.spill_dir {
            None => Backing::Ram(blob),
            Some(dir) => {
                let p = dir.join(format!("partition_{pid:05}.fan"));
                fs::write(&p, &blob)?;
                Backing::File(p)
            }
        };
        self.partitions.insert(pid, backing);
        Ok(n)
    }

    /// Stored-location lookup.
    pub fn locate(&self, path: &str) -> Option<&StoredAt> {
        self.index.get(path)
    }

    pub fn stat(&self, path: &str) -> Option<&FileStat> {
        self.stats.get(path)
    }

    /// Index lookup + backing handle for one stored file.
    fn backing_of(&self, path: &str) -> Result<(StoredAt, &Backing)> {
        let at = *self
            .index
            .get(path)
            .ok_or_else(|| FanError::NotFound(path.to_string()))?;
        let backing = self
            .partitions
            .get(&at.partition)
            .ok_or_else(|| FanError::Format(format!("missing partition {}", at.partition)))?;
        Ok((at, backing))
    }

    /// Read one stored range out of a spilled partition file.
    fn read_spilled(p: &std::path::Path, at: &StoredAt) -> Result<Vec<u8>> {
        use std::io::{Read, Seek, SeekFrom};
        let mut f = fs::File::open(p)?;
        f.seek(SeekFrom::Start(at.offset))?;
        let mut buf = vec![0u8; at.stored_len as usize];
        f.read_exact(&mut buf)?;
        Ok(buf)
    }

    /// Read the *stored* bytes of `path` (compressed bytes when compressed —
    /// decompression happens on the reading node, §5.4).
    ///
    /// Returns a shared `Arc<[u8]>` buffer materialized in one copy (that
    /// *is* the disk read); everything downstream (worker serve path,
    /// transport response, refcount cache, VFS descriptors) clones the Arc,
    /// never the payload.
    pub fn read_stored(&self, path: &str) -> Result<(Arc<[u8]>, StoredAt)> {
        let (at, backing) = self.backing_of(path)?;
        let bytes: Arc<[u8]> = match backing {
            Backing::Ram(blob) => {
                Arc::from(&blob[at.offset as usize..(at.offset + at.stored_len) as usize])
            }
            Backing::File(p) => Self::read_spilled(p, &at)?.into(),
        };
        Ok((bytes, at))
    }

    /// Read + decompress to raw file contents.
    pub fn read_raw(&self, path: &str) -> Result<Vec<u8>> {
        let (at, backing) = self.backing_of(path)?;
        let stored = match backing {
            Backing::Ram(blob) => {
                blob[at.offset as usize..(at.offset + at.stored_len) as usize].to_vec()
            }
            Backing::File(p) => Self::read_spilled(p, &at)?,
        };
        if at.compressed {
            crate::compress::lzss::decompress(&stored, at.raw_len as usize)
        } else {
            Ok(stored)
        }
    }

    pub fn file_count(&self) -> usize {
        self.index.len()
    }

    pub fn partition_count(&self) -> usize {
        self.partitions.len()
    }

    pub fn bytes_stored(&self) -> u64 {
        self.bytes_stored
    }

    /// Paths indexed here (unordered).
    pub fn paths(&self) -> impl Iterator<Item = &String> {
        self.index.keys()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::partition::builder::{build_partitions, InputFile};
    use crate::util::prng::Prng;

    fn sample_files(n: usize) -> Vec<InputFile> {
        let mut rng = Prng::new(10);
        (0..n)
            .map(|i| {
                let mut data = vec![0u8; 256 + rng.index(512)];
                if i % 2 == 0 {
                    rng.fill_bytes(&mut data);
                } else {
                    data.fill(i as u8);
                }
                InputFile {
                    path: format!("train/class{}/img{i}.raw", i % 3),
                    data,
                }
            })
            .collect()
    }

    #[test]
    fn ram_store_roundtrip() {
        let files = sample_files(20);
        let (blobs, _) = build_partitions(&files, 2, Codec::Lzss(3)).unwrap();
        let mut store = DiskStore::in_memory();
        let mut loaded = 0;
        for (pid, blob) in blobs.into_iter().enumerate() {
            loaded += store.load_partition(pid as u32, blob, "/fanstore/u").unwrap();
        }
        assert_eq!(loaded, 20);
        assert_eq!(store.file_count(), 20);
        for f in &files {
            let path = format!("/fanstore/u/{}", f.path);
            assert_eq!(store.read_raw(&path).unwrap(), f.data, "{path}");
            assert_eq!(store.stat(&path).unwrap().size as usize, f.data.len());
        }
    }

    #[test]
    fn disk_store_roundtrip() {
        let dir = std::env::temp_dir().join(format!("fanstore_test_{}", std::process::id()));
        let files = sample_files(10);
        let (blobs, _) = build_partitions(&files, 3, Codec::None).unwrap();
        let mut store = DiskStore::on_disk(&dir).unwrap();
        for (pid, blob) in blobs.into_iter().enumerate() {
            store.load_partition(pid as u32, blob, "/fanstore/u").unwrap();
        }
        for f in &files {
            let path = format!("/fanstore/u/{}", f.path);
            assert_eq!(store.read_raw(&path).unwrap(), f.data);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_path_is_not_found() {
        let store = DiskStore::in_memory();
        assert!(matches!(
            store.read_raw("/nope"),
            Err(FanError::NotFound(_))
        ));
    }

    #[test]
    fn read_stored_returns_compressed_bytes() {
        let files = vec![InputFile {
            path: "a/rle.bin".into(),
            data: vec![7u8; 8192],
        }];
        let (blobs, _) = build_partitions(&files, 1, Codec::Lzss(5)).unwrap();
        let mut store = DiskStore::in_memory();
        store
            .load_partition(0, blobs.into_iter().next().unwrap(), "/m")
            .unwrap();
        let (stored, at) = store.read_stored("/m/a/rle.bin").unwrap();
        assert!(at.compressed);
        assert!(stored.len() < 8192 / 10);
        assert_eq!(store.read_raw("/m/a/rle.bin").unwrap(), vec![7u8; 8192]);
    }
}
