//! Real local byte store used by in-process FanStore nodes.
//!
//! When a node loads a partition it "dumps the actual data into local
//! storage and builds an index of file path and storage place" (§5.2).
//! `DiskStore` is that local storage: one backing blob per partition, an
//! index of path → (partition, offset, stored_len, compressed, raw_len),
//! and optional spill to an actual directory on disk (tmpfs/SSD) so the
//! in-proc cluster exercises real file I/O when asked to.
//!
//! # Spilled-read modes
//!
//! Spilled partitions keep a persistent handle per blob, so a stored-range
//! read costs ([`SpillReadMode`]):
//!
//! | mode     | syscalls per read | copies | mechanism |
//! |----------|-------------------|--------|-----------|
//! | `Mmap`   | 0                 | 0      | [`Payload`] view of the mapped region |
//! | `Pread`  | 1                 | 1 (the read) | positioned read on the pooled fd |
//! | `Reopen` | 4 (open/seek/read/close) | 1 | the pre-pool baseline, kept for comparison |
//!
//! The map is created with raw libc syscalls (no crates.io in this build);
//! if mapping fails the partition silently degrades to pooled `pread`.
//! Per-mode read counters are exposed via [`DiskStore::spill_read_counts`]
//! and surface in `NodeStats`.
//!
//! # Tiered placement (PR 8)
//!
//! A partition's backing is no longer fixed at load time.  Each partition
//! lives in a [`PartitionSlot`]: an `RwLock`'d [`Backing`] plus a relaxed
//! heat counter bumped by every read (local *and* remote-served reads both
//! funnel through [`DiskStore::read_stored`], so heat sees every touch).
//! [`DiskStore::promote_partition`] swaps a spilled backing for a RAM blob
//! and [`DiskStore::demote_partition`] swaps a RAM blob back to its spill
//! file — atomically, under the `Payload` ownership rules: the old
//! backing's `Arc` (RAM blob or mmap region) stays alive until every
//! outstanding view drops, so in-flight descriptors, cache pins and queued
//! replies keep reading the old bytes and **no reader ever blocks on a
//! migration** (reads take the slot's read lock; the write lock is held
//! only for the pointer swap itself — the blob copy happens outside it).
//! The background migrator (`node::NodeShared`) drives these from a
//! [`PlacementPolicy`](crate::storage::placement::PlacementPolicy) fed by
//! [`DiskStore::take_heat`].
//!
//! [`DiskStore::read_stored`] hands out [`Payload`] handles: RAM-backed and
//! mmap-backed partitions serve **zero-copy views** whose `Arc` keeps the
//! blob/region alive (mapped) for the handle's lifetime — so the region is
//! only unmapped once the store *and* every outstanding reader, cache entry
//! and half-written frame are gone (the `Payload` ownership rules).

use std::collections::HashMap;
use std::fs;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::compress::Codec;
use crate::error::{FanError, Result};
use crate::metadata::record::FileStat;
use crate::partition::format::PartitionReader;
use crate::storage::payload::{Payload, PayloadRegion};
use crate::storage::placement::PartitionHeat;

/// How stored ranges are read back out of spilled partition files.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SpillReadMode {
    /// open + seek + read + close per read (baseline; measurably slower).
    Reopen,
    /// One positioned read per range on a persistent per-partition handle.
    #[default]
    Pread,
    /// Zero-syscall memcpy out of an `mmap`'d region (falls back to
    /// `Pread` per partition if the map cannot be created).
    Mmap,
}

impl SpillReadMode {
    pub fn name(&self) -> &'static str {
        match self {
            SpillReadMode::Reopen => "reopen",
            SpillReadMode::Pread => "pread",
            SpillReadMode::Mmap => "mmap",
        }
    }

    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Option<SpillReadMode> {
        match s.to_ascii_lowercase().as_str() {
            "reopen" => Some(SpillReadMode::Reopen),
            "pread" => Some(SpillReadMode::Pread),
            "mmap" => Some(SpillReadMode::Mmap),
            _ => None,
        }
    }
}

/// Read-only memory map of one spilled partition file, created with raw
/// libc syscalls (the build has no crates.io, so no `memmap` crate).
/// Unmapped exactly once, on drop.
#[cfg(unix)]
mod mmap_region {
    use std::fs;
    use std::io;
    use std::os::unix::io::AsRawFd;
    use std::sync::atomic::{AtomicU64, Ordering};

    const PROT_READ: i32 = 1;
    const MAP_SHARED: i32 = 1;
    const MADV_WILLNEED: i32 = 3;
    const MADV_DONTNEED: i32 = 4;
    const PAGE: usize = 4096;

    extern "C" {
        fn mmap(
            addr: *mut u8,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut u8;
        fn munmap(addr: *mut u8, len: usize) -> i32;
        fn madvise(addr: *mut u8, len: usize, advice: i32) -> i32;
    }

    /// Process-wide tally of successful `madvise` hints (relaxed,
    /// monotonic) — tests and benches snapshot before/after to prove the
    /// hints actually fired.
    static MADVISE_CALLS: AtomicU64 = AtomicU64::new(0);

    pub fn madvise_calls() -> u64 {
        MADVISE_CALLS.load(Ordering::Relaxed)
    }

    /// Page-residency hint passed down to the kernel.
    #[derive(Clone, Copy)]
    pub enum Advice {
        /// About to be read (prefetch pickup): fault pages in ahead of use.
        WillNeed,
        /// Gone cold (demotion, epoch tail): drop the page-cache references;
        /// a later read simply re-faults from the file.
        DontNeed,
    }

    pub struct MmapRegion {
        ptr: *mut u8,
        len: usize,
    }

    // The region is written before mapping, never mutated after, and
    // unmapped once on Drop — shared &[u8] views are safe across threads.
    unsafe impl Send for MmapRegion {}
    unsafe impl Sync for MmapRegion {}

    impl MmapRegion {
        pub fn map(file: &fs::File) -> io::Result<MmapRegion> {
            let len = file.metadata()?.len() as usize;
            if len == 0 {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidInput,
                    "cannot map an empty partition",
                ));
            }
            let ptr = unsafe {
                mmap(
                    std::ptr::null_mut(),
                    len,
                    PROT_READ,
                    MAP_SHARED,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr as isize == -1 {
                return Err(io::Error::last_os_error());
            }
            Ok(MmapRegion { ptr, len })
        }

        pub fn as_slice(&self) -> &[u8] {
            unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
        }

        /// Advise the kernel about `self[off..off + len]` (clamped to the
        /// region, start aligned down to a page as `madvise` requires).
        /// Best-effort: a refusing kernel is ignored; successes bump
        /// [`madvise_calls`].
        pub fn advise(&self, off: usize, len: usize, advice: Advice) {
            if len == 0 || off >= self.len {
                return;
            }
            let start = off & !(PAGE - 1);
            let end = off.saturating_add(len).min(self.len);
            let a = match advice {
                Advice::WillNeed => MADV_WILLNEED,
                Advice::DontNeed => MADV_DONTNEED,
            };
            let rc = unsafe { madvise(self.ptr.add(start), end - start, a) };
            if rc == 0 {
                MADVISE_CALLS.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    impl Drop for MmapRegion {
        fn drop(&mut self) {
            unsafe {
                munmap(self.ptr, self.len);
            }
        }
    }

    // lets `Payload` views borrow ranges of the map, keeping it mapped
    // (the Arc in the handle) until the last view is gone
    impl crate::storage::payload::PayloadRegion for MmapRegion {
        fn bytes(&self) -> &[u8] {
            self.as_slice()
        }
    }
}

#[cfg(unix)]
use mmap_region::MmapRegion;

/// Successful `madvise` hints issued since process start (0 off-unix).
#[cfg(unix)]
pub fn madvise_calls() -> u64 {
    mmap_region::madvise_calls()
}

#[cfg(not(unix))]
pub fn madvise_calls() -> u64 {
    0
}

/// Index entry for one stored file.
#[derive(Clone, Copy, Debug)]
pub struct StoredAt {
    pub partition: u32,
    pub offset: u64,
    pub stored_len: u64,
    pub raw_len: u64,
    pub codec: Codec,
}

/// Persistent read handles for one spilled partition: the blob path (for
/// `Reopen`), the pooled fd (`Pread` — positioned reads need no per-call
/// seek and share the handle lock-free), and the optional mapped region.
struct SpillFile {
    path: PathBuf,
    file: fs::File,
    #[cfg(unix)]
    map: Option<Arc<MmapRegion>>,
}

impl SpillFile {
    fn open(path: PathBuf, mode: SpillReadMode) -> Result<SpillFile> {
        let file = fs::File::open(&path)?;
        #[cfg(unix)]
        let map = if mode == SpillReadMode::Mmap {
            // a partition that cannot be mapped degrades to pooled pread
            MmapRegion::map(&file).ok().map(Arc::new)
        } else {
            None
        };
        #[cfg(not(unix))]
        let _ = mode;
        Ok(SpillFile {
            path,
            file,
            #[cfg(unix)]
            map,
        })
    }
}

/// Backing for partition blobs.
enum Backing {
    /// Blob kept in RAM (fast tier).  `Arc`'d so reads serve zero-copy
    /// `Payload` views that outlive a subsequent demotion.
    Ram(Arc<Vec<u8>>),
    /// Blob spilled to a file (slow tier) with persistent handles.
    File(SpillFile),
}

/// One partition's migratable state: the swappable backing plus the heat
/// counter the placement policy samples.  Reads take the read lock for the
/// duration of handle construction only; migrations do their byte copies
/// *outside* the write lock and hold it just for the swap.
struct PartitionSlot {
    backing: RwLock<Backing>,
    /// Touches since the last [`DiskStore::take_heat`] (relaxed).
    heat: AtomicU64,
    /// Stored blob size — identical in both tiers, used for budgeting.
    bytes: u64,
}

/// Relaxed per-mode spilled-read tallies (merged into `NodeStats`).
#[derive(Debug, Default)]
struct SpillReadCounters {
    reopen: AtomicU64,
    pread: AtomicU64,
    mmap: AtomicU64,
}

/// Relaxed tier-migration tallies (merged into `NodeStats`).
#[derive(Debug, Default)]
struct TierCounters {
    promotions: AtomicU64,
    demotions: AtomicU64,
    migrated_bytes: AtomicU64,
    /// Reads served out of the RAM tier.
    hot_hits: AtomicU64,
}

/// A node's local store: dumped partitions + the path index.
pub struct DiskStore {
    partitions: HashMap<u32, PartitionSlot>,
    index: HashMap<String, StoredAt>,
    stats: HashMap<String, FileStat>,
    spill_dir: Option<PathBuf>,
    spill_mode: SpillReadMode,
    spill_counts: SpillReadCounters,
    tier_counts: TierCounters,
    bytes_stored: u64,
}

impl DiskStore {
    /// In-RAM store.
    pub fn in_memory() -> Self {
        DiskStore {
            partitions: HashMap::new(),
            index: HashMap::new(),
            stats: HashMap::new(),
            spill_dir: None,
            spill_mode: SpillReadMode::default(),
            spill_counts: SpillReadCounters::default(),
            tier_counts: TierCounters::default(),
            bytes_stored: 0,
        }
    }

    /// Store that spills partition blobs to `dir` and reads them back with
    /// real file I/O (default [`SpillReadMode`]).
    pub fn on_disk(dir: impl Into<PathBuf>) -> Result<Self> {
        Self::on_disk_with_mode(dir, SpillReadMode::default())
    }

    /// [`DiskStore::on_disk`] with an explicit spilled-read mode.
    pub fn on_disk_with_mode(dir: impl Into<PathBuf>, mode: SpillReadMode) -> Result<Self> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        Ok(DiskStore {
            partitions: HashMap::new(),
            index: HashMap::new(),
            stats: HashMap::new(),
            spill_dir: Some(dir),
            spill_mode: mode,
            spill_counts: SpillReadCounters::default(),
            tier_counts: TierCounters::default(),
            bytes_stored: 0,
        })
    }

    pub fn spill_read_mode(&self) -> SpillReadMode {
        self.spill_mode
    }

    /// Whether this store can demote (it has somewhere to spill to).
    pub fn can_demote(&self) -> bool {
        self.spill_dir.is_some()
    }

    /// Spilled reads served since launch as `(reopen, pread, mmap)`.
    pub fn spill_read_counts(&self) -> (u64, u64, u64) {
        (
            self.spill_counts.reopen.load(Ordering::Relaxed),
            self.spill_counts.pread.load(Ordering::Relaxed),
            self.spill_counts.mmap.load(Ordering::Relaxed),
        )
    }

    /// Tier-migration tallies since launch as
    /// `(promotions, demotions, migrated_bytes, tier_hot_hits)`.
    pub fn tier_counts(&self) -> (u64, u64, u64, u64) {
        (
            self.tier_counts.promotions.load(Ordering::Relaxed),
            self.tier_counts.demotions.load(Ordering::Relaxed),
            self.tier_counts.migrated_bytes.load(Ordering::Relaxed),
            self.tier_counts.hot_hits.load(Ordering::Relaxed),
        )
    }

    /// Load (dump) one partition blob, indexing every contained file under
    /// `mount`-prefixed paths (paper §5.2: `/fanstore/<user>/<orig-path>`).
    ///
    /// Atomic: a malformed/torn blob leaves the index untouched.
    pub fn load_partition(&mut self, pid: u32, blob: Vec<u8>, mount: &str) -> Result<u32> {
        let mut reader = PartitionReader::new(&blob)?;
        // stage the whole partition first; commit only on full success
        let mut staged = Vec::new();
        while let Some((e, data_off)) = reader.next_entry()? {
            let path = format!("{}/{}", mount.trim_end_matches('/'), e.name);
            staged.push((
                path,
                StoredAt {
                    partition: pid,
                    offset: data_off,
                    stored_len: e.stored_len(),
                    raw_len: e.stat.size,
                    codec: e.codec,
                },
                e.stat,
            ));
        }
        let mut n = 0u32;
        for (path, at, stat) in staged {
            self.index.insert(path.clone(), at);
            self.stats.insert(path, stat);
            n += 1;
        }
        let blob_len = blob.len() as u64;
        self.bytes_stored += blob_len;
        let backing = match &self.spill_dir {
            None => Backing::Ram(Arc::new(blob)),
            Some(dir) => {
                let p = dir.join(format!("partition_{pid:05}.fan"));
                fs::write(&p, &blob)?;
                Backing::File(SpillFile::open(p, self.spill_mode)?)
            }
        };
        self.partitions.insert(
            pid,
            PartitionSlot {
                backing: RwLock::new(backing),
                heat: AtomicU64::new(0),
                bytes: blob_len,
            },
        );
        Ok(n)
    }

    /// Stored-location lookup.
    pub fn locate(&self, path: &str) -> Option<&StoredAt> {
        self.index.get(path)
    }

    pub fn stat(&self, path: &str) -> Option<&FileStat> {
        self.stats.get(path)
    }

    /// Index lookup + partition slot for one stored file.
    fn slot_of(&self, path: &str) -> Result<(StoredAt, &PartitionSlot)> {
        let at = *self
            .index
            .get(path)
            .ok_or_else(|| FanError::NotFound(path.to_string()))?;
        let slot = self
            .partitions
            .get(&at.partition)
            .ok_or_else(|| FanError::Format(format!("missing partition {}", at.partition)))?;
        Ok((at, slot))
    }

    /// Read one stored range out of a spilled partition via the configured
    /// mode: a **zero-copy [`Payload`] view** of the mapped region, one
    /// positioned read on the pooled handle, or the open/seek/read
    /// baseline (those reads materialize owned bytes — the read *is* the
    /// single copy).
    fn read_spilled(&self, sf: &SpillFile, at: &StoredAt) -> Result<Payload> {
        let len = at.stored_len as usize;
        #[cfg(unix)]
        if let Some(map) = &sf.map {
            let m = map.as_slice();
            let off = at.offset as usize;
            if off.checked_add(len).map(|end| end > m.len()).unwrap_or(true) {
                return Err(FanError::Format(format!(
                    "stored range {off}+{len} exceeds mapped partition of {} bytes",
                    m.len()
                )));
            }
            self.spill_counts.mmap.fetch_add(1, Ordering::Relaxed);
            let region: Arc<dyn PayloadRegion> = Arc::clone(map) as Arc<dyn PayloadRegion>;
            return Ok(Payload::view(region, off, len));
        }
        match self.spill_mode {
            SpillReadMode::Reopen => {
                use std::io::{Read, Seek, SeekFrom};
                self.spill_counts.reopen.fetch_add(1, Ordering::Relaxed);
                let mut f = fs::File::open(&sf.path)?;
                f.seek(SeekFrom::Start(at.offset))?;
                let mut buf = vec![0u8; len];
                f.read_exact(&mut buf)?;
                Ok(buf.into())
            }
            // Pread, or Mmap whose region could not be created
            _ => {
                let mut buf = vec![0u8; len];
                #[cfg(unix)]
                {
                    use std::os::unix::fs::FileExt;
                    self.spill_counts.pread.fetch_add(1, Ordering::Relaxed);
                    sf.file.read_exact_at(&mut buf, at.offset)?;
                }
                #[cfg(not(unix))]
                {
                    // no positioned-read API: this really is a reopen, so
                    // count it honestly as one
                    use std::io::{Read, Seek, SeekFrom};
                    self.spill_counts.reopen.fetch_add(1, Ordering::Relaxed);
                    let mut f = fs::File::open(&sf.path)?;
                    f.seek(SeekFrom::Start(at.offset))?;
                    f.read_exact(&mut buf)?;
                }
                Ok(buf.into())
            }
        }
    }

    /// Lookup + backing dispatch shared by the stored and raw read paths.
    /// Every call bumps the partition's heat counter (the placement
    /// policy's food) and holds the slot's read lock only while the
    /// handle is constructed — a concurrent migration waits for the swap,
    /// never the other way around.
    fn read_payload(&self, path: &str) -> Result<(Payload, StoredAt)> {
        let (at, slot) = self.slot_of(path)?;
        slot.heat.fetch_add(1, Ordering::Relaxed);
        let guard = slot.backing.read().expect("backing lock poisoned");
        let payload = match &*guard {
            Backing::Ram(blob) => {
                self.tier_counts.hot_hits.fetch_add(1, Ordering::Relaxed);
                Payload::view(
                    Arc::clone(blob) as Arc<dyn PayloadRegion>,
                    at.offset as usize,
                    at.stored_len as usize,
                )
            }
            Backing::File(sf) => self.read_spilled(sf, &at)?,
        };
        Ok((payload, at))
    }

    /// Read the *stored* bytes of `path` (compressed bytes when compressed —
    /// decompression happens on the reading node, §5.4).
    ///
    /// Returns a [`Payload`] handle: RAM and mmap backings serve a
    /// **zero-copy view** whose `Arc` keeps the blob/region alive for the
    /// handle's lifetime; pooled-pread/reopen backings serve owned bytes
    /// materialized by the disk read itself.  Compressed entries come back
    /// as a self-describing [`Payload::Compressed`] wrapper around that
    /// view, so the wire, the refcount cache and the VFS all know how (and
    /// how much) to decode without consulting the index again.  Everything
    /// downstream (worker serve path, transport response, refcount cache,
    /// VFS descriptors, the frame encoder's vectored send) clones the
    /// handle, never the bytes.
    pub fn read_stored(&self, path: &str) -> Result<(Payload, StoredAt)> {
        let (payload, at) = self.read_payload(path)?;
        Ok((Payload::compressed(at.codec, at.raw_len, payload), at))
    }

    /// Read + decompress to raw file contents.
    pub fn read_raw(&self, path: &str) -> Result<Vec<u8>> {
        let (stored, at) = self.read_payload(path)?;
        match at.codec {
            Codec::None => Ok(stored.to_vec()),
            codec => codec.decompress(&stored, at.raw_len as usize),
        }
    }

    /// Promote a spilled partition into the RAM tier.  Returns the bytes
    /// moved (0 if already resident or lost a race).  The blob is read
    /// from disk *outside* the write lock; the lock is held only for the
    /// swap.  The displaced `SpillFile` drops here, but its mmap region
    /// stays alive (mapped) through any outstanding `Payload` views — the
    /// ownership rules make the swap invisible to in-flight readers.
    pub fn promote_partition(&self, pid: u32) -> Result<u64> {
        let slot = self
            .partitions
            .get(&pid)
            .ok_or_else(|| FanError::Format(format!("missing partition {pid}")))?;
        let path = {
            let guard = slot.backing.read().expect("backing lock poisoned");
            match &*guard {
                Backing::Ram(_) => return Ok(0),
                Backing::File(sf) => sf.path.clone(),
            }
        };
        let blob = fs::read(&path)?;
        let n = blob.len() as u64;
        let mut guard = slot.backing.write().expect("backing lock poisoned");
        if matches!(&*guard, Backing::Ram(_)) {
            return Ok(0); // lost a promote race; keep the winner's blob
        }
        *guard = Backing::Ram(Arc::new(blob));
        drop(guard);
        self.tier_counts.promotions.fetch_add(1, Ordering::Relaxed);
        self.tier_counts.migrated_bytes.fetch_add(n, Ordering::Relaxed);
        Ok(n)
    }

    /// Demote a RAM-resident partition back to its spill file.  Returns
    /// the bytes moved (0 if already spilled or lost a race).  The spill
    /// file persists across a promotion, so this usually just reopens it;
    /// the file is (re)written only when missing or torn.  The displaced
    /// RAM blob's `Arc` keeps serving outstanding `Payload` views until
    /// they drop.  Requires a spill dir ([`DiskStore::can_demote`]).
    pub fn demote_partition(&self, pid: u32) -> Result<u64> {
        let dir = self
            .spill_dir
            .as_ref()
            .ok_or_else(|| FanError::Format("demotion requires a spill dir".to_string()))?;
        let slot = self
            .partitions
            .get(&pid)
            .ok_or_else(|| FanError::Format(format!("missing partition {pid}")))?;
        let blob = {
            let guard = slot.backing.read().expect("backing lock poisoned");
            match &*guard {
                Backing::File(_) => return Ok(0),
                Backing::Ram(b) => Arc::clone(b),
            }
        };
        let p = dir.join(format!("partition_{pid:05}.fan"));
        let torn = fs::metadata(&p)
            .map(|m| m.len() != blob.len() as u64)
            .unwrap_or(true);
        if torn {
            fs::write(&p, &blob[..])?;
        }
        let sf = SpillFile::open(p, self.spill_mode)?;
        #[cfg(unix)]
        if let Some(map) = &sf.map {
            // cold data: tell the kernel to drop the pages now rather than
            // under pressure later; a future read re-faults from the file
            map.advise(0, blob.len(), mmap_region::Advice::DontNeed);
        }
        let n = blob.len() as u64;
        let mut guard = slot.backing.write().expect("backing lock poisoned");
        if matches!(&*guard, Backing::File(_)) {
            return Ok(0); // lost a demote race
        }
        *guard = Backing::File(sf);
        drop(guard);
        self.tier_counts.demotions.fetch_add(1, Ordering::Relaxed);
        self.tier_counts.migrated_bytes.fetch_add(n, Ordering::Relaxed);
        Ok(n)
    }

    /// Hint the kernel to fault in `path`'s stored range ahead of an
    /// imminent read (prefetch pickup).  No-op for RAM / pread / reopen
    /// backings — only mapped spill files have pages to advise.
    pub fn advise_willneed(&self, path: &str) {
        #[cfg(unix)]
        if let Ok((at, slot)) = self.slot_of(path) {
            if let Backing::File(sf) = &*slot.backing.read().expect("backing lock poisoned") {
                if let Some(map) = &sf.map {
                    map.advise(
                        at.offset as usize,
                        at.stored_len as usize,
                        mmap_region::Advice::WillNeed,
                    );
                }
            }
        }
        #[cfg(not(unix))]
        let _ = path;
    }

    /// Hint the kernel that a mapped spilled partition has gone cold
    /// (epoch tail): drop its page-cache references now.  No-op for RAM
    /// or unmapped backings.
    pub fn advise_dontneed_partition(&self, pid: u32) {
        #[cfg(unix)]
        if let Some(slot) = self.partitions.get(&pid) {
            if let Backing::File(sf) = &*slot.backing.read().expect("backing lock poisoned") {
                if let Some(map) = &sf.map {
                    map.advise(0, map.as_slice().len(), mmap_region::Advice::DontNeed);
                }
            }
        }
        #[cfg(not(unix))]
        let _ = pid;
    }

    /// Does this store hold partition `pid` (either tier)?
    pub fn has_partition(&self, pid: u32) -> bool {
        self.partitions.contains_key(&pid)
    }

    /// Read the entire container blob of partition `pid` — the unit the
    /// re-replicator streams node-to-node ([`FetchPartition`] serves it,
    /// the adoptee re-indexes it with `load_partition`).  RAM backings
    /// hand out a zero-copy [`Payload`] view over the whole blob; spilled
    /// backings materialize it with one `fs::read` outside the backing
    /// lock (repair is a background path — it must not pin the lock for
    /// the duration of a disk read).
    ///
    /// [`FetchPartition`]: crate::net::transport::Request::FetchPartition
    pub fn partition_blob(&self, pid: u32) -> Result<Payload> {
        let slot = self
            .partitions
            .get(&pid)
            .ok_or_else(|| FanError::Format(format!("missing partition {pid}")))?;
        let path = {
            let guard = slot.backing.read().expect("backing lock poisoned");
            match &*guard {
                Backing::Ram(blob) => {
                    let len = blob.len();
                    return Ok(Payload::view(
                        Arc::clone(blob) as Arc<dyn PayloadRegion>,
                        0,
                        len,
                    ));
                }
                Backing::File(sf) => sf.path.clone(),
            }
        };
        Ok(fs::read(path)?.into())
    }

    /// Whether partition `pid` currently lives in the RAM tier.
    pub fn partition_resident(&self, pid: u32) -> Option<bool> {
        self.partitions.get(&pid).map(|slot| {
            matches!(
                &*slot.backing.read().expect("backing lock poisoned"),
                Backing::Ram(_)
            )
        })
    }

    /// Bytes currently held by RAM-tier backings (budget enforcement).
    pub fn ram_resident_bytes(&self) -> u64 {
        self.partitions
            .values()
            .filter(|slot| {
                matches!(
                    &*slot.backing.read().expect("backing lock poisoned"),
                    Backing::Ram(_)
                )
            })
            .map(|slot| slot.bytes)
            .sum()
    }

    /// Drain this interval's heat sample for the placement policy: each
    /// partition's touches since the last call (counter swaps to 0), its
    /// current tier and its blob size.  Sorted by pid for determinism.
    pub fn take_heat(&self) -> Vec<PartitionHeat> {
        let mut v: Vec<PartitionHeat> = self
            .partitions
            .iter()
            .map(|(pid, slot)| PartitionHeat {
                pid: *pid,
                touches: slot.heat.swap(0, Ordering::Relaxed),
                resident: matches!(
                    &*slot.backing.read().expect("backing lock poisoned"),
                    Backing::Ram(_)
                ),
                bytes: slot.bytes,
            })
            .collect();
        v.sort_by_key(|h| h.pid);
        v
    }

    pub fn file_count(&self) -> usize {
        self.index.len()
    }

    pub fn partition_count(&self) -> usize {
        self.partitions.len()
    }

    pub fn bytes_stored(&self) -> u64 {
        self.bytes_stored
    }

    /// Paths indexed here (unordered).
    pub fn paths(&self) -> impl Iterator<Item = &String> {
        self.index.keys()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::partition::builder::{build_partitions, InputFile};
    use crate::util::prng::Prng;
    use std::sync::atomic::AtomicU32;

    /// Unique per-test scratch directory, removed on drop, so concurrent
    /// tests in one process (or leftovers from a killed run) never collide.
    struct TestDir(PathBuf);

    impl TestDir {
        fn new(tag: &str) -> TestDir {
            static SEQ: AtomicU32 = AtomicU32::new(0);
            let dir = std::env::temp_dir().join(format!(
                "fanstore_test_{tag}_{}_{}",
                std::process::id(),
                SEQ.fetch_add(1, Ordering::Relaxed)
            ));
            std::fs::remove_dir_all(&dir).ok();
            TestDir(dir)
        }
    }

    impl Drop for TestDir {
        fn drop(&mut self) {
            std::fs::remove_dir_all(&self.0).ok();
        }
    }

    fn sample_files(n: usize) -> Vec<InputFile> {
        let mut rng = Prng::new(10);
        (0..n)
            .map(|i| {
                let mut data = vec![0u8; 256 + rng.index(512)];
                if i % 2 == 0 {
                    rng.fill_bytes(&mut data);
                } else {
                    data.fill(i as u8);
                }
                InputFile {
                    path: format!("train/class{}/img{i}.raw", i % 3),
                    data,
                }
            })
            .collect()
    }

    #[test]
    fn ram_store_roundtrip() {
        let files = sample_files(20);
        let (blobs, _) = build_partitions(&files, 2, Codec::Lzss(3)).unwrap();
        let mut store = DiskStore::in_memory();
        let mut loaded = 0;
        for (pid, blob) in blobs.into_iter().enumerate() {
            loaded += store.load_partition(pid as u32, blob, "/fanstore/u").unwrap();
        }
        assert_eq!(loaded, 20);
        assert_eq!(store.file_count(), 20);
        for f in &files {
            let path = format!("/fanstore/u/{}", f.path);
            assert_eq!(store.read_raw(&path).unwrap(), f.data, "{path}");
            assert_eq!(store.stat(&path).unwrap().size as usize, f.data.len());
        }
        assert_eq!(store.spill_read_counts(), (0, 0, 0), "RAM never spills");
        // every RAM read is a hot-tier hit
        let (p, d, mb, hot) = store.tier_counts();
        assert_eq!((p, d, mb), (0, 0, 0));
        assert_eq!(hot, 20);
    }

    #[test]
    fn disk_store_roundtrip() {
        let dir = TestDir::new("roundtrip");
        let files = sample_files(10);
        let (blobs, _) = build_partitions(&files, 3, Codec::None).unwrap();
        let mut store = DiskStore::on_disk(&dir.0).unwrap();
        for (pid, blob) in blobs.into_iter().enumerate() {
            store.load_partition(pid as u32, blob, "/fanstore/u").unwrap();
        }
        for f in &files {
            let path = format!("/fanstore/u/{}", f.path);
            assert_eq!(store.read_raw(&path).unwrap(), f.data);
        }
        // default mode pools the handle: one positioned read per file
        let (reopen, pread, mmap) = store.spill_read_counts();
        assert_eq!((reopen, mmap), (0, 0));
        assert_eq!(pread, 10);
    }

    #[test]
    fn every_spill_mode_roundtrips_and_counts() {
        let files = sample_files(12);
        let (blobs, _) = build_partitions(&files, 2, Codec::Lzss(3)).unwrap();
        for mode in [
            SpillReadMode::Reopen,
            SpillReadMode::Pread,
            SpillReadMode::Mmap,
        ] {
            let dir = TestDir::new(mode.name());
            let mut store = DiskStore::on_disk_with_mode(&dir.0, mode).unwrap();
            assert_eq!(store.spill_read_mode(), mode);
            for (pid, blob) in blobs.iter().enumerate() {
                store
                    .load_partition(pid as u32, blob.clone(), "/m")
                    .unwrap();
            }
            for f in &files {
                let path = format!("/m/{}", f.path);
                assert_eq!(store.read_raw(&path).unwrap(), f.data, "{mode:?} {path}");
                let (stored, at) = store.read_stored(&path).unwrap();
                assert_eq!(at.raw_len as usize, f.data.len());
                assert_eq!(stored.len() as u64, at.stored_len);
            }
            let (reopen, pread, mmap) = store.spill_read_counts();
            let total = reopen + pread + mmap;
            assert_eq!(total, 2 * files.len() as u64, "{mode:?}: {total}");
            match mode {
                SpillReadMode::Reopen => assert_eq!((pread, mmap), (0, 0)),
                SpillReadMode::Pread => assert_eq!((reopen, mmap), (0, 0)),
                // mmap may legitimately fall back to pread on exotic
                // filesystems, but must never reopen
                SpillReadMode::Mmap => assert_eq!(reopen, 0),
            }
        }
    }

    #[test]
    fn spill_mode_parse_roundtrip() {
        for mode in [
            SpillReadMode::Reopen,
            SpillReadMode::Pread,
            SpillReadMode::Mmap,
        ] {
            assert_eq!(SpillReadMode::parse(mode.name()), Some(mode));
        }
        assert_eq!(SpillReadMode::parse("MMAP"), Some(SpillReadMode::Mmap));
        assert_eq!(SpillReadMode::parse("nope"), None);
    }

    #[test]
    fn missing_path_is_not_found() {
        let store = DiskStore::in_memory();
        assert!(matches!(
            store.read_raw("/nope"),
            Err(FanError::NotFound(_))
        ));
    }

    #[test]
    fn read_stored_returns_compressed_bytes() {
        let files = vec![InputFile {
            path: "a/rle.bin".into(),
            data: vec![7u8; 8192],
        }];
        let (blobs, _) = build_partitions(&files, 1, Codec::Lzss(5)).unwrap();
        let mut store = DiskStore::in_memory();
        store
            .load_partition(0, blobs.into_iter().next().unwrap(), "/m")
            .unwrap();
        let (stored, at) = store.read_stored("/m/a/rle.bin").unwrap();
        assert_eq!(at.codec, Codec::Lzss(5));
        assert_eq!(stored.codec(), Codec::Lzss(5));
        assert_eq!(stored.raw_len(), 8192);
        assert!(stored.len() < 8192 / 10);
        assert_eq!(store.read_raw("/m/a/rle.bin").unwrap(), vec![7u8; 8192]);
    }

    #[test]
    fn promote_demote_roundtrip_with_exact_counter_algebra() {
        for mode in [
            SpillReadMode::Reopen,
            SpillReadMode::Pread,
            SpillReadMode::Mmap,
        ] {
            let dir = TestDir::new(&format!("tier_{}", mode.name()));
            let files = sample_files(16);
            let (blobs, _) = build_partitions(&files, 4, Codec::Lzss(3)).unwrap();
            let mut store = DiskStore::on_disk_with_mode(&dir.0, mode).unwrap();
            let mut blob_sizes = Vec::new();
            for (pid, blob) in blobs.into_iter().enumerate() {
                blob_sizes.push(blob.len() as u64);
                store.load_partition(pid as u32, blob, "/m").unwrap();
            }
            assert!(store.can_demote());
            for pid in 0..4u32 {
                assert_eq!(store.partition_resident(pid), Some(false));
            }
            assert_eq!(store.ram_resident_bytes(), 0);

            // promote 0 and 2; reads must stay byte-identical throughout
            let moved = store.promote_partition(0).unwrap() + store.promote_partition(2).unwrap();
            assert_eq!(moved, blob_sizes[0] + blob_sizes[2]);
            assert_eq!(store.promote_partition(0).unwrap(), 0, "idempotent");
            assert_eq!(store.partition_resident(0), Some(true));
            assert_eq!(store.partition_resident(1), Some(false));
            assert_eq!(store.ram_resident_bytes(), blob_sizes[0] + blob_sizes[2]);
            for f in &files {
                let path = format!("/m/{}", f.path);
                assert_eq!(store.read_raw(&path).unwrap(), f.data, "{mode:?} {path}");
            }

            // demote 0 back; bytes still identical
            let back = store.demote_partition(0).unwrap();
            assert_eq!(back, blob_sizes[0]);
            assert_eq!(store.demote_partition(0).unwrap(), 0, "idempotent");
            assert_eq!(store.partition_resident(0), Some(false));
            assert_eq!(store.ram_resident_bytes(), blob_sizes[2]);
            for f in &files {
                let path = format!("/m/{}", f.path);
                assert_eq!(store.read_raw(&path).unwrap(), f.data, "{mode:?} {path}");
            }

            let (p, d, mb, _hot) = store.tier_counts();
            assert_eq!((p, d), (2, 1));
            assert_eq!(mb, blob_sizes[0] * 2 + blob_sizes[2], "migrated bytes balance");
        }
    }

    #[test]
    fn demotion_requires_a_spill_dir() {
        let files = sample_files(4);
        let (blobs, _) = build_partitions(&files, 1, Codec::None).unwrap();
        let mut store = DiskStore::in_memory();
        store
            .load_partition(0, blobs.into_iter().next().unwrap(), "/m")
            .unwrap();
        assert!(!store.can_demote());
        assert!(store.demote_partition(0).is_err());
        // promotion of a RAM partition is a no-op, not an error
        assert_eq!(store.promote_partition(0).unwrap(), 0);
    }

    #[test]
    fn payloads_outlive_migration() {
        // a handle taken before a tier swap keeps serving the OLD backing's
        // bytes — migration never invalidates in-flight readers
        let dir = TestDir::new("outlive");
        let files = sample_files(6);
        let (blobs, _) = build_partitions(&files, 1, Codec::None).unwrap();
        let mut store = DiskStore::on_disk_with_mode(&dir.0, SpillReadMode::Mmap).unwrap();
        store
            .load_partition(0, blobs.into_iter().next().unwrap(), "/m")
            .unwrap();
        let path = format!("/m/{}", files[0].path);
        let (before, _) = store.read_stored(&path).unwrap();
        store.promote_partition(0).unwrap();
        let (after_promote, _) = store.read_stored(&path).unwrap();
        store.demote_partition(0).unwrap();
        let (after_demote, _) = store.read_stored(&path).unwrap();
        // all three handles stay readable and byte-identical, each pinned
        // to the backing generation it was born under
        assert_eq!(&before[..], &files[0].data[..]);
        assert_eq!(&after_promote[..], &files[0].data[..]);
        assert_eq!(&after_demote[..], &files[0].data[..]);
        assert!(
            !before.same(&after_promote),
            "different backing generations are different pins"
        );
    }

    #[test]
    fn take_heat_drains_touch_counts() {
        let files = sample_files(8);
        let (blobs, _) = build_partitions(&files, 2, Codec::None).unwrap();
        let mut store = DiskStore::in_memory();
        for (pid, blob) in blobs.into_iter().enumerate() {
            store.load_partition(pid as u32, blob, "/m").unwrap();
        }
        let hot_path = format!("/m/{}", files[0].path);
        let hot_pid = store.locate(&hot_path).unwrap().partition;
        for _ in 0..5 {
            store.read_raw(&hot_path).unwrap();
        }
        let heat = store.take_heat();
        assert_eq!(heat.len(), 2);
        let hot = heat.iter().find(|h| h.pid == hot_pid).unwrap();
        assert_eq!(hot.touches, 5);
        assert!(hot.resident);
        assert!(hot.bytes > 0);
        // drained: a second sample sees zero touches
        assert!(store.take_heat().iter().all(|h| h.touches == 0));
    }

    #[cfg(unix)]
    #[test]
    fn madvise_hints_fire_on_mapped_partitions() {
        let dir = TestDir::new("madvise");
        let files = sample_files(6);
        let (blobs, _) = build_partitions(&files, 1, Codec::None).unwrap();
        let mut store = DiskStore::on_disk_with_mode(&dir.0, SpillReadMode::Mmap).unwrap();
        store
            .load_partition(0, blobs.into_iter().next().unwrap(), "/m")
            .unwrap();
        let path = format!("/m/{}", files[0].path);
        let mapped = {
            let (p, _) = store.read_stored(&path).unwrap();
            matches!(p, Payload::View { .. })
        };
        if !mapped {
            return; // mmap degraded to pread on this filesystem: nothing to advise
        }
        let before = madvise_calls();
        store.advise_willneed(&path);
        assert_eq!(madvise_calls(), before + 1, "WILLNEED fired");
        store.advise_dontneed_partition(0);
        assert_eq!(madvise_calls(), before + 2, "DONTNEED fired");
        // demotion of a RAM partition re-advises the fresh cold map
        store.promote_partition(0).unwrap();
        let mid = madvise_calls();
        store.demote_partition(0).unwrap();
        assert_eq!(madvise_calls(), mid + 1, "demotion advises DONTNEED");
        // bytes survive all the advice
        assert_eq!(store.read_raw(&path).unwrap(), files[0].data);
    }
}
