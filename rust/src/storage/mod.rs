//! Storage backends: device timing models for the simulator and a real
//! byte store for the in-process cluster.
//!
//! The §6.4 comparison (Fig 3/4) is FanStore vs **SSD** vs **SSD-fuse** vs
//! **SFS (Lustre)**.  [`models`] parameterizes those devices from the paper's
//! own single-node envelope; [`disk`] is the real local store a FanStore node
//! dumps partitions into in `InProc` mode.

pub mod disk;
pub mod models;
pub mod payload;

pub use disk::{DiskStore, SpillReadMode};
pub use models::{DeviceProfile, FuseModel, SharedFsModel, SsdModel};
pub use payload::{payload_copies, Payload, PayloadRegion};
