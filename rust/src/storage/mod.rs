//! Storage backends: device timing models for the simulator and a real
//! byte store for the in-process cluster.
//!
//! The §6.4 comparison (Fig 3/4) is FanStore vs **SSD** vs **SSD-fuse** vs
//! **SFS (Lustre)**.  [`models`] parameterizes those devices from the paper's
//! own single-node envelope; [`disk`] is the real local store a FanStore node
//! dumps partitions into in `InProc` mode.

pub mod disk;
pub mod models;
pub mod payload;
pub mod placement;

pub use disk::{madvise_calls, DiskStore, SpillReadMode};
pub use models::{DeviceProfile, DramModel, FuseModel, SharedFsModel, SsdModel};
pub use payload::{payload_copies, Payload, PayloadRegion};
pub use placement::{
    FreqPlacement, MigrationPlan, NoopPlacement, PartitionHeat, PlacementKind, PlacementPolicy,
};
