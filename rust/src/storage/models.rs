//! Analytic device-timing models (virtual-time costs).
//!
//! Calibration targets come from the paper itself, not from our host:
//! * Fig 3: FanStore reaches 71–99 % of raw-SSD bandwidth; SSD-fuse is
//!   2.9–4.4× slower than FanStore; Lustre (SFS) is 4.0–64.7× slower,
//!   worst for small files (metadata-bound).
//! * §6.1: GPU-cluster SSDs (~60 GB) and CPU-cluster SSDs (~144 GB) are
//!   SATA-class (2018 era): ~500 MB/s sequential, ~85 µs access.
//!
//! All costs are *service times* to be scheduled on a [`Resource`]
//! (rust/src/sim/resource.rs); contention then emerges from FIFO queueing.

use crate::sim::clock::{transfer_ns, SimNs, US};

/// DRAM tier: what a RAM-resident (or heat-promoted, PR 8) partition read
/// costs.  The point of the model is the *contrast* with the SSD/FUSE/SFS
/// tiers below — the tiered-placement simulator charges `DramModel` for
/// hot-set hits and the device model for spilled reads, which is exactly
/// the gap the background migrator converts into throughput.
#[derive(Clone, Copy, Debug)]
pub struct DramModel {
    pub read_latency_ns: SimNs,
    pub read_bw: u64, // bytes/s
}

impl DramModel {
    /// DDR4-era node memory (§6.1 testbeds): ~100 ns access, ~10 GB/s
    /// effective single-stream copy bandwidth.
    pub fn ddr4_2018() -> Self {
        DramModel {
            read_latency_ns: US / 10,
            read_bw: 10_000_000_000,
        }
    }

    /// Service time for one read of `bytes` out of the RAM tier.
    pub fn read_service(&self, bytes: u64) -> SimNs {
        self.read_latency_ns + transfer_ns(bytes, self.read_bw)
    }
}

/// SATA/NVMe-class local SSD.
#[derive(Clone, Copy, Debug)]
pub struct SsdModel {
    pub read_latency_ns: SimNs,
    pub write_latency_ns: SimNs,
    pub read_bw: u64,  // bytes/s
    pub write_bw: u64, // bytes/s
    /// Internal queue lanes (NVMe-style parallelism; SATA = 1).
    pub lanes: usize,
}

impl SsdModel {
    /// 2018-era SATA SSD as in both testbeds (§6.1).
    pub fn sata_2018() -> Self {
        SsdModel {
            read_latency_ns: 85 * US,
            write_latency_ns: 95 * US,
            read_bw: 520_000_000,
            write_bw: 470_000_000,
            lanes: 1,
        }
    }

    /// Service time for one sequential read of `bytes` (whole-file reads,
    /// paper §3.4: "read sequentially and completely").
    pub fn read_service(&self, bytes: u64) -> SimNs {
        self.read_latency_ns + transfer_ns(bytes, self.read_bw)
    }

    pub fn write_service(&self, bytes: u64) -> SimNs {
        self.write_latency_ns + transfer_ns(bytes, self.write_bw)
    }
}

/// FUSE wrapper: same SSD behind a user-kernel-user crossing per syscall
/// plus an extra buffer copy.  Vangoor et al. (FAST'17, the paper's [38])
/// measured 2–5× degradation for small-file metadata+data workloads; the
/// crossing cost and copy bandwidth below land FUSE in the paper's observed
/// 2.9–4.4× band vs FanStore.
#[derive(Clone, Copy, Debug)]
pub struct FuseModel {
    pub ssd: SsdModel,
    /// Cost of one request's user→kernel→userspace-daemon round trip.
    pub crossing_ns: SimNs,
    /// Extra copy through the FUSE buffer.
    pub copy_bw: u64,
    /// FUSE splits large reads into 128 KiB requests.
    pub max_read: u64,
}

impl FuseModel {
    pub fn default_2018() -> Self {
        FuseModel {
            ssd: SsdModel::sata_2018(),
            // request round trip through /dev/fuse incl. daemon wakeup +
            // scheduling under I/O load (Vangoor et al. measure 100s of µs
            // for metadata-heavy small-file workloads)
            crossing_ns: 200 * US,
            copy_bw: 500_000_000,
            max_read: 128 * 1024,
        }
    }

    /// Whole-file read: open crossing + per-chunk crossings + device + copy.
    pub fn read_service(&self, bytes: u64) -> SimNs {
        let chunks = bytes.div_ceil(self.max_read).max(1);
        // open+release crossings + one crossing per 128 KiB read request
        let crossings = (2 + chunks) * self.crossing_ns;
        crossings + self.ssd.read_service(bytes) + transfer_ns(bytes, self.copy_bw)
    }

    pub fn metadata_service(&self) -> SimNs {
        self.crossing_ns
    }
}

/// Lustre-class shared parallel file system.
///
/// Two shared bottlenecks (cluster-wide `Resource`s, not per node):
/// * a **single metadata server** — the paper's §3.3 point: "there may be
///   only one single metadata server such as Lustre";
/// * an **OST pool** with fixed aggregate bandwidth shared by all clients.
/// Per-client bandwidth is additionally capped by the client's LNET link.
#[derive(Clone, Copy, Debug)]
pub struct SharedFsModel {
    /// MDS service time per metadata RPC.
    pub mds_op_ns: SimNs,
    /// Metadata RPCs per file open (open + LDLM lock + layout + close…:
    /// the small-file tax that makes Lustre 4–65× slower in Fig 3).
    pub rpcs_per_open: u32,
    /// Aggregate OST bandwidth shared by everyone (bytes/s).
    pub ost_agg_bw: u64,
    /// Number of OST lanes (stripes servable in parallel).
    pub ost_lanes: usize,
    /// Effective per-client data bandwidth under production sharing
    /// (bytes/s) — §6.5.2: "the performance can fluctuate depending on the
    /// workload [40]".
    pub client_bw: u64,
    /// RPC round-trip latency client<->server.
    pub rpc_ns: SimNs,
    /// Background load factor scaling MDS/OST service times.
    pub background_load: f64,
}

impl SharedFsModel {
    /// Production Lustre of the paper's era, moderately loaded.
    pub fn lustre_2018() -> Self {
        SharedFsModel {
            mds_op_ns: 350 * US,
            rpcs_per_open: 6,
            ost_agg_bw: 12_000_000_000,
            ost_lanes: 32,
            client_bw: 150_000_000,
            rpc_ns: 250 * US,
            background_load: 1.0,
        }
    }

    /// MDS service per metadata op (to schedule on the shared MDS resource).
    pub fn mds_service(&self) -> SimNs {
        (self.mds_op_ns as f64 * self.background_load) as SimNs
    }

    /// Total MDS service consumed by one file open (all its RPCs).
    pub fn open_service(&self) -> SimNs {
        self.mds_service() * self.rpcs_per_open as u64
    }

    /// OST service for `bytes` (scheduled on the shared OST resource).
    pub fn ost_service(&self, bytes: u64) -> SimNs {
        (transfer_ns(bytes, self.ost_agg_bw) as f64 * self.background_load) as SimNs
    }

    /// Client-side wire time for `bytes` (scheduled on the client NIC).
    pub fn client_service(&self, bytes: u64) -> SimNs {
        transfer_ns(bytes, self.client_bw)
    }
}

/// Everything Fig 3/4 needs about one storage option, bundled.
#[derive(Clone, Copy, Debug)]
pub enum DeviceProfile {
    Ssd(SsdModel),
    Fuse(FuseModel),
    SharedFs(SharedFsModel),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::clock::{MS, NS_PER_SEC};

    #[test]
    fn dram_tier_beats_every_device_tier() {
        let dram = DramModel::ddr4_2018();
        let ssd = SsdModel::sata_2018();
        for bytes in [4 * 1024, 128 * 1024, 8 << 20] {
            let hot = dram.read_service(bytes);
            let cold = ssd.read_service(bytes);
            assert!(
                cold > 10 * hot,
                "{bytes}B: dram {hot}ns should be >10x faster than ssd {cold}ns"
            );
        }
    }

    #[test]
    fn ssd_read_service_sane() {
        let ssd = SsdModel::sata_2018();
        let t = ssd.read_service(128 * 1024);
        // 128 KiB at 520 MB/s ≈ 252µs + 85µs latency
        assert!(t > 300 * US && t < 400 * US, "{t}");
    }

    #[test]
    fn ssd_bandwidth_asymptote() {
        let ssd = SsdModel::sata_2018();
        let t = ssd.read_service(512 * 1024 * 1024);
        let bw = 512.0 * 1024.0 * 1024.0 / (t as f64 / NS_PER_SEC as f64);
        assert!((bw - 520e6).abs() / 520e6 < 0.01, "bw {bw}");
    }

    #[test]
    fn fuse_slower_than_ssd_small_files() {
        let ssd = SsdModel::sata_2018();
        let fuse = FuseModel::default_2018();
        let ratio = fuse.read_service(128 * 1024) as f64 / ssd.read_service(128 * 1024) as f64;
        assert!(ratio > 1.4, "fuse/ssd = {ratio}");
    }

    #[test]
    fn fuse_overhead_amortizes_for_big_files() {
        let fuse = FuseModel::default_2018();
        let ssd = SsdModel::sata_2018();
        let small = fuse.read_service(128 * 1024) as f64 / ssd.read_service(128 * 1024) as f64;
        let big = fuse.read_service(8 << 20) as f64 / ssd.read_service(8 << 20) as f64;
        assert!(big < small, "relative overhead should shrink: {small} -> {big}");
    }

    #[test]
    fn sfs_metadata_dominates_small_files() {
        let sfs = SharedFsModel::lustre_2018();
        // One 128 KiB read = open (MDS) + rpc + data; vs SSD it must be
        // several times slower even for a single client.
        let t = sfs.mds_service() + sfs.rpc_ns + sfs.client_service(128 * 1024);
        let ssd = SsdModel::sata_2018().read_service(128 * 1024);
        assert!(t > 2 * ssd, "sfs {t} vs ssd {ssd}");
    }

    #[test]
    fn sfs_mds_saturates_under_concurrency() {
        // 1000 concurrent opens serialize on the MDS: makespan ≈ 1000 * op.
        let sfs = SharedFsModel::lustre_2018();
        let mut mds = crate::sim::Resource::new(1);
        let mut last = 0;
        for _ in 0..1000 {
            last = mds.serve(0, sfs.mds_service());
        }
        assert!(last >= 1000 * sfs.mds_service());
        assert!(last > 300 * MS);
    }
}
