//! Zero-copy payload handles for the serve path.
//!
//! A [`Payload`] is the unit of file content flowing through the data
//! plane: `DiskStore::read_stored` → the node's `FileFetch`/`Response`
//! variants → the refcount cache → VFS descriptors → the wire encoder's
//! vectored send.  It is either an exclusively-owned buffer (decoded
//! content, network receives, output bytes) or a **borrowed byte range of
//! a shared region** — a RAM partition blob or an `mmap`'d spill file —
//! in which case the `Arc` inside the handle keeps the region alive (and,
//! for maps, *mapped*) for as long as any reader, cache entry, in-flight
//! response or half-written frame still references it.
//!
//! # Ownership rules
//!
//! * A region (partition blob / mmap) may only be unmapped or freed when
//!   its `Arc` count reaches zero — i.e. when the owning `DiskStore` is
//!   gone **and** no `Payload` view of it survives anywhere (cache entry,
//!   open descriptor, queued reply, frame mid-write).  Dropping the store
//!   while payloads are live is therefore safe by construction.
//! * Regions are written before they are shared and never mutated after,
//!   so concurrent `as_slice` views need no synchronization.
//! * Pin identity in the refcount cache is [`Payload::same`]: the same
//!   region + range (or the same owned allocation), never byte equality.
//!
//! # Copy accounting
//!
//! The whole point of the handle is that serving spilled bytes performs
//! **zero payload memcpys node-side**.  Every place a payload's bytes are
//! actually duplicated ([`Payload::to_vec`], [`Payload::into_arc`] on a
//! view, the wire coalescing buffer via [`record_copy`]) bumps a global
//! relaxed counter, exposed as [`payload_copies`]; the hotpath bench
//! proves the zero-copy serve path by snapshotting it.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::compress::Codec;

/// Process-wide tally of payload byte duplications (relaxed; see the
/// module docs).  Monotonic — benches snapshot before/after and diff.
static PAYLOAD_COPIES: AtomicU64 = AtomicU64::new(0);

/// Payload memcpys performed since process start.
pub fn payload_copies() -> u64 {
    PAYLOAD_COPIES.load(Ordering::Relaxed)
}

/// Record one payload memcpy performed outside the handle's own methods
/// (e.g. the wire writer flattening a data frame into a coalescing
/// buffer).
pub fn record_copy() {
    PAYLOAD_COPIES.fetch_add(1, Ordering::Relaxed);
}

/// A shared, immutable byte region a [`Payload`] may borrow a range of.
/// Implementors: RAM partition blobs (`Vec<u8>`) and mmap'd spill files
/// (`storage::disk`'s map type).  `Send + Sync` is part of the contract:
/// regions are written before sharing and never mutated after.
pub trait PayloadRegion: Send + Sync {
    fn bytes(&self) -> &[u8];
}

impl PayloadRegion for Vec<u8> {
    fn bytes(&self) -> &[u8] {
        self
    }
}

/// Handle to one file's stored (or decoded) bytes — see the module docs.
/// Cloning clones an `Arc`, never the bytes.
#[derive(Clone)]
pub enum Payload {
    /// Exclusively-owned whole buffer (decoded content, network receives,
    /// buffered output bytes).
    Owned(Arc<[u8]>),
    /// Borrowed range of a shared region; the `Arc` keeps the region
    /// alive (mapped) for the payload's lifetime.
    View {
        region: Arc<dyn PayloadRegion>,
        off: usize,
        len: usize,
    },
    /// Compressed representation of a `raw_len`-byte file: `inner` holds
    /// the stored (compressed) bytes — still zero-copy, typically a view
    /// of a partition region — and `codec` decodes them.  This is what
    /// rides the wire and sits in the refcount cache; the consuming side
    /// performs the single decode at VFS/prefetch pickup.
    Compressed {
        codec: Codec,
        raw_len: u64,
        inner: Box<Payload>,
    },
}

impl Payload {
    /// Zero-copy view of `region[off..off + len]`.
    pub fn view(region: Arc<dyn PayloadRegion>, off: usize, len: usize) -> Payload {
        assert!(
            off.checked_add(len).map(|e| e <= region.bytes().len()).unwrap_or(false),
            "payload view {off}+{len} exceeds region of {} bytes",
            region.bytes().len()
        );
        Payload::View { region, off, len }
    }

    /// Wrap stored bytes in their compressed identity.  Collapses to the
    /// plain payload when `codec` is `None` (nothing to decode), so raw
    /// entries pay no wrapper anywhere in the plane.
    pub fn compressed(codec: Codec, raw_len: u64, inner: Payload) -> Payload {
        if codec.is_none() {
            inner
        } else {
            Payload::Compressed {
                codec,
                raw_len,
                inner: Box::new(inner),
            }
        }
    }

    /// Slice of the bytes this handle carries: the *stored* representation
    /// (compressed bytes for a `Compressed` payload).
    pub fn as_slice(&self) -> &[u8] {
        match self {
            Payload::Owned(a) => a,
            Payload::View { region, off, len } => &region.bytes()[*off..*off + *len],
            Payload::Compressed { inner, .. } => inner.as_slice(),
        }
    }

    /// Stored length in bytes (compressed size for `Compressed` payloads).
    pub fn len(&self) -> usize {
        match self {
            Payload::Owned(a) => a.len(),
            Payload::View { len, .. } => *len,
            Payload::Compressed { inner, .. } => inner.len(),
        }
    }

    /// Codec these bytes are stored under (`None` for plain payloads).
    pub fn codec(&self) -> Codec {
        match self {
            Payload::Compressed { codec, .. } => *codec,
            _ => Codec::None,
        }
    }

    /// Decoded length: `raw_len` for `Compressed` payloads, the stored
    /// length otherwise (plain payloads are already decoded).
    pub fn raw_len(&self) -> u64 {
        match self {
            Payload::Compressed { raw_len, .. } => *raw_len,
            _ => self.len() as u64,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Pin identity: same owned allocation, or same region + range.
    /// (Never byte equality — two generations of one path may hold equal
    /// bytes and must still be distinguishable.)
    pub fn same(&self, other: &Payload) -> bool {
        match (self, other) {
            (Payload::Owned(a), Payload::Owned(b)) => Arc::ptr_eq(a, b),
            (
                Payload::View { region: ra, off: oa, len: la },
                Payload::View { region: rb, off: ob, len: lb },
            ) => {
                // compare region identity by data address (not vtable)
                std::ptr::eq(
                    Arc::as_ptr(ra) as *const u8,
                    Arc::as_ptr(rb) as *const u8,
                ) && oa == ob
                    && la == lb
            }
            (
                Payload::Compressed {
                    codec: ca,
                    raw_len: la,
                    inner: ia,
                },
                Payload::Compressed {
                    codec: cb,
                    raw_len: lb,
                    inner: ib,
                },
            ) => ca == cb && la == lb && ia.same(ib),
            _ => false,
        }
    }

    /// Materialize into an exclusively-owned `Arc<[u8]>`.  Free for
    /// `Owned` payloads; **copies (and counts the copy) for views** — use
    /// only where an `Arc<[u8]>` is genuinely required.
    pub fn into_arc(self) -> Arc<[u8]> {
        match self {
            Payload::Owned(a) => a,
            Payload::View { region, off, len } => {
                record_copy();
                Arc::from(&region.bytes()[off..off + len])
            }
            Payload::Compressed { inner, .. } => inner.into_arc(),
        }
    }

    /// Copy the bytes out (always a counted memcpy).
    pub fn to_vec(&self) -> Vec<u8> {
        record_copy();
        self.as_slice().to_vec()
    }
}

impl std::ops::Deref for Payload {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl AsRef<[u8]> for Payload {
    fn as_ref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl From<Arc<[u8]>> for Payload {
    fn from(a: Arc<[u8]>) -> Payload {
        Payload::Owned(a)
    }
}

impl From<Vec<u8>> for Payload {
    fn from(v: Vec<u8>) -> Payload {
        Payload::Owned(v.into())
    }
}

impl std::fmt::Debug for Payload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Payload::Owned(a) => write!(f, "Payload::Owned({} bytes)", a.len()),
            Payload::View { off, len, .. } => {
                write!(f, "Payload::View({off}+{len} bytes)")
            }
            Payload::Compressed {
                codec,
                raw_len,
                inner,
            } => write!(f, "Payload::Compressed({codec}, {raw_len} raw, {inner:?})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn region(n: usize) -> Arc<dyn PayloadRegion> {
        Arc::new((0..n).map(|i| i as u8).collect::<Vec<u8>>())
    }

    #[test]
    fn view_exposes_the_range_without_copying() {
        let r = region(64);
        let before = payload_copies();
        let p = Payload::view(Arc::clone(&r), 8, 16);
        assert_eq!(p.len(), 16);
        assert_eq!(&p[..], &r.bytes()[8..24]);
        assert_eq!(payload_copies(), before, "a view costs no copy");
        // cloning clones the handle, not the bytes
        let q = p.clone();
        assert!(p.same(&q));
        assert_eq!(payload_copies(), before);
    }

    #[test]
    fn same_is_range_and_allocation_identity() {
        let r = region(32);
        let a = Payload::view(Arc::clone(&r), 0, 8);
        let b = Payload::view(Arc::clone(&r), 0, 8);
        let c = Payload::view(Arc::clone(&r), 8, 8);
        assert!(a.same(&b), "same region + range");
        assert!(!a.same(&c), "different range");
        let o1: Payload = vec![0u8; 8].into();
        let o2: Payload = vec![0u8; 8].into();
        assert!(o1.same(&o1.clone()));
        assert!(!o1.same(&o2), "equal bytes, different allocations");
        assert!(!o1.same(&a), "owned vs view never match");
        // a different region with identical content is a different pin
        let r2 = region(32);
        let d = Payload::view(r2, 0, 8);
        assert!(!a.same(&d));
    }

    #[test]
    fn into_arc_is_free_for_owned_and_counted_for_views() {
        let owned: Payload = vec![7u8; 32].into();
        let before = payload_copies();
        let a = owned.clone().into_arc();
        assert_eq!(payload_copies(), before, "owned materialization is free");
        assert_eq!(&a[..], &[7u8; 32]);

        let r = region(16);
        let v = Payload::view(r, 4, 8);
        let a = v.into_arc();
        assert_eq!(payload_copies(), before + 1, "view materialization copies");
        assert_eq!(&a[..], &[4, 5, 6, 7, 8, 9, 10, 11]);
    }

    #[test]
    fn to_vec_always_counts() {
        let p: Payload = vec![1u8, 2, 3].into();
        let before = payload_copies();
        assert_eq!(p.to_vec(), vec![1, 2, 3]);
        assert_eq!(payload_copies(), before + 1);
    }

    #[test]
    #[should_panic]
    fn out_of_range_view_is_rejected() {
        let r = region(8);
        let _ = Payload::view(r, 4, 8);
    }

    #[test]
    fn compressed_wrapper_delegates_and_collapses() {
        let r = region(64);
        let before = payload_copies();
        let stored = Payload::view(Arc::clone(&r), 8, 16);
        let p = Payload::compressed(Codec::Lzss(5), 4096, stored.clone());
        // the wrapper exposes the STORED bytes and length...
        assert_eq!(&p[..], &r.bytes()[8..24]);
        assert_eq!(p.len(), 16);
        // ...while carrying the decode metadata
        assert_eq!(p.codec(), Codec::Lzss(5));
        assert_eq!(p.raw_len(), 4096);
        assert_eq!(payload_copies(), before, "wrapping costs no copy");

        // Codec::None collapses to the plain payload
        let plain = Payload::compressed(Codec::None, 16, stored.clone());
        assert!(plain.same(&stored));
        assert_eq!(plain.codec(), Codec::None);
        assert_eq!(plain.raw_len(), 16);

        // pin identity: same codec + raw_len + inner pin
        let q = Payload::compressed(Codec::Lzss(5), 4096, stored.clone());
        assert!(p.same(&q));
        assert!(!p.same(&Payload::compressed(Codec::Lzss(3), 4096, stored.clone())));
        assert!(!p.same(&Payload::compressed(Codec::Lzss(5), 4095, stored.clone())));
        assert!(!p.same(&stored), "wrapped and bare pins differ");
    }

    #[test]
    fn region_outlives_its_store_via_the_handle() {
        // the Arc in the handle is the only thing keeping the region alive
        let p = {
            let r = region(128);
            Payload::view(r, 100, 28)
        };
        assert_eq!(p.len(), 28);
        assert_eq!(p[0], 100);
        assert_eq!(p[27], 127);
    }
}
