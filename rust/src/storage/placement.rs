//! Placement policies for heat-based tiered storage (PR 8).
//!
//! FanStore's §4 design picks a partition's tier (RAM vs spill) statically
//! at launch; DL access is skewed and shifts across epochs, so the hot set
//! should *converge* into RAM instead.  A [`PlacementPolicy`] turns one
//! migration tick's heat sample ([`PartitionHeat`], drained from
//! `DiskStore::take_heat`) plus the node's RAM budget into a
//! [`MigrationPlan`] — which partitions to promote into RAM and which to
//! demote back to spill.  The background migrator in `node::NodeShared`
//! executes the plan; the policy itself never touches bytes.
//!
//! # Contract
//!
//! * `plan` is called from exactly one thread (the migrator), so policies
//!   may keep interior state (EWMA histories) without synchronization —
//!   the trait only requires `Send`.
//! * The heat sample is sorted by pid and covers every partition; plans
//!   must be deterministic functions of (state, sample, budget) so tests
//!   and the in-proc simulator can replay migration decisions exactly.
//! * Promotions listed in a plan must fit the budget *assuming the listed
//!   demotions happen first*; the migrator executes demotions before
//!   promotions and re-checks residency against the budget as a backstop.
//! * A budget of 0 means "no RAM tier": policies must plan nothing.

use std::collections::HashMap;

/// One partition's slice of a migration-tick heat sample.
#[derive(Clone, Copy, Debug)]
pub struct PartitionHeat {
    pub pid: u32,
    /// Reads that touched this partition since the last sample.
    pub touches: u64,
    /// Whether it currently lives in the RAM tier.
    pub resident: bool,
    /// Stored blob size (same in both tiers) — the budget currency.
    pub bytes: u64,
}

/// What one migration tick should move.  Demotions are executed first so
/// promotions fit the freed budget.
#[derive(Clone, Debug, Default)]
pub struct MigrationPlan {
    pub promote: Vec<u32>,
    pub demote: Vec<u32>,
}

impl MigrationPlan {
    pub fn is_empty(&self) -> bool {
        self.promote.is_empty() && self.demote.is_empty()
    }
}

/// Tier-placement decision maker — see the module docs for the contract.
pub trait PlacementPolicy: Send {
    fn name(&self) -> &'static str;

    /// Decide this tick's migrations from the drained heat sample and the
    /// RAM budget in bytes.
    fn plan(&mut self, heat: &[PartitionHeat], ram_budget_bytes: u64) -> MigrationPlan;
}

/// Today's static behavior: never migrate anything.
#[derive(Debug, Default)]
pub struct NoopPlacement;

impl PlacementPolicy for NoopPlacement {
    fn name(&self) -> &'static str {
        "noop"
    }

    fn plan(&mut self, _heat: &[PartitionHeat], _ram_budget_bytes: u64) -> MigrationPlan {
        MigrationPlan::default()
    }
}

/// Frequency policy: per-partition EWMA of touch counts picks the target
/// RAM set greedily (hottest first) under the byte budget.
///
/// Residents get a hysteresis bonus when ranked, so a spilled partition
/// must be measurably hotter (not merely tied) to displace a resident one
/// — without it, equal-heat partitions would swap tiers every tick and the
/// migrator would churn bytes for nothing.
#[derive(Debug)]
pub struct FreqPlacement {
    /// EWMA smoothing factor in [0, 1]: weight of the newest sample.
    alpha: f64,
    /// Multiplier applied to resident partitions' scores when ranking.
    hysteresis: f64,
    ewma: HashMap<u32, f64>,
}

impl FreqPlacement {
    pub fn new() -> FreqPlacement {
        FreqPlacement {
            alpha: 0.5,
            hysteresis: 1.25,
            ewma: HashMap::new(),
        }
    }

    /// Override the smoothing factor (tests; clamped to [0, 1]).
    pub fn with_alpha(mut self, alpha: f64) -> FreqPlacement {
        self.alpha = alpha.clamp(0.0, 1.0);
        self
    }

    /// Current smoothed heat of `pid` (0 if never sampled).
    pub fn score(&self, pid: u32) -> f64 {
        self.ewma.get(&pid).copied().unwrap_or(0.0)
    }
}

impl Default for FreqPlacement {
    fn default() -> Self {
        FreqPlacement::new()
    }
}

impl PlacementPolicy for FreqPlacement {
    fn name(&self) -> &'static str {
        "freq"
    }

    fn plan(&mut self, heat: &[PartitionHeat], ram_budget_bytes: u64) -> MigrationPlan {
        // fold this tick into the EWMA history first — even when the
        // budget is 0 the history should keep tracking the workload
        for h in heat {
            let e = self.ewma.entry(h.pid).or_insert(0.0);
            *e = self.alpha * h.touches as f64 + (1.0 - self.alpha) * *e;
        }
        if ram_budget_bytes == 0 {
            return MigrationPlan::default();
        }

        // rank hottest-first; residents get the hysteresis bonus and win
        // ties (stable order: score desc, resident first, pid asc)
        let mut ranked: Vec<&PartitionHeat> = heat.iter().collect();
        ranked.sort_by(|a, b| {
            let sa = self.score(a.pid) * if a.resident { self.hysteresis } else { 1.0 };
            let sb = self.score(b.pid) * if b.resident { self.hysteresis } else { 1.0 };
            sb.partial_cmp(&sa)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(b.resident.cmp(&a.resident))
                .then(a.pid.cmp(&b.pid))
        });

        // greedy fill: the target RAM set is the hottest prefix that fits;
        // never-touched partitions (score 0) are left where they are
        let mut budget = ram_budget_bytes;
        let mut plan = MigrationPlan::default();
        for h in ranked {
            let wanted = self.score(h.pid) > 0.0 && h.bytes <= budget;
            if wanted {
                budget -= h.bytes;
                if !h.resident {
                    plan.promote.push(h.pid);
                }
            } else if h.resident {
                plan.demote.push(h.pid);
            }
        }
        plan
    }
}

/// Config/CLI spelling of a placement policy.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PlacementKind {
    /// Static placement (today's behavior); no migrator thread runs.
    #[default]
    Noop,
    /// Frequency/EWMA policy ([`FreqPlacement`]).
    Freq,
}

impl PlacementKind {
    pub fn name(&self) -> &'static str {
        match self {
            PlacementKind::Noop => "noop",
            PlacementKind::Freq => "freq",
        }
    }

    pub fn parse(s: &str) -> Option<PlacementKind> {
        match s.to_ascii_lowercase().as_str() {
            "noop" | "static" => Some(PlacementKind::Noop),
            "freq" | "ewma" => Some(PlacementKind::Freq),
            _ => None,
        }
    }

    pub fn build(&self) -> Box<dyn PlacementPolicy> {
        match self {
            PlacementKind::Noop => Box::new(NoopPlacement),
            PlacementKind::Freq => Box::new(FreqPlacement::new()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(rows: &[(u32, u64, bool, u64)]) -> Vec<PartitionHeat> {
        rows.iter()
            .map(|&(pid, touches, resident, bytes)| PartitionHeat {
                pid,
                touches,
                resident,
                bytes,
            })
            .collect()
    }

    #[test]
    fn noop_never_plans() {
        let mut p = NoopPlacement;
        let heat = sample(&[(0, 100, false, 10), (1, 0, true, 10)]);
        let plan = p.plan(&heat, 1 << 30);
        assert!(plan.is_empty());
        assert_eq!(p.name(), "noop");
    }

    #[test]
    fn freq_promotes_hottest_under_budget() {
        let mut p = FreqPlacement::new().with_alpha(1.0);
        // three 10-byte spilled partitions, budget fits two
        let heat = sample(&[(0, 5, false, 10), (1, 50, false, 10), (2, 20, false, 10)]);
        let plan = p.plan(&heat, 20);
        assert_eq!(plan.promote, vec![1, 2], "hottest two fit");
        assert!(plan.demote.is_empty());
    }

    #[test]
    fn freq_zero_budget_plans_nothing() {
        let mut p = FreqPlacement::new();
        let heat = sample(&[(0, 100, false, 10), (1, 100, true, 10)]);
        assert!(p.plan(&heat, 0).is_empty());
    }

    #[test]
    fn freq_demotes_cold_residents_when_heat_shifts() {
        let mut p = FreqPlacement::new().with_alpha(1.0);
        // tick 1: partition 0 is hot and gets the single RAM slot
        let plan = p.plan(&sample(&[(0, 100, false, 10), (1, 0, false, 10)]), 10);
        assert_eq!(plan.promote, vec![0]);
        // tick 2: the workload moved to partition 1 decisively
        let plan = p.plan(&sample(&[(0, 0, true, 10), (1, 100, false, 10)]), 10);
        assert_eq!(plan.promote, vec![1]);
        assert_eq!(plan.demote, vec![0]);
    }

    #[test]
    fn hysteresis_prevents_tie_flapping() {
        let mut p = FreqPlacement::new().with_alpha(1.0);
        // equal heat: the resident keeps its slot, the challenger stays out
        let plan = p.plan(&sample(&[(0, 50, true, 10), (1, 50, false, 10)]), 10);
        assert!(plan.is_empty(), "equal heat must not churn: {plan:?}");
        // a decisive lead (beyond the 1.25x bonus) does displace
        let plan = p.plan(&sample(&[(0, 10, true, 10), (1, 100, false, 10)]), 10);
        assert_eq!(plan.demote, vec![0]);
        assert_eq!(plan.promote, vec![1]);
    }

    #[test]
    fn never_touched_partitions_stay_put() {
        let mut p = FreqPlacement::new();
        // huge budget, but nothing has been read: no speculative promotion
        let plan = p.plan(&sample(&[(0, 0, false, 10), (1, 0, false, 10)]), 1 << 30);
        assert!(plan.is_empty());
    }

    #[test]
    fn oversized_partition_is_skipped_not_wedged() {
        let mut p = FreqPlacement::new().with_alpha(1.0);
        // partition 0 is hot but bigger than the whole budget; 1 still fits
        let plan = p.plan(&sample(&[(0, 100, false, 50), (1, 10, false, 10)]), 20);
        assert_eq!(plan.promote, vec![1]);
    }

    #[test]
    fn ewma_smooths_bursts() {
        let mut p = FreqPlacement::new().with_alpha(0.5);
        let heat = sample(&[(0, 100, false, 10)]);
        p.plan(&heat, 0);
        assert!((p.score(0) - 50.0).abs() < 1e-9);
        // a silent tick halves the score instead of zeroing it
        p.plan(&sample(&[(0, 0, false, 10)]), 0);
        assert!((p.score(0) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn kind_parse_build_roundtrip() {
        for kind in [PlacementKind::Noop, PlacementKind::Freq] {
            assert_eq!(PlacementKind::parse(kind.name()), Some(kind));
            assert_eq!(kind.build().name(), kind.name());
        }
        assert_eq!(PlacementKind::parse("EWMA"), Some(PlacementKind::Freq));
        assert_eq!(PlacementKind::parse("static"), Some(PlacementKind::Noop));
        assert_eq!(PlacementKind::parse("nope"), None);
    }
}
