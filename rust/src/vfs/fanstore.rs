//! FanStore VFS client: the user-space logic behind the intercepted calls.
//!
//! One `FanStoreVfs` per training process.  It shares its node's
//! [`NodeShared`] (store, caches, metadata) with the node's worker thread
//! and every other client on the node, and reaches other nodes through the
//! transport — a remote `open()` is the round-trip message of paper §5.4.
//!
//! There is no node-global lock on this path: input metadata and the
//! partition store are sealed immutable, the refcount cache is sharded, and
//! stats are atomics — so K clients on one node proceed in parallel.  File
//! content moves as `Arc<[u8]>` end to end; `read()` copies into the
//! caller's buffer (the POSIX contract) but nothing else copies payloads.

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::error::{FanError, Result};
use crate::metadata::record::{FileLocation, FileMeta, FileStat};
use crate::metadata::table::normalize;
use crate::net::transport::{InProcTransport, PendingReply, Request, Response};
use crate::node::NodeShared;
use crate::vfs::{Fd, OpenFlags, Vfs};

enum OpenFile {
    Read {
        path: String,
        data: Arc<[u8]>,
        pos: usize,
    },
    Write {
        path: String,
        buf: Vec<u8>,
    },
}

/// Client handle bound to one node.
pub struct FanStoreVfs {
    node_id: u32,
    shared: Arc<NodeShared>,
    transport: InProcTransport,
    fds: HashMap<Fd, OpenFile>,
    next_fd: Fd,
}

impl FanStoreVfs {
    pub fn new(node_id: u32, shared: Arc<NodeShared>, transport: InProcTransport) -> Self {
        FanStoreVfs {
            node_id,
            shared,
            transport,
            fds: HashMap::new(),
            next_fd: 3, // 0,1,2 are stdio, as tradition demands
        }
    }

    fn alloc_fd(&mut self) -> Fd {
        let fd = self.next_fd;
        self.next_fd += 1;
        fd
    }

    /// Fetch + decompress an input file's content, going through the node's
    /// refcount cache.  Returns a pinned Arc (caller must `release` on
    /// close — handled by [`Vfs::close`]).
    fn fetch_input(&mut self, path: &str, loc: FileLocation) -> Result<Arc<[u8]>> {
        // 1) cache hit on this node?
        if let Some(data) = self.shared.cache.acquire(path) {
            return Ok(data);
        }
        // 2) local partition?  (replicated directories — the test-set
        //    broadcast of §5.4 — are always local)
        let holder = if loc.partition == crate::metadata::record::REPLICATED_PARTITION {
            self.node_id
        } else {
            self.shared.placement.choose_holder(loc.partition, self.node_id)
        };
        let stats = &self.shared.stats;
        let (stored, raw_len, compressed) = if holder == self.node_id {
            let (stored, at) = self.shared.store.read_stored(path)?;
            stats.local_reads.fetch_add(1, Ordering::Relaxed);
            stats
                .bytes_read_local
                .fetch_add(stored.len() as u64, Ordering::Relaxed);
            (stored, at.raw_len, at.compressed)
        } else {
            // 3) remote round trip (paper §5.4)
            let resp = self.transport.call(
                self.node_id,
                holder,
                Request::ReadFile {
                    path: path.to_string(),
                },
            )?;
            let (stored, raw_len, compressed) = resp.into_file_data()?;
            stats.remote_reads_issued.fetch_add(1, Ordering::Relaxed);
            stats
                .bytes_fetched_remote
                .fetch_add(stored.len() as u64, Ordering::Relaxed);
            (stored, raw_len, compressed)
        };
        // 4) decompress on the reading node (§5.4)
        let raw: Arc<[u8]> = if compressed {
            let out = crate::compress::lzss::decompress(&stored, raw_len as usize)?;
            stats.decompressions.fetch_add(1, Ordering::Relaxed);
            out.into()
        } else {
            stored
        };
        Ok(self.shared.cache.insert(path, raw))
    }

    /// Read an already-committed output file (checkpoint resume path),
    /// going through the refcount cache exactly like inputs do — repeated
    /// resume `open()`s on one node fetch from the origin once.
    fn fetch_output(&mut self, path: &str, meta: &FileMeta) -> Result<Arc<[u8]>> {
        if let Some(data) = self.shared.cache.acquire(path) {
            // Guard against a cached generation that predates an
            // unlink+rewrite on the home node (only the home invalidates
            // its own cache): the authoritative stat is the referee.  A
            // same-size rewrite slips through — acceptable for the DL
            // pattern, which never unlinks (§3.4).
            if data.len() as u64 == meta.stat.size {
                return Ok(data);
            }
            // single-lock, generation-aware refresh: drops our pin and
            // removes the entry only if it still holds this stale data
            self.shared.cache.retire(path, &data);
        }
        let stats = &self.shared.stats;
        let origin = meta.location.node;
        let data: Arc<[u8]> = if origin == self.node_id {
            let data = self
                .shared
                .output_data
                .read()
                .unwrap()
                .get(path)
                .cloned()
                .ok_or_else(|| FanError::NotFound(path.to_string()))?;
            stats.local_reads.fetch_add(1, Ordering::Relaxed);
            stats
                .bytes_read_local
                .fetch_add(data.len() as u64, Ordering::Relaxed);
            data
        } else {
            let resp = self.transport.call(
                self.node_id,
                origin,
                Request::ReadFile {
                    path: path.to_string(),
                },
            )?;
            let (stored, _, _) = resp.into_file_data()?;
            stats.remote_reads_issued.fetch_add(1, Ordering::Relaxed);
            stats
                .bytes_fetched_remote
                .fetch_add(stored.len() as u64, Ordering::Relaxed);
            stored
        };
        Ok(self.shared.cache.insert(path, data))
    }

    /// Locate output metadata: local home table, else ask the home node.
    fn stat_output(&mut self, path: &str) -> Result<FileMeta> {
        let home = self.shared.placement.output_home(path);
        if home == self.node_id {
            return self
                .shared
                .output_meta
                .read()
                .unwrap()
                .get(path)
                .cloned()
                .ok_or_else(|| FanError::NotFound(path.to_string()));
        }
        match self.transport.call(
            self.node_id,
            home,
            Request::StatOutput {
                path: path.to_string(),
            },
        )? {
            Response::Meta { stat, origin } => Ok(FileMeta {
                stat,
                location: FileLocation {
                    node: origin,
                    partition: u32::MAX,
                    offset: 0,
                    stored_len: stat.size,
                    compressed: false,
                },
            }),
            Response::Err(_) => Err(FanError::NotFound(path.to_string())),
            other => Err(FanError::Transport(format!("unexpected {other:?}"))),
        }
    }
}

impl Vfs for FanStoreVfs {
    fn open(&mut self, path: &str, flags: OpenFlags) -> Result<Fd> {
        let path = normalize(path);
        match flags {
            OpenFlags::Read => {
                let loc = self.shared.input_meta.get(&path).map(|m| m.location);
                let data = match loc {
                    Some(loc) => self.fetch_input(&path, loc)?,
                    None => {
                        // not an input: maybe a committed output file
                        let meta = self.stat_output(&path)?;
                        self.fetch_output(&path, &meta)?
                    }
                };
                let fd = self.alloc_fd();
                self.fds.insert(fd, OpenFile::Read { path, data, pos: 0 });
                Ok(fd)
            }
            OpenFlags::Write => {
                if self.shared.input_meta.get(&path).is_some() {
                    return Err(FanError::Consistency(format!(
                        "input files are immutable: {path}"
                    )));
                }
                if self.stat_output(&path).is_ok() {
                    return Err(FanError::Consistency(format!(
                        "output files are single-write: {path}"
                    )));
                }
                let fd = self.alloc_fd();
                self.fds.insert(fd, OpenFile::Write { path, buf: Vec::new() });
                Ok(fd)
            }
        }
    }

    fn read(&mut self, fd: Fd, buf: &mut [u8]) -> Result<usize> {
        match self.fds.get_mut(&fd) {
            Some(OpenFile::Read { data, pos, .. }) => {
                let n = buf.len().min(data.len() - *pos);
                buf[..n].copy_from_slice(&data[*pos..*pos + n]);
                *pos += n;
                Ok(n)
            }
            Some(OpenFile::Write { .. }) => Err(FanError::Consistency(
                "descriptor is write-only".into(),
            )),
            None => Err(FanError::BadFd(fd)),
        }
    }

    fn write(&mut self, fd: Fd, data: &[u8]) -> Result<usize> {
        match self.fds.get_mut(&fd) {
            Some(OpenFile::Write { buf, .. }) => {
                // §5.4: "the data written is concatenated to a buffer"
                buf.extend_from_slice(data);
                Ok(data.len())
            }
            Some(OpenFile::Read { .. }) => Err(FanError::Consistency(
                "descriptor is read-only".into(),
            )),
            None => Err(FanError::BadFd(fd)),
        }
    }

    fn close(&mut self, fd: Fd) -> Result<()> {
        match self.fds.remove(&fd) {
            Some(OpenFile::Read { path, data, .. }) => {
                self.shared.cache.release(&path, &data);
                Ok(())
            }
            Some(OpenFile::Write { path, buf }) => {
                // visible-until-finish commit (§5.4): store data on the
                // originating node, forward metadata to the home node.
                let size = buf.len() as u64;
                let meta = FileMeta {
                    stat: FileStat::regular(crate::metadata::placement::path_hash(&path), size),
                    location: FileLocation {
                        node: self.node_id,
                        partition: u32::MAX,
                        offset: 0,
                        stored_len: size,
                        compressed: false,
                    },
                };
                // data first, then the metadata commit: once the name is
                // discoverable at the home node, the bytes must already be
                // servable from here.
                self.shared
                    .output_data
                    .write()
                    .unwrap()
                    .insert(path.clone(), buf.into());
                let home = self.shared.placement.output_home(&path);
                if home == self.node_id {
                    self.shared.serve(&Request::CommitOutput { path, meta });
                } else {
                    self.transport
                        .call(self.node_id, home, Request::CommitOutput { path, meta })?;
                }
                // count only once the commit actually landed — a dead home
                // node must not inflate the committed totals
                self.shared
                    .stats
                    .outputs_committed
                    .fetch_add(1, Ordering::Relaxed);
                self.shared
                    .stats
                    .output_bytes
                    .fetch_add(size, Ordering::Relaxed);
                Ok(())
            }
            None => Err(FanError::BadFd(fd)),
        }
    }

    fn stat(&mut self, path: &str) -> Result<FileStat> {
        let path = normalize(path);
        if let Ok(s) = self.shared.input_meta.stat(&path) {
            return Ok(s);
        }
        self.stat_output(&path).map(|m| m.stat)
    }

    fn readdir(&mut self, dir: &str) -> Result<Vec<String>> {
        let dir = normalize(dir);
        let mut names: Vec<String> = match self.shared.input_meta.readdir(&dir) {
            Ok(v) => v.to_vec(),
            Err(FanError::NotFound(_)) => Vec::new(),
            Err(e) => return Err(e),
        };
        // Output metadata is spread over all nodes — a full listing is a
        // gather, the §4 critique of distributed metadata made concrete.
        // Issue the request to every peer first, then collect: the N-1
        // round trips overlap instead of serializing.
        let n = self.transport.node_count();
        let mut pending: Vec<PendingReply> = Vec::with_capacity(n as usize);
        for node in 0..n {
            if node != self.node_id {
                pending.push(self.transport.send(
                    self.node_id,
                    node,
                    Request::ListOutputs { dir: dir.clone() },
                )?);
            }
        }
        // serve the local share while the peers work
        if let Response::Names(v) = self.shared.serve(&Request::ListOutputs { dir: dir.clone() }) {
            names.extend(v);
        }
        for p in pending {
            if let Response::Names(v) = p.wait()? {
                names.extend(v);
            }
        }
        names.sort();
        names.dedup();
        if names.is_empty() {
            // distinguish empty dir from missing dir via input table
            if !self.shared.input_meta.is_dir(&dir) {
                return Err(FanError::NotFound(dir));
            }
        }
        Ok(names)
    }

    fn unlink(&mut self, path: &str) -> Result<()> {
        let path = normalize(path);
        if self.shared.input_meta.get(&path).is_some() {
            return Err(FanError::Consistency(format!(
                "input files are immutable: {path}"
            )));
        }
        let home = self.shared.placement.output_home(&path);
        if home == self.node_id {
            self.shared.output_meta.write().unwrap().remove(&path)?;
            self.shared.output_data.write().unwrap().remove(&path);
            // drop any cached copy so a later same-name output can't serve
            // stale bytes (outstanding readers keep their pinned Arc)
            self.shared.cache.invalidate(&path);
            Ok(())
        } else {
            // remove metadata at home; data GC at origin is lazy
            match self.transport.call(
                self.node_id,
                home,
                Request::StatOutput { path: path.clone() },
            )? {
                Response::Meta { .. } => {
                    // Note: full remote unlink protocol elided — the DL
                    // pattern never unlinks (§3.4); this path serves tests.
                    Err(FanError::Consistency(
                        "remote unlink not supported by the DL I/O pattern".into(),
                    ))
                }
                _ => Err(FanError::NotFound(path)),
            }
        }
    }
}
