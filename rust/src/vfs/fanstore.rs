//! FanStore VFS client: the user-space logic behind the intercepted calls.
//!
//! One `FanStoreVfs` per training process.  It shares its node's state
//! (store, caches, metadata) with the node's worker thread, and reaches
//! other nodes through the transport — a remote `open()` is the round-trip
//! message of paper §5.4.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::error::{FanError, Result};
use crate::metadata::record::{FileLocation, FileMeta, FileStat};
use crate::metadata::table::normalize;
use crate::net::transport::{InProcTransport, Request};
use crate::node::NodeState;
use crate::vfs::{Fd, OpenFlags, Vfs};

enum OpenFile {
    Read {
        path: String,
        data: Arc<Vec<u8>>,
        pos: usize,
    },
    Write {
        path: String,
        buf: Vec<u8>,
    },
}

/// Client handle bound to one node.
pub struct FanStoreVfs {
    node_id: u32,
    state: Arc<Mutex<NodeState>>,
    transport: InProcTransport,
    fds: HashMap<Fd, OpenFile>,
    next_fd: Fd,
}

impl FanStoreVfs {
    pub fn new(node_id: u32, state: Arc<Mutex<NodeState>>, transport: InProcTransport) -> Self {
        FanStoreVfs {
            node_id,
            state,
            transport,
            fds: HashMap::new(),
            next_fd: 3, // 0,1,2 are stdio, as tradition demands
        }
    }

    fn alloc_fd(&mut self) -> Fd {
        let fd = self.next_fd;
        self.next_fd += 1;
        fd
    }

    /// Fetch + decompress an input file's content, going through the node's
    /// refcount cache.  Returns a pinned Arc (caller must `release` on
    /// close — handled by [`Vfs::close`]).
    fn fetch_input(&mut self, path: &str, loc: FileLocation) -> Result<Arc<Vec<u8>>> {
        // 1) cache hit on this node?
        {
            let mut st = self.state.lock().unwrap();
            if let Some(data) = st.cache.acquire(path) {
                return Ok(data);
            }
        }
        // 2) local partition?  (replicated directories — the test-set
        //    broadcast of §5.4 — are always local)
        let holder = if loc.partition == crate::metadata::record::REPLICATED_PARTITION {
            self.node_id
        } else {
            let st = self.state.lock().unwrap();
            st.placement.choose_holder(loc.partition, self.node_id)
        };
        let (stored, raw_len, compressed) = if holder == self.node_id {
            let mut st = self.state.lock().unwrap();
            let (stored, at) = st.store.read_stored(path)?;
            st.stats.local_reads += 1;
            st.stats.bytes_read_local += stored.len() as u64;
            (stored, at.raw_len, at.compressed)
        } else {
            // 3) remote round trip (paper §5.4)
            let resp = self.transport.call(
                self.node_id,
                holder,
                Request::ReadFile {
                    path: path.to_string(),
                },
            )?;
            let (stored, raw_len, compressed) = resp.into_file_data()?;
            let mut st = self.state.lock().unwrap();
            st.stats.remote_reads_issued += 1;
            st.stats.bytes_fetched_remote += stored.len() as u64;
            (stored, raw_len, compressed)
        };
        // 4) decompress on the reading node (§5.4)
        let raw = if compressed {
            let out = crate::compress::lzss::decompress(&stored, raw_len as usize)?;
            self.state.lock().unwrap().stats.decompressions += 1;
            out
        } else {
            stored
        };
        Ok(self.state.lock().unwrap().cache.insert(path, raw))
    }

    /// Read an already-committed output file (checkpoint resume path).
    fn fetch_output(&mut self, path: &str, meta: &FileMeta) -> Result<Arc<Vec<u8>>> {
        let origin = meta.location.node;
        if origin == self.node_id {
            let st = self.state.lock().unwrap();
            return st
                .output_data
                .get(path)
                .cloned()
                .ok_or_else(|| FanError::NotFound(path.to_string()));
        }
        let resp = self.transport.call(
            self.node_id,
            origin,
            Request::ReadFile {
                path: path.to_string(),
            },
        )?;
        let (stored, _, _) = resp.into_file_data()?;
        Ok(Arc::new(stored))
    }

    /// Locate output metadata: local home table, else ask the home node.
    fn stat_output(&mut self, path: &str) -> Result<FileMeta> {
        let home = {
            let st = self.state.lock().unwrap();
            st.placement.output_home(path)
        };
        if home == self.node_id {
            let st = self.state.lock().unwrap();
            return st
                .output_meta
                .get(path)
                .cloned()
                .ok_or_else(|| FanError::NotFound(path.to_string()));
        }
        match self.transport.call(
            self.node_id,
            home,
            Request::StatOutput {
                path: path.to_string(),
            },
        )? {
            crate::net::transport::Response::Meta { stat, origin } => Ok(FileMeta {
                stat,
                location: FileLocation {
                    node: origin,
                    partition: u32::MAX,
                    offset: 0,
                    stored_len: stat.size,
                    compressed: false,
                },
            }),
            crate::net::transport::Response::Err(_) => {
                Err(FanError::NotFound(path.to_string()))
            }
            other => Err(FanError::Transport(format!("unexpected {other:?}"))),
        }
    }
}

impl Vfs for FanStoreVfs {
    fn open(&mut self, path: &str, flags: OpenFlags) -> Result<Fd> {
        let path = normalize(path);
        match flags {
            OpenFlags::Read => {
                let loc = {
                    let st = self.state.lock().unwrap();
                    st.input_meta.get(&path).map(|m| m.location)
                };
                let data = match loc {
                    Some(loc) => self.fetch_input(&path, loc)?,
                    None => {
                        // not an input: maybe a committed output file
                        let meta = self.stat_output(&path)?;
                        self.fetch_output(&path, &meta)?
                    }
                };
                let fd = self.alloc_fd();
                self.fds.insert(
                    fd,
                    OpenFile::Read {
                        path,
                        data,
                        pos: 0,
                    },
                );
                Ok(fd)
            }
            OpenFlags::Write => {
                {
                    let st = self.state.lock().unwrap();
                    if st.input_meta.get(&path).is_some() {
                        return Err(FanError::Consistency(format!(
                            "input files are immutable: {path}"
                        )));
                    }
                }
                if self.stat_output(&path).is_ok() {
                    return Err(FanError::Consistency(format!(
                        "output files are single-write: {path}"
                    )));
                }
                let fd = self.alloc_fd();
                self.fds.insert(fd, OpenFile::Write { path, buf: Vec::new() });
                Ok(fd)
            }
        }
    }

    fn read(&mut self, fd: Fd, buf: &mut [u8]) -> Result<usize> {
        match self.fds.get_mut(&fd) {
            Some(OpenFile::Read { data, pos, .. }) => {
                let n = buf.len().min(data.len() - *pos);
                buf[..n].copy_from_slice(&data[*pos..*pos + n]);
                *pos += n;
                Ok(n)
            }
            Some(OpenFile::Write { .. }) => Err(FanError::Consistency(
                "descriptor is write-only".into(),
            )),
            None => Err(FanError::BadFd(fd)),
        }
    }

    fn write(&mut self, fd: Fd, data: &[u8]) -> Result<usize> {
        match self.fds.get_mut(&fd) {
            Some(OpenFile::Write { buf, .. }) => {
                // §5.4: "the data written is concatenated to a buffer"
                buf.extend_from_slice(data);
                Ok(data.len())
            }
            Some(OpenFile::Read { .. }) => Err(FanError::Consistency(
                "descriptor is read-only".into(),
            )),
            None => Err(FanError::BadFd(fd)),
        }
    }

    fn close(&mut self, fd: Fd) -> Result<()> {
        match self.fds.remove(&fd) {
            Some(OpenFile::Read { path, data, .. }) => {
                drop(data);
                self.state.lock().unwrap().cache.release(&path);
                Ok(())
            }
            Some(OpenFile::Write { path, buf }) => {
                // visible-until-finish commit (§5.4): store data on the
                // originating node, forward metadata to the home node.
                let size = buf.len() as u64;
                let meta = FileMeta {
                    stat: FileStat::regular(crate::metadata::placement::path_hash(&path), size),
                    location: FileLocation {
                        node: self.node_id,
                        partition: u32::MAX,
                        offset: 0,
                        stored_len: size,
                        compressed: false,
                    },
                };
                let home = {
                    let mut st = self.state.lock().unwrap();
                    st.output_data.insert(path.clone(), Arc::new(buf));
                    st.stats.outputs_committed += 1;
                    st.stats.output_bytes += size;
                    st.placement.output_home(&path)
                };
                if home == self.node_id {
                    self.state
                        .lock()
                        .unwrap()
                        .serve(&Request::CommitOutput { path, meta });
                } else {
                    self.transport
                        .call(self.node_id, home, Request::CommitOutput { path, meta })?;
                }
                Ok(())
            }
            None => Err(FanError::BadFd(fd)),
        }
    }

    fn stat(&mut self, path: &str) -> Result<FileStat> {
        let path = normalize(path);
        {
            let st = self.state.lock().unwrap();
            if let Ok(s) = st.input_meta.stat(&path) {
                return Ok(s);
            }
        }
        self.stat_output(&path).map(|m| m.stat)
    }

    fn readdir(&mut self, dir: &str) -> Result<Vec<String>> {
        let dir = normalize(dir);
        let mut names: Vec<String> = {
            let st = self.state.lock().unwrap();
            match st.input_meta.readdir(&dir) {
                Ok(v) => v.to_vec(),
                Err(FanError::NotFound(_)) => Vec::new(),
                Err(e) => return Err(e),
            }
        };
        // Output metadata is spread over all nodes — a full listing is a
        // gather, the §4 critique of distributed metadata made concrete.
        let n = self.transport.node_count();
        for node in 0..n {
            let extra = if node == self.node_id {
                match self.state.lock().unwrap().serve(&Request::ListOutputs { dir: dir.clone() }) {
                    crate::net::transport::Response::Names(v) => v,
                    _ => Vec::new(),
                }
            } else {
                match self.transport.call(
                    self.node_id,
                    node,
                    Request::ListOutputs { dir: dir.clone() },
                )? {
                    crate::net::transport::Response::Names(v) => v,
                    _ => Vec::new(),
                }
            };
            names.extend(extra);
        }
        names.sort();
        names.dedup();
        if names.is_empty() {
            // distinguish empty dir from missing dir via input table
            let st = self.state.lock().unwrap();
            if !st.input_meta.is_dir(&dir) {
                return Err(FanError::NotFound(dir));
            }
        }
        Ok(names)
    }

    fn unlink(&mut self, path: &str) -> Result<()> {
        let path = normalize(path);
        {
            let st = self.state.lock().unwrap();
            if st.input_meta.get(&path).is_some() {
                return Err(FanError::Consistency(format!(
                    "input files are immutable: {path}"
                )));
            }
        }
        let home = {
            let st = self.state.lock().unwrap();
            st.placement.output_home(&path)
        };
        if home == self.node_id {
            let mut st = self.state.lock().unwrap();
            st.output_meta.remove(&path)?;
            st.output_data.remove(&path);
            Ok(())
        } else {
            // remove metadata at home; data GC at origin is lazy
            match self.transport.call(
                self.node_id,
                home,
                Request::StatOutput { path: path.clone() },
            )? {
                crate::net::transport::Response::Meta { .. } => {
                    // Note: full remote unlink protocol elided — the DL
                    // pattern never unlinks (§3.4); this path serves tests.
                    Err(FanError::Consistency(
                        "remote unlink not supported by the DL I/O pattern".into(),
                    ))
                }
                _ => Err(FanError::NotFound(path)),
            }
        }
    }
}
