//! FanStore VFS client: the user-space logic behind the intercepted calls.
//!
//! One `FanStoreVfs` per training process.  It shares its node's
//! [`NodeShared`] (store, caches, metadata) with the node's worker thread
//! and every other client on the node, and reaches other nodes through the
//! transport — a remote `open()` is the round-trip message of paper §5.4.
//!
//! There is no node-global lock on this path: input metadata and the
//! partition store are sealed immutable, the refcount cache is sharded, and
//! stats are atomics — so K clients on one node proceed in parallel.  File
//! content moves as `Payload` handles end to end (for RAM/mmap-backed
//! partitions a zero-copy view of the region itself); `read()` copies into
//! the caller's buffer (the POSIX contract) but nothing else copies
//! payloads.  Wire paths are `Arc<str>` handles, cloned per request.
//!
//! # Failure semantics (PR 7)
//!
//! Every input read funnels through
//! [`NodeShared::fetch_inputs_batched`], which owns failover: on a
//! transport error the fetch retries the next live holder from the node's
//! health map, so `open()`/`read_all()` survive a dead peer transparently
//! whenever a replica exists.  When *every* holder of a file is down, the
//! call returns `FanError::Transport` (mapping to `EIO` at the syscall
//! boundary) within the configured call timeout — a degraded read is a
//! real errno, never a hang.

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::compress::Codec;
use crate::error::{FanError, Result};
use crate::metadata::record::{FileLocation, FileMeta, FileStat};
use crate::metadata::table::normalize;
use crate::net::transport::{
    FileFetch, MetaFetch, PendingReply, Request, Response, Transport,
};
use crate::node::NodeShared;
use crate::prefetch::PrefetchHandle;
use crate::storage::payload::Payload;
use crate::vfs::{Fd, OpenFlags, Vfs};

enum OpenFile {
    Read {
        path: String,
        /// The refcount-cache pin, in *stored* (possibly compressed) form —
        /// the handle `close()` releases.  Cache identity, not content.
        pin: Payload,
        /// Decoded content served to `read()` (the pin itself when the
        /// entry is uncompressed — no copy).
        data: Payload,
        pos: usize,
    },
    Write {
        path: String,
        buf: Vec<u8>,
    },
}

/// Client handle bound to one node.  Holds its fabric as `Arc<dyn
/// Transport>`, so the same client logic runs over the in-proc channels or
/// real TCP sockets unchanged.
pub struct FanStoreVfs {
    node_id: u32,
    shared: Arc<NodeShared>,
    transport: Arc<dyn Transport>,
    fds: HashMap<Fd, OpenFile>,
    next_fd: Fd,
    /// Node prefetch engine, when attached: `fetch_input` claims fetched
    /// pins from it before touching the cache or the network.
    prefetcher: Option<PrefetchHandle>,
    /// Pins warmed by [`Vfs::prefetch`] (the batched mini-batch hint),
    /// consumed by the subsequent `open`s.  Leftovers are released on the
    /// next hint or on drop.
    warm: HashMap<Arc<str>, Payload>,
}

impl FanStoreVfs {
    pub fn new(node_id: u32, shared: Arc<NodeShared>, transport: Arc<dyn Transport>) -> Self {
        FanStoreVfs {
            node_id,
            shared,
            transport,
            fds: HashMap::new(),
            next_fd: 3, // 0,1,2 are stdio, as tradition demands
            prefetcher: None,
            warm: HashMap::new(),
        }
    }

    /// Attach the node's background prefetch engine; subsequent input
    /// opens claim prefetched content instead of fetching synchronously.
    pub fn attach_prefetcher(&mut self, handle: PrefetchHandle) {
        self.prefetcher = Some(handle);
    }

    fn alloc_fd(&mut self) -> Fd {
        let fd = self.next_fd;
        self.next_fd += 1;
        fd
    }

    /// Release every unconsumed warm pin (stale batch hint).
    fn drain_warm(&mut self) {
        for (path, pin) in self.warm.drain() {
            self.shared.cache.release(&path, &pin);
        }
    }

    /// Retire the mutated path's ancestor-chain listings on this node and
    /// tell every peer to do the same (directory-granular: unrelated hot
    /// listings stay cached across checkpoints).  Awaited: once this
    /// returns, a `readdir` anywhere in the cluster re-gathers and sees
    /// the mutation that prompted the call.  `home` is skipped — its
    /// `CommitOutput`/`UnlinkOutput` serve arm already invalidated its own
    /// listings when the mutation landed there.  Best effort per peer — an
    /// unreachable node cannot be holding a *fresh* stale listing, and it
    /// re-gathers once it recovers.
    fn invalidate_listings_cluster_wide(&self, home: u32, path: &Arc<str>) {
        self.shared.invalidate_listings_for(path);
        let n = self.transport.node_count();
        let pending: Vec<PendingReply> = (0..n)
            .filter(|&node| node != self.node_id && node != home)
            .filter_map(|node| {
                self.transport
                    .send(
                        self.node_id,
                        node,
                        Request::InvalidateListings {
                            path: Arc::clone(path),
                        },
                    )
                    .ok()
            })
            .collect();
        for p in pending {
            let _ = p.wait();
        }
    }

    /// Fetch an input file's content in stored form, going through the
    /// node's refcount cache.  Returns a pinned handle (caller must
    /// `release` on close — handled by [`Vfs::close`]); a compressed entry
    /// is expanded once, at `open`, by [`NodeShared::decode_payload`].
    fn fetch_input(&mut self, path: &str, loc: FileLocation) -> Result<Payload> {
        // 0) pin warmed by a batched prefetch() hint: already ours
        if let Some(pin) = self.warm.remove(path) {
            return Ok(pin);
        }
        // 1) background prefetch pipeline owns it?  The claim transfers the
        //    engine's cache pin to this descriptor (steady-state hot path).
        if let Some(pf) = &self.prefetcher {
            if let Some(pin) = pf.wait(path) {
                return Ok(pin);
            }
        }
        // 2..4) cache / local store / remote round trip (paper §5.4): the
        // shared batched-fetch body, degenerate single-path case
        let batch = self
            .shared
            .fetch_inputs_batched(self.transport.as_ref(), vec![(path.into(), loc)]);
        let (_, outcome) = batch
            .outcomes
            .into_iter()
            .next()
            .expect("one outcome per requested path");
        outcome.map(|(pin, _src)| pin)
    }

    /// Read an already-committed output file (checkpoint resume path),
    /// going through the refcount cache exactly like inputs do — repeated
    /// resume `open()`s on one node fetch from the origin once.
    fn fetch_output(&mut self, path: &str, meta: &FileMeta) -> Result<Payload> {
        if let Some(data) = self.shared.cache.acquire(path) {
            // Guard against a cached copy that predates an unlink+rewrite
            // on the home node (only the home invalidates its own cache):
            // the authoritative stat is the referee.  The commit generation
            // recorded when these bytes were inserted closes the last
            // window — a same-origin same-size rewrite carries a fresh
            // generation and retires the stale copy too.
            let cached_gen = self.shared.output_gen.read().unwrap().get(path).copied();
            let gen_fresh = match cached_gen {
                Some(g) => g == meta.generation,
                None => true, // pre-stamp resident bytes: size check only
            };
            if data.len() as u64 == meta.stat.size && gen_fresh {
                return Ok(data);
            }
            // single-lock, generation-aware refresh: drops our pin and
            // removes the entry only if it still holds this stale data
            self.shared.cache.retire(path, &data);
        }
        // Candidate sources (PR 9): the origin buffered the bytes at
        // `write()`, and `close()` fanned a copy out to every output home —
        // any one of them serves a resume read, so the death of the origin
        // no longer loses the checkpoint.  When a home is Down, the
        // deterministic adoptee may hold a repaired copy; ask it last.
        let origin = meta.location.node;
        let homes = self.shared.placement.output_homes(path);
        let mut sources: Vec<u32> = Vec::with_capacity(homes.len() + 2);
        sources.push(origin);
        for &h in &homes {
            if !sources.contains(&h) {
                sources.push(h);
            }
        }
        let down = |n: u32| {
            n != self.node_id
                && self.shared.health.state(n) == crate::net::health::PeerState::Down
        };
        if homes.iter().any(|&h| down(h)) {
            let start = (homes[0] + 1) % self.shared.placement.nodes;
            if let Some(a) = self.shared.placement.adopt_node(&homes, start, down) {
                if !sources.contains(&a) {
                    sources.push(a);
                }
            }
        }
        let stats = &self.shared.stats;
        let mut transport_err: Option<FanError> = None;
        let mut found: Option<Payload> = None;
        for &src in &sources {
            if src == self.node_id {
                let local = self.shared.output_data.read().unwrap().get(path).cloned();
                if let Some(data) = local {
                    stats.local_reads.fetch_add(1, Ordering::Relaxed);
                    stats
                        .bytes_read_local
                        .fetch_add(data.len() as u64, Ordering::Relaxed);
                    found = Some(data.into());
                    break;
                }
                continue;
            }
            // batched-read request even for one file: its per-file result
            // keeps a gone-at-source file distinguishable (ENOENT) from a
            // transport fault, which the stale-metadata retry in `open`
            // depends on
            let resp = self
                .transport
                .call(
                    self.node_id,
                    src,
                    Request::ReadFiles {
                        paths: vec![path.into()],
                    },
                )
                .and_then(|r| r.into_files_data());
            match resp {
                Ok(files) => {
                    self.shared.health.record_success(src, None);
                    let fetch = files
                        .into_iter()
                        .next()
                        .map(|(_, f)| f)
                        .unwrap_or(FileFetch::NotFound);
                    match fetch.into_result(path) {
                        Ok(stored) => {
                            stats.remote_reads_issued.fetch_add(1, Ordering::Relaxed);
                            stats
                                .bytes_fetched_remote
                                .fetch_add(stored.len() as u64, Ordering::Relaxed);
                            found = Some(stored);
                            break;
                        }
                        // this source never got (or already dropped) a copy;
                        // the next replica may still hold one
                        Err(FanError::NotFound(_)) => {}
                        Err(e) => return Err(e),
                    }
                }
                Err(e) => {
                    if self.shared.health.record_failure(src) {
                        stats.peers_marked_down.fetch_add(1, Ordering::Relaxed);
                        self.transport.evict(src);
                    }
                    transport_err = Some(e);
                }
            }
        }
        let data: Payload = match found {
            Some(data) => data,
            // every reachable source answered ENOENT: authoritative miss
            // (drives the stale-metadata retry in `open`).  If a source was
            // unreachable the bytes may still exist — that is EIO, not a lie.
            None => match transport_err {
                Some(e) => return Err(e),
                None => return Err(FanError::NotFound(path.to_string())),
            },
        };
        // remember which commit generation these resident bytes belong to —
        // the referee for the staleness check above on later re-opens
        self.shared
            .output_gen
            .write()
            .unwrap()
            .insert(path.to_string(), meta.generation);
        Ok(self.shared.cache.insert(path, data))
    }

    /// Locate output metadata: local home table, else the node's metadata
    /// cache (saving the `StatOutput` round trip), else ask the home node
    /// and cache the answer next to the (eventually) cached bytes.
    fn stat_output(&mut self, path: &str) -> Result<FileMeta> {
        self.stat_output_ex(path, false)
    }

    fn stat_output_ex(&mut self, path: &str, fresh: bool) -> Result<FileMeta> {
        let homes = self.shared.placement.output_homes(path);
        let primary = homes[0];
        if homes.contains(&self.node_id) {
            let local = self.shared.output_meta.read().unwrap().get(path).cloned();
            if let Some(meta) = local {
                return Ok(meta);
            }
            if primary == self.node_id {
                // the primary's table is the authority for the name
                return Err(FanError::NotFound(path.to_string()));
            }
            // a secondary home without the record (missed replica commit):
            // fall through and ask the other homes
        }
        if !fresh {
            let cached = self
                .shared
                .output_meta_cache
                .read()
                .unwrap()
                .get(path)
                .cloned();
            if let Some(meta) = cached {
                self.shared
                    .stats
                    .output_meta_hits
                    .fetch_add(1, Ordering::Relaxed);
                return Ok(meta);
            }
        }
        // Ask the homes health-ordered, primary preferred (PR 9): any home
        // can answer a stat because `close()` replicated the stamped
        // metadata.  Only the *primary's* ENOENT is authoritative — a
        // secondary may simply have missed its replica commit, so its miss
        // only counts once no home can prove the name exists.
        let remote: Vec<u32> = homes
            .iter()
            .copied()
            .filter(|&h| h != self.node_id)
            .collect();
        let mut transport_err: Option<FanError> = None;
        let mut missing_at: Option<u32> = None;
        for &h in &self.shared.health.order_candidates(&remote, primary) {
            match self.transport.call(
                self.node_id,
                h,
                Request::StatOutput { path: path.into() },
            ) {
                Ok(Response::Meta {
                    stat,
                    origin,
                    generation,
                }) => {
                    self.shared.health.record_success(h, None);
                    let meta = output_meta(stat, origin, generation);
                    self.shared
                        .output_meta_cache
                        .write()
                        .unwrap()
                        .insert(path.to_string(), meta.clone());
                    return Ok(meta);
                }
                Ok(Response::Err(_)) => {
                    self.shared.health.record_success(h, None);
                    if h == primary {
                        return Err(FanError::NotFound(path.to_string()));
                    }
                    missing_at = Some(h);
                }
                Ok(other) => {
                    return Err(FanError::Transport(format!("unexpected {other:?}")))
                }
                Err(e) => {
                    if self.shared.health.record_failure(h) {
                        self.shared
                            .stats
                            .peers_marked_down
                            .fetch_add(1, Ordering::Relaxed);
                        self.transport.evict(h);
                    }
                    transport_err = Some(e);
                }
            }
        }
        // Double-failure window (PR 10, carried from PR 9): when the homes
        // themselves are down, the deterministic adoptee may hold a
        // repaired copy — `repair_tick` re-commits bytes + stamped
        // metadata there with the same `adopt_node` arithmetic used here.
        // Its found answer is as good as a home's; its ENOENT is NOT
        // authoritative (the repair may simply not have run yet).
        let down = |n: u32| {
            n != self.node_id
                && self.shared.health.state(n) == crate::net::health::PeerState::Down
        };
        if homes.iter().any(|&h| down(h)) {
            let start = (homes[0] + 1) % self.shared.placement.nodes;
            if let Some(a) = self.shared.placement.adopt_node(&homes, start, down) {
                if a == self.node_id {
                    let local = self.shared.output_meta.read().unwrap().get(path).cloned();
                    if let Some(meta) = local {
                        return Ok(meta);
                    }
                } else if let Ok(Response::Meta {
                    stat,
                    origin,
                    generation,
                }) = self.transport.call(
                    self.node_id,
                    a,
                    Request::StatOutput { path: path.into() },
                ) {
                    self.shared.health.record_success(a, None);
                    let meta = output_meta(stat, origin, generation);
                    self.shared
                        .output_meta_cache
                        .write()
                        .unwrap()
                        .insert(path.to_string(), meta.clone());
                    return Ok(meta);
                }
            }
        }
        match (missing_at, transport_err) {
            // every reachable home answered ENOENT and nobody was skipped:
            // the name provably does not exist
            (Some(_), None) => Err(FanError::NotFound(path.to_string())),
            // an unreachable home might still hold the record a reachable
            // secondary missed — EIO, never a fabricated ENOENT
            (_, Some(e)) => Err(e),
            // single-node homes degenerate: remote set was empty
            (None, None) => Err(FanError::NotFound(path.to_string())),
        }
    }
}

/// Reader-side record for a committed output from its home node's answer.
fn output_meta(stat: FileStat, origin: u32, generation: u64) -> FileMeta {
    FileMeta {
        stat,
        location: FileLocation {
            node: origin,
            partition: u32::MAX,
            offset: 0,
            stored_len: stat.size,
            codec: Codec::None,
        },
        generation,
    }
}

impl Vfs for FanStoreVfs {
    fn open(&mut self, path: &str, flags: OpenFlags) -> Result<Fd> {
        let path = normalize(path);
        match flags {
            OpenFlags::Read => {
                let loc = self.shared.input_meta.get(&path).map(|m| m.location);
                let pin = match loc {
                    Some(loc) => self.fetch_input(&path, loc)?,
                    None => {
                        // Not an input: a committed output file.  When its
                        // bytes are resident on this node, the stat must be
                        // authoritative — it is the stale-generation referee
                        // for the cached copy, and a cached stat would just
                        // ratify its own generation.  The metadata cache only
                        // short-circuits opens that must contact the origin
                        // anyway, where a stale entry is corrected by the
                        // origin's per-file ENOENT below.
                        let resident = self.shared.cache.contains(&path);
                        let meta = self.stat_output_ex(&path, resident)?;
                        match self.fetch_output(&path, &meta) {
                            Ok(data) => data,
                            Err(FanError::NotFound(_)) => {
                                // cached metadata can go stale after a
                                // cross-node unlink(+rewrite): the origin
                                // answered ENOENT, so drop the cached entry
                                // and retry once against the home node
                                self.shared
                                    .output_meta_cache
                                    .write()
                                    .unwrap()
                                    .remove(&path);
                                let meta = self.stat_output_ex(&path, true)?;
                                self.fetch_output(&path, &meta)?
                            }
                            Err(e) => return Err(e),
                        }
                    }
                };
                // the single decode point (§5.4): the cache pin stays in
                // stored form; this descriptor gets the expanded content —
                // via the decoded side cache, so N concurrent opens of one
                // hot compressed file share a single decompression (PR 8).
                // On a codec fault the pin must not leak its refcount.
                let data = match self.shared.decode_payload_cached(&path, &pin) {
                    Ok(data) => data,
                    Err(e) => {
                        self.shared.cache.release(&path, &pin);
                        return Err(e);
                    }
                };
                let fd = self.alloc_fd();
                self.fds.insert(
                    fd,
                    OpenFile::Read {
                        path,
                        pin,
                        data,
                        pos: 0,
                    },
                );
                Ok(fd)
            }
            OpenFlags::Write => {
                if self.shared.input_meta.get(&path).is_some() {
                    return Err(FanError::Consistency(format!(
                        "input files are immutable: {path}"
                    )));
                }
                // single-write guard against the AUTHORITATIVE home, never
                // the metadata cache: a stale cached entry surviving a
                // cross-node unlink must not refuse the name forever
                if self.stat_output_ex(&path, true).is_ok() {
                    return Err(FanError::Consistency(format!(
                        "output files are single-write: {path}"
                    )));
                }
                let fd = self.alloc_fd();
                self.fds.insert(fd, OpenFile::Write { path, buf: Vec::new() });
                Ok(fd)
            }
        }
    }

    fn read(&mut self, fd: Fd, buf: &mut [u8]) -> Result<usize> {
        match self.fds.get_mut(&fd) {
            Some(OpenFile::Read { data, pos, .. }) => {
                let bytes = data.as_slice();
                let n = buf.len().min(bytes.len() - *pos);
                buf[..n].copy_from_slice(&bytes[*pos..*pos + n]);
                *pos += n;
                Ok(n)
            }
            Some(OpenFile::Write { .. }) => Err(FanError::Consistency(
                "descriptor is write-only".into(),
            )),
            None => Err(FanError::BadFd(fd)),
        }
    }

    fn write(&mut self, fd: Fd, data: &[u8]) -> Result<usize> {
        match self.fds.get_mut(&fd) {
            Some(OpenFile::Write { buf, .. }) => {
                // §5.4: "the data written is concatenated to a buffer"
                buf.extend_from_slice(data);
                Ok(data.len())
            }
            Some(OpenFile::Read { .. }) => Err(FanError::Consistency(
                "descriptor is read-only".into(),
            )),
            None => Err(FanError::BadFd(fd)),
        }
    }

    fn close(&mut self, fd: Fd) -> Result<()> {
        match self.fds.remove(&fd) {
            Some(OpenFile::Read { path, pin, .. }) => {
                self.shared.cache.release(&path, &pin);
                Ok(())
            }
            Some(OpenFile::Write { path, buf }) => {
                // visible-until-finish commit (§5.4): store data on the
                // originating node, forward metadata to the home node.
                let size = buf.len() as u64;
                let meta = FileMeta {
                    stat: FileStat::regular(crate::metadata::placement::path_hash(&path), size),
                    location: FileLocation {
                        node: self.node_id,
                        partition: u32::MAX,
                        offset: 0,
                        stored_len: size,
                        codec: Codec::None,
                    },
                    // stamped by the home node when the commit lands
                    generation: 0,
                };
                // data first, then the metadata commit: once the name is
                // discoverable at the home node, the bytes must already be
                // servable from here.
                let bytes: Arc<[u8]> = buf.into();
                let payload: Payload = Arc::clone(&bytes).into();
                self.shared
                    .output_data
                    .write()
                    .unwrap()
                    .insert(path.clone(), bytes);
                let homes = self.shared.placement.output_homes(&path);
                let home = homes[0];
                // one interned wire handle for the commits + the broadcast
                let path: Arc<str> = path.into();
                // The primary home is the serializer: it stamps the commit
                // generation and its success IS the commit.  Data rides
                // along, so the home set can serve reads without the origin.
                let landed = if home == self.node_id {
                    self.shared.serve(&Request::CommitOutput {
                        path: Arc::clone(&path),
                        meta: meta.clone(),
                        data: payload.clone(),
                        stamped: false,
                    })
                } else {
                    self.transport.call(
                        self.node_id,
                        home,
                        Request::CommitOutput {
                            path: Arc::clone(&path),
                            meta: meta.clone(),
                            data: payload.clone(),
                            stamped: false,
                        },
                    )?
                };
                let generation = match landed {
                    Response::Meta { generation, .. } => generation,
                    other => {
                        return Err(FanError::Transport(format!(
                            "commit not acknowledged: {other:?}"
                        )))
                    }
                };
                // Replica fan-out (PR 9): the stamped meta + bytes go to the
                // remaining homes, so the checkpoint survives the death of
                // its origin or primary.  Best effort — a missed replica is
                // healed by the background re-replicator, and generation
                // stamps resolve any commit/repair race deterministically.
                let mut replica = meta;
                replica.generation = generation;
                for &h in &homes[1..] {
                    let req = Request::CommitOutput {
                        path: Arc::clone(&path),
                        meta: replica.clone(),
                        data: payload.clone(),
                        stamped: true,
                    };
                    if h == self.node_id {
                        self.shared.serve(&req);
                    } else {
                        let _ = self.transport.call(self.node_id, h, req);
                    }
                }
                // count only once the commit actually landed — a dead home
                // node must not inflate the committed totals
                self.shared
                    .stats
                    .outputs_committed
                    .fetch_add(1, Ordering::Relaxed);
                self.shared
                    .stats
                    .output_bytes
                    .fetch_add(size, Ordering::Relaxed);
                // the new name is listable everywhere: retire its ancestor
                // listings on every node before the close returns
                self.invalidate_listings_cluster_wide(home, &path);
                Ok(())
            }
            None => Err(FanError::BadFd(fd)),
        }
    }

    fn stat(&mut self, path: &str) -> Result<FileStat> {
        let path = normalize(path);
        if let Ok(s) = self.shared.input_meta.stat(&path) {
            return Ok(s);
        }
        self.stat_output(&path).map(|m| m.stat)
    }

    /// Batched stat: inputs answered from the replicated table, locally
    /// homed outputs from this node's own table, and every remote home gets
    /// **one `StatOutputs` round trip**, all in flight before any reply is
    /// awaited — a multi-shard checkpoint resume stats all its shards in
    /// one round trip per home node instead of one per shard.  Fetched
    /// metadata lands in the node's output-meta cache, so the subsequent
    /// shard `open`s skip their `StatOutput` too.
    fn stat_many(&mut self, paths: &[String]) -> Vec<Result<FileStat>> {
        enum Slot {
            Done(Result<FileStat>),
            Pending,
        }
        let normalized: Vec<String> = paths.iter().map(|p| normalize(p)).collect();
        let mut slots: Vec<Slot> = Vec::with_capacity(normalized.len());
        let mut remote: HashMap<u32, Vec<(usize, Arc<str>)>> = HashMap::new();
        for (i, path) in normalized.iter().enumerate() {
            if let Ok(s) = self.shared.input_meta.stat(path) {
                slots.push(Slot::Done(Ok(s)));
                continue;
            }
            let home = self.shared.placement.output_home(path);
            if home == self.node_id {
                let stat = self.shared.output_meta.read().unwrap().get(path).map(|m| m.stat);
                slots.push(Slot::Done(
                    stat.ok_or_else(|| FanError::NotFound(path.clone())),
                ));
                continue;
            }
            // already-cached remote metadata answers without joining any
            // batch — the same round trip the single-path stat saves
            let cached = self
                .shared
                .output_meta_cache
                .read()
                .unwrap()
                .get(path)
                .map(|m| m.stat);
            if let Some(stat) = cached {
                self.shared
                    .stats
                    .output_meta_hits
                    .fetch_add(1, Ordering::Relaxed);
                slots.push(Slot::Done(Ok(stat)));
                continue;
            }
            slots.push(Slot::Pending);
            remote.entry(home).or_default().push((i, path.as_str().into()));
        }
        // one batched request per remote home, all issued before any wait
        // (Arc clones of the interned handles, no string copies)
        let pending: Vec<(Vec<(usize, Arc<str>)>, Result<PendingReply>)> = remote
            .into_iter()
            .map(|(home, entries)| {
                let reply = self.transport.send(
                    self.node_id,
                    home,
                    Request::StatOutputs {
                        paths: entries.iter().map(|(_, p)| Arc::clone(p)).collect(),
                    },
                );
                (entries, reply)
            })
            .collect();
        for (entries, reply) in pending {
            let metas = reply
                .and_then(|r| r.wait())
                .and_then(|resp| resp.into_metas());
            match metas {
                Ok(metas) => {
                    // looked up by `get`, never `remove`: duplicate (or
                    // alias-normalized) paths in one call must all resolve
                    let by_path: HashMap<Arc<str>, MetaFetch> = metas.into_iter().collect();
                    for (i, path) in entries {
                        let outcome = match by_path.get(&*path) {
                            Some(MetaFetch::Meta {
                                stat,
                                origin,
                                generation,
                            }) => {
                                // cache next to the eventually cached bytes,
                                // like a single StatOutput answer would be
                                self.shared
                                    .output_meta_cache
                                    .write()
                                    .unwrap()
                                    .insert(
                                        path.to_string(),
                                        output_meta(*stat, *origin, *generation),
                                    );
                                Ok(*stat)
                            }
                            Some(MetaFetch::NotFound) => {
                                Err(FanError::NotFound(path.to_string()))
                            }
                            None => Err(FanError::Transport(format!(
                                "home reply missing entry for {path}"
                            ))),
                        };
                        slots[i] = Slot::Done(outcome);
                    }
                }
                // primary home unreachable: recover each path through the
                // replicated homes (PR 9) instead of failing the whole
                // shard batch — only if no home can answer does the
                // transport failure surface (never a fabricated ENOENT)
                Err(_) => {
                    for (i, path) in entries {
                        slots[i] = Slot::Done(self.stat_output_ex(&path, true).map(|m| m.stat));
                    }
                }
            }
        }
        slots
            .into_iter()
            .zip(normalized)
            .map(|(slot, path)| match slot {
                Slot::Done(r) => r,
                Slot::Pending => Err(FanError::Transport(format!("no stat reply for {path}"))),
            })
            .collect()
    }

    fn readdir(&mut self, dir: &str) -> Result<Vec<String>> {
        let dir = normalize(dir);
        // Steady state: the node's generation-stamped listing cache makes
        // the whole gather a local lookup.  Any commit/unlink anywhere in
        // the cluster invalidates it before the mutating call returns (the
        // writer's awaited `InvalidateListings` broadcast), so a listing
        // taken after a mutation always re-gathers.
        if let Some(names) = self.shared.cached_listing(&dir) {
            self.shared
                .stats
                .readdir_cache_hits
                .fetch_add(1, Ordering::Relaxed);
            return Ok((*names).clone());
        }
        // stamp BEFORE gathering: an invalidation racing this gather bumps
        // the generation and the stale merge below is not installed
        let gen = self.shared.listing_generation();
        let mut names: Vec<String> = match self.shared.input_meta.readdir(&dir) {
            Ok(v) => v.to_vec(),
            Err(FanError::NotFound(_)) => Vec::new(),
            Err(e) => return Err(e),
        };
        // Output metadata is spread over all nodes — a full listing is a
        // gather, the §4 critique of distributed metadata made concrete.
        // Issue the request to every peer first, then collect: the N-1
        // round trips overlap instead of serializing.
        let n = self.transport.node_count();
        // one interned handle for the whole gather: peers get Arc clones
        let wire_dir: Arc<str> = dir.as_str().into();
        let mut pending: Vec<PendingReply> = Vec::with_capacity(n as usize);
        for node in 0..n {
            if node != self.node_id {
                pending.push(self.transport.send(
                    self.node_id,
                    node,
                    Request::ListOutputs {
                        dir: Arc::clone(&wire_dir),
                    },
                )?);
            }
        }
        // serve the local share while the peers work
        if let Response::Names(v) = self.shared.serve(&Request::ListOutputs { dir: wire_dir }) {
            names.extend(v);
        }
        for p in pending {
            if let Response::Names(v) = p.wait()? {
                names.extend(v);
            }
        }
        names.sort();
        names.dedup();
        if names.is_empty() {
            // distinguish empty dir from missing dir via input table
            if !self.shared.input_meta.is_dir(&dir) {
                return Err(FanError::NotFound(dir));
            }
        }
        self.shared.install_listing(&dir, gen, &names);
        Ok(names)
    }

    /// Batched mini-batch read-ahead: resolve every path against the warm
    /// set / prefetcher first, then run the rest through the node's shared
    /// batched-fetch body ([`NodeShared::fetch_inputs_batched`]: cache
    /// acquire, overlapped local reads, **one `ReadFiles` round trip per
    /// owner node**).  Fetched pins park in the warm set for the subsequent
    /// `open`s.  Purely advisory: per-file failures (ENOENT, fault, dead
    /// peer) are skipped here and surface with the right errno at `open`
    /// time.
    fn prefetch(&mut self, paths: &[String]) -> Result<()> {
        self.drain_warm();
        // dedup inside one hint: a duplicated (or alias-normalized) path
        // would otherwise be fetched twice and its second cache pin leaked
        // when warm.insert overwrote the first
        let mut seen: std::collections::HashSet<Arc<str>> = std::collections::HashSet::new();
        let mut items: Vec<(Arc<str>, FileLocation)> = Vec::new();
        for p in paths {
            let path: Arc<str> = normalize(p).into();
            if self.warm.contains_key(&*path) || seen.contains(&*path) {
                continue; // duplicate inside this batch
            }
            // only inputs are hintable (outputs keep the per-open path);
            // resolving this BEFORE any cache acquire keeps the node-wide
            // miss/fetch algebra exact for hints containing bad paths
            let Some(loc) = self.shared.input_meta.get(&path).map(|m| m.location) else {
                continue;
            };
            // the background pipeline may already hold it
            if let Some(pf) = &self.prefetcher {
                if let Some(pin) = pf.wait(&path) {
                    self.warm.insert(path, pin);
                    continue;
                }
            }
            seen.insert(Arc::clone(&path));
            items.push((path, loc));
        }
        let batch = self
            .shared
            .fetch_inputs_batched(self.transport.as_ref(), items);
        for (path, outcome) in batch.outcomes {
            let Ok((pin, _src)) = outcome else { continue };
            if let Some(extra) = self.warm.insert(Arc::clone(&path), pin) {
                // defensive: should be unreachable given the dedup above —
                // drop the superseded pin so the entry still drains to zero
                self.shared.cache.release(&path, &extra);
            }
        }
        Ok(())
    }

    fn unlink(&mut self, path: &str) -> Result<()> {
        let path = normalize(path);
        if self.shared.input_meta.get(&path).is_some() {
            return Err(FanError::Consistency(format!(
                "input files are immutable: {path}"
            )));
        }
        // 1) remove the authoritative metadata at the primary home; the
        //    answer names the originating node holding the bytes
        let homes = self.shared.placement.output_homes(&path);
        let home = homes[0];
        // one interned wire handle for the unlinks + drops + broadcast
        let wire_path: Arc<str> = path.as_str().into();
        let origin = if home == self.node_id {
            let meta = self.shared.output_meta.write().unwrap().remove(&path)?;
            meta.location.node
        } else {
            match self.transport.call(
                self.node_id,
                home,
                Request::UnlinkOutput {
                    path: Arc::clone(&wire_path),
                },
            )? {
                Response::Meta { origin, .. } => origin,
                Response::Err(_) => return Err(FanError::NotFound(path)),
                other => return Err(FanError::Transport(format!("unexpected {other:?}"))),
            }
        };
        // this node can no longer prove the resident bytes' generation
        self.shared.output_gen.write().unwrap().remove(&path);
        // 2) this node can no longer serve the dead generation (outstanding
        //    readers keep their pinned Arc; generation-aware releases make
        //    their eventual close a no-op)
        self.shared.cache.invalidate(&path);
        self.shared.output_meta_cache.write().unwrap().remove(&path);
        // 3) retire the replica metas and GC every buffered copy (PR 9: the
        //    origin's write buffer plus the copy each home landed at commit,
        //    plus a possible repaired copy at the deterministic adoptee).
        //    Best effort: a dead copy-holder cannot leak, the name is
        //    already gone from the primary, and ENOENT replies are the
        //    idempotence we expect.
        let mut copies: Vec<u32> = Vec::with_capacity(homes.len() + 2);
        copies.push(origin);
        copies.push(home);
        for &h in &homes[1..] {
            if !copies.contains(&h) {
                copies.push(h);
            }
        }
        let down = |n: u32| {
            n != self.node_id
                && self.shared.health.state(n) == crate::net::health::PeerState::Down
        };
        if homes.iter().any(|&h| down(h)) {
            let start = (homes[0] + 1) % self.shared.placement.nodes;
            if let Some(a) = self.shared.placement.adopt_node(&homes, start, down) {
                if !copies.contains(&a) {
                    copies.push(a);
                }
            }
        }
        for &h in &copies {
            if h != home && h != self.node_id {
                // replica meta (the primary's was removed above; a
                // secondary's local remove below needs no round trip)
                let _ = self.transport.call(
                    self.node_id,
                    h,
                    Request::UnlinkOutput {
                        path: Arc::clone(&wire_path),
                    },
                );
            } else if h != home {
                let _ = self.shared.output_meta.write().unwrap().remove(&path);
            }
            if h == self.node_id {
                self.shared.serve(&Request::DropOutput {
                    path: Arc::clone(&wire_path),
                });
            } else {
                let _ = self.transport.call(
                    self.node_id,
                    h,
                    Request::DropOutput {
                        path: Arc::clone(&wire_path),
                    },
                );
            }
        }
        // the name is gone from every listing: retire its ancestor-chain
        // listings cluster-wide before unlink returns
        self.invalidate_listings_cluster_wide(home, &wire_path);
        Ok(())
    }
}

impl Drop for FanStoreVfs {
    fn drop(&mut self) {
        // unconsumed batch-hint pins must not outlive the "process".  Open
        // descriptors intentionally keep their pins (crash analogue — the
        // refcount survives, see `cluster_survives_client_drop_mid_read`).
        self.drain_warm();
    }
}
