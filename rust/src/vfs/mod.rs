//! The POSIX-compliant interface (paper §5.5).
//!
//! On the real system FanStore patches glibc's `open/read/write/close/stat/
//! readdir` in user space (function interception, no FUSE kernel crossing).
//! Here the same dispatch boundary is the [`Vfs`] trait: the training code
//! and workload generators are written against POSIX-shaped calls and can be
//! pointed at FanStore, raw local storage, or any modelled backend without
//! change — exactly the no-code-changes property the paper claims for its
//! interception layer.
//!
//! Consistency contract (paper §3.5): multi-read single-write.  Input files
//! are immutable; output files are written by exactly one descriptor and
//! become visible only after `close()`.

pub mod fanstore;
pub mod localfs;

pub use fanstore::FanStoreVfs;
pub use localfs::LocalVfs;

use crate::error::Result;
use crate::metadata::record::FileStat;

/// Descriptor handed out by `open`.
pub type Fd = u64;

/// Open mode (subset POSIX flags the DL I/O pattern uses, §3.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpenFlags {
    /// `O_RDONLY` — whole-file sequential read.
    Read,
    /// `O_WRONLY | O_CREAT | O_EXCL` — write a fresh output file.
    Write,
}

/// POSIX-shaped file API.  All methods are `&mut self` — one `Vfs` value is
/// one "process" (its own descriptor table), matching the per-process
/// interception state of the paper.
pub trait Vfs: Send {
    fn open(&mut self, path: &str, flags: OpenFlags) -> Result<Fd>;
    /// Sequential read into `buf`; returns bytes read (0 = EOF).
    fn read(&mut self, fd: Fd, buf: &mut [u8]) -> Result<usize>;
    /// Append `data` to an output descriptor.
    fn write(&mut self, fd: Fd, data: &[u8]) -> Result<usize>;
    fn close(&mut self, fd: Fd) -> Result<()>;
    fn stat(&mut self, path: &str) -> Result<FileStat>;
    fn readdir(&mut self, dir: &str) -> Result<Vec<String>>;
    fn unlink(&mut self, path: &str) -> Result<()>;

    /// Batch read-ahead hint — the `posix_fadvise(POSIX_FADV_WILLNEED)`
    /// analogue for a mini-batch about to be opened sequentially.  Purely
    /// advisory: backends that can batch or overlap remote fetches override
    /// it (FanStore groups the paths by owner node and issues one batched
    /// request per peer); the default no-op keeps POSIX-only backends
    /// correct, and per-file errors surface at the subsequent `open`.
    fn prefetch(&mut self, _paths: &[String]) -> Result<()> {
        Ok(())
    }

    /// Batched stat, one result per path in order (multi-shard checkpoint
    /// resume stats every shard before reading any).  Backends with remote
    /// metadata override it to gather per metadata home in one round trip
    /// each (FanStore's `StatOutputs`); the default is a per-path loop.
    fn stat_many(&mut self, paths: &[String]) -> Vec<Result<FileStat>> {
        paths.iter().map(|p| self.stat(p)).collect()
    }

    /// Convenience: open+read-to-end+close (the DL input pattern, §3.4:
    /// "when a file is read, it is read sequentially and completely").
    fn read_all(&mut self, path: &str) -> Result<Vec<u8>> {
        let fd = self.open(path, OpenFlags::Read)?;
        let size = {
            // read in 1 MiB slabs; files are small (KB–MB, Table 2)
            let mut out = Vec::new();
            let mut buf = vec![0u8; 1 << 20];
            loop {
                let n = self.read(fd, &mut buf)?;
                if n == 0 {
                    break;
                }
                out.extend_from_slice(&buf[..n]);
            }
            out
        };
        self.close(fd)?;
        Ok(size)
    }

    /// Convenience: create+write+close one output file (checkpoint pattern).
    fn write_file(&mut self, path: &str, data: &[u8]) -> Result<()> {
        let fd = self.open(path, OpenFlags::Write)?;
        let mut off = 0;
        while off < data.len() {
            off += self.write(fd, &data[off..])?;
        }
        self.close(fd)
    }
}
