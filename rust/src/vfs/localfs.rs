//! Plain local-filesystem VFS (the "SSD" baseline of Fig 3/4 in `InProc`
//! mode, and a utility for staging datasets in tests/examples).
//!
//! Paths are rooted at a directory; the descriptor table mirrors
//! [`FanStoreVfs`] so workloads behave identically against both.

use std::collections::HashMap;
use std::fs;
use std::io::{Read, Write as _};
use std::path::PathBuf;

use crate::error::{FanError, Result};
use crate::metadata::record::FileStat;
use crate::metadata::table::normalize;
use crate::vfs::{Fd, OpenFlags, Vfs};

enum OpenFile {
    Read(fs::File),
    Write(fs::File),
}

/// VFS over a real directory tree.
pub struct LocalVfs {
    root: PathBuf,
    fds: HashMap<Fd, OpenFile>,
    next_fd: Fd,
}

impl LocalVfs {
    pub fn new(root: impl Into<PathBuf>) -> Result<Self> {
        let root = root.into();
        fs::create_dir_all(&root)?;
        Ok(LocalVfs {
            root,
            fds: HashMap::new(),
            next_fd: 3,
        })
    }

    fn resolve(&self, path: &str) -> PathBuf {
        let norm = normalize(path);
        self.root.join(norm.trim_start_matches('/'))
    }
}

impl Vfs for LocalVfs {
    fn open(&mut self, path: &str, flags: OpenFlags) -> Result<Fd> {
        let p = self.resolve(path);
        let file = match flags {
            OpenFlags::Read => OpenFile::Read(
                fs::File::open(&p).map_err(|_| FanError::NotFound(path.to_string()))?,
            ),
            OpenFlags::Write => {
                if let Some(parent) = p.parent() {
                    fs::create_dir_all(parent)?;
                }
                if p.exists() {
                    return Err(FanError::Exists(path.to_string()));
                }
                OpenFile::Write(fs::File::create(&p)?)
            }
        };
        let fd = self.next_fd;
        self.next_fd += 1;
        self.fds.insert(fd, file);
        Ok(fd)
    }

    fn read(&mut self, fd: Fd, buf: &mut [u8]) -> Result<usize> {
        match self.fds.get_mut(&fd) {
            Some(OpenFile::Read(f)) => Ok(f.read(buf)?),
            Some(OpenFile::Write(_)) => {
                Err(FanError::Consistency("descriptor is write-only".into()))
            }
            None => Err(FanError::BadFd(fd)),
        }
    }

    fn write(&mut self, fd: Fd, data: &[u8]) -> Result<usize> {
        match self.fds.get_mut(&fd) {
            Some(OpenFile::Write(f)) => Ok(f.write(data)?),
            Some(OpenFile::Read(_)) => {
                Err(FanError::Consistency("descriptor is read-only".into()))
            }
            None => Err(FanError::BadFd(fd)),
        }
    }

    fn close(&mut self, fd: Fd) -> Result<()> {
        self.fds.remove(&fd).map(|_| ()).ok_or(FanError::BadFd(fd))
    }

    fn stat(&mut self, path: &str) -> Result<FileStat> {
        let p = self.resolve(path);
        let md = fs::metadata(&p).map_err(|_| FanError::NotFound(path.to_string()))?;
        let mut s = if md.is_dir() {
            FileStat::directory(1)
        } else {
            FileStat::regular(1, md.len())
        };
        s.size = if md.is_dir() { 4096 } else { md.len() };
        Ok(s)
    }

    fn readdir(&mut self, dir: &str) -> Result<Vec<String>> {
        let p = self.resolve(dir);
        let rd = fs::read_dir(&p).map_err(|_| FanError::NotFound(dir.to_string()))?;
        let mut names: Vec<String> = rd
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .collect();
        names.sort();
        Ok(names)
    }

    fn unlink(&mut self, path: &str) -> Result<()> {
        let p = self.resolve(path);
        fs::remove_file(&p).map_err(|_| FanError::NotFound(path.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_vfs(tag: &str) -> (LocalVfs, PathBuf) {
        let dir = std::env::temp_dir().join(format!("fanstore_local_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        (LocalVfs::new(&dir).unwrap(), dir)
    }

    #[test]
    fn write_then_read_roundtrip() {
        let (mut v, dir) = tmp_vfs("rw");
        v.write_file("/d/hello.bin", b"hello world").unwrap();
        assert_eq!(v.read_all("/d/hello.bin").unwrap(), b"hello world");
        assert_eq!(v.stat("/d/hello.bin").unwrap().size, 11);
        assert_eq!(v.readdir("/d").unwrap(), vec!["hello.bin"]);
        fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn exclusive_create() {
        let (mut v, dir) = tmp_vfs("excl");
        v.write_file("/x", b"1").unwrap();
        assert!(matches!(
            v.open("/x", OpenFlags::Write),
            Err(FanError::Exists(_))
        ));
        fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_file_enoent() {
        let (mut v, dir) = tmp_vfs("missing");
        assert!(matches!(v.read_all("/nope"), Err(FanError::NotFound(_))));
        fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn unlink_removes() {
        let (mut v, dir) = tmp_vfs("unlink");
        v.write_file("/z", b"z").unwrap();
        v.unlink("/z").unwrap();
        assert!(v.read_all("/z").is_err());
        fs::remove_dir_all(dir).ok();
    }
}
