//! Discrete-event-lite simulation substrate.
//!
//! The paper's evaluation runs on clusters (24 GPU nodes, 512 CPU nodes) we
//! do not have; the scaling figures (5, 6, 7, 8, 9, 11) are regenerated on a
//! virtual-time simulator instead.  The model is deliberately simple and
//! deterministic:
//!
//! * every simulated I/O thread carries its own virtual clock,
//! * every contended device (a node's SSD, a node's NIC, the shared file
//!   system's metadata server and OSTs) is a FIFO [`Resource`] timeline,
//! * the [`ThreadSet`] scheduler always advances the globally-earliest
//!   thread, so resource queueing is causally consistent.
//!
//! The FanStore logic running *on top* of the clock is the real thing — real
//! metadata tables, real placement, real partition indexes — only device
//! timings are modelled (DESIGN.md §1).

pub mod clock;
pub mod resource;

pub use clock::{SimNs, MS, NS_PER_SEC, SEC, US};
pub use resource::{Resource, ThreadSet};
