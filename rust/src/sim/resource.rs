//! FIFO resource timelines and the earliest-thread scheduler.

use crate::sim::clock::SimNs;

/// A serially-shared device: at most one operation in service at a time,
/// FIFO order by arrival.  `serve` returns the completion time.
///
/// `lanes > 1` models devices with internal parallelism (e.g. an OST pool or
/// a multi-queue NVMe): the op takes the earliest-free lane.
#[derive(Clone, Debug)]
pub struct Resource {
    lanes: Vec<SimNs>,
}

impl Resource {
    pub fn new(lanes: usize) -> Self {
        assert!(lanes > 0);
        Resource {
            lanes: vec![0; lanes],
        }
    }

    /// Arrive at `now`, occupy the device for `service` ns; returns the
    /// completion time (>= now + service).
    pub fn serve(&mut self, now: SimNs, service: SimNs) -> SimNs {
        // earliest-available lane
        let lane = self
            .lanes
            .iter()
            .enumerate()
            .min_by_key(|(_, &t)| t)
            .map(|(i, _)| i)
            .unwrap();
        let start = now.max(self.lanes[lane]);
        let end = start + service;
        self.lanes[lane] = end;
        end
    }

    /// Next instant the device has a free lane (for utilization reporting).
    pub fn free_at(&self) -> SimNs {
        *self.lanes.iter().min().unwrap()
    }

    /// Busy-until horizon (max over lanes).
    pub fn horizon(&self) -> SimNs {
        *self.lanes.iter().max().unwrap()
    }

    pub fn reset(&mut self) {
        self.lanes.fill(0);
    }
}

/// Per-thread virtual clocks + the "advance the earliest thread" scheduler.
#[derive(Clone, Debug)]
pub struct ThreadSet {
    clocks: Vec<SimNs>,
    done: Vec<bool>,
}

impl ThreadSet {
    pub fn new(n: usize) -> Self {
        ThreadSet {
            clocks: vec![0; n],
            done: vec![false; n],
        }
    }

    pub fn len(&self) -> usize {
        self.clocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.clocks.is_empty()
    }

    /// Index of the earliest unfinished thread, or None when all done.
    pub fn earliest(&self) -> Option<usize> {
        self.clocks
            .iter()
            .zip(&self.done)
            .enumerate()
            .filter(|(_, (_, &d))| !d)
            .min_by_key(|(_, (&c, _))| c)
            .map(|(i, _)| i)
    }

    pub fn now(&self, i: usize) -> SimNs {
        self.clocks[i]
    }

    pub fn advance_to(&mut self, i: usize, t: SimNs) {
        debug_assert!(t >= self.clocks[i], "time went backwards");
        self.clocks[i] = t;
    }

    pub fn finish(&mut self, i: usize) {
        self.done[i] = true;
    }

    /// Makespan: time at which the last thread finished.
    pub fn makespan(&self) -> SimNs {
        self.clocks.iter().copied().max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_queueing() {
        let mut r = Resource::new(1);
        assert_eq!(r.serve(0, 10), 10);
        assert_eq!(r.serve(0, 10), 20); // queued behind the first
        assert_eq!(r.serve(100, 5), 105); // idle gap
    }

    #[test]
    fn lanes_parallelize() {
        let mut r = Resource::new(2);
        assert_eq!(r.serve(0, 10), 10);
        assert_eq!(r.serve(0, 10), 10); // second lane
        assert_eq!(r.serve(0, 10), 20); // back to lane 0
    }

    #[test]
    fn threadset_scheduler_order() {
        let mut ts = ThreadSet::new(3);
        ts.advance_to(0, 5);
        ts.advance_to(1, 3);
        ts.advance_to(2, 9);
        assert_eq!(ts.earliest(), Some(1));
        ts.finish(1);
        assert_eq!(ts.earliest(), Some(0));
        ts.finish(0);
        ts.finish(2);
        assert_eq!(ts.earliest(), None);
        assert_eq!(ts.makespan(), 9);
    }

    #[test]
    fn contention_makespan_matches_theory() {
        // 4 threads, each doing 10 ops of 1000ns on one shared device:
        // makespan must be exactly 40_000ns (perfect FIFO interleave).
        let mut ts = ThreadSet::new(4);
        let mut dev = Resource::new(1);
        let mut remaining = [10u32; 4];
        while let Some(i) = ts.earliest() {
            if remaining[i] == 0 {
                ts.finish(i);
                continue;
            }
            let done = dev.serve(ts.now(i), 1000);
            ts.advance_to(i, done);
            remaining[i] -= 1;
        }
        assert_eq!(ts.makespan(), 40_000);
    }
}
