//! Virtual time units.

/// Virtual nanoseconds since simulation start.
pub type SimNs = u64;

pub const NS_PER_SEC: u64 = 1_000_000_000;
pub const SEC: u64 = NS_PER_SEC;
pub const MS: u64 = 1_000_000;
pub const US: u64 = 1_000;

/// Service time for moving `bytes` at `bytes_per_sec`.
#[inline]
pub fn transfer_ns(bytes: u64, bytes_per_sec: u64) -> SimNs {
    if bytes_per_sec == 0 {
        return 0;
    }
    // round up: a transfer always costs at least 1 ns
    ((bytes as u128 * NS_PER_SEC as u128).div_ceil(bytes_per_sec as u128)) as SimNs
}

/// Seconds as f64 for reporting.
#[inline]
pub fn to_secs(ns: SimNs) -> f64 {
    ns as f64 / NS_PER_SEC as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_scales() {
        assert_eq!(transfer_ns(1_000_000_000, 1_000_000_000), NS_PER_SEC);
        assert_eq!(transfer_ns(500, 1000), NS_PER_SEC / 2);
        assert_eq!(transfer_ns(0, 1000), 0);
        assert_eq!(transfer_ns(100, 0), 0);
    }

    #[test]
    fn rounds_up() {
        assert_eq!(transfer_ns(1, 1_000_000_000), 1);
        assert_eq!(transfer_ns(3, 2_000_000_000), 2);
    }

    #[test]
    fn to_secs_works() {
        assert!((to_secs(1_500_000_000) - 1.5).abs() < 1e-12);
    }
}
