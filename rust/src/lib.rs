//! # FanStore — a transient runtime file system for distributed DL I/O
//!
//! Reproduction of *FanStore: Enabling Efficient and Scalable I/O for
//! Distributed Deep Learning* (Zhang et al., 2018).  See `DESIGN.md` for the
//! system inventory and the substitution table (the paper's clusters, MPI,
//! Lustre and glibc interception are simulated/modelled — everything else is
//! implemented for real).
//!
//! Layer map:
//! * **L3 (this crate)** — the FanStore runtime FS: partitions, replicated /
//!   consistent-hashed metadata, refcounted cache, transport, replication,
//!   the cluster simulator, baseline storage models, workload generators, the
//!   distributed-training driver and the experiment harness.
//! * **L2/L1 (python/, build-time only)** — JAX training-step graphs with
//!   Pallas kernels, AOT-lowered to `artifacts/*.hlo.txt`, executed from
//!   [`runtime`] via PJRT.
//!
//! Quick tour: [`partition`] packs datasets (paper §5.2, Table 3);
//! [`metadata`] is §5.3; [`cache`]+[`node`]+[`prefetch`] are §5.4 (the
//! latter being the background worker threads that overlap fetch with
//! compute, via batched per-peer reads); [`vfs`] is the
//! POSIX-compliant interface of §5.5; [`compress`] is the LZSS codec of
//! §5.4/§6.6; [`sim`]+[`net`]+[`storage`] model the testbeds of §6.1;
//! [`experiments`] regenerates every figure of §6.

pub mod cache;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod experiments;
pub mod fuzz;
pub mod metadata;
pub mod net;
pub mod node;
pub mod partition;
pub mod prefetch;
pub mod runtime;
pub mod sim;
pub mod storage;
pub mod trainer;
pub mod util;
pub mod vfs;
pub mod workload;

pub use error::{FanError, Result};
