//! Inter-node message passing: the [`Transport`] trait and its in-process
//! implementation.
//!
//! Stands in for the paper's MPI point-to-point: each node runs a worker
//! (service) thread draining a request queue; remote file access is a
//! request/response round trip carrying the *stored* bytes (compressed data
//! travels compressed — decompression happens on the reader, §5.4).
//!
//! Every consumer (VFS, prefetcher, coordinator) holds an
//! `Arc<dyn Transport>`, so the same cluster logic runs over
//! [`InProcTransport`] (std::sync::mpsc replacing `MPI_Send/Recv`) or
//! [`crate::net::tcp::TcpTransport`] (real sockets, length-prefixed frames
//! from [`crate::net::wire`]) without change.  The protocol, message sizes
//! and who-talks-to-whom are identical either way, which is what the
//! experiments depend on (DESIGN.md substitution table).
//!
//! Payloads travel as [`Payload`] handles: the worker serves a shared
//! view of its store (for RAM- and mmap-backed partitions a zero-copy
//! view of the region itself) and the reply path moves the handle
//! (in-proc) or writes its bytes straight to the socket (TCP), so a
//! remote read never copies the stored bytes on the serving side.  Paths
//! travel as `Arc<str>`: the decode side interns them per connection and
//! batched serves clone the `Arc`, never the string.  [`Transport::send`] exposes the
//! asynchronous half of a round trip so gather patterns (e.g. `readdir`
//! collecting `ListOutputs` from every node) can issue all requests first
//! and overlap the waits.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

use crate::error::{FanError, Result};
use crate::metadata::record::{FileMeta, FileStat};
use crate::storage::payload::Payload;

/// Requests a FanStore worker thread services (paper §5.1 "worker threads
/// ... handle file system requests").
#[derive(Debug)]
pub enum Request {
    /// Read the stored bytes of an input (or committed output) file.
    ReadFile { path: Arc<str> },
    /// Read a whole mini-batch's stored bytes in one round trip.  The reply
    /// carries one [`FileFetch`] per requested path (same order), so a
    /// missing or faulted file never poisons the rest of the batch.
    ReadFiles { paths: Vec<Arc<str>> },
    /// Stat a path this node is authoritative for (output files).
    StatOutput { path: Arc<str> },
    /// Stat a whole batch of output paths homed on this node in one round
    /// trip (multi-shard checkpoint resume).  The reply carries one
    /// [`MetaFetch`] per requested path, request order — `ReadFiles`'
    /// per-path-outcome shape applied to metadata.
    StatOutputs { paths: Vec<Arc<str>> },
    /// Forward a finished output file's metadata *and bytes* to a home
    /// node (visible-until-finish commit, §5.4; replicated homes PR 9).
    /// `stamped == false` is the primary commit: the receiving home stamps
    /// `meta.generation` from its commit counter and echoes it back in a
    /// [`Response::Meta`].  `stamped == true` installs a replica (secondary
    /// homes, repair pushes) with the generation already assigned, so all
    /// homes agree on the stamp the primary chose.
    CommitOutput {
        path: Arc<str>,
        meta: FileMeta,
        data: Payload,
        stamped: bool,
    },
    /// List output files homed on this node under a directory.
    ListOutputs { dir: Arc<str> },
    /// Remove an output file's metadata at its home node; the reply names
    /// the originating node so the caller can GC the buffered bytes there.
    UnlinkOutput { path: Arc<str> },
    /// Drop the buffered bytes of an unlinked output at its originating
    /// node (idempotent — a second drop is a no-op).
    DropOutput { path: Arc<str> },
    /// Retire the receiving node's cached `readdir` listings along the
    /// committed/unlinked path's ancestor chain (directory-granular —
    /// unrelated hot listings stay cached).  Broadcast (and awaited) by
    /// the writer once a commit/unlink lands, so the steady-state
    /// `readdir` on every node can be a local cache lookup.
    InvalidateListings { path: Arc<str> },
    /// Liveness probe (PR 7 health layer).  Carries the sender's node
    /// epoch; the reply carries the receiver's, so a restarted peer (new
    /// epoch) is distinguishable from the incarnation that was probed.
    Ping { epoch: u64 },
    /// Stream the whole container blob of an input partition to a peer
    /// (PR 9 re-replication pull).  The reply is a
    /// [`Response::PartitionData`] riding the zero-copy [`Payload`] path.
    FetchPartition { pid: u32 },
    /// Install a partition blob on the receiving node (PR 9 re-replication
    /// push — reseeding a restarted peer).  Idempotent: a node that
    /// already holds `pid` replies Ok without re-indexing.
    InstallPartition { pid: u32, blob: Payload },
    /// Orderly shutdown of the worker thread.
    Shutdown,
}

/// Per-file outcome inside a batched [`Response::FilesData`] reply.  Keeps
/// the ENOENT vs. real-I/O-fault distinction the single-file path has, per
/// file, so callers can retry or surface exactly the right errno.
#[derive(Debug)]
pub enum FileFetch {
    /// The stored bytes, self-describing: a [`Payload::Compressed`] handle
    /// carries its codec and raw length with it, so compressed data rides
    /// the wire (and the cache) compressed and the single decode happens at
    /// the consuming side's pickup.
    Data { stored: Payload },
    /// The path is not stored (and not buffered) on the serving node.
    NotFound,
    /// The path exists but reading it failed (spilled-file I/O error,
    /// partition format fault, ...) — must not masquerade as ENOENT.
    Fault(String),
}

impl FileFetch {
    /// Caller-facing conversion preserving the errno distinction.
    pub fn into_result(self, path: &str) -> Result<Payload> {
        match self {
            FileFetch::Data { stored } => Ok(stored),
            FileFetch::NotFound => Err(FanError::NotFound(path.to_string())),
            FileFetch::Fault(e) => Err(FanError::Transport(format!("EIO {path}: {e}"))),
        }
    }

    pub fn is_data(&self) -> bool {
        matches!(self, FileFetch::Data { .. })
    }
}

/// Per-path outcome inside a batched [`Response::Metas`] reply (the
/// metadata analogue of [`FileFetch`]).
#[derive(Clone, Debug)]
pub enum MetaFetch {
    Meta {
        stat: FileStat,
        origin: u32,
        generation: u64,
    },
    /// No output with that path is homed on the serving node.
    NotFound,
}

/// Worker replies.
#[derive(Debug)]
pub enum Response {
    /// Stored bytes of one file (self-describing [`Payload`], like
    /// [`FileFetch::Data`]).
    FileData { stored: Payload },
    /// Batched read reply: one entry per requested path, request order.
    /// Paths are `Arc` clones of the request's — no string copies.
    FilesData(Vec<(Arc<str>, FileFetch)>),
    /// Output-file metadata: the stat plus the node that buffered the data
    /// (the originating node, §5.4 — reads must go there, not to the home)
    /// plus the commit generation stamped by the home node.
    Meta {
        stat: FileStat,
        origin: u32,
        generation: u64,
    },
    /// Batched stat reply: one entry per requested path, request order.
    Metas(Vec<(Arc<str>, MetaFetch)>),
    Names(Vec<String>),
    /// Liveness probe reply: the responding node's epoch number (stamped
    /// once per incarnation at seal time).  A changed epoch means the peer
    /// restarted since it was last seen.
    Pong { epoch: u64 },
    /// A whole partition container blob (reply to
    /// [`Request::FetchPartition`]) — the unit of background repair.
    PartitionData { blob: Payload },
    Ok,
    Err(String),
}

/// Where a worker's reply goes: an in-proc channel or a framed write back
/// onto the TCP connection the request came from.  Transport-agnostic so
/// the node worker never knows which fabric delivered the request.
pub struct ReplySink(Box<dyn FnOnce(Response) + Send>);

impl ReplySink {
    /// Reply into an mpsc channel (the in-proc path).
    pub fn channel(tx: Sender<Response>) -> ReplySink {
        ReplySink(Box::new(move |resp| {
            let _ = tx.send(resp);
        }))
    }

    /// Reply through an arbitrary delivery closure (the TCP path encodes
    /// the response with its correlation id and writes the frame).
    pub fn from_fn<F: FnOnce(Response) + Send + 'static>(f: F) -> ReplySink {
        ReplySink(Box::new(f))
    }

    /// Swallow the reply (fire-and-forget requests like broadcast shutdown).
    pub fn discard() -> ReplySink {
        ReplySink(Box::new(|_| {}))
    }

    pub fn send(self, resp: Response) {
        (self.0)(resp)
    }
}

impl std::fmt::Debug for ReplySink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("ReplySink")
    }
}

/// An addressed request with its reply sink.
pub struct Message {
    pub from: u32,
    pub req: Request,
    pub reply: ReplySink,
}

/// The per-node receive side handed to its worker thread.  Both transports
/// feed the same inbox, so `FanStoreNode::spawn` is fabric-agnostic.
pub struct NodeEndpoint {
    pub node_id: u32,
    pub inbox: Receiver<Message>,
}

/// An in-flight request: the reply side of a round trip started with
/// [`Transport::send`].  Dropping it abandons the reply.
pub struct PendingReply {
    to: u32,
    rx: Receiver<Response>,
}

impl PendingReply {
    /// Wrap the receive half of a reply channel (used by transports; the
    /// TCP demux thread feeds the channel when the correlated frame lands).
    pub fn from_channel(to: u32, rx: Receiver<Response>) -> PendingReply {
        PendingReply { to, rx }
    }

    /// Block until the worker replies.
    pub fn wait(self) -> Result<Response> {
        self.rx
            .recv()
            .map_err(|_| FanError::Transport(format!("node {} dropped the reply", self.to)))
    }

    /// Block at most `timeout` for the reply.  A timeout maps to
    /// [`FanError::Transport`] just like a dropped reply — the caller can
    /// not tell a slow peer from a dead one, and the health layer treats
    /// both identically.
    pub fn wait_timeout(self, timeout: Duration) -> Result<Response> {
        self.rx.recv_timeout(timeout).map_err(|e| match e {
            RecvTimeoutError::Timeout => FanError::Transport(format!(
                "node {} reply timed out after {}ms",
                self.to,
                timeout.as_millis()
            )),
            RecvTimeoutError::Disconnected => {
                FanError::Transport(format!("node {} dropped the reply", self.to))
            }
        })
    }
}

/// The fabric abstraction every consumer programs against: synchronous
/// round trips (`call`) and the asynchronous `send`/[`PendingReply`] split
/// for overlapped gathers.  Implementations: [`InProcTransport`] (mpsc) and
/// [`crate::net::tcp::TcpTransport`] (real sockets).
pub trait Transport: Send + Sync {
    /// How many nodes this transport can address.
    fn node_count(&self) -> u32;

    /// Enqueue a request at `to` and return the pending reply without
    /// blocking — the building block for overlapped gathers.
    fn send(&self, from: u32, to: u32, req: Request) -> Result<PendingReply>;

    /// Fire-and-forget shutdown to every node.
    fn shutdown_all(&self);

    /// Drop any cached connection state to `node` (pooled sockets, ...).
    /// Called by the health layer when a peer is marked Down so the next
    /// contact re-dials instead of reusing a dead socket.  No-op for
    /// transports without connection state.
    fn evict(&self, _node: u32) {}

    /// Upper bound every [`Transport::call`] waits for a reply, if the
    /// transport was configured with one.  `None` = wait forever (the
    /// pre-PR-7 behaviour, still the default for tests that want strict
    /// blocking semantics).
    fn call_timeout(&self) -> Option<Duration> {
        None
    }

    /// Round-trip request to `to`; blocks until the worker replies or the
    /// configured [`Transport::call_timeout`] elapses.
    fn call(&self, from: u32, to: u32, req: Request) -> Result<Response> {
        let pending = self.send(from, to, req)?;
        match self.call_timeout() {
            Some(t) => pending.wait_timeout(t),
            None => pending.wait(),
        }
    }
}

/// Sender half bundle: lets any node address any other node in process.
#[derive(Clone)]
pub struct InProcTransport {
    peers: Vec<Sender<Message>>,
    /// Bounded wait for `call` round trips.  A cleanly-killed in-proc node
    /// fails fast anyway (its inbox `Receiver` drops, so `peer.send` and
    /// parked reply waits both error), but a wedged worker that still owns
    /// its endpoint would block forever without this bound.
    call_timeout: Option<Duration>,
}

impl InProcTransport {
    /// Build a fully-connected transport for `n` nodes; returns the shared
    /// sender bundle plus one endpoint per node.
    pub fn fully_connected(n: u32) -> (InProcTransport, Vec<NodeEndpoint>) {
        let mut peers = Vec::with_capacity(n as usize);
        let mut endpoints = Vec::with_capacity(n as usize);
        for node_id in 0..n {
            let (tx, rx) = channel();
            peers.push(tx);
            endpoints.push(NodeEndpoint { node_id, inbox: rx });
        }
        (
            InProcTransport {
                peers,
                call_timeout: None,
            },
            endpoints,
        )
    }

    /// Bound every `call` round trip to `timeout` (builder-style).
    pub fn with_call_timeout(mut self, timeout: Duration) -> InProcTransport {
        self.call_timeout = Some(timeout);
        self
    }

    pub fn node_count(&self) -> u32 {
        self.peers.len() as u32
    }

    /// See [`Transport::send`].
    pub fn send(&self, from: u32, to: u32, req: Request) -> Result<PendingReply> {
        let peer = self
            .peers
            .get(to as usize)
            .ok_or_else(|| FanError::Transport(format!("no such node {to}")))?;
        let (reply_tx, reply_rx) = channel();
        peer.send(Message {
            from,
            req,
            reply: ReplySink::channel(reply_tx),
        })
        .map_err(|_| FanError::Transport(format!("node {to} is down")))?;
        Ok(PendingReply { to, rx: reply_rx })
    }

    /// See [`Transport::call`].
    pub fn call(&self, from: u32, to: u32, req: Request) -> Result<Response> {
        let pending = self.send(from, to, req)?;
        match self.call_timeout {
            Some(t) => pending.wait_timeout(t),
            None => pending.wait(),
        }
    }

    /// See [`Transport::shutdown_all`].
    pub fn shutdown_all(&self) {
        for peer in self.peers.iter() {
            let _ = peer.send(Message {
                from: u32::MAX,
                req: Request::Shutdown,
                reply: ReplySink::discard(),
            });
        }
    }
}

impl Transport for InProcTransport {
    fn node_count(&self) -> u32 {
        InProcTransport::node_count(self)
    }

    fn send(&self, from: u32, to: u32, req: Request) -> Result<PendingReply> {
        InProcTransport::send(self, from, to, req)
    }

    fn shutdown_all(&self) {
        InProcTransport::shutdown_all(self)
    }

    fn call_timeout(&self) -> Option<Duration> {
        self.call_timeout
    }
}

impl Response {
    /// Unwrap a `FileData` response.
    pub fn into_file_data(self) -> Result<Payload> {
        match self {
            Response::FileData { stored } => Ok(stored),
            Response::Err(e) => Err(FanError::Transport(e)),
            other => Err(FanError::Transport(format!(
                "expected FileData, got {other:?}"
            ))),
        }
    }

    /// Unwrap a `FilesData` (batched read) response.
    pub fn into_files_data(self) -> Result<Vec<(Arc<str>, FileFetch)>> {
        match self {
            Response::FilesData(files) => Ok(files),
            Response::Err(e) => Err(FanError::Transport(e)),
            other => Err(FanError::Transport(format!(
                "expected FilesData, got {other:?}"
            ))),
        }
    }

    /// Unwrap a `Metas` (batched stat) response.
    pub fn into_metas(self) -> Result<Vec<(Arc<str>, MetaFetch)>> {
        match self {
            Response::Metas(metas) => Ok(metas),
            Response::Err(e) => Err(FanError::Transport(e)),
            other => Err(FanError::Transport(format!(
                "expected Metas, got {other:?}"
            ))),
        }
    }

    /// Unwrap a `PartitionData` (repair transfer) response.
    pub fn into_partition_data(self) -> Result<Payload> {
        match self {
            Response::PartitionData { blob } => Ok(blob),
            Response::Err(e) => Err(FanError::Transport(e)),
            other => Err(FanError::Transport(format!(
                "expected PartitionData, got {other:?}"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    /// Minimal echo worker used to exercise the transport alone.
    fn spawn_echo(ep: NodeEndpoint) -> thread::JoinHandle<u32> {
        thread::spawn(move || {
            let mut served = 0;
            while let Ok(msg) = ep.inbox.recv() {
                match msg.req {
                    Request::Shutdown => break,
                    Request::ReadFile { path } => {
                        served += 1;
                        msg.reply.send(Response::FileData {
                            stored: path.as_bytes().to_vec().into(),
                        });
                    }
                    Request::Ping { epoch } => {
                        msg.reply.send(Response::Pong { epoch: epoch + 100 });
                    }
                    Request::ReadFiles { paths } => {
                        served += 1;
                        let files = paths
                            .into_iter()
                            .map(|p| {
                                let fetch = if p.contains("missing") {
                                    FileFetch::NotFound
                                } else {
                                    FileFetch::Data {
                                        stored: p.as_bytes().to_vec().into(),
                                    }
                                };
                                (p, fetch)
                            })
                            .collect();
                        msg.reply.send(Response::FilesData(files));
                    }
                    _ => {
                        msg.reply.send(Response::Ok);
                    }
                }
            }
            served
        })
    }

    #[test]
    fn roundtrip_between_nodes() {
        let (tp, eps) = InProcTransport::fully_connected(3);
        let handles: Vec<_> = eps.into_iter().map(spawn_echo).collect();
        let resp = tp
            .call(0, 2, Request::ReadFile { path: "/x/y".into() })
            .unwrap();
        let data = resp.into_file_data().unwrap();
        assert_eq!(&data[..], &b"/x/y"[..]);
        tp.shutdown_all();
        let served: u32 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(served, 1);
    }

    #[test]
    fn batched_roundtrip_preserves_order_and_per_file_results() {
        let (tp, eps) = InProcTransport::fully_connected(2);
        let handles: Vec<_> = eps.into_iter().map(spawn_echo).collect();
        let resp = tp
            .call(
                0,
                1,
                Request::ReadFiles {
                    paths: vec!["/a".into(), "/missing/x".into(), "/b".into()],
                },
            )
            .unwrap();
        let files = resp.into_files_data().unwrap();
        assert_eq!(files.len(), 3);
        assert_eq!(&*files[0].0, "/a");
        assert!(files[0].1.is_data());
        assert_eq!(&*files[1].0, "/missing/x");
        assert!(matches!(files[1].1, FileFetch::NotFound));
        // one missing file does not poison the rest of the batch
        let (path, fetch) = files.into_iter().nth(2).unwrap();
        assert_eq!(&*path, "/b");
        let data = fetch.into_result(&path).unwrap();
        assert_eq!(&data[..], b"/b");
        // ENOENT maps to NotFound, not a transport fault
        assert!(matches!(
            FileFetch::NotFound.into_result("/missing/x"),
            Err(FanError::NotFound(_))
        ));
        assert!(matches!(
            FileFetch::Fault("disk on fire".into()).into_result("/a"),
            Err(FanError::Transport(_))
        ));
        tp.shutdown_all();
        let served: u32 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(served, 1, "one round trip served the whole batch");
    }

    #[test]
    fn unknown_node_is_error() {
        let (tp, _eps) = InProcTransport::fully_connected(2);
        assert!(tp.call(0, 9, Request::Shutdown).is_err());
    }

    #[test]
    fn ping_pong_roundtrip_carries_epochs() {
        let (tp, eps) = InProcTransport::fully_connected(2);
        let handles: Vec<_> = eps.into_iter().map(spawn_echo).collect();
        match tp.call(0, 1, Request::Ping { epoch: 7 }).unwrap() {
            Response::Pong { epoch } => assert_eq!(epoch, 107),
            other => panic!("expected Pong, got {other:?}"),
        }
        tp.shutdown_all();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn killed_inproc_node_errors_instead_of_blocking() {
        let (tp, eps) = InProcTransport::fully_connected(2);
        let tp = tp.with_call_timeout(Duration::from_secs(5));
        let mut handles: Vec<_> = eps.into_iter().map(spawn_echo).collect();
        // kill node 1 only: its worker breaks, dropping the inbox Receiver
        tp.call(0, 1, Request::Shutdown).ok();
        handles.pop().unwrap().join().unwrap();
        // a send to the dead node fails fast — no hang, a real error
        let t0 = std::time::Instant::now();
        let err = tp.call(0, 1, Request::ReadFile { path: "/x".into() });
        assert!(matches!(err, Err(FanError::Transport(_))), "{err:?}");
        assert!(
            t0.elapsed() < Duration::from_secs(4),
            "dead-node call must not block to the timeout"
        );
        tp.shutdown_all();
        handles.pop().unwrap().join().unwrap();
    }

    #[test]
    fn wedged_worker_trips_the_call_timeout() {
        let (tp, mut eps) = InProcTransport::fully_connected(2);
        let tp = tp.with_call_timeout(Duration::from_millis(50));
        // node 1's endpoint stays alive but nobody drains it: the wedged-
        // worker case the bounded wait exists for.
        let _wedged = eps.pop().unwrap();
        let t0 = std::time::Instant::now();
        let err = tp.call(0, 1, Request::ReadFile { path: "/x".into() });
        assert!(matches!(err, Err(FanError::Transport(_))), "{err:?}");
        assert!(t0.elapsed() >= Duration::from_millis(50));
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn overlapped_sends_collect_in_any_order() {
        let (tp, eps) = InProcTransport::fully_connected(4);
        let handles: Vec<_> = eps.into_iter().map(spawn_echo).collect();
        // issue to all peers first, then collect — the gather pattern
        let pending: Vec<PendingReply> = (1..4)
            .map(|to| {
                tp.send(0, to, Request::ReadFile { path: format!("/p{to}").into() })
                    .unwrap()
            })
            .collect();
        for (i, p) in pending.into_iter().enumerate() {
            let data = p.wait().unwrap().into_file_data().unwrap();
            assert_eq!(&data[..], format!("/p{}", i + 1).as_bytes());
        }
        tp.shutdown_all();
        let served: u32 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(served, 3);
    }

    #[test]
    fn many_concurrent_callers() {
        let (tp, eps) = InProcTransport::fully_connected(2);
        let handles: Vec<_> = eps.into_iter().map(spawn_echo).collect();
        let mut callers = Vec::new();
        for i in 0..8 {
            let tp = tp.clone();
            callers.push(thread::spawn(move || {
                for j in 0..50 {
                    let r = tp
                        .call(0, 1, Request::ReadFile {
                            path: format!("/f/{i}_{j}").into(),
                        })
                        .unwrap();
                    let d = r.into_file_data().unwrap();
                    assert_eq!(&d[..], format!("/f/{i}_{j}").as_bytes());
                }
            }));
        }
        for c in callers {
            c.join().unwrap();
        }
        tp.shutdown_all();
        let served: u32 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(served, 400);
    }

    #[test]
    fn dyn_transport_dispatch_matches_inherent() {
        let (tp, eps) = InProcTransport::fully_connected(2);
        let handles: Vec<_> = eps.into_iter().map(spawn_echo).collect();
        let dynt: Arc<dyn Transport> = Arc::new(tp);
        assert_eq!(dynt.node_count(), 2);
        let resp = dynt
            .call(0, 1, Request::ReadFile { path: "/dyn".into() })
            .unwrap();
        let data = resp.into_file_data().unwrap();
        assert_eq!(&data[..], b"/dyn");
        dynt.shutdown_all();
        let served: u32 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(served, 1);
    }
}
