//! Interconnect models and the inter-node transport.
//!
//! The paper's remote file access is "a round-trip MPI message" (§1) over
//! FDR InfiniBand (GPU cluster, 56 Gb/s, sub-µs latency) or Omni-Path
//! (CPU cluster, 100 Gb/s).  [`fabric`] is the virtual-time cost model of
//! those links; [`transport`] is the real message-passing layer used by the
//! in-process cluster (std::sync::mpsc standing in for MPI point-to-point,
//! same request/response protocol, real bytes).

pub mod fabric;
pub mod transport;

pub use fabric::Fabric;
pub use transport::{InProcTransport, Message, NodeEndpoint, Request, Response};
