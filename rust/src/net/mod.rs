//! Interconnect models and the inter-node transport.
//!
//! The paper's remote file access is "a round-trip MPI message" (§1) over
//! FDR InfiniBand (GPU cluster, 56 Gb/s, sub-µs latency) or Omni-Path
//! (CPU cluster, 100 Gb/s).  [`fabric`] is the virtual-time cost model of
//! those links; [`transport`] defines the real message-passing layer — the
//! [`transport::Transport`] trait plus the in-process implementation
//! (std::sync::mpsc standing in for MPI point-to-point); [`wire`] is the
//! length-prefixed frame codec for the same protocol; [`tcp`] runs it over
//! real sockets (loopback single-process or multi-host via the
//! `fanstore cluster` CLI); [`health`] is the per-peer failure detector
//! (Up → Suspect → Down, peer epochs, jittered backoff) behind read-path
//! failover; [`fault`] wraps any transport in deterministic, replayable
//! chaos for the kill-a-node tests.

pub mod fabric;
pub mod fault;
pub mod health;
pub mod tcp;
pub mod transport;
pub mod wire;

pub use fabric::Fabric;
pub use fault::{FaultEvent, FaultInjector, FaultPlan};
pub use health::{HealthMap, HealthPolicy, PeerState};
pub use tcp::{TcpServer, TcpTransport};
pub use transport::{
    InProcTransport, Message, NodeEndpoint, Request, Response, Transport,
};
