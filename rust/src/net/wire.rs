//! Wire format for the transport protocol: length-prefixed frames with an
//! explicit varint/LE encoding for every [`Request`]/[`Response`] variant.
//!
//! No external crates — the codec is written out by hand against std.
//!
//! # Frame layout
//!
//! ```text
//! [u32 LE body length][body]
//! request  body: [u8 kind=1][u64 LE correlation id][u32 LE from][u8 tag][fields]
//! response body: [u8 kind=2][u64 LE correlation id][u8 tag][fields]
//! ```
//!
//! Strings and byte payloads are varint(LEB128)-length-prefixed; fixed ids
//! (`from`, node ids, correlation ids) are little-endian; file stats ride as
//! their existing 144-byte partition image ([`FileStat::encode`]).
//!
//! The encoder produces a [`Frame`]: a chunk list where owned header bytes
//! and shared [`Payload`] handles interleave.  [`Frame::write_to`] writes
//! the chunks in order, so serving a read never copies the stored bytes
//! into an intermediate buffer on the send side — a spilled mmap-backed
//! read goes region → socket with **zero payload memcpys node-side**, and
//! the frame's handle keeps the region mapped until the write completes.
//! The receive side reads one bounded body and parses it; payload bytes
//! are materialized once into fresh owned buffers (that copy *is* the
//! network receive), and paths are interned per connection through a
//! [`PathInterner`], so an epoch's worth of repeated request paths decodes
//! into `Arc` clones of one allocation each.

use std::collections::HashSet;
use std::io::{IoSlice, Read, Write};
use std::sync::Arc;

use crate::compress::Codec;
use crate::error::{FanError, Result};
use crate::metadata::record::{FileMeta, FileStat, STAT_BYTES};
use crate::net::transport::{FileFetch, MetaFetch, Request, Response};
use crate::storage::payload::{self, Payload};

/// Sanity cap on one frame body (a `ReadFiles` reply carrying a whole
/// mini-batch of multi-MB files fits with room to spare; a corrupt length
/// prefix does not get to allocate half the address space).
pub const MAX_FRAME: u32 = 1 << 30;

const KIND_REQUEST: u8 = 1;
const KIND_RESPONSE: u8 = 2;

const REQ_READ_FILE: u8 = 0;
const REQ_READ_FILES: u8 = 1;
const REQ_STAT_OUTPUT: u8 = 2;
const REQ_STAT_OUTPUTS: u8 = 3;
const REQ_COMMIT_OUTPUT: u8 = 4;
const REQ_LIST_OUTPUTS: u8 = 5;
const REQ_UNLINK_OUTPUT: u8 = 6;
const REQ_DROP_OUTPUT: u8 = 7;
const REQ_SHUTDOWN: u8 = 8;
const REQ_INVALIDATE_LISTINGS: u8 = 9;
const REQ_PING: u8 = 10;
const REQ_FETCH_PARTITION: u8 = 11;
const REQ_INSTALL_PARTITION: u8 = 12;

const RESP_FILE_DATA: u8 = 0;
const RESP_FILES_DATA: u8 = 1;
const RESP_META: u8 = 2;
const RESP_METAS: u8 = 3;
const RESP_NAMES: u8 = 4;
const RESP_OK: u8 = 5;
const RESP_ERR: u8 = 6;
const RESP_PONG: u8 = 7;
const RESP_PARTITION_DATA: u8 = 8;

const FETCH_DATA: u8 = 0;
const FETCH_NOT_FOUND: u8 = 1;
const FETCH_FAULT: u8 = 2;

const META_FOUND: u8 = 0;
const META_NOT_FOUND: u8 = 1;

enum Chunk {
    Owned(Vec<u8>),
    Shared(Payload),
}

/// One encoded frame: interleaved owned header bytes and shared payload
/// handles (which keep their backing buffer/region alive until the frame
/// is written or dropped).
pub struct Frame {
    chunks: Vec<Chunk>,
}

impl Frame {
    fn new() -> Frame {
        Frame {
            chunks: vec![Chunk::Owned(Vec::with_capacity(64))],
        }
    }

    fn tail(&mut self) -> &mut Vec<u8> {
        if !matches!(self.chunks.last(), Some(Chunk::Owned(_))) {
            self.chunks.push(Chunk::Owned(Vec::new()));
        }
        match self.chunks.last_mut() {
            Some(Chunk::Owned(v)) => v,
            _ => unreachable!("tail chunk just ensured owned"),
        }
    }

    fn put_u8(&mut self, v: u8) {
        self.tail().push(v);
    }

    fn put_u32(&mut self, v: u32) {
        self.tail().extend_from_slice(&v.to_le_bytes());
    }

    fn put_u64(&mut self, v: u64) {
        self.tail().extend_from_slice(&v.to_le_bytes());
    }

    fn put_varint(&mut self, mut v: u64) {
        let t = self.tail();
        loop {
            let b = (v & 0x7F) as u8;
            v >>= 7;
            if v == 0 {
                t.push(b);
                break;
            }
            t.push(b | 0x80);
        }
    }

    fn put_slice(&mut self, s: &[u8]) {
        self.tail().extend_from_slice(s);
    }

    fn put_str(&mut self, s: &str) {
        self.put_varint(s.len() as u64);
        self.put_slice(s.as_bytes());
    }

    /// Append a payload without copying it: the handle rides in the chunk
    /// list and its bytes are written straight to the socket (a zero-copy
    /// view stays a view all the way to the `writev`).
    fn put_shared(&mut self, payload: Payload) {
        self.put_varint(payload.len() as u64);
        self.chunks.push(Chunk::Shared(payload));
    }

    /// Total body length (without the 4-byte frame prefix).
    pub fn body_len(&self) -> usize {
        self.chunks
            .iter()
            .map(|c| match c {
                Chunk::Owned(v) => v.len(),
                Chunk::Shared(a) => a.len(),
            })
            .sum()
    }

    /// Write `[len][body]` to `w` with one `write_vectored` spanning the
    /// length prefix and every chunk, repeated only when the writer takes
    /// a short write — serving a read is ~1 syscall instead of one per
    /// chunk, and the `Arc` payloads still go to the socket uncopied.
    pub fn write_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        let len = self.body_len();
        if len > MAX_FRAME as usize {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("frame body {len} exceeds MAX_FRAME"),
            ));
        }
        let prefix = (len as u32).to_le_bytes();
        let mut parts: Vec<&[u8]> = Vec::with_capacity(1 + self.chunks.len());
        parts.push(&prefix);
        for c in &self.chunks {
            let s: &[u8] = match c {
                Chunk::Owned(v) => v,
                Chunk::Shared(a) => a,
            };
            if !s.is_empty() {
                parts.push(s);
            }
        }
        write_all_vectored(w, &parts)
    }

    /// Serialize `[len][body]` into `out` (the send-coalescing path: small
    /// frames accumulate in one buffer flushed by a single write).
    /// Flattening a payload chunk here duplicates its bytes, so each one
    /// is recorded as a payload memcpy — only sub-capacity data frames pay
    /// it (large frames write through vectored, and the small `Meta`/ack
    /// frames that coalescing exists for carry no payload chunks at all).
    pub fn append_to(&self, out: &mut Vec<u8>) -> std::io::Result<()> {
        let len = self.body_len();
        if len > MAX_FRAME as usize {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("frame body {len} exceeds MAX_FRAME"),
            ));
        }
        out.reserve(4 + len);
        out.extend_from_slice(&(len as u32).to_le_bytes());
        for c in &self.chunks {
            match c {
                Chunk::Owned(v) => out.extend_from_slice(v),
                Chunk::Shared(a) => {
                    payload::record_copy();
                    out.extend_from_slice(a);
                }
            }
        }
        Ok(())
    }

    /// Flatten the body into one buffer (tests / diagnostics).
    pub fn to_body_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.body_len());
        for c in &self.chunks {
            match c {
                Chunk::Owned(v) => out.extend_from_slice(v),
                Chunk::Shared(a) => out.extend_from_slice(a),
            }
        }
        out
    }
}

/// `write_all` over a scatter list: issue `write_vectored` and advance
/// through partial writes until every byte is gone.  (std's
/// `Write::write_all_vectored` is unstable; this is the loop it would do.)
fn write_all_vectored(w: &mut impl Write, parts: &[&[u8]]) -> std::io::Result<()> {
    let mut idx = 0; // first part not fully written
    let mut off = 0; // bytes of parts[idx] already written
    let mut slices: Vec<IoSlice> = Vec::with_capacity(parts.len());
    while idx < parts.len() {
        if parts[idx].len() == off {
            // empty part (or fully written by the accounting below)
            idx += 1;
            off = 0;
            continue;
        }
        slices.clear();
        slices.push(IoSlice::new(&parts[idx][off..]));
        slices.extend(parts[idx + 1..].iter().map(|p| IoSlice::new(p)));
        let mut n = match w.write_vectored(&slices) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "writer accepted zero bytes",
                ))
            }
            Ok(n) => n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        while n > 0 && idx < parts.len() {
            let rem = parts[idx].len() - off;
            if n >= rem {
                n -= rem;
                idx += 1;
                off = 0;
            } else {
                off += n;
                n = 0;
            }
        }
    }
    Ok(())
}

/// Default flush threshold for [`CoalescingWriter`] buffers.
pub const COALESCE_CAPACITY: usize = 16 * 1024;

/// Per-connection send coalescing.  Small frames append to a bounded
/// buffer; the buffer flushes in one write when
///
/// 1. it reaches capacity,
/// 2. a frame at least as large as the capacity arrives (the buffer
///    drains first, then the large frame is written through vectored,
///    skipping the copy), or
/// 3. the caller reports that no further writer is queued on the
///    connection (`more_queued == false`).
///
/// Rule 3 is the latency bound: a request with nobody behind it is
/// flushed before `write_frame` returns, so coalescing only ever delays a
/// frame behind writes that were already queued ahead of it.  A metadata
/// storm (stat storm, batched resume) pays ~1 syscall per buffer instead
/// of one per frame.
pub struct CoalescingWriter<W: Write> {
    inner: W,
    buf: Vec<u8>,
    cap: usize,
    frames: u64,
    flushes: u64,
}

impl<W: Write> CoalescingWriter<W> {
    pub fn new(inner: W) -> CoalescingWriter<W> {
        Self::with_capacity(inner, COALESCE_CAPACITY)
    }

    pub fn with_capacity(inner: W, cap: usize) -> CoalescingWriter<W> {
        let cap = cap.max(1);
        CoalescingWriter {
            inner,
            buf: Vec::with_capacity(cap),
            cap,
            frames: 0,
            flushes: 0,
        }
    }

    /// Queue or write one frame.  `more_queued` is the caller's statement
    /// that another writer is already waiting on this connection.
    pub fn write_frame(&mut self, frame: &Frame, more_queued: bool) -> std::io::Result<()> {
        self.frames += 1;
        if 4 + frame.body_len() >= self.cap {
            // large frame: drain the buffer (ordering!), then write through
            self.flush_buf()?;
            frame.write_to(&mut self.inner)?;
            self.flushes += 1;
        } else {
            frame.append_to(&mut self.buf)?;
            if self.buf.len() >= self.cap {
                self.flush_buf()?;
            }
        }
        if !more_queued {
            self.flush_buf()?;
        }
        Ok(())
    }

    /// Force out any buffered bytes.
    pub fn flush(&mut self) -> std::io::Result<()> {
        self.flush_buf()
    }

    fn flush_buf(&mut self) -> std::io::Result<()> {
        if self.buf.is_empty() {
            return Ok(());
        }
        self.inner.write_all(&self.buf)?;
        self.buf.clear();
        self.flushes += 1;
        self.inner.flush()
    }

    /// `(frames accepted, flushes issued)` — the coalescing win is the
    /// ratio (bench/test accounting).
    pub fn counts(&self) -> (u64, u64) {
        (self.frames, self.flushes)
    }

    pub fn get_ref(&self) -> &W {
        &self.inner
    }
}

/// Decode-side path interner, one per connection: every path decoded on
/// the connection is stored once as an `Arc<str>`; repeats (steady-state
/// training re-requests the same dataset paths epoch after epoch, batched
/// replies echo their request's paths) decode into `Arc` clones of that
/// single allocation instead of fresh `String`s.
///
/// Bounded **by entries and by bytes**: at [`PathInterner::CAP`] distinct
/// paths or [`PathInterner::BYTE_CAP`] retained path bytes the table
/// resets (outstanding `Arc`s stay valid — only future dedup restarts),
/// so a hostile stream of long distinct paths cannot pin unbounded memory
/// per connection.
#[derive(Default)]
pub struct PathInterner {
    paths: HashSet<Arc<str>>,
    bytes: usize,
}

impl PathInterner {
    /// Entry-count reset threshold (distinct paths per connection).  Far
    /// above any real dataset's working set of *wire-visible* paths.
    pub const CAP: usize = 1 << 20;
    /// Byte reset threshold: total retained path bytes per connection.
    /// Caps the adversarial case (CAP long distinct paths) at ~16 MiB
    /// instead of hundreds of MB.
    pub const BYTE_CAP: usize = 16 << 20;

    /// The interned handle for `s`, allocating only on first sight.
    pub fn intern(&mut self, s: &str) -> Arc<str> {
        if let Some(a) = self.paths.get(s) {
            return Arc::clone(a);
        }
        if self.paths.len() >= Self::CAP || self.bytes + s.len() > Self::BYTE_CAP {
            self.paths.clear();
            self.bytes = 0;
        }
        let a: Arc<str> = Arc::from(s);
        self.bytes += s.len();
        self.paths.insert(Arc::clone(&a));
        a
    }

    /// Distinct paths currently interned.
    pub fn len(&self) -> usize {
        self.paths.len()
    }

    pub fn is_empty(&self) -> bool {
        self.paths.is_empty()
    }
}

/// Body bytes read per step by [`read_frame`].  Allocation grows with the
/// bytes actually delivered, so a lying `MAX_FRAME`-adjacent length prefix
/// on a torn stream costs at most one chunk, not a gigabyte.
pub(crate) const READ_CHUNK: usize = 64 * 1024;

/// Read one `[len][body]` frame; returns the body.
///
/// The body is read incrementally in [`READ_CHUNK`] steps: the buffer only
/// ever holds capacity for bytes the peer has actually produced (plus one
/// chunk), so a corrupt or hostile length prefix cannot drive a large
/// speculative allocation before the stream runs dry.
pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)
        .map_err(|e| FanError::Transport(format!("frame read: {e}")))?;
    let len = u32::from_le_bytes(len);
    if len > MAX_FRAME {
        return Err(FanError::Format(format!(
            "frame length {len} exceeds MAX_FRAME"
        )));
    }
    let len = len as usize;
    let mut body = Vec::with_capacity(len.min(READ_CHUNK));
    while body.len() < len {
        let step = (len - body.len()).min(READ_CHUNK);
        let start = body.len();
        body.resize(start + step, 0);
        r.read_exact(&mut body[start..])
            .map_err(|e| FanError::Transport(format!("frame body read: {e}")))?;
    }
    Ok(body)
}

/// Bounds-checked cursor over one frame body.
struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    fn new(buf: &'a [u8]) -> WireReader<'a> {
        WireReader { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            return Err(FanError::Format(format!(
                "frame truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn get_varint(&mut self) -> Result<u64> {
        let mut v: u64 = 0;
        let mut shift = 0u32;
        loop {
            let b = self.get_u8()?;
            if shift == 63 && b > 1 {
                return Err(FanError::Format("varint overflows u64".into()));
            }
            v |= u64::from(b & 0x7F) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
            if shift > 63 {
                return Err(FanError::Format("varint longer than 10 bytes".into()));
            }
        }
    }

    /// Varint length that must fit in the remaining bytes (corrupt counts
    /// cannot trigger huge allocations).
    fn get_len(&mut self) -> Result<usize> {
        let n = self.get_varint()?;
        if n > self.remaining() as u64 {
            return Err(FanError::Format(format!(
                "length {n} exceeds remaining frame bytes {}",
                self.remaining()
            )));
        }
        Ok(n as usize)
    }

    /// Varint *element count* for a batch whose elements each encode to at
    /// least `min_encoded` bytes.  Rejected before any allocation if the
    /// remaining frame bytes cannot possibly back that many elements —
    /// a corrupt count cannot reserve memory the frame never shipped.
    fn get_count(&mut self, min_encoded: usize) -> Result<usize> {
        let n = self.get_varint()?;
        let max = (self.remaining() / min_encoded.max(1)) as u64;
        if n > max {
            return Err(FanError::Format(format!(
                "batch count {n} exceeds what {} remaining frame bytes can encode",
                self.remaining()
            )));
        }
        Ok(n as usize)
    }

    fn get_str(&mut self) -> Result<String> {
        let n = self.get_len()?;
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|_| FanError::Format("non-UTF8 string in frame".into()))
    }

    /// Decode a path through the connection's interner: repeated paths
    /// come back as `Arc` clones of one allocation.
    fn get_path(&mut self, paths: &mut PathInterner) -> Result<Arc<str>> {
        let n = self.get_len()?;
        let s = std::str::from_utf8(self.take(n)?)
            .map_err(|_| FanError::Format("non-UTF8 string in frame".into()))?;
        Ok(paths.intern(s))
    }

    /// Materialize a received payload (this copy *is* the network
    /// receive — the frame body buffer does not outlive the decode).
    fn get_bytes(&mut self) -> Result<Payload> {
        let n = self.get_len()?;
        let owned: Arc<[u8]> = self.take(n)?.into();
        Ok(Payload::Owned(owned))
    }

    fn expect_end(&self) -> Result<()> {
        if self.remaining() != 0 {
            return Err(FanError::Format(format!(
                "{} trailing bytes after frame payload",
                self.remaining()
            )));
        }
        Ok(())
    }
}

/// Batch vector whose *preallocation* is capped by the bytes actually left
/// in the frame: decoded elements (16–24 B of `Arc<str>` / tuple each) can
/// be far wider than their 1–2 B minimum encoding, so even a count that
/// passed [`WireReader::get_count`] could otherwise reserve ~16–24× the
/// input.  Real batches (paths are ≥ ~8 bytes on the wire) still get their
/// full capacity up front; hostile degenerate counts fall back to amortized
/// growth, bounding speculative allocation at ~2× the remaining bytes.
fn bounded_vec<T>(n: usize, remaining: usize) -> Vec<T> {
    let elem = std::mem::size_of::<T>().max(1);
    let cap_elems = (2 * remaining) / elem + 1;
    Vec::with_capacity(n.min(cap_elems))
}

fn put_stat(f: &mut Frame, stat: &FileStat) {
    f.put_slice(&stat.encode());
}

fn get_stat(r: &mut WireReader) -> Result<FileStat> {
    FileStat::decode(r.take(STAT_BYTES)?)
}

fn put_meta(f: &mut Frame, meta: &FileMeta) {
    put_stat(f, &meta.stat);
    f.put_u32(meta.location.node);
    f.put_u32(meta.location.partition);
    f.put_varint(meta.location.offset);
    f.put_varint(meta.location.stored_len);
    f.put_u8(meta.location.codec.to_wire());
    f.put_varint(meta.generation);
}

fn get_meta(r: &mut WireReader) -> Result<FileMeta> {
    let stat = get_stat(r)?;
    let node = r.get_u32()?;
    let partition = r.get_u32()?;
    let offset = r.get_varint()?;
    let stored_len = r.get_varint()?;
    let codec = Codec::from_wire(r.get_u8()?)?;
    let generation = r.get_varint()?;
    Ok(FileMeta {
        stat,
        location: crate::metadata::record::FileLocation {
            node,
            partition,
            offset,
            stored_len,
            codec,
        },
        generation,
    })
}

fn put_fetch(f: &mut Frame, fetch: &FileFetch) {
    match fetch {
        FileFetch::Data { stored } => {
            f.put_u8(FETCH_DATA);
            f.put_varint(stored.raw_len());
            f.put_u8(stored.codec().to_wire());
            f.put_shared(stored.clone());
        }
        FileFetch::NotFound => f.put_u8(FETCH_NOT_FOUND),
        FileFetch::Fault(e) => {
            f.put_u8(FETCH_FAULT);
            f.put_str(e);
        }
    }
}

fn get_fetch(r: &mut WireReader) -> Result<FileFetch> {
    // (payload bytes are materialized by get_bytes — the network receive)
    match r.get_u8()? {
        FETCH_DATA => {
            let raw_len = r.get_varint()?;
            let codec = Codec::from_wire(r.get_u8()?)?;
            let stored = r.get_bytes()?;
            Ok(FileFetch::Data {
                stored: Payload::compressed(codec, raw_len, stored),
            })
        }
        FETCH_NOT_FOUND => Ok(FileFetch::NotFound),
        FETCH_FAULT => Ok(FileFetch::Fault(r.get_str()?)),
        t => Err(FanError::Format(format!("unknown FileFetch tag {t}"))),
    }
}

/// Encode one addressed request with its correlation id.
pub fn encode_request(corr: u64, from: u32, req: &Request) -> Frame {
    let mut f = Frame::new();
    f.put_u8(KIND_REQUEST);
    f.put_u64(corr);
    f.put_u32(from);
    match req {
        Request::ReadFile { path } => {
            f.put_u8(REQ_READ_FILE);
            f.put_str(path);
        }
        Request::ReadFiles { paths } => {
            f.put_u8(REQ_READ_FILES);
            f.put_varint(paths.len() as u64);
            for p in paths {
                f.put_str(p);
            }
        }
        Request::StatOutput { path } => {
            f.put_u8(REQ_STAT_OUTPUT);
            f.put_str(path);
        }
        Request::StatOutputs { paths } => {
            f.put_u8(REQ_STAT_OUTPUTS);
            f.put_varint(paths.len() as u64);
            for p in paths {
                f.put_str(p);
            }
        }
        Request::CommitOutput {
            path,
            meta,
            data,
            stamped,
        } => {
            f.put_u8(REQ_COMMIT_OUTPUT);
            f.put_str(path);
            put_meta(&mut f, meta);
            f.put_u8(u8::from(*stamped));
            f.put_varint(data.raw_len());
            f.put_u8(data.codec().to_wire());
            f.put_shared(data.clone());
        }
        Request::ListOutputs { dir } => {
            f.put_u8(REQ_LIST_OUTPUTS);
            f.put_str(dir);
        }
        Request::UnlinkOutput { path } => {
            f.put_u8(REQ_UNLINK_OUTPUT);
            f.put_str(path);
        }
        Request::DropOutput { path } => {
            f.put_u8(REQ_DROP_OUTPUT);
            f.put_str(path);
        }
        Request::InvalidateListings { path } => {
            f.put_u8(REQ_INVALIDATE_LISTINGS);
            f.put_str(path);
        }
        Request::Ping { epoch } => {
            f.put_u8(REQ_PING);
            f.put_u64(*epoch);
        }
        Request::FetchPartition { pid } => {
            f.put_u8(REQ_FETCH_PARTITION);
            f.put_u32(*pid);
        }
        Request::InstallPartition { pid, blob } => {
            f.put_u8(REQ_INSTALL_PARTITION);
            f.put_u32(*pid);
            f.put_shared(blob.clone());
        }
        Request::Shutdown => f.put_u8(REQ_SHUTDOWN),
    }
    f
}

/// Decode one request frame body → (correlation id, from, request).
/// `paths` is the connection's interner — repeated paths across frames
/// decode into `Arc` clones of one allocation.
pub fn decode_request(body: &[u8], paths: &mut PathInterner) -> Result<(u64, u32, Request)> {
    let mut r = WireReader::new(body);
    if r.get_u8()? != KIND_REQUEST {
        return Err(FanError::Format("frame is not a request".into()));
    }
    let corr = r.get_u64()?;
    let from = r.get_u32()?;
    let req = match r.get_u8()? {
        REQ_READ_FILE => Request::ReadFile {
            path: r.get_path(paths)?,
        },
        REQ_READ_FILES => {
            // each path encodes to >= 1 byte (its length varint)
            let n = r.get_count(1)?;
            let mut batch = bounded_vec(n, r.remaining());
            for _ in 0..n {
                batch.push(r.get_path(paths)?);
            }
            Request::ReadFiles { paths: batch }
        }
        REQ_STAT_OUTPUT => Request::StatOutput {
            path: r.get_path(paths)?,
        },
        REQ_STAT_OUTPUTS => {
            let n = r.get_count(1)?;
            let mut batch = bounded_vec(n, r.remaining());
            for _ in 0..n {
                batch.push(r.get_path(paths)?);
            }
            Request::StatOutputs { paths: batch }
        }
        REQ_COMMIT_OUTPUT => {
            let path = r.get_path(paths)?;
            let meta = get_meta(&mut r)?;
            let stamped = match r.get_u8()? {
                0 => false,
                1 => true,
                t => return Err(FanError::Format(format!("bad stamped flag {t}"))),
            };
            let raw_len = r.get_varint()?;
            let codec = Codec::from_wire(r.get_u8()?)?;
            let data = Payload::compressed(codec, raw_len, r.get_bytes()?);
            Request::CommitOutput {
                path,
                meta,
                data,
                stamped,
            }
        }
        REQ_LIST_OUTPUTS => Request::ListOutputs {
            dir: r.get_path(paths)?,
        },
        REQ_UNLINK_OUTPUT => Request::UnlinkOutput {
            path: r.get_path(paths)?,
        },
        REQ_DROP_OUTPUT => Request::DropOutput {
            path: r.get_path(paths)?,
        },
        REQ_INVALIDATE_LISTINGS => Request::InvalidateListings {
            path: r.get_path(paths)?,
        },
        REQ_PING => Request::Ping {
            epoch: r.get_u64()?,
        },
        REQ_FETCH_PARTITION => Request::FetchPartition { pid: r.get_u32()? },
        REQ_INSTALL_PARTITION => {
            let pid = r.get_u32()?;
            let blob = r.get_bytes()?;
            Request::InstallPartition { pid, blob }
        }
        REQ_SHUTDOWN => Request::Shutdown,
        t => return Err(FanError::Format(format!("unknown request tag {t}"))),
    };
    r.expect_end()?;
    Ok((corr, from, req))
}

/// Encode one correlated response.
pub fn encode_response(corr: u64, resp: &Response) -> Frame {
    let mut f = Frame::new();
    f.put_u8(KIND_RESPONSE);
    f.put_u64(corr);
    match resp {
        Response::FileData { stored } => {
            f.put_u8(RESP_FILE_DATA);
            f.put_varint(stored.raw_len());
            f.put_u8(stored.codec().to_wire());
            f.put_shared(stored.clone());
        }
        Response::FilesData(files) => {
            f.put_u8(RESP_FILES_DATA);
            f.put_varint(files.len() as u64);
            for (path, fetch) in files {
                f.put_str(path);
                put_fetch(&mut f, fetch);
            }
        }
        Response::Meta {
            stat,
            origin,
            generation,
        } => {
            f.put_u8(RESP_META);
            put_stat(&mut f, stat);
            f.put_u32(*origin);
            f.put_varint(*generation);
        }
        Response::Metas(metas) => {
            f.put_u8(RESP_METAS);
            f.put_varint(metas.len() as u64);
            for (path, m) in metas {
                f.put_str(path);
                match m {
                    MetaFetch::Meta {
                        stat,
                        origin,
                        generation,
                    } => {
                        f.put_u8(META_FOUND);
                        put_stat(&mut f, stat);
                        f.put_u32(*origin);
                        f.put_varint(*generation);
                    }
                    MetaFetch::NotFound => f.put_u8(META_NOT_FOUND),
                }
            }
        }
        Response::Names(names) => {
            f.put_u8(RESP_NAMES);
            f.put_varint(names.len() as u64);
            for n in names {
                f.put_str(n);
            }
        }
        Response::Pong { epoch } => {
            f.put_u8(RESP_PONG);
            f.put_u64(*epoch);
        }
        Response::PartitionData { blob } => {
            f.put_u8(RESP_PARTITION_DATA);
            f.put_shared(blob.clone());
        }
        Response::Ok => f.put_u8(RESP_OK),
        Response::Err(e) => {
            f.put_u8(RESP_ERR);
            f.put_str(e);
        }
    }
    f
}

/// Decode one response frame body → (correlation id, response).
/// `paths` interns the batched-reply paths exactly like the request side.
pub fn decode_response(body: &[u8], paths: &mut PathInterner) -> Result<(u64, Response)> {
    let mut r = WireReader::new(body);
    if r.get_u8()? != KIND_RESPONSE {
        return Err(FanError::Format("frame is not a response".into()));
    }
    let corr = r.get_u64()?;
    let resp = match r.get_u8()? {
        RESP_FILE_DATA => {
            let raw_len = r.get_varint()?;
            let codec = Codec::from_wire(r.get_u8()?)?;
            let stored = r.get_bytes()?;
            Response::FileData {
                stored: Payload::compressed(codec, raw_len, stored),
            }
        }
        RESP_FILES_DATA => {
            // each entry encodes to >= 2 bytes (path length varint + tag)
            let n = r.get_count(2)?;
            let mut files = bounded_vec(n, r.remaining());
            for _ in 0..n {
                let path = r.get_path(paths)?;
                let fetch = get_fetch(&mut r)?;
                files.push((path, fetch));
            }
            Response::FilesData(files)
        }
        RESP_META => {
            let stat = get_stat(&mut r)?;
            let origin = r.get_u32()?;
            let generation = r.get_varint()?;
            Response::Meta {
                stat,
                origin,
                generation,
            }
        }
        RESP_METAS => {
            let n = r.get_count(2)?;
            let mut metas = bounded_vec(n, r.remaining());
            for _ in 0..n {
                let path = r.get_path(paths)?;
                let m = match r.get_u8()? {
                    META_FOUND => {
                        let stat = get_stat(&mut r)?;
                        let origin = r.get_u32()?;
                        let generation = r.get_varint()?;
                        MetaFetch::Meta {
                            stat,
                            origin,
                            generation,
                        }
                    }
                    META_NOT_FOUND => MetaFetch::NotFound,
                    t => return Err(FanError::Format(format!("unknown MetaFetch tag {t}"))),
                };
                metas.push((path, m));
            }
            Response::Metas(metas)
        }
        RESP_NAMES => {
            let n = r.get_count(1)?;
            let mut names = bounded_vec(n, r.remaining());
            for _ in 0..n {
                names.push(r.get_str()?);
            }
            Response::Names(names)
        }
        RESP_PONG => Response::Pong {
            epoch: r.get_u64()?,
        },
        RESP_PARTITION_DATA => Response::PartitionData {
            blob: r.get_bytes()?,
        },
        RESP_OK => Response::Ok,
        RESP_ERR => Response::Err(r.get_str()?),
        t => return Err(FanError::Format(format!("unknown response tag {t}"))),
    };
    r.expect_end()?;
    Ok((corr, resp))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metadata::record::FileLocation;

    fn meta(gen: u64) -> FileMeta {
        FileMeta {
            stat: FileStat::regular(77, 1234),
            location: FileLocation {
                node: 3,
                partition: u32::MAX,
                offset: 9_000_000_123,
                stored_len: 1234,
                codec: Codec::Lzss(5),
            },
            generation: gen,
        }
    }

    fn roundtrip_request(req: &Request) -> (u64, u32, Request) {
        let body = encode_request(0xC0FFEE, 7, req).to_body_bytes();
        decode_request(&body, &mut PathInterner::default()).unwrap()
    }

    fn roundtrip_response(resp: &Response) -> (u64, Response) {
        let body = encode_response(0xDECAF, resp).to_body_bytes();
        decode_response(&body, &mut PathInterner::default()).unwrap()
    }

    fn strs(v: &[Arc<str>]) -> Vec<&str> {
        v.iter().map(|p| &**p).collect()
    }

    #[test]
    fn request_variants_roundtrip() {
        // every Request variant survives encode → decode intact
        let (corr, from, req) = roundtrip_request(&Request::ReadFile { path: "/a/b".into() });
        assert_eq!((corr, from), (0xC0FFEE, 7));
        assert!(matches!(req, Request::ReadFile { path } if &*path == "/a/b"));

        let (_, _, req) = roundtrip_request(&Request::ReadFiles {
            paths: vec!["/x".into(), "".into(), "/ü/ñ".into()],
        });
        match req {
            Request::ReadFiles { paths } => assert_eq!(strs(&paths), vec!["/x", "", "/ü/ñ"]),
            other => panic!("unexpected {other:?}"),
        }

        let (_, _, req) = roundtrip_request(&Request::StatOutput { path: "/o".into() });
        assert!(matches!(req, Request::StatOutput { path } if &*path == "/o"));

        let (_, _, req) = roundtrip_request(&Request::StatOutputs {
            paths: vec!["/s1".into(), "/s2".into()],
        });
        match req {
            Request::StatOutputs { paths } => assert_eq!(strs(&paths), vec!["/s1", "/s2"]),
            other => panic!("unexpected {other:?}"),
        }

        let (_, _, req) = roundtrip_request(&Request::CommitOutput {
            path: "/ckpt/m.bin".into(),
            meta: meta(42),
            data: vec![7u8; 42].into(),
            stamped: true,
        });
        match req {
            Request::CommitOutput {
                path,
                meta: m,
                data,
                stamped,
            } => {
                assert_eq!(&*path, "/ckpt/m.bin");
                assert_eq!(m, meta(42));
                assert_eq!(data.as_slice(), &[7u8; 42][..]);
                assert!(stamped);
            }
            other => panic!("unexpected {other:?}"),
        }

        let (_, _, req) = roundtrip_request(&Request::FetchPartition { pid: 0xBEEF });
        assert!(matches!(req, Request::FetchPartition { pid } if pid == 0xBEEF));
        let (_, _, req) = roundtrip_request(&Request::InstallPartition {
            pid: 3,
            blob: vec![0xA5u8; 1024].into(),
        });
        match req {
            Request::InstallPartition { pid, blob } => {
                assert_eq!(pid, 3);
                assert_eq!(blob.as_slice(), &[0xA5u8; 1024][..]);
            }
            other => panic!("unexpected {other:?}"),
        }

        let (_, _, req) = roundtrip_request(&Request::ListOutputs { dir: "/d".into() });
        assert!(matches!(req, Request::ListOutputs { dir } if &*dir == "/d"));
        let (_, _, req) = roundtrip_request(&Request::UnlinkOutput { path: "/u".into() });
        assert!(matches!(req, Request::UnlinkOutput { path } if &*path == "/u"));
        let (_, _, req) = roundtrip_request(&Request::DropOutput { path: "/g".into() });
        assert!(matches!(req, Request::DropOutput { path } if &*path == "/g"));
        let (_, _, req) =
            roundtrip_request(&Request::InvalidateListings { path: "/ckpt/new.bin".into() });
        assert!(matches!(req, Request::InvalidateListings { path } if &*path == "/ckpt/new.bin"));
        let (_, _, req) = roundtrip_request(&Request::Ping { epoch: u64::MAX - 1 });
        assert!(matches!(req, Request::Ping { epoch } if epoch == u64::MAX - 1));
        let (_, _, req) = roundtrip_request(&Request::Shutdown);
        assert!(matches!(req, Request::Shutdown));
    }

    #[test]
    fn response_variants_roundtrip() {
        let payload = Payload::compressed(Codec::Lzss(5), 4096, vec![7u8; 300].into());
        let (corr, resp) = roundtrip_response(&Response::FileData {
            stored: payload.clone(),
        });
        assert_eq!(corr, 0xDECAF);
        match resp {
            Response::FileData { stored } => {
                assert_eq!(&stored[..], &payload[..]);
                assert_eq!(stored.raw_len(), 4096);
                assert_eq!(stored.codec(), Codec::Lzss(5));
            }
            other => panic!("unexpected {other:?}"),
        }

        let (_, resp) = roundtrip_response(&Response::FilesData(vec![
            (
                "/a".into(),
                FileFetch::Data {
                    stored: vec![1, 2, 3].into(),
                },
            ),
            ("/b".into(), FileFetch::NotFound),
            ("/c".into(), FileFetch::Fault("disk on fire".into())),
        ]));
        match resp {
            Response::FilesData(files) => {
                assert_eq!(files.len(), 3);
                match &files[0].1 {
                    FileFetch::Data { stored } => {
                        assert_eq!(&stored[..], &[1, 2, 3]);
                        assert_eq!(stored.raw_len(), 3);
                        assert_eq!(stored.codec(), Codec::None);
                    }
                    other => panic!("unexpected {other:?}"),
                }
                assert!(matches!(files[1].1, FileFetch::NotFound));
                assert!(matches!(&files[2].1, FileFetch::Fault(e) if e == "disk on fire"));
            }
            other => panic!("unexpected {other:?}"),
        }

        let stat = FileStat::directory(9);
        let (_, resp) = roundtrip_response(&Response::Meta {
            stat,
            origin: 11,
            generation: u64::MAX,
        });
        match resp {
            Response::Meta {
                stat: s,
                origin,
                generation,
            } => {
                assert_eq!(s, stat);
                assert_eq!(origin, 11);
                assert_eq!(generation, u64::MAX);
            }
            other => panic!("unexpected {other:?}"),
        }

        let (_, resp) = roundtrip_response(&Response::Metas(vec![
            (
                "/m1".into(),
                MetaFetch::Meta {
                    stat: FileStat::regular(1, 10),
                    origin: 2,
                    generation: 5,
                },
            ),
            ("/m2".into(), MetaFetch::NotFound),
        ]));
        match resp {
            Response::Metas(metas) => {
                assert_eq!(metas.len(), 2);
                match &metas[0].1 {
                    MetaFetch::Meta {
                        stat,
                        origin,
                        generation,
                    } => {
                        assert_eq!(stat.size, 10);
                        assert_eq!(*origin, 2);
                        assert_eq!(*generation, 5);
                    }
                    other => panic!("unexpected {other:?}"),
                }
                assert!(matches!(metas[1].1, MetaFetch::NotFound));
            }
            other => panic!("unexpected {other:?}"),
        }

        let (_, resp) =
            roundtrip_response(&Response::Names(vec!["a.bin".into(), "b.bin".into()]));
        match resp {
            Response::Names(names) => assert_eq!(names, vec!["a.bin", "b.bin"]),
            other => panic!("unexpected {other:?}"),
        }

        let (_, resp) = roundtrip_response(&Response::Pong { epoch: 0x8000_0000_0001 });
        assert!(matches!(resp, Response::Pong { epoch } if epoch == 0x8000_0000_0001));
        let (_, resp) = roundtrip_response(&Response::PartitionData {
            blob: vec![0x5Au8; 2048].into(),
        });
        match resp {
            Response::PartitionData { blob } => assert_eq!(blob.as_slice(), &[0x5Au8; 2048][..]),
            other => panic!("unexpected {other:?}"),
        }
        let (_, resp) = roundtrip_response(&Response::Ok);
        assert!(matches!(resp, Response::Ok));
        let (_, resp) = roundtrip_response(&Response::Err("nope".into()));
        assert!(matches!(resp, Response::Err(e) if e == "nope"));
    }

    #[test]
    fn empty_batches_roundtrip() {
        let (_, _, req) = roundtrip_request(&Request::ReadFiles { paths: vec![] });
        assert!(matches!(req, Request::ReadFiles { paths } if paths.is_empty()));
        let (_, resp) = roundtrip_response(&Response::FilesData(vec![]));
        assert!(matches!(resp, Response::FilesData(v) if v.is_empty()));
        let (_, resp) = roundtrip_response(&Response::Metas(vec![]));
        assert!(matches!(resp, Response::Metas(v) if v.is_empty()));
    }

    #[test]
    fn varint_boundaries_roundtrip() {
        for v in [0u64, 127, 128, 16_383, 16_384, u32::MAX as u64, u64::MAX] {
            let mut f = Frame::new();
            f.put_varint(v);
            let body = f.to_body_bytes();
            let mut r = WireReader::new(&body);
            assert_eq!(r.get_varint().unwrap(), v, "varint {v}");
            r.expect_end().unwrap();
        }
    }

    #[test]
    fn truncated_frames_are_rejected_at_every_cut() {
        // any prefix of a valid body must decode to an error, never panic
        let body = encode_request(
            1,
            0,
            &Request::CommitOutput {
                path: "/ckpt/x".into(),
                meta: meta(3),
                data: vec![1u8; 3].into(),
                stamped: false,
            },
        )
        .to_body_bytes();
        let mut it = PathInterner::default();
        for cut in 0..body.len() {
            assert!(
                decode_request(&body[..cut], &mut it).is_err(),
                "cut at {cut} must fail"
            );
        }
        // repair transfer frames: payload length prefixes under the knife
        let body = encode_request(
            7,
            2,
            &Request::InstallPartition {
                pid: 5,
                blob: vec![2u8; 32].into(),
            },
        )
        .to_body_bytes();
        for cut in 0..body.len() {
            assert!(
                decode_request(&body[..cut], &mut it).is_err(),
                "install cut at {cut} must fail"
            );
        }
        let body = encode_response(
            8,
            &Response::PartitionData {
                blob: vec![3u8; 32].into(),
            },
        )
        .to_body_bytes();
        for cut in 0..body.len() {
            assert!(
                decode_response(&body[..cut], &mut it).is_err(),
                "partition-data cut at {cut} must fail"
            );
        }
        let resp = Response::FilesData(vec![(
            "/p".into(),
            FileFetch::Data {
                stored: vec![9u8; 64].into(),
            },
        )]);
        let body = encode_response(2, &resp).to_body_bytes();
        for cut in 0..body.len() {
            assert!(
                decode_response(&body[..cut], &mut it).is_err(),
                "cut at {cut} must fail"
            );
        }
        // health-probe frames under the knife too: the fixed-width epoch
        // must be rejected at every partial width
        let body = encode_request(3, 1, &Request::Ping { epoch: 0xAB }).to_body_bytes();
        for cut in 0..body.len() {
            assert!(
                decode_request(&body[..cut], &mut it).is_err(),
                "ping cut at {cut} must fail"
            );
        }
        let body = encode_response(4, &Response::Pong { epoch: 0xCD }).to_body_bytes();
        for cut in 0..body.len() {
            assert!(
                decode_response(&body[..cut], &mut it).is_err(),
                "pong cut at {cut} must fail"
            );
        }
    }

    #[test]
    fn corrupt_frames_are_rejected() {
        let mut it = PathInterner::default();
        // wrong kind byte
        let mut body = encode_request(1, 0, &Request::Shutdown).to_body_bytes();
        body[0] = KIND_RESPONSE;
        assert!(decode_request(&body, &mut it).is_err());
        // unknown tag
        let mut body = encode_request(1, 0, &Request::Shutdown).to_body_bytes();
        let tag_off = body.len() - 1;
        body[tag_off] = 0xEE;
        assert!(decode_request(&body, &mut it).is_err());
        // trailing garbage
        let mut body = encode_response(1, &Response::Ok).to_body_bytes();
        body.push(0);
        assert!(decode_response(&body, &mut it).is_err());
        // trailing garbage after a well-formed ping/pong epoch
        let mut body = encode_request(1, 0, &Request::Ping { epoch: 9 }).to_body_bytes();
        body.push(0xFF);
        assert!(decode_request(&body, &mut it).is_err());
        let mut body = encode_response(1, &Response::Pong { epoch: 9 }).to_body_bytes();
        body.push(0xFF);
        assert!(decode_response(&body, &mut it).is_err());
        // payload length pointing past the end of the frame
        let mut f = Frame::new();
        f.put_u8(KIND_RESPONSE);
        f.put_u64(1);
        f.put_u8(RESP_FILE_DATA);
        f.put_varint(10);
        f.put_u8(0);
        f.put_varint(1 << 40); // claims a petabyte payload
        assert!(decode_response(&f.to_body_bytes(), &mut it).is_err());
        // oversized length prefix is rejected before allocating
        let mut framed = Vec::new();
        framed.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        let mut cur = std::io::Cursor::new(framed);
        assert!(read_frame(&mut cur).is_err());
    }

    /// A corrupt batch count larger than the remaining frame bytes could
    /// possibly encode must be rejected *before* any element vector is
    /// reserved — on every one of the five batched arms.
    #[test]
    fn hostile_batch_counts_are_rejected_before_allocation() {
        let mut it = PathInterner::default();
        let huge = u32::MAX as u64; // ~4 G elements claimed in a tiny body
        for tag in [REQ_READ_FILES, REQ_STAT_OUTPUTS] {
            let mut f = Frame::new();
            f.put_u8(KIND_REQUEST);
            f.put_u64(1);
            f.put_u32(0);
            f.put_u8(tag);
            f.put_varint(huge);
            f.put_slice(&[0; 8]); // 8 tail bytes cannot back 4G paths
            let err = decode_request(&f.to_body_bytes(), &mut it).unwrap_err();
            assert!(matches!(err, FanError::Format(_)), "tag {tag}: {err:?}");
        }
        for tag in [RESP_FILES_DATA, RESP_METAS, RESP_NAMES] {
            let mut f = Frame::new();
            f.put_u8(KIND_RESPONSE);
            f.put_u64(1);
            f.put_u8(tag);
            f.put_varint(huge);
            f.put_slice(&[0; 8]);
            let err = decode_response(&f.to_body_bytes(), &mut it).unwrap_err();
            assert!(matches!(err, FanError::Format(_)), "tag {tag}: {err:?}");
        }
    }

    /// Degenerate-but-valid batches (many empty names) still decode: the
    /// count guard keys off minimum *encoded* size, not decoded width.
    #[test]
    fn degenerate_empty_name_batches_still_decode() {
        let mut it = PathInterner::default();
        let names: Vec<String> = vec![String::new(); 64];
        let body = encode_response(7, &Response::Names(names.clone())).to_body_bytes();
        let (corr, resp) = decode_response(&body, &mut it).unwrap();
        assert_eq!(corr, 7);
        assert_eq!(resp, Response::Names(names));
    }

    /// A length prefix just under MAX_FRAME over a stream that delivers
    /// only a few bytes must fail from the short read, without ever
    /// allocating the claimed gigabyte (the incremental read stops at the
    /// first starved chunk; the byte bound itself is asserted under the
    /// counting allocator in tests/fuzz_corpus.rs).
    #[test]
    fn max_frame_adjacent_prefix_fails_cheaply_on_short_stream() {
        for claimed in [MAX_FRAME, MAX_FRAME - 1, MAX_FRAME / 2] {
            let mut framed = Vec::new();
            framed.extend_from_slice(&claimed.to_le_bytes());
            framed.extend_from_slice(&[0xAA; 64]); // stream dies after 64 B
            let mut cur = std::io::Cursor::new(framed);
            let err = read_frame(&mut cur).unwrap_err();
            assert!(matches!(err, FanError::Transport(_)), "got {err:?}");
        }
    }

    #[test]
    fn unknown_codec_byte_is_rejected_at_decode() {
        // a FileData frame whose codec id is outside 0..=9 must error, not
        // decode into a payload nobody can interpret
        let mut it = PathInterner::default();
        let mut f = Frame::new();
        f.put_u8(KIND_RESPONSE);
        f.put_u64(1);
        f.put_u8(RESP_FILE_DATA);
        f.put_varint(8);
        f.put_u8(0x7F); // not a codec id
        f.put_varint(3);
        f.put_slice(&[1, 2, 3]);
        let err = decode_response(&f.to_body_bytes(), &mut it).unwrap_err();
        assert!(matches!(err, FanError::Codec(_)), "got {err:?}");
        // same guard on the batched fetch arm
        let mut f = Frame::new();
        f.put_u8(KIND_RESPONSE);
        f.put_u64(2);
        f.put_u8(RESP_FILES_DATA);
        f.put_varint(1);
        f.put_str("/p");
        f.put_u8(FETCH_DATA);
        f.put_varint(8);
        f.put_u8(0xEE);
        f.put_varint(1);
        f.put_slice(&[0]);
        assert!(decode_response(&f.to_body_bytes(), &mut it).is_err());
    }

    #[test]
    fn compressed_payloads_ride_the_wire_compressed() {
        // encode a genuinely LZSS-compressed file: the frame carries the
        // small representation, and the decoded handle still knows how to
        // expand it on the consuming side
        let raw = vec![0x5Au8; 8192];
        let codec = Codec::Lzss(5);
        let stored = codec.compress(&raw).expect("compressible");
        assert!(stored.len() < raw.len() / 4);
        let payload = Payload::compressed(codec, raw.len() as u64, stored.clone().into());
        let frame = encode_response(7, &Response::FileData { stored: payload });
        // the frame body carries stored bytes, not raw bytes
        assert!(frame.body_len() < raw.len() / 2, "wire must stay compressed");
        let (_, resp) =
            decode_response(&frame.to_body_bytes(), &mut PathInterner::default()).unwrap();
        let got = resp.into_file_data().unwrap();
        assert_eq!(got.codec(), codec);
        assert_eq!(got.raw_len(), raw.len() as u64);
        assert_eq!(&got[..], &stored[..]);
        assert_eq!(got.codec().decompress(&got, raw.len()).unwrap(), raw);

        // and through the batched arm
        let payload = Payload::compressed(codec, raw.len() as u64, stored.clone().into());
        let resp = Response::FilesData(vec![("/d/f".into(), FileFetch::Data { stored: payload })]);
        let body = encode_response(8, &resp).to_body_bytes();
        let (_, decoded) = decode_response(&body, &mut PathInterner::default()).unwrap();
        match decoded {
            Response::FilesData(files) => {
                let fetch = files.into_iter().next().unwrap().1;
                let got = fetch.into_result("/d/f").unwrap();
                assert_eq!(got.codec(), codec);
                assert_eq!(got.codec().decompress(&got, raw.len()).unwrap(), raw);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn truncated_compressed_frame_fails_at_decompress_not_decode() {
        // wire framing cannot see inside the compressed stream: a payload
        // cut short still decodes as a frame, but the codec must reject it
        let raw = vec![0x33u8; 4096];
        let codec = Codec::Lzss(5);
        let stored = codec.compress(&raw).expect("compressible");
        let cut = &stored[..stored.len() - 1];
        let payload = Payload::compressed(codec, raw.len() as u64, cut.to_vec().into());
        let body = encode_response(9, &Response::FileData { stored: payload }).to_body_bytes();
        let (_, resp) = decode_response(&body, &mut PathInterner::default()).unwrap();
        let got = resp.into_file_data().unwrap();
        assert!(got.codec().decompress(&got, raw.len()).is_err());
    }

    #[test]
    fn framing_roundtrips_over_a_stream() {
        let frame = encode_response(
            99,
            &Response::FileData {
                stored: vec![5u8; 1000].into(),
            },
        );
        let mut buf = Vec::new();
        frame.write_to(&mut buf).unwrap();
        assert_eq!(buf.len(), frame.body_len() + 4);
        let mut cur = std::io::Cursor::new(buf);
        let body = read_frame(&mut cur).unwrap();
        let (corr, resp) = decode_response(&body, &mut PathInterner::default()).unwrap();
        assert_eq!(corr, 99);
        let data = resp.into_file_data().unwrap();
        assert_eq!(&data[..], &[5u8; 1000]);
    }

    /// Writer that accepts at most `max` bytes per call — forces the
    /// vectored write loop through every partial-write path.
    struct ShortWriter {
        out: Vec<u8>,
        max: usize,
    }

    impl Write for ShortWriter {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            let n = buf.len().min(self.max);
            self.out.extend_from_slice(&buf[..n]);
            Ok(n)
        }

        fn write_vectored(&mut self, bufs: &[IoSlice<'_>]) -> std::io::Result<usize> {
            let mut left = self.max;
            let mut written = 0;
            for b in bufs {
                if left == 0 {
                    break;
                }
                let n = b.len().min(left);
                self.out.extend_from_slice(&b[..n]);
                written += n;
                left -= n;
            }
            Ok(written)
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    fn sample_frames() -> Vec<Frame> {
        let mut frames = Vec::new();
        for i in 0..40u64 {
            frames.push(encode_request(
                i,
                0,
                &Request::StatOutput {
                    path: format!("/ckpt/shard_{i:03}.bin").into(),
                },
            ));
        }
        // a payload larger than the test coalescing capacity: must write
        // through (and stay in order relative to the buffered frames)
        frames.push(encode_response(
            99,
            &Response::FileData {
                stored: vec![0xAB; 4096].into(),
            },
        ));
        for i in 40..60u64 {
            frames.push(encode_request(i, 1, &Request::ReadFile {
                path: format!("/f{i}").into(),
            }));
        }
        frames
    }

    fn decode_stream(mut bytes: &[u8]) -> Vec<Vec<u8>> {
        let mut bodies = Vec::new();
        while !bytes.is_empty() {
            let mut cur = std::io::Cursor::new(bytes);
            let body = read_frame(&mut cur).expect("well-formed stream");
            let consumed = cur.position() as usize;
            bytes = &bytes[consumed..];
            bodies.push(body);
        }
        bodies
    }

    #[test]
    fn vectored_write_survives_short_writes() {
        for frame in sample_frames() {
            for max in [1usize, 3, 7, 64] {
                let mut w = ShortWriter { out: Vec::new(), max };
                frame.write_to(&mut w).unwrap();
                let mut flat = Vec::new();
                flat.extend_from_slice(&(frame.body_len() as u32).to_le_bytes());
                flat.extend_from_slice(&frame.to_body_bytes());
                assert_eq!(w.out, flat, "short-write max {max}");
            }
        }
    }

    #[test]
    fn coalesced_and_per_frame_sends_decode_identically() {
        let frames = sample_frames();
        // per-frame: every frame flushed on its own
        let mut per_frame: Vec<u8> = Vec::new();
        for f in &frames {
            f.write_to(&mut per_frame).unwrap();
        }
        // coalesced: writers stay queued until the last frame
        let mut cw = CoalescingWriter::with_capacity(Vec::new(), 512);
        for (i, f) in frames.iter().enumerate() {
            cw.write_frame(f, i + 1 != frames.len()).unwrap();
        }
        let (sent, flushes) = cw.counts();
        assert_eq!(sent, frames.len() as u64);
        assert!(
            flushes < sent,
            "coalescing must batch small frames: {flushes} flushes for {sent} frames"
        );
        let coalesced = cw.inner;
        assert_eq!(coalesced, per_frame, "byte-identical streams");
        let a = decode_stream(&per_frame);
        let b = decode_stream(&coalesced);
        assert_eq!(a.len(), frames.len());
        assert_eq!(a, b);
        // the decoded sequence is the original frames, in order
        for (frame, body) in frames.iter().zip(&a) {
            assert_eq!(&frame.to_body_bytes(), body);
        }
    }

    #[test]
    fn coalesced_sends_through_a_short_writer_stay_intact() {
        let frames = sample_frames();
        let mut cw = CoalescingWriter::with_capacity(
            ShortWriter { out: Vec::new(), max: 5 },
            512,
        );
        for (i, f) in frames.iter().enumerate() {
            cw.write_frame(f, i + 1 != frames.len()).unwrap();
        }
        let out = cw.get_ref().out.clone();
        let bodies = decode_stream(&out);
        assert_eq!(bodies.len(), frames.len());
        for (frame, body) in frames.iter().zip(&bodies) {
            assert_eq!(&frame.to_body_bytes(), body);
        }
    }

    #[test]
    fn lone_frame_is_flushed_immediately() {
        // the queue-drained rule: nobody behind you -> no added latency
        let mut cw = CoalescingWriter::with_capacity(Vec::new(), 1 << 20);
        let f = encode_request(1, 0, &Request::ReadFile { path: "/x".into() });
        cw.write_frame(&f, false).unwrap();
        assert_eq!(cw.get_ref().len(), 4 + f.body_len(), "no bytes held back");
    }

    #[test]
    fn shared_payloads_are_not_copied_into_the_header() {
        // the payload handle rides as its own chunk: same backing bytes
        let payload: Payload = vec![1u8; 1 << 16].into();
        let frame = encode_response(
            1,
            &Response::FileData {
                stored: payload.clone(),
            },
        );
        let shared_ptrs: Vec<*const u8> = frame
            .chunks
            .iter()
            .filter_map(|c| match c {
                Chunk::Shared(a) => Some(a.as_slice().as_ptr()),
                Chunk::Owned(_) => None,
            })
            .collect();
        assert_eq!(shared_ptrs, vec![payload.as_slice().as_ptr()]);
    }

    #[test]
    fn decode_interns_repeated_paths_per_connection() {
        // two frames carrying the same path on one "connection" decode
        // into Arc clones of a single allocation
        let mut it = PathInterner::default();
        let body = encode_request(1, 0, &Request::ReadFile { path: "/data/f1".into() })
            .to_body_bytes();
        let (_, _, ra) = decode_request(&body, &mut it).unwrap();
        let body = encode_request(
            2,
            0,
            &Request::ReadFiles {
                paths: vec!["/data/f1".into(), "/data/f2".into(), "/data/f1".into()],
            },
        )
        .to_body_bytes();
        let (_, _, rb) = decode_request(&body, &mut it).unwrap();
        let a = match ra {
            Request::ReadFile { path } => path,
            other => panic!("unexpected {other:?}"),
        };
        let b = match rb {
            Request::ReadFiles { paths } => paths,
            other => panic!("unexpected {other:?}"),
        };
        assert!(Arc::ptr_eq(&a, &b[0]), "same path, same allocation");
        assert!(Arc::ptr_eq(&b[0], &b[2]), "within one frame too");
        assert!(!Arc::ptr_eq(&b[0], &b[1]));
        assert_eq!(it.len(), 2, "two distinct paths interned");
        // batched replies intern through the response decoder as well
        let resp = Response::FilesData(vec![
            ("/data/f1".into(), FileFetch::NotFound),
            ("/data/f3".into(), FileFetch::NotFound),
        ]);
        let body = encode_response(3, &resp).to_body_bytes();
        let (_, decoded) = decode_response(&body, &mut it).unwrap();
        match decoded {
            Response::FilesData(files) => {
                assert!(Arc::ptr_eq(&files[0].0, &a), "reply path reuses the request's");
                assert_eq!(it.len(), 3);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn interner_resets_at_capacity_but_stays_correct() {
        let mut it = PathInterner::default();
        let a = it.intern("/x");
        let b = it.intern("/x");
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(it.len(), 1);
        assert!(!it.is_empty());
        // force a reset through the public API contract: after clear,
        // old handles stay valid and new interns still round-trip
        for i in 0..100 {
            it.intern(&format!("/spam/{i}"));
        }
        let c = it.intern("/x");
        assert_eq!(&*a, &*c, "same content either side of any reset");
    }
}
