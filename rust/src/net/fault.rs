//! Deterministic fault injection: a [`Transport`] wrapper that makes chaos
//! replayable (PR 7).
//!
//! [`FaultInjector`] wraps any `Arc<dyn Transport>` and injects, per
//! message, from a seeded [`Prng`]:
//!
//! - **drops** — the request is never forwarded; the caller gets an
//!   immediate transport error (a lost packet / refused connect),
//! - **delays** — the send is held for a bounded number of milliseconds
//!   (a congested link),
//! - **resets** — the request *is* delivered but the reply channel is
//!   torn down (a connection reset mid-round-trip: the peer did the work,
//!   the caller never learns), and
//! - **whole-node kills** — [`FaultInjector::kill_node`] makes every
//!   subsequent message to that node fail like a dead host.
//!
//! Same seed + same message sequence ⇒ the exact same injected schedule,
//! recorded in an event log ([`FaultInjector::events`]) so tests can
//! assert the replay.  With all probabilities zero the wrapper is a thin
//! pass-through — the `failover/` bench sections measure exactly that
//! overhead on the healthy path.

use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::error::{FanError, Result};
use crate::net::transport::{PendingReply, Request, Transport};
use crate::util::prng::Prng;

/// Per-message fault probabilities (each rolled independently, in
/// drop → reset → delay order).
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultPlan {
    /// P(request silently dropped before the wire).
    pub drop_p: f64,
    /// P(request delivered, reply lost).
    pub reset_p: f64,
    /// P(send delayed); delay is uniform in `1..=max_delay_ms`.
    pub delay_p: f64,
    pub max_delay_ms: u64,
}

impl FaultPlan {
    /// No probabilistic faults — kills only.  The healthy-path baseline.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }
}

/// One injected fault, in injection order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultEvent {
    Dropped { to: u32 },
    Reset { to: u32 },
    Delayed { to: u32, ms: u64 },
    Killed { node: u32 },
}

/// The chaos wrapper.  See module docs.
pub struct FaultInjector {
    inner: Arc<dyn Transport>,
    plan: FaultPlan,
    rng: Mutex<Prng>,
    killed: Mutex<Vec<bool>>,
    events: Mutex<Vec<FaultEvent>>,
}

impl FaultInjector {
    pub fn new(inner: Arc<dyn Transport>, plan: FaultPlan, seed: u64) -> FaultInjector {
        let nodes = inner.node_count() as usize;
        FaultInjector {
            inner,
            plan,
            rng: Mutex::new(Prng::new(seed)),
            killed: Mutex::new(vec![false; nodes]),
            events: Mutex::new(Vec::new()),
        }
    }

    /// Make `node` unreachable: every later message to it errors like a
    /// dead host, and its pooled connections are evicted from the inner
    /// transport.  (The node's worker itself is not touched — pair with
    /// `Cluster::kill_node` to actually stop it.)
    pub fn kill_node(&self, node: u32) {
        if let Some(k) = self.killed.lock().unwrap().get_mut(node as usize) {
            *k = true;
        }
        self.inner.evict(node);
        self.events.lock().unwrap().push(FaultEvent::Killed { node });
    }

    /// The injected schedule so far, in order.
    pub fn events(&self) -> Vec<FaultEvent> {
        self.events.lock().unwrap().clone()
    }

    /// Decide this message's fate.  One lock scope so concurrent senders
    /// serialize their draws; within a single-threaded send sequence the
    /// draw order — and therefore the schedule — is fully deterministic.
    fn roll(&self, to: u32) -> Option<FaultEvent> {
        let mut rng = self.rng.lock().unwrap();
        // fixed draw count per message keeps schedules aligned across runs
        let drop_roll = rng.chance(self.plan.drop_p);
        let reset_roll = rng.chance(self.plan.reset_p);
        let delay_roll = rng.chance(self.plan.delay_p);
        let delay_ms = 1 + rng.below(self.plan.max_delay_ms.max(1));
        let ev = if drop_roll {
            Some(FaultEvent::Dropped { to })
        } else if reset_roll {
            Some(FaultEvent::Reset { to })
        } else if delay_roll {
            Some(FaultEvent::Delayed { to, ms: delay_ms })
        } else {
            None
        };
        if let Some(ev) = ev {
            self.events.lock().unwrap().push(ev);
        }
        ev
    }

    fn is_killed(&self, to: u32) -> bool {
        self.killed
            .lock()
            .unwrap()
            .get(to as usize)
            .copied()
            .unwrap_or(false)
    }
}

impl Transport for FaultInjector {
    fn node_count(&self) -> u32 {
        self.inner.node_count()
    }

    fn send(&self, from: u32, to: u32, req: Request) -> Result<PendingReply> {
        if self.is_killed(to) {
            return Err(FanError::Transport(format!("node {to} is down (killed)")));
        }
        match self.roll(to) {
            Some(FaultEvent::Dropped { .. }) => {
                Err(FanError::Transport(format!("fault: dropped send to {to}")))
            }
            Some(FaultEvent::Reset { .. }) => {
                // delivered but the reply path is torn down: forward, then
                // hand back a reply whose sender is already gone
                let _delivered = self.inner.send(from, to, req)?;
                let (tx, rx) = channel();
                drop(tx);
                Ok(PendingReply::from_channel(to, rx))
            }
            Some(FaultEvent::Delayed { ms, .. }) => {
                std::thread::sleep(Duration::from_millis(ms));
                self.inner.send(from, to, req)
            }
            _ => self.inner.send(from, to, req),
        }
    }

    fn shutdown_all(&self) {
        self.inner.shutdown_all()
    }

    fn evict(&self, node: u32) {
        self.inner.evict(node)
    }

    fn call_timeout(&self) -> Option<Duration> {
        self.inner.call_timeout()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::transport::{InProcTransport, Response};
    use std::thread;

    fn echo_cluster(n: u32) -> (Arc<dyn Transport>, Vec<thread::JoinHandle<()>>) {
        let (tp, eps) = InProcTransport::fully_connected(n);
        let handles = eps
            .into_iter()
            .map(|ep| {
                thread::spawn(move || {
                    while let Ok(msg) = ep.inbox.recv() {
                        match msg.req {
                            Request::Shutdown => break,
                            _ => msg.reply.send(Response::Ok),
                        }
                    }
                })
            })
            .collect();
        (Arc::new(tp.with_call_timeout(Duration::from_secs(5))), handles)
    }

    #[test]
    fn same_seed_same_schedule() {
        let plan = FaultPlan {
            drop_p: 0.3,
            reset_p: 0.2,
            delay_p: 0.3,
            max_delay_ms: 2,
        };
        let mut schedules = Vec::new();
        for _ in 0..2 {
            let (tp, handles) = echo_cluster(2);
            let inj = FaultInjector::new(tp.clone(), plan, 0xC4A05);
            for i in 0..40 {
                let _ = inj.call(0, 1, Request::ReadFile {
                    path: format!("/f{i}").into(),
                });
            }
            schedules.push(inj.events());
            tp.shutdown_all();
            for h in handles {
                h.join().unwrap();
            }
        }
        assert!(!schedules[0].is_empty(), "0.8 fault mass must fire in 40 sends");
        assert_eq!(schedules[0], schedules[1], "same seed ⇒ same schedule");
        // a different seed produces a different schedule
        let (tp, handles) = echo_cluster(2);
        let inj = FaultInjector::new(tp.clone(), plan, 0x0DD5EED);
        for i in 0..40 {
            let _ = inj.call(0, 1, Request::ReadFile {
                path: format!("/f{i}").into(),
            });
        }
        assert_ne!(schedules[0], inj.events());
        tp.shutdown_all();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn drops_and_resets_error_kills_are_sticky_and_zero_plan_is_transparent() {
        let (tp, handles) = echo_cluster(3);
        let inj = FaultInjector::new(tp.clone(), FaultPlan::none(), 7);
        // zero plan: every call goes through
        for _ in 0..20 {
            let r = inj.call(0, 1, Request::ListOutputs { dir: "/".into() });
            assert!(matches!(r, Ok(Response::Ok)), "{r:?}");
        }
        assert!(inj.events().is_empty());
        // kill: sticky, immediate, and logged
        inj.kill_node(2);
        let err = inj.call(0, 2, Request::ListOutputs { dir: "/".into() });
        assert!(matches!(err, Err(FanError::Transport(_))), "{err:?}");
        assert_eq!(inj.events(), vec![FaultEvent::Killed { node: 2 }]);
        // a reset delivers the request but loses the reply
        let reset_only = FaultPlan {
            reset_p: 1.0,
            ..FaultPlan::none()
        };
        let inj = FaultInjector::new(tp.clone(), reset_only, 7);
        let err = inj.call(0, 1, Request::ListOutputs { dir: "/".into() });
        assert!(matches!(err, Err(FanError::Transport(_))), "{err:?}");
        assert_eq!(inj.events(), vec![FaultEvent::Reset { to: 1 }]);
        // a drop never reaches the peer
        let drop_only = FaultPlan {
            drop_p: 1.0,
            ..FaultPlan::none()
        };
        let inj = FaultInjector::new(tp.clone(), drop_only, 7);
        let err = inj.call(0, 1, Request::ListOutputs { dir: "/".into() });
        assert!(matches!(err, Err(FanError::Transport(_))), "{err:?}");
        tp.shutdown_all();
        for h in handles {
            h.join().unwrap();
        }
    }
}
